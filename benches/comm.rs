//! Comm-lane benches: the lane-priced step fold next to the exposure
//! trajectory it models.
//!
//! `plan_lane_times` is the hot inner call of every priced sweep cell
//! (throughput curves, Auto-Tempo pricing, the sim backend), so its
//! cost is benched per rig. Alongside the timings, the harness records
//! the modeled exposure trajectory — exposed collective milliseconds
//! versus batch on each multi-device rig — which is the quantity the
//! paper's §4.2 amortization argument is about: the collective is
//! batch-independent, the backward is not, so exposure must fall as
//! batch grows down to the embedding-bucket floor. CI uploads the JSON
//! as `BENCH_comm.json` and gates on its presence.

use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::graph::SchedulePlan;
use tempo::perfmodel::plan_lane_times;
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();

    // the fold itself: per-cell pricing cost on each paper rig
    for (name, cfg) in [
        ("bert-large-s128", ModelConfig::bert_large().with_seq_len(128)),
        ("bert-large-s512", ModelConfig::bert_large().with_seq_len(512)),
    ] {
        let base = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
        let over = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            let spec = gpu.spec();
            h.bench(&format!("comm/lane-times-baseline/{name}-{}", gpu.name()), || {
                std::hint::black_box(plan_lane_times(&cfg, &base, &spec, 8));
            });
            h.bench(&format!("comm/lane-times-overlapped/{name}-{}", gpu.name()), || {
                std::hint::black_box(plan_lane_times(&cfg, &over, &spec, 8));
            });
        }
    }

    // the modeled trajectory: exposure amortizes with batch on the
    // multi-device rigs (the embedding tail bucket is the floor)
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
        let spec = gpu.spec();
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
        println!("exposure trajectory on {} ×{}:", gpu.name(), spec.devices);
        for b in [1usize, 2, 4, 8, 16] {
            let lt = plan_lane_times(&cfg, &plan, &spec, b);
            println!(
                "  B={b:>2}: all-reduce {:7.3} ms, exposed {:7.3} ms, step {:7.3} ms",
                lt.comm_total * 1e3,
                lt.comm_exposed * 1e3,
                lt.step * 1e3,
            );
        }
    }

    h.write_csv("bench_results/bench_comm.csv").unwrap();
    h.write_json("bench_results/BENCH_comm.json").unwrap();
}
