//! Tensor-parallel benches: the shard-degree frontier and what the TP
//! lane adds to the hot pricing path.
//!
//! For the flagship config the harness records (a) the cost of pricing
//! sharded plans through `plan_lane_times` per degree — the TP lane
//! adds one exposure fold over the in-block collectives, and the Auto
//! search prices shard candidates at every permitted degree — (b) the
//! incremental-pricing pair on a sharded mixed placement: the full
//! `lower_step` event-tape fold vs the composed segment-chunk fold
//! that prices the same plan bit-identically (the chunk cache is keyed
//! by shard degree, so sharded plans must keep the ISSUE 8 composed/
//! full-fold speedup), and (c) the modeled shard-degree frontier on
//! the A100 box: max batch and step time at max batch per degree,
//! plus the `TpPolicy::Auto` winner — the ISSUE 10 claim in numbers.
//! CI uploads the JSON as `BENCH_tp.json` and gates the sharded
//! composed pricing within the ≥10× composed/full-fold ratio.

use tempo::autotempo::{placement_search_tp, PlacementMode, TpPolicy};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::graph::{self, CkptStyle, Lowering, Residency, SchedulePlan};
use tempo::memmodel::max_batch_for_plan;
use tempo::perfmodel::{plan_lane_times, plan_step_time};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let n = cfg.layers;
    let spec = Gpu::A100.spec();

    // pricing cost: the TP-lane fold per degree next to the unsharded
    // fold (degree 1 has an empty collective list)
    for d in [1usize, 2, 4, 8] {
        assert!(cfg.tp_permitted(d) || d == 1, "flagship dims must divide by {d}");
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); n],
            vec![Residency::Shard; n],
            true,
        )
        .with_tp(d);
        h.bench(&format!("tp/lane-times-tp{d}/bert-large-s512-a100"), || {
            std::hint::black_box(plan_lane_times(&cfg, &plan, &spec, 8));
        });
    }

    // the incremental-pricing pair on a sharded mixed placement (shard
    // the bottom half, checkpoint the rest, rewrites everywhere): full
    // event-tape fold vs the composed segment-chunk fold — the pair CI
    // holds at >= 10x, same as the unsharded ISSUE 8 gate
    let mixed = {
        let mut residency = vec![Residency::Checkpoint(CkptStyle::Overlapped); n];
        for arm in residency.iter_mut().take(n / 2) {
            *arm = Residency::Shard;
        }
        SchedulePlan::from_placement(vec![OptimizationSet::full(); n], residency, true).with_tp(4)
    };
    let fullfold = h.bench("tp/price-fullfold-tp4/bert-large-s512", || {
        std::hint::black_box(
            graph::lower_step(&cfg, &mixed, Lowering::for_model(&cfg)).summarize_step(),
        );
    });
    // re-price through the warm chunk cache: drop only the whole-plan
    // summary each iteration, so every pass pays the O(layers)
    // recombine — the cost of re-pricing an arm after a mutation
    let composed = h.bench("tp/price-composed-tp4/bert-large-s512", || {
        graph::clear_schedule_cache();
        std::hint::black_box(graph::schedule_summary(&cfg, &mixed));
    });

    // the Auto search end to end: every permitted degree's candidate
    // family enumerated, summarized, pruned and priced in one query
    h.bench("tp/auto-capacity-search/bert-large-s512-a100", || {
        std::hint::black_box(placement_search_tp(
            &cfg,
            Gpu::A100,
            PlacementMode::Joint,
            TpPolicy::Auto,
            None,
        ));
    });

    // the modeled shard-degree frontier: max batch and step time at max
    // batch per degree (the numbers behind the README worked example)
    let auto = placement_search_tp(&cfg, Gpu::A100, PlacementMode::Joint, TpPolicy::Auto, None);
    println!("shard-degree frontier on A100 ({} layers, S=512):", n);
    for d in [1usize, 2, 4, 8] {
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); n],
            vec![Residency::Shard; n],
            true,
        )
        .with_tp(d);
        let fit = max_batch_for_plan(&cfg, &plan, Gpu::A100);
        let step = if fit.max_batch > 0 {
            plan_step_time(&cfg, &plan, &spec, fit.max_batch)
        } else {
            f64::INFINITY
        };
        println!(
            "  uniform-shard tp {d}: max batch {:>3}, step at max {:8.1} ms",
            fit.max_batch,
            step * 1e3,
        );
    }
    println!(
        "  auto winner   tp {}: max batch {:>3} ({})",
        auto.tp, auto.max_batch, auto.rationale
    );
    let speedup = fullfold.mean.as_secs_f64() / composed.mean.as_secs_f64();
    println!(
        "sharded incremental pricing: full fold {:.3?} vs composed {:.3?} — {speedup:.1}x \
         (CI gates >= 10x)",
        fullfold.mean, composed.mean
    );

    h.write_csv("bench_results/bench_tp.csv").unwrap();
    h.write_json("bench_results/BENCH_tp.json").unwrap();
}
