//! Memory-model benches: the capacity queries Auto-Tempo runs in its
//! inner search loop must be cheap (they are pure arithmetic).

use tempo::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use tempo::memmodel::{layer_activation_bytes, max_batch, ModelFootprint};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large512 = ModelConfig::bert_large().with_seq_len(512);

    h.bench("layer_inventory/bert-large-s512", || {
        std::hint::black_box(layer_activation_bytes(&large512, 8, OptimizationSet::full()));
    });

    h.bench("breakdown/bert-large-s512", || {
        let fp = ModelFootprint::new(large512.clone(), Technique::Tempo);
        std::hint::black_box(fp.breakdown(8));
    });

    h.bench("max_batch_search/bert-large-s512-2080ti", || {
        std::hint::black_box(max_batch(&large512, Technique::Tempo, Gpu::Rtx2080Ti));
    });

    h.bench("max_batch_search/all-techniques-all-gpus", || {
        for tech in Technique::all() {
            for gpu in Gpu::all() {
                std::hint::black_box(max_batch(&large512, tech, gpu));
            }
        }
    });

    h.bench("table2/full-regeneration", || {
        std::hint::black_box(tempo::memmodel::table2());
    });

    h.write_csv("bench_results/bench_memmodel.csv").unwrap();
}
