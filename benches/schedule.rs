//! Execution-schedule benches: lowering, liveness-fold and search cost.
//!
//! The schedule refactor routes every capacity query through
//! `graph::schedule_summary` — a memoized, batch-free fold over the
//! lowered fwd+bwd event timeline. This bench gives that cost a
//! trajectory next to PR 3's `BENCH_graph.json`: cold lowering (builds
//! the event/tensor vectors for the whole model chain), the memoized
//! hot path every sweep cell pays, the full timeline fold at a
//! concrete batch (what `tempo schedule` renders), and the max-batch
//! binary search Auto-Tempo and Table 2 run per cell. The sweep-shaped
//! loop mirrors `BENCH_graph.json`'s `pricing/sweep-16x4` case so the
//! "memoized schedule pricing stays within ~2× of block-summary
//! pricing" acceptance bound has a measured artifact. CI uploads the
//! JSON as `BENCH_schedule.json`.

use tempo::autotempo::fine_search;
use tempo::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use tempo::graph::{self, Lowering, SchedulePlan};
use tempo::memmodel::max_batch;
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large512 = ModelConfig::bert_large().with_seq_len(512);
    let lowering = Lowering::for_model(&large512);
    let tempo_plan = SchedulePlan::for_technique(&large512, Technique::Tempo, true);
    let ck_plan = SchedulePlan::for_technique(&large512, Technique::Checkpoint, true);

    // cold path: build the whole-model event timeline + batch-free fold
    h.bench("schedule/lower-cold/bert-large-s512", || {
        let s = graph::lower_step(&large512, &tempo_plan, lowering);
        std::hint::black_box(s.summarize_step());
    });
    h.bench("schedule/lower-cold-checkpoint/bert-large-s512", || {
        let s = graph::lower_step(&large512, &ck_plan, lowering);
        std::hint::black_box(s.summarize_step());
    });

    // hot path: the memoized Arc lookup every sweep cell pays
    graph::schedule_summary(&large512, &tempo_plan); // warm
    h.bench("schedule/summary-memoized/bert-large-s512", || {
        std::hint::black_box(graph::schedule_summary(&large512, &tempo_plan));
    });

    // the concrete-batch liveness fold `tempo schedule` renders
    let schedule = graph::lower_step(&large512, &tempo_plan, lowering);
    h.bench("schedule/timeline-fold-b8/bert-large-s512", || {
        std::hint::black_box(schedule.timeline(8).peak_bytes);
    });

    // Table 2-style cell: max batch binary-searched against the
    // timeline peak (≈40 memoized peak queries)
    h.bench("schedule/max-batch-cell/bert-large-s512-2080ti", || {
        std::hint::black_box(max_batch(&large512, Technique::Tempo, Gpu::Rtx2080Ti));
    });

    // sweep-shaped loop: 16 subsets × 4 batches priced through the
    // schedule — the direct counterpart of BENCH_graph.json's
    // pricing/sweep-16x4 case (acceptance: within ~2× of it)
    let subsets = OptimizationSet::all_subsets();
    for &opts in &subsets {
        graph::schedule_summary(&large512, &SchedulePlan::uniform(&large512, opts, true)); // warm
    }
    h.bench("schedule/sweep-16x4/bert-large-s512", || {
        let mut acc = 0u64;
        for &opts in &subsets {
            let s = graph::schedule_summary(&large512, &SchedulePlan::uniform(&large512, opts, true));
            for batch in [1u64, 4, 8, 16] {
                acc = acc.wrapping_add(s.peak_bytes(batch));
            }
        }
        std::hint::black_box(acc);
    });

    // end-to-end fine search (binary search over prefix plans, each
    // priced against its own schedule's peak)
    h.bench("schedule/fine-search/bert-large-s512-2080ti", || {
        std::hint::black_box(fine_search(&large512, Gpu::Rtx2080Ti, 3));
    });

    println!("schedule cache holds {} lowered step schedules", graph::schedule_cache_len());
    h.write_csv("bench_results/bench_schedule.csv").unwrap();
    h.write_json("bench_results/BENCH_schedule.json").unwrap();
}
