//! Offload benches: the residency frontier across the three plan
//! families, on the paper's three rigs.
//!
//! For each rig × flagship config the harness records (a) the cost of
//! pricing an all-offload plan through `plan_lane_times` — the host
//! lane adds two transfer folds per offloaded layer to the hot pricing
//! path, and the joint search now prices hundreds of offload
//! candidates per query — and (b) the modeled frontier itself: max
//! batch and step time at max batch for rewrites-only, uniform serial
//! checkpointing, all-offload, and the joint `placement_search` winner.
//! The frontier is the ISSUE 7 claim in numbers: offload holds
//! near-constant device-side activation memory, so its max batch tops
//! the checkpoint families on the memory-bound rigs while its exposed
//! host-link tail prices the throughput cost of getting there. CI
//! uploads the JSON as `BENCH_offload.json` and gates on its presence.

use tempo::autotempo::{placement_search, LayerPlan, PlacementMode};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::graph::{CkptStyle, Residency, SchedulePlan};
use tempo::memmodel::max_batch_for_plan;
use tempo::perfmodel::{plan_lane_times, plan_step_time};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let n = cfg.layers;

    // the plan families on the frontier
    let rewrites = LayerPlan::uniform(n, OptimizationSet::full()).schedule_plan();
    let serial = LayerPlan::uniform_checkpoint(n, CkptStyle::Serial).schedule_plan();
    let offload = SchedulePlan::from_placement(
        vec![OptimizationSet::full(); n],
        vec![Residency::Offload; n],
        true,
    );

    // pricing cost: the host-lane fold next to the offload-free fold
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100, Gpu::A100] {
        let spec = gpu.spec();
        h.bench(&format!("offload/lane-times-rewrites/{}", gpu.name()), || {
            std::hint::black_box(plan_lane_times(&cfg, &rewrites, &spec, 8));
        });
        h.bench(&format!("offload/lane-times-all-offload/{}", gpu.name()), || {
            std::hint::black_box(plan_lane_times(&cfg, &offload, &spec, 8));
        });
    }

    // the joint search with the offload arms in the candidate family —
    // the end-to-end cost a capacity query now pays
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100, Gpu::A100] {
        h.bench(&format!("offload/joint-capacity-search/{}", gpu.name()), || {
            std::hint::black_box(placement_search(&cfg, gpu, PlacementMode::Joint, None));
        });
    }

    // the modeled frontier: max batch and step time at max batch per
    // family per rig (the numbers behind the README worked example)
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100, Gpu::A100] {
        let spec = gpu.spec();
        let joint = placement_search(&cfg, gpu, PlacementMode::Joint, None);
        println!("residency frontier on {} ({} layers, S=512):", gpu.name(), n);
        for (family, plan) in [
            ("rewrites", &rewrites),
            ("serial-ckpt", &serial),
            ("all-offload", &offload),
            ("joint-winner", &joint.plan.schedule_plan()),
        ] {
            let fit = max_batch_for_plan(&cfg, plan, gpu);
            let step = if fit.max_batch > 0 {
                plan_step_time(&cfg, plan, &spec, fit.max_batch)
            } else {
                f64::INFINITY
            };
            println!(
                "  {family:>12}: max batch {:>3}, step at max {:8.1} ms",
                fit.max_batch,
                step * 1e3,
            );
        }
    }

    h.write_csv("bench_results/bench_offload.csv").unwrap();
    h.write_json("bench_results/BENCH_offload.json").unwrap();
}
