//! Graph-IR benches: lowering and sweep-pricing cost.
//!
//! The layer-graph refactor routes every capacity/roofline query through
//! `graph::` block summaries, memoized per (block, dims, lowering,
//! rewrite set). This bench gives that cost a trajectory: cold lowering
//! (allocates the op/tensor vectors), the memoized hot path (what sweeps
//! actually pay), and the end-to-end pricing loops that Table 2 /
//! Auto-Tempo run thousands of times. CI uploads the JSON as
//! `BENCH_graph.json`.

use tempo::autotempo::{fine_search, LayerPlan};
use tempo::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use tempo::graph;
use tempo::memmodel::{layer_activation_bytes, max_batch};
use tempo::perfmodel::step_census;
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large512 = ModelConfig::bert_large().with_seq_len(512);

    // cold path: full lowering + fold, no cache
    h.bench("lowering/cold/bert-large-s512", || {
        let g = graph::encoder_block(&large512);
        std::hint::black_box(g.summarize(OptimizationSet::full()));
    });

    // hot path: the memoized Arc lookup every sweep cell pays
    graph::encoder_summary(&large512, OptimizationSet::full()); // warm
    h.bench("lowering/memoized/bert-large-s512", || {
        std::hint::black_box(graph::encoder_summary(&large512, OptimizationSet::full()));
    });

    // the memmodel fold (graph-backed layer_activation_bytes)
    h.bench("pricing/layer-bytes/bert-large-s512", || {
        std::hint::black_box(layer_activation_bytes(&large512, 8, OptimizationSet::full()));
    });

    // the perfmodel fold (graph-backed step census)
    h.bench("pricing/step-census/bert-large-s512", || {
        std::hint::black_box(step_census(&large512, Technique::Tempo, 8));
    });

    // Table 2-style cell: binary-search max batch (≈40 breakdowns)
    h.bench("pricing/max-batch-cell/bert-large-s512-2080ti", || {
        std::hint::black_box(max_batch(&large512, Technique::Tempo, Gpu::Rtx2080Ti));
    });

    // sweep-shaped loop: 16 subsets × 4 batches — the grid Fig 12 and
    // the fine search re-price constantly
    let subsets = OptimizationSet::all_subsets();
    h.bench("pricing/sweep-16x4/bert-large-s512", || {
        let mut acc = 0u64;
        for &opts in &subsets {
            for batch in [1usize, 4, 8, 16] {
                acc = acc.wrapping_add(layer_activation_bytes(&large512, batch, opts).total());
            }
        }
        std::hint::black_box(acc);
    });

    // mixed per-layer plan pricing (Auto-Tempo's inner loop)
    let plan = LayerPlan::rewrites_only(
        (0..large512.layers).map(|l| subsets[l % subsets.len()]).collect(),
    );
    h.bench("pricing/mixed-plan/bert-large-s512", || {
        std::hint::black_box(plan.total_bytes(&large512, 4));
    });

    // end-to-end fine search (binary search over prefix plans)
    h.bench("autotempo/fine-search/bert-large-s512-2080ti", || {
        std::hint::black_box(fine_search(&large512, Gpu::Rtx2080Ti, 3));
    });

    println!("graph cache holds {} lowered blocks", graph::cache_len());
    h.write_csv("bench_results/bench_graph.csv").unwrap();
    h.write_json("bench_results/BENCH_graph.json").unwrap();
}
