//! End-to-end L3 hot-path bench: training-step dispatch latency per
//! artifact variant on the sim backend (host-side coordinator cost —
//! data pipeline, state shuttling, ABI bookkeeping), plus the data
//! pipeline in isolation. With `--features pjrt` and on-disk artifacts
//! this is the profile the §Perf pass iterates on (see EXPERIMENTS.md
//! §Perf); the sim numbers isolate the coordinator overhead that the
//! PJRT numbers include.

use tempo::config::TrainingConfig;
use tempo::coordinator::{Trainer, TrainerOptions};
use tempo::data::{Corpus, CorpusConfig, MlmBatcher, MlmConfig};
use tempo::runtime::{ArtifactIndex, SimBackend};
use tempo::util::BenchHarness;

fn main() {
    let index = ArtifactIndex::load_or_builtin("artifacts");
    let backend = SimBackend::new();
    let mut h = BenchHarness::heavy();

    // data pipeline alone
    let corpus = Corpus::new(CorpusConfig::default(), 1);
    let mut batcher = MlmBatcher::new(corpus, MlmConfig::default(), 8, 64, 2);
    h.bench("data/mlm-batch-8x64", || {
        std::hint::black_box(batcher.next_batch().unwrap());
    });

    // full train-step dispatch per variant
    for name in ["bert_tiny_baseline", "bert_tiny_checkpoint", "bert_tiny_tempo"] {
        let artifact = index.open(name).unwrap();
        let cfg = TrainingConfig { artifact: name.into(), steps: 1, ..Default::default() };
        let mut trainer = Trainer::new(&backend, artifact, cfg, TrainerOptions::default()).unwrap();
        h.bench(&format!("sim_step/{name}"), || {
            trainer.step().unwrap();
        });
    }

    // the bigger e2e model
    if let Ok(artifact) = index.open("bert_mini_tempo") {
        let cfg = TrainingConfig { artifact: "bert_mini_tempo".into(), steps: 1, ..Default::default() };
        let mut trainer = Trainer::new(&backend, artifact, cfg, TrainerOptions::default()).unwrap();
        h.bench("sim_step/bert_mini_tempo", || {
            trainer.step().unwrap();
        });
    }

    // eval step (params only, no optimizer)
    let artifact = index.open("bert_tiny_tempo").unwrap();
    let cfg = TrainingConfig { artifact: "bert_tiny_tempo".into(), steps: 1, ..Default::default() };
    let mut trainer = Trainer::new(&backend, artifact, cfg, TrainerOptions::default()).unwrap();
    h.bench("sim_eval/bert_tiny_tempo", || {
        trainer.evaluate().unwrap();
    });

    // the real §Perf numbers: PJRT step latency per variant (feature +
    // on-disk artifacts required; silently skipped otherwise)
    #[cfg(feature = "pjrt")]
    {
        use tempo::runtime::PjrtBackend;
        if index.is_builtin() {
            eprintln!("artifacts/ missing — run `make artifacts` for the PJRT step bench");
        } else {
            let pjrt = PjrtBackend::cpu().expect("PJRT CPU client");
            for name in ["bert_tiny_baseline", "bert_tiny_checkpoint", "bert_tiny_tempo"] {
                let artifact = index.open(name).unwrap();
                let cfg = TrainingConfig { artifact: name.into(), steps: 1, ..Default::default() };
                let mut trainer =
                    Trainer::new(&pjrt, artifact, cfg, TrainerOptions::default()).unwrap();
                h.bench(&format!("train_step/{name}"), || {
                    trainer.step().unwrap();
                });
            }
        }
    }

    h.write_csv("bench_results/bench_runtime_step.csv").unwrap();
}
