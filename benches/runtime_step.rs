//! End-to-end L3 hot-path bench: real PJRT training-step latency per
//! artifact variant, plus the data pipeline and the host↔device
//! conversion costs in isolation. This is the profile the §Perf pass
//! iterates on (see EXPERIMENTS.md §Perf).

use tempo::config::TrainingConfig;
use tempo::coordinator::{Trainer, TrainerOptions};
use tempo::data::{Corpus, CorpusConfig, MlmBatcher, MlmConfig};
use tempo::runtime::{ArtifactIndex, Runtime};
use tempo::util::BenchHarness;

fn main() {
    let Ok(index) = ArtifactIndex::load("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping runtime bench");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let mut h = BenchHarness::heavy();

    // data pipeline alone
    let corpus = Corpus::new(CorpusConfig::default(), 1);
    let mut batcher = MlmBatcher::new(corpus, MlmConfig::default(), 8, 64, 2);
    h.bench("data/mlm-batch-8x64", || {
        std::hint::black_box(batcher.next_batch().unwrap());
    });

    // full train step per variant (compile once via Trainer construction)
    for name in ["bert_tiny_baseline", "bert_tiny_checkpoint", "bert_tiny_tempo"] {
        let artifact = index.open(name).unwrap();
        let cfg = TrainingConfig { artifact: name.into(), steps: 1, ..Default::default() };
        let mut trainer = Trainer::new(&rt, artifact, cfg, TrainerOptions::default()).unwrap();
        h.bench(&format!("train_step/{name}"), || {
            trainer.step().unwrap();
        });
    }

    // the bigger e2e model
    if let Ok(artifact) = index.open("bert_mini_tempo") {
        let cfg = TrainingConfig { artifact: "bert_mini_tempo".into(), steps: 1, ..Default::default() };
        let mut trainer = Trainer::new(&rt, artifact, cfg, TrainerOptions::default()).unwrap();
        h.bench("train_step/bert_mini_tempo", || {
            trainer.step().unwrap();
        });
    }

    // eval step (params only, no optimizer)
    let artifact = index.open("bert_tiny_tempo").unwrap();
    let cfg = TrainingConfig { artifact: "bert_tiny_tempo".into(), steps: 1, ..Default::default() };
    let mut trainer = Trainer::new(&rt, artifact, cfg, TrainerOptions::default()).unwrap();
    h.bench("eval_step/bert_tiny_tempo", || {
        trainer.evaluate().unwrap();
    });

    h.write_csv("bench_results/bench_runtime_step.csv").unwrap();
}
