//! Perf-model benches: roofline evaluation and full figure sweeps.

use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::perfmodel::{step_time, throughput_at_max_batch};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large = ModelConfig::bert_large().with_seq_len(512);

    h.bench("step_time/single-eval", || {
        std::hint::black_box(step_time(&large, Technique::Tempo, &Gpu::V100.spec(), 4));
    });

    h.bench("throughput_at_max_batch/one-point", || {
        std::hint::black_box(throughput_at_max_batch(&large, Technique::Tempo, Gpu::V100));
    });

    h.bench("fig5/full-sweep", || {
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            for s in [128usize, 512] {
                let cfg = ModelConfig::bert_large().with_seq_len(s);
                for tech in Technique::all() {
                    std::hint::black_box(throughput_at_max_batch(&cfg, tech, gpu));
                }
            }
        }
    });

    h.bench("fig8/seq-sweep", || {
        let cfg12 = ModelConfig::bert_large().with_layers(12);
        for s in [512usize, 1024, 1536, 2048, 2560, 3072] {
            let cfg = cfg12.with_seq_len(s);
            for tech in Technique::all() {
                std::hint::black_box(throughput_at_max_batch(&cfg, tech, Gpu::A100));
            }
        }
    });

    h.write_csv("bench_results/bench_perfmodel.csv").unwrap();
}
