//! Placement-search benches: the joint (rewrite ∪ checkpoint) search
//! cost next to the schedule layer it folds.
//!
//! The joint search enumerates ~1.1k canonical candidate plans on
//! BERT-LARGE, summarizes each once (memoized per distinct plan —
//! DESIGN.md §Schedule), dominance-prunes before pricing, and
//! binary-searches max batch only for the survivors. This bench gives
//! each stage a trajectory: the memoized steady-state search (what a
//! sweep pays per cell), the same search with pruning disabled (the
//! cost the dominance rule removes), and the uniform-family baseline.
//! CI uploads the JSON as `BENCH_placement.json` and gates the
//! steady-state joint search against `BENCH_schedule.json`'s
//! lower-cold case so a memoization or pruning regression fails the
//! leg rather than silently multiplying sweep cost.

use tempo::autotempo::{placement_search, placement_search_with, PlacementMode};
use tempo::config::{Gpu, ModelConfig};
use tempo::graph;
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large512 = ModelConfig::bert_large().with_seq_len(512);

    // steady state: summaries memoized after the warmup iterations —
    // the per-cell cost a placement sweep actually pays
    h.bench("placement/joint-search/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            None,
        ));
    });

    // target-mode search (clamped-throughput objective)
    h.bench("placement/joint-search-target8/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            Some(8),
        ));
    });

    // pruning disabled: every candidate pays the max-batch binary
    // search — the work the dominance rule exists to avoid
    h.bench("placement/joint-search-nopruning/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search_with(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            None,
            false,
        ));
    });

    // the pre-placement family, for scale
    h.bench("placement/uniform-search/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Uniform,
            None,
        ));
    });

    let d = placement_search(&large512, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
    println!(
        "joint search funnel: {} candidates, {} pruned, {} priced; schedule cache holds {} plans",
        d.stats.enumerated,
        d.stats.pruned,
        d.stats.priced,
        graph::schedule_cache_len()
    );
    h.write_csv("bench_results/bench_placement.csv").unwrap();
    h.write_json("bench_results/BENCH_placement.json").unwrap();
}
