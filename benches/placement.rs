//! Placement-search benches: the joint (rewrite ∪ checkpoint ∪
//! offload) search cost next to the schedule layer it folds.
//!
//! The joint search enumerates ~1.5k canonical candidate plans on
//! BERT-LARGE, summarizes each once (memoized per distinct plan —
//! DESIGN.md §Schedule), dominance-prunes before pricing, and
//! binary-searches max batch only for the survivors. This bench gives
//! each stage a trajectory: the memoized steady-state search (what a
//! sweep pays per cell), the same search with pruning disabled (the
//! cost the dominance rule removes), the uniform-family baseline, the
//! cold-cache search (what the first sweep cell pays), and the
//! incremental-pricing pair — one plan priced by the full
//! `lower_step` fold vs composed from the segment-chunk cache
//! (DESIGN.md §Schedule "Segment summaries"). CI uploads the JSON as
//! `BENCH_placement.json` (cache hit/miss counters annotated onto the
//! steady-state row) and gates the steady-state joint search against
//! `BENCH_schedule.json`'s lower-cold case AND the full-fold/composed
//! ratio at ≥ 10× so a memoization, chunking or pruning regression
//! fails the leg rather than silently multiplying sweep cost.

use tempo::autotempo::{
    placement_search, placement_search_jobs, placement_search_with, PlacementMode, TpPolicy,
};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::coordinator::ExperimentEngine;
use tempo::graph::{self, CkptStyle, Lowering, Residency, SchedulePlan};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    let large512 = ModelConfig::bert_large().with_seq_len(512);

    // steady state: summaries memoized after the warmup iterations —
    // the per-cell cost a placement sweep actually pays. Counters are
    // snapshotted around the case so the annotations describe *its*
    // cache traffic, not the cold/no-pruning legs that run after it.
    let cache_base = graph::cache_stats();
    let steady = h.bench("placement/joint-search/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            None,
        ));
    });
    let steady_caches = graph::cache_stats_since(&cache_base);

    // target-mode search (clamped-throughput objective)
    h.bench("placement/joint-search-target8/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            Some(8),
        ));
    });

    // pruning disabled: every candidate pays the max-batch binary
    // search — the work the dominance rule exists to avoid
    h.bench("placement/joint-search-nopruning/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search_with(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            None,
            false,
        ));
    });

    // the pre-placement family, for scale
    h.bench("placement/uniform-search/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Uniform,
            None,
        ));
    });

    // cold caches: what the FIRST sweep cell pays — every donor plan
    // re-lowered, every composition re-folded
    h.bench("placement/joint-search-cold/bert-large-s512-2080ti", || {
        graph::clear_plan_caches();
        std::hint::black_box(placement_search(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            None,
        ));
    });

    // the incremental-pricing pair, on one representative mixed
    // placement (offload the bottom third, checkpoint the middle,
    // rewrites everywhere): the full event-tape fold vs the composed
    // segment-chunk fold that prices the same plan bit-identically
    let mixed = {
        let n = large512.layers;
        let mut residency = vec![Residency::Resident; n];
        for (l, r) in residency.iter_mut().enumerate() {
            if l < n / 3 {
                *r = Residency::Offload;
            } else if l < 2 * n / 3 {
                *r = Residency::Checkpoint(CkptStyle::Overlapped);
            }
        }
        SchedulePlan::from_placement(vec![OptimizationSet::full(); n], residency, true)
    };
    let fullfold = h.bench("placement/price-fullfold/bert-large-s512", || {
        std::hint::black_box(
            graph::lower_step(&large512, &mixed, Lowering::for_model(&large512)).summarize_step(),
        );
    });
    // re-price through the warm chunk cache: drop only the whole-plan
    // summary each iteration, so every pass pays the O(layers)
    // recombine — the cost of re-pricing an arm after a mutation
    let composed = h.bench("placement/price-composed/bert-large-s512", || {
        graph::clear_schedule_cache();
        std::hint::black_box(graph::schedule_summary(&large512, &mixed));
    });

    // the same steady-state search across 4 workers (bit-identical
    // winner — tests/incremental_pricing.rs pins it)
    let engine4 = ExperimentEngine::new(4);
    let par4 = h.bench("placement/joint-search-j4/bert-large-s512-2080ti", || {
        std::hint::black_box(placement_search_jobs(
            &large512,
            Gpu::Rtx2080Ti,
            PlacementMode::Joint,
            TpPolicy::Fixed(1),
            None,
            true,
            &engine4,
        ));
    });

    let d = placement_search(&large512, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
    println!(
        "joint search funnel: {} candidates, {} pruned, {} priced; schedule cache holds {} plans",
        d.stats.enumerated,
        d.stats.pruned,
        d.stats.priced,
        graph::schedule_cache_len()
    );
    let speedup = fullfold.mean.as_secs_f64() / composed.mean.as_secs_f64();
    println!(
        "incremental pricing: full fold {:.3?} vs composed {:.3?} — {speedup:.1}x (CI gates >= 10x)",
        fullfold.mean, composed.mean
    );
    println!(
        "parallel search: jobs-1 {:.3?} vs jobs-4 {:.3?} — {:.2}x (informational; \
         scaling depends on the runner's cores)",
        steady.mean,
        par4.mean,
        steady.mean.as_secs_f64() / par4.mean.as_secs_f64()
    );

    // cache counters scoped to the steady-state case ride on its row in
    // the JSON artifact (hit/miss are deltas; entries/bytes resident)
    for (name, s) in steady_caches {
        let row = "placement/joint-search/bert-large-s512-2080ti";
        h.annotate(row, &format!("cache_{name}_entries"), s.entries as f64);
        h.annotate(row, &format!("cache_{name}_hits"), s.hits as f64);
        h.annotate(row, &format!("cache_{name}_misses"), s.misses as f64);
        h.annotate(row, &format!("cache_{name}_approx_bytes"), s.approx_bytes as f64);
    }
    h.write_csv("bench_results/bench_placement.csv").unwrap();
    h.write_json("bench_results/BENCH_placement.json").unwrap();
}
