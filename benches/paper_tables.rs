//! Regenerate EVERY paper table/figure (the `cargo bench` entry point
//! for the reproduction harness) and time each generator.
//!
//! Output CSVs land in bench_results/<id>.csv; the rendered tables go
//! to stdout so `cargo bench | tee bench_output.txt` captures the whole
//! reproduction in one artifact.

use tempo::report::{run_experiment, ALL_EXPERIMENTS};
use tempo::util::BenchHarness;

fn main() {
    let mut h = BenchHarness::new();
    for e in ALL_EXPERIMENTS {
        let table = run_experiment(e.id).unwrap();
        println!("\n[{} — {}]", e.paper_ref, e.description);
        println!("{}", table.render());
        table.write_csv(e.id).unwrap();
        h.bench(&format!("generate/{}", e.id), || {
            std::hint::black_box(run_experiment(e.id).unwrap());
        });
    }
    h.write_csv("bench_results/bench_paper_tables.csv").unwrap();
}
