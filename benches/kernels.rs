//! Numeric-kernel benches: per-kernel ns/element and the thread-scaling
//! trajectory of the band/chunk-parallel execution paths.
//!
//! Every kernel is timed twice — on the serial engine and on an engine
//! sized to the host's cores — over buffers large enough that the
//! per-band dispatch overhead amortizes (DESIGN.md §Kernels). CI
//! uploads the JSON as `BENCH_kernels.json` and gates the best
//! serial/parallel speedup at ≥ 2× on the multi-core runner, so a
//! parallelism regression (kernels silently serializing, band sizing
//! pessimized) fails the leg instead of just slowing the backend down.
//! The end-to-end rows time a full kernel-backend training step at the
//! measured probe's toy dims — the unit `tempo autotempo --probe
//! measured` replays per candidate.

use tempo::autotempo::probe_config;
use tempo::config::{ModelConfig, Technique};
use tempo::coordinator::ExperimentEngine;
use tempo::graph::SchedulePlan;
use tempo::kernels::{gelu_bwd, gelu_fwd, layernorm_bwd, layernorm_fwd, matmul, softmax_fwd, LN_EPS};
use tempo::runtime::{init_params, step_trace, Manifest, StepBatch};
use tempo::tensor::Rng;

fn randf(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect()
}

fn main() {
    let mut h = tempo::util::BenchHarness::heavy();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = ExperimentEngine::serial();
    let par = ExperimentEngine::new(threads);
    let mut rng = Rng::new(0xBE7C);

    // engines paired per kernel: (row suffix, engine)
    let engines: [(&str, &ExperimentEngine); 2] = [("serial", &serial), ("par", &par)];

    // matmul 512x256 · 256x256 — the band-parallel workhorse
    let (m, k, n) = (512usize, 256usize, 256usize);
    let a = randf(&mut rng, m * k);
    let b = randf(&mut rng, k * n);
    for (tag, e) in engines {
        let r = h.bench(&format!("kernels/matmul-512x256x256/{tag}"), || {
            std::hint::black_box(matmul(e, &a, &b, m, k, n));
        });
        h.annotate(&r.name, "ns_per_mac", r.mean.as_nanos() as f64 / (m * k * n) as f64);
    }

    // GELU fwd/bwd over 4M elements — the chunk-parallel path
    let gx = randf(&mut rng, 1 << 22);
    let gdy = randf(&mut rng, 1 << 22);
    for (tag, e) in engines {
        let r = h.bench(&format!("kernels/gelu-fwd-4m/{tag}"), || {
            std::hint::black_box(gelu_fwd(e, &gx));
        });
        h.annotate(&r.name, "ns_per_elem", r.mean.as_nanos() as f64 / gx.len() as f64);
        let r = h.bench(&format!("kernels/gelu-bwd-4m/{tag}"), || {
            std::hint::black_box(gelu_bwd(e, &gdy, &gx));
        });
        h.annotate(&r.name, "ns_per_elem", r.mean.as_nanos() as f64 / gx.len() as f64);
    }

    // LayerNorm 4096x768 fwd + output-based bwd — band-parallel rows
    let (rows, cols) = (4096usize, 768usize);
    let lx = randf(&mut rng, rows * cols);
    let ldy = randf(&mut rng, rows * cols);
    let gamma = vec![1.0f32; cols];
    let beta = vec![0.0f32; cols];
    let f = layernorm_fwd(&serial, &lx, &gamma, &beta, rows, cols, LN_EPS);
    for (tag, e) in engines {
        let r = h.bench(&format!("kernels/layernorm-fwd-4096x768/{tag}"), || {
            std::hint::black_box(layernorm_fwd(e, &lx, &gamma, &beta, rows, cols, LN_EPS));
        });
        h.annotate(&r.name, "ns_per_elem", r.mean.as_nanos() as f64 / lx.len() as f64);
        let r = h.bench(&format!("kernels/layernorm-bwd-4096x768/{tag}"), || {
            std::hint::black_box(layernorm_bwd(e, &ldy, &f.y, &gamma, &beta, &f.rstd, rows, cols));
        });
        h.annotate(&r.name, "ns_per_elem", r.mean.as_nanos() as f64 / lx.len() as f64);
    }

    // softmax 4096x512 — the attention-probability shape
    let (srows, scols) = (4096usize, 512usize);
    let sx = randf(&mut rng, srows * scols);
    for (tag, e) in engines {
        let r = h.bench(&format!("kernels/softmax-fwd-4096x512/{tag}"), || {
            std::hint::black_box(softmax_fwd(e, &sx, srows, scols));
        });
        h.annotate(&r.name, "ns_per_elem", r.mean.as_nanos() as f64 / sx.len() as f64);
    }

    // end to end: one kernel-backend training step at the probe dims —
    // the unit the measured Auto-Tempo probe replays per candidate
    let cfg = probe_config(&ModelConfig::bert_tiny());
    let manifest = Manifest::synthetic("bench_kernels", "mlm", "tempo", "kernel", 2, &cfg, 2);
    let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
    let batch = StepBatch::synthetic(&manifest, 5);
    let mut params = init_params(&manifest, 11);
    for (tag, e) in engines {
        h.bench(&format!("kernels/step-probe-bert-tiny/{tag}"), || {
            std::hint::black_box(
                step_trace(&manifest, &plan, e, &mut params, &batch, 0, 21, 1e-3).unwrap(),
            );
        });
    }

    let by_name: std::collections::BTreeMap<String, f64> =
        h.results().iter().map(|r| (r.name.clone(), r.mean.as_secs_f64())).collect();
    let mut best = 0.0f64;
    for case in [
        "kernels/matmul-512x256x256",
        "kernels/gelu-fwd-4m",
        "kernels/layernorm-fwd-4096x768",
        "kernels/softmax-fwd-4096x512",
    ] {
        let s = by_name[&format!("{case}/serial")];
        let p = by_name[&format!("{case}/par")];
        let speedup = s / p;
        best = best.max(speedup);
        println!("{case}: {speedup:.2}x over serial at {threads} threads");
        h.annotate(&format!("{case}/par"), "speedup_vs_serial", speedup);
        h.annotate(&format!("{case}/par"), "threads", threads as f64);
    }
    println!(
        "best parallel speedup: {best:.2}x at {threads} threads \
         (CI gates >= 2x on its multi-core runner)"
    );

    h.write_csv("bench_results/bench_kernels.csv").unwrap();
    h.write_json("bench_results/BENCH_kernels.json").unwrap();
}
