//! Calibration pins: the analytical memory model must keep tracking the
//! paper's published numbers (Table 2 max-batch cells and the §4.2
//! fixed-batch GB figures). Every assertion message names the exact
//! (GPU, seq-len, technique) cell that drifted so a regression in
//! `memmodel` is immediately attributable.

use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::memmodel::{gb_at_b15, max_batch, table2, PAPER_GB_AT_B15, PAPER_TABLE2};

/// Tolerance for a Table 2 max-batch cell: max(2 sequences, 25%).
fn batch_tolerance(paper: usize) -> f64 {
    (paper as f64 * 0.25).max(2.0)
}

#[test]
fn table2_covers_the_full_paper_grid() {
    let rows = table2();
    // 6 (technique, seq) pairs × 2 GPUs
    assert_eq!(rows.len(), PAPER_TABLE2.len() * 2);
    for &(tech, s, _, _) in &PAPER_TABLE2 {
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            assert!(
                rows.iter().any(|r| r.technique == tech
                    && r.seq_len == s
                    && r.gpu == gpu),
                "Table 2 regeneration is missing the ({}, S={s}, {}) cell",
                gpu.name(),
                tech.name()
            );
        }
    }
}

#[test]
fn table2_baseline_and_tempo_pinned_to_paper() {
    for row in table2() {
        if row.technique == Technique::Checkpoint {
            continue; // bounded separately below
        }
        let tol = batch_tolerance(row.paper_batch);
        let diff = (row.model_batch as f64 - row.paper_batch as f64).abs();
        assert!(
            diff <= tol,
            "Table 2 cell ({}, S={}, {}) drifted: model max-batch {} vs paper {} \
             (|diff| {diff:.1} > tol {tol:.1})",
            row.gpu.name(),
            row.seq_len,
            row.technique.name(),
            row.model_batch,
            row.paper_batch
        );
    }
}

#[test]
fn table2_checkpoint_bounded() {
    // The byte model is optimistic for checkpointing (the paper's 4-GPU
    // PyTorch runs hit allocator fragmentation + DDP staging); pin the
    // ratio band instead of the cell value.
    for row in table2() {
        if row.technique != Technique::Checkpoint {
            continue;
        }
        let ratio = row.model_batch as f64 / row.paper_batch as f64;
        assert!(
            (1.0..=4.0).contains(&ratio),
            "Table 2 cell ({}, S={}, Checkpoint) drifted: model {} vs paper {} \
             (ratio {ratio:.2} outside [1.0, 4.0])",
            row.gpu.name(),
            row.seq_len,
            row.model_batch,
            row.paper_batch
        );
    }
}

#[test]
fn headline_two_x_batch_at_s512_pinned() {
    // Abstract: "up to 2× higher batch sizes".
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let base = max_batch(&cfg, Technique::Baseline, gpu).max_batch.max(1);
        let tempo = max_batch(&cfg, Technique::Tempo, gpu).max_batch;
        let ratio = tempo as f64 / base as f64;
        assert!(
            (1.5..=2.6).contains(&ratio),
            "headline cell ({}, S=512): Tempo/Baseline max-batch ratio {ratio:.2} \
             left the paper's ~2× band (Tempo {tempo} vs Baseline {base})",
            gpu.name()
        );
    }
}

#[test]
fn gb_at_b15_pinned_to_paper() {
    for (tech, paper) in PAPER_GB_AT_B15 {
        let got = gb_at_b15(tech);
        let rel = (got - paper).abs() / paper;
        assert!(
            rel < 0.25,
            "§4.2 fixed-batch cell (BERT-LARGE, S=128, B=15, {}) drifted: \
             model {got:.2} GB vs paper {paper} GB (rel {:.1}% > 25%)",
            tech.name(),
            100.0 * rel
        );
    }
}

#[test]
fn gb_at_b15_ordering_matches_paper() {
    // §4.2: Checkpoint < Tempo < Baseline at equal batch.
    let chk = gb_at_b15(Technique::Checkpoint);
    let tempo = gb_at_b15(Technique::Tempo);
    let base = gb_at_b15(Technique::Baseline);
    assert!(
        chk < tempo,
        "§4.2 ordering broke: Checkpoint {chk:.2} GB !< Tempo {tempo:.2} GB at B=15 S=128"
    );
    assert!(
        tempo < base,
        "§4.2 ordering broke: Tempo {tempo:.2} GB !< Baseline {base:.2} GB at B=15 S=128"
    );
}
