//! End-to-end coordinator flows on the deterministic sim backend.
//!
//! Unlike `integration_runtime.rs` (pjrt feature + on-disk artifacts),
//! everything here runs from a fresh checkout with **zero artifacts
//! present**: the builtin manifest set + `SimBackend` cover `Trainer`,
//! `compare_variants`, `finetune_trials` and the Auto-Tempo search.

use tempo::autotempo::{coarse_pass, fine_search};
use tempo::config::{Gpu, ModelConfig, TrainingConfig};
use tempo::coordinator::{
    compare_variants, finetune_trials, ExperimentEngine, Trainer, TrainerOptions,
};
use tempo::runtime::{ArtifactIndex, SimBackend};
use tempo::util::TempDir;

fn quick_cfg(artifact: &str, steps: usize) -> TrainingConfig {
    TrainingConfig {
        artifact: artifact.into(),
        steps,
        warmup_steps: 2,
        peak_lr: 2e-3,
        seed: 7,
        eval_every: 0,
        log_every: 1000,
    }
}

#[test]
fn builtin_index_needs_no_files() {
    let idx = ArtifactIndex::builtin();
    assert!(idx.is_builtin());
    for name in ["bert_tiny_baseline", "bert_tiny_tempo", "cls_tiny_tempo", "pallas_smoke"] {
        let a = idx.open(name).unwrap();
        assert!(a.is_synthetic(), "{name} should be synthetic");
    }
}

#[test]
fn trainer_runs_and_reduces_loss() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("bert_tiny_tempo").unwrap();
    let mut trainer =
        Trainer::new(&backend, artifact, quick_cfg("bert_tiny_tempo", 40), TrainerOptions::default())
            .unwrap();
    trainer.run().unwrap();
    let records = trainer.metrics().records();
    assert_eq!(records.len(), 40);
    let first = records.first().unwrap().loss;
    let last = records.last().unwrap().loss;
    assert!(last < first - 0.6, "loss did not fall: {first:.3} → {last:.3}");
    // step latency comes from the roofline model, not wall clock
    assert!(trainer.metrics().throughput() > 0.0);
}

#[test]
fn eval_returns_finite_loss_and_metric() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("bert_tiny_baseline").unwrap();
    let mut trainer = Trainer::new(
        &backend,
        artifact,
        quick_cfg("bert_tiny_baseline", 1),
        TrainerOptions::default(),
    )
    .unwrap();
    trainer.step().unwrap();
    let (loss, metric) = trainer.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "eval loss {loss}");
    assert!((0.0..=1.0).contains(&metric), "mlm token prob {metric}");
}

#[test]
fn checkpoint_resume_roundtrip() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let dir = TempDir::new().unwrap();
    let ck = dir.file("state.ck");

    let artifact = idx.open("bert_tiny_tempo").unwrap();
    let mut t1 = Trainer::new(
        &backend,
        artifact.clone(),
        quick_cfg("bert_tiny_tempo", 6),
        TrainerOptions { checkpoint_out: Some(ck.clone()), ..Default::default() },
    )
    .unwrap();
    t1.run().unwrap();

    let t2 = Trainer::new(
        &backend,
        artifact,
        quick_cfg("bert_tiny_tempo", 6),
        TrainerOptions { resume_from: Some(ck), ..Default::default() },
    )
    .unwrap();
    assert_eq!(t2.state().unwrap().step, 6);
    assert_eq!(t2.state().unwrap().params()[0], t1.state().unwrap().params()[0]);
}

#[test]
fn resume_from_mismatched_checkpoint_fails_up_front() {
    // A checkpoint saved for one config must be rejected at Trainer::new
    // with a clear message, not a confusing ABI error mid-training.
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let dir = TempDir::new().unwrap();
    let ck = dir.file("tiny.ck");

    let mut t1 = Trainer::new(
        &backend,
        idx.open("bert_tiny_tempo").unwrap(),
        quick_cfg("bert_tiny_tempo", 2),
        TrainerOptions { checkpoint_out: Some(ck.clone()), ..Default::default() },
    )
    .unwrap();
    t1.run().unwrap();

    let err = Trainer::new(
        &backend,
        idx.open("bert_mini_tempo").unwrap(),
        quick_cfg("bert_mini_tempo", 2),
        TrainerOptions { resume_from: Some(ck), ..Default::default() },
    )
    .err()
    .expect("mismatched checkpoint must be rejected");
    let msg = err.to_string();
    assert!(msg.contains("does not match artifact bert_mini_tempo"), "{msg}");
}

#[test]
fn variants_track_each_other() {
    // Fig 6a miniature: identical config/seed across variants → the sim
    // trajectories coincide (the paper reports ≤0.5% endpoint gap).
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let result = compare_variants(
        &backend,
        &idx,
        &["bert_tiny_baseline", "bert_tiny_tempo", "bert_tiny_checkpoint"],
        &quick_cfg("", 12),
        &ExperimentEngine::serial(),
        false,
    )
    .unwrap();
    assert!(result.failures.is_empty());
    assert_eq!(result.curves.len(), 3);
    assert_eq!(result.curves[0].losses.len(), 12);
    assert!(
        result.max_endpoint_rel_diff < 1e-9,
        "sim variants deviate {:.3e}",
        result.max_endpoint_rel_diff
    );
}

#[test]
fn different_data_seeds_give_different_curves() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let run = |seed: u64| {
        let mut cfg = quick_cfg("bert_tiny_tempo", 8);
        cfg.seed = seed;
        let artifact = idx.open("bert_tiny_tempo").unwrap();
        let mut t = Trainer::new(&backend, artifact, cfg, TrainerOptions::default()).unwrap();
        t.run().unwrap();
        t.metrics().records().iter().map(|r| r.loss).collect::<Vec<f64>>()
    };
    assert_ne!(run(1), run(2), "seed must perturb the trajectory");
}

#[test]
fn finetune_learns_above_chance() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("cls_tiny_tempo").unwrap();
    let result =
        finetune_trials(&backend, &artifact, 1, 50, 50, 2e-3, 11, &ExperimentEngine::serial(), false)
            .unwrap();
    let (_, med, _) = result.final_band();
    assert!(med > 0.7, "median accuracy {med:.3} not above chance");
}

#[test]
fn finetune_band_spans_trials() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("cls_tiny_baseline").unwrap();
    let result =
        finetune_trials(&backend, &artifact, 3, 20, 10, 1e-3, 5, &ExperimentEngine::serial(), false)
            .unwrap();
    assert!(result.failures.is_empty());
    assert_eq!(result.trials.len(), 3);
    for t in &result.trials {
        assert_eq!(t.accuracy.len(), 2, "eval every 10 over 20 steps");
    }
    let (lo, med, hi) = result.final_band();
    assert!(lo <= med && med <= hi);
}

#[test]
fn pallas_smoke_steps_on_sim() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("pallas_smoke").unwrap();
    assert_eq!(artifact.manifest.impl_name, "pallas");
    let mut trainer =
        Trainer::new(&backend, artifact, quick_cfg("pallas_smoke", 2), TrainerOptions::default())
            .unwrap();
    let l1 = trainer.step().unwrap();
    let l2 = trainer.step().unwrap();
    assert!(l1.is_finite() && l2.is_finite());
}

#[test]
fn autotempo_search_completes_with_zero_artifacts() {
    // Auto-Tempo profiles come from the analytical models — no runtime,
    // no artifacts. Both policies must complete and return sane plans.
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let coarse = coarse_pass(&cfg, Gpu::Rtx2080Ti);
    assert!(coarse.max_batch > 0);
    assert_eq!(coarse.plan.per_layer.len(), cfg.layers);

    let fine = fine_search(&cfg, Gpu::Rtx2080Ti, 2);
    assert!(fine.max_batch >= 2, "target batch 2 must be reachable");
    assert!(fine.plan.applied_layers() <= cfg.layers);
}

#[test]
fn modeled_step_time_orders_techniques() {
    // At equal batch the roofline model must charge checkpointing its
    // re-forward: sim baseline steps are "faster" than checkpoint steps.
    let backend = SimBackend::with_gpu(Gpu::V100);
    let idx = ArtifactIndex::builtin();
    use tempo::runtime::Backend;
    let base = backend
        .modeled_step_time(&idx.open("bert_tiny_baseline").unwrap())
        .unwrap();
    let chk = backend
        .modeled_step_time(&idx.open("bert_tiny_checkpoint").unwrap())
        .unwrap();
    assert!(chk > base, "checkpoint {chk:?} should exceed baseline {base:?}");
}
