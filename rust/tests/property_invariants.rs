//! Property-based tests over the analytical models and substrates.
//!
//! The offline build has no proptest; `cases!` drives each property over
//! hundreds of seeded-random inputs via the in-tree SplitMix64 RNG, with
//! failing inputs printed for reproduction.

use tempo::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use tempo::data::{Corpus, CorpusConfig, MlmBatcher, MlmConfig};
use tempo::graph::{schedule_summary, CkptStyle, Residency, SchedulePlan};
use tempo::memmodel::{layer_activation_bytes, max_batch, ModelFootprint};
use tempo::perfmodel::{plan_lane_times, step_time};
use tempo::tensor::Rng;
use tempo::util::Json;

/// Run `body(rng, case_index)` for `n` seeded cases.
fn cases(n: usize, seed: u64, mut body: impl FnMut(&mut Rng, usize)) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let mut case_rng = rng.fork(i as u64);
        body(&mut case_rng, i);
    }
}

/// A random plausible transformer config.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let heads = [2usize, 4, 8, 12, 16][rng.below(5)];
    let hidden = heads * 64;
    ModelConfig {
        name: "rand".into(),
        kind: tempo::config::ModelKind::Bert,
        hidden,
        layers: rng.range(1, 25),
        heads,
        seq_len: [64usize, 128, 256, 512, 1024][rng.below(5)],
        intermediate: hidden * 4,
        vocab_size: rng.range(4096, 50000),
        max_position: 1024,
        type_vocab: 2,
        dropout_p: 0.1,
    }
}

#[test]
fn prop_tempo_never_increases_footprint() {
    cases(200, 1, |rng, i| {
        let cfg = random_config(rng);
        let b = rng.range(1, 17);
        let base = layer_activation_bytes(&cfg, b, OptimizationSet::none()).total();
        for opts in OptimizationSet::all_subsets() {
            let v = layer_activation_bytes(&cfg, b, opts).total();
            assert!(v <= base, "case {i}: {cfg:?} opts {opts:?} grew {v} > {base}");
        }
        let full = layer_activation_bytes(&cfg, b, OptimizationSet::full()).total();
        assert!(full < base, "case {i}: full tempo saved nothing");
    });
}

/// Timeline peak of a uniform rewrite plan at batch `b`.
fn timeline_peak(cfg: &ModelConfig, opts: OptimizationSet, b: usize) -> u64 {
    schedule_summary(cfg, &SchedulePlan::uniform(cfg, opts, true)).peak_bytes(b as u64)
}

#[test]
fn prop_rewrites_never_increase_timeline_peak() {
    // Adding any rewrite to an OptimizationSet never *increases* the
    // execution-schedule timeline peak at fixed (config, batch): every
    // rewrite either deletes a retained tensor or swaps it for a
    // strictly narrower one, and the backward workspace is sized by the
    // widest map whether or not its forward copy was rewritten away.
    let one_of = ["gelu", "layernorm", "dropout", "softmax"];

    // every preset × all 16 subsets × each missing rewrite
    let presets = [
        ModelConfig::bert_base(),
        ModelConfig::bert_large(),
        ModelConfig::gpt2(),
        ModelConfig::roberta_large(),
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
    ];
    for cfg in &presets {
        for opts in OptimizationSet::all_subsets() {
            let base = timeline_peak(cfg, opts, 4);
            for which in one_of {
                let bigger = opts.union(OptimizationSet::only(which).unwrap());
                let v = timeline_peak(cfg, bigger, 4);
                assert!(v <= base, "{}: {opts:?} + {which} grew {v} > {base}", cfg.name);
            }
        }
    }

    // and seeded-random shapes/batches, property-test style
    cases(40, 9, |rng, i| {
        let cfg = random_config(rng);
        let b = rng.range(1, 13);
        for opts in OptimizationSet::all_subsets() {
            let base = timeline_peak(&cfg, opts, b);
            for which in one_of {
                let bigger = opts.union(OptimizationSet::only(which).unwrap());
                let v = timeline_peak(&cfg, bigger, b);
                assert!(v <= base, "case {i}: {cfg:?} B={b} {opts:?} + {which} grew");
            }
        }
    });
}

#[test]
fn prop_footprint_monotone_in_batch_and_seq() {
    cases(100, 2, |rng, i| {
        let cfg = random_config(rng);
        let fp = ModelFootprint::new(cfg.clone(), Technique::Tempo);
        let b = rng.range(1, 12);
        assert!(
            fp.total_bytes(b + 1) > fp.total_bytes(b),
            "case {i}: not monotone in batch"
        );
        if cfg.seq_len < 1024 {
            let fp2 = ModelFootprint::new(cfg.with_seq_len(cfg.seq_len * 2), Technique::Tempo);
            assert!(
                fp2.total_bytes(b) > fp.total_bytes(b),
                "case {i}: not monotone in seq"
            );
        }
    });
}

#[test]
fn prop_max_batch_fit_is_tight_and_consistent() {
    cases(60, 3, |rng, i| {
        let cfg = random_config(rng);
        let gpu = Gpu::all()[rng.below(3)];
        let tech = Technique::all()[rng.below(3)];
        let fit = max_batch(&cfg, tech, gpu);
        let budget = gpu.spec().usable_bytes();
        if fit.max_batch > 0 {
            assert!(fit.bytes_at_max <= budget, "case {i}: over budget at max");
        }
        assert!(fit.bytes_over > budget, "case {i}: max+1 still fits");
    });
}

#[test]
fn prop_step_time_monotone_in_batch() {
    cases(60, 4, |rng, i| {
        let cfg = random_config(rng);
        let gpu = Gpu::all()[rng.below(3)];
        let tech = Technique::all()[rng.below(3)];
        let b = rng.range(1, 16);
        let t1 = step_time(&cfg, tech, &gpu.spec(), b);
        let t2 = step_time(&cfg, tech, &gpu.spec(), b + 1);
        assert!(t2 > t1, "case {i}: step time fell with batch");
        // per-sequence time must not increase
        assert!(
            t2 / (b + 1) as f64 <= t1 / b as f64 * 1.0000001,
            "case {i}: per-seq time rose with batch"
        );
    });
}

#[test]
fn prop_checkpoint_always_smallest_tempo_in_between() {
    cases(80, 5, |rng, i| {
        let cfg = random_config(rng);
        let b = rng.range(1, 8);
        let base = ModelFootprint::new(cfg.clone(), Technique::Baseline).total_bytes(b);
        let tempo = ModelFootprint::new(cfg.clone(), Technique::Tempo).total_bytes(b);
        let chk = ModelFootprint::new(cfg.clone(), Technique::Checkpoint).total_bytes(b);
        assert!(tempo < base, "case {i}");
        // checkpoint wins on stored bytes once depth amortizes its
        // doubled backward transient (one full recomputed layer + grads);
        // for shallow stacks tempo can legitimately be smaller
        if cfg.layers >= 6 {
            assert!(chk < tempo, "case {i}: {cfg:?}");
        }
    });
}

#[test]
fn prop_mlm_batches_always_well_formed() {
    cases(40, 6, |rng, i| {
        let vocab = rng.range(1024, 8192);
        let seq = [16usize, 32, 64, 128][rng.below(4)];
        let bsz = rng.range(1, 9);
        let corpus = Corpus::new(CorpusConfig { vocab_size: vocab, ..Default::default() }, rng.next_u64());
        let mut gen = MlmBatcher::new(corpus, MlmConfig::default(), bsz, seq, rng.next_u64());
        for _ in 0..3 {
            let batch = gen.next_batch().unwrap();
            let ids = batch.input_ids.as_i32().unwrap();
            let labels = batch.labels.as_i32().unwrap();
            let attn = batch.attention_mask.as_i32().unwrap();
            assert_eq!(ids.len(), bsz * seq, "case {i}");
            for (j, (&t, (&l, &m))) in ids.iter().zip(labels.iter().zip(attn)).enumerate() {
                assert!((0..vocab as i32).contains(&t), "case {i} tok {j}: {t}");
                assert!(m == 0 || m == 1);
                assert!(l == -100 || (0..vocab as i32).contains(&l));
                if m == 0 {
                    assert_eq!(l, -100, "case {i}: label on padding");
                }
            }
        }
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.coin(0.5)),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let opts = ['a', 'β', '"', '\\', '\n', 'z', '7', ' '];
                        opts[rng.below(opts.len())]
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut pairs = Vec::new();
                for k in 0..rng.below(5) {
                    pairs.push((format!("k{k}"), random_json(rng, depth - 1)));
                }
                Json::Obj(pairs.into_iter().collect())
            }
        }
    }
    cases(300, 7, |rng, i| {
        let doc = random_json(rng, 3);
        let text = if rng.coin(0.5) { doc.pretty() } else { doc.to_string() };
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {i}: {e}\n{text}"));
        assert_eq!(back, doc, "case {i}");
    });
}

#[test]
fn prop_exposure_bounded_by_collective_total() {
    // the exposure fold can never expose more than the collective
    // itself takes, never goes negative, and the two lanes decompose
    // the step exactly
    cases(60, 10, |rng, i| {
        let cfg = random_config(rng);
        let gpu = Gpu::all()[rng.below(3)];
        let tech = Technique::all()[rng.below(3)];
        let b = rng.range(1, 16);
        let plan = SchedulePlan::for_technique(&cfg, tech, true);
        let lt = plan_lane_times(&cfg, &plan, &gpu.spec(), b);
        assert!(
            lt.comm_exposed >= 0.0 && lt.comm_exposed <= lt.comm_total,
            "case {i}: exposed {} ∉ [0, {}]",
            lt.comm_exposed,
            lt.comm_total
        );
        assert_eq!(
            lt.step,
            lt.compute + lt.comm_exposed + lt.host_exposed + lt.tp_exposed,
            "case {i}: lanes must sum to the step"
        );
        assert!(lt.hidden_recompute >= 0.0, "case {i}");
        let spec = gpu.spec();
        if spec.allreduce_bw.is_none() || spec.devices == 1 {
            assert_eq!(lt.comm_total, 0.0, "case {i}: no-collective rig priced comm");
        } else {
            assert!(lt.comm_total > 0.0, "case {i}: multi-device rig must pay the all-reduce");
        }
    });
}

#[test]
fn prop_exposure_monotone_in_interconnect_slowness() {
    // halving the all-reduce bandwidth lengthens every bucket, so the
    // collective total strictly grows and the exposed residual never
    // shrinks (the backward lags it hides behind are bandwidth-free)
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
    for b in [1usize, 4] {
        let mut prev_total = f64::INFINITY;
        let mut prev_exposed = f64::INFINITY;
        for bw in [5.0e9, 10.0e9, 25.0e9, 55.0e9, 300.0e9] {
            let mut spec = Gpu::V100.spec();
            spec.allreduce_bw = Some(bw);
            let lt = plan_lane_times(&cfg, &plan, &spec, b);
            assert!(lt.comm_total < prev_total, "bw {bw} B={b}: total not strictly decreasing");
            assert!(
                lt.comm_exposed <= prev_exposed,
                "bw {bw} B={b}: exposed grew as the link sped up"
            );
            prev_total = lt.comm_total;
            prev_exposed = lt.comm_exposed;
        }
    }
}

#[test]
fn single_device_lane_times_are_the_pre_lane_compute_timeline() {
    // the comm lane is strictly additive: a 1-device rig prices exactly
    // as its compute lane, and widening the rig never changes the
    // compute lane (peak and census live in the schedule summary, which
    // never sees the rig at all) — the tentpole's backward-compat pin
    let presets = [
        ModelConfig::bert_base(),
        ModelConfig::bert_large(),
        ModelConfig::gpt2(),
        ModelConfig::roberta_large(),
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
    ];
    for cfg in &presets {
        for tech in Technique::all() {
            let plan = SchedulePlan::for_technique(cfg, tech, true);
            for b in [1usize, 4, 32] {
                for gpu in Gpu::all() {
                    let spec = gpu.spec();
                    let solo = spec.with_devices(1);
                    let l1 = plan_lane_times(cfg, &plan, &solo, b);
                    let ln = plan_lane_times(cfg, &plan, &spec, b);
                    let ctx = format!("{} {tech:?} B={b} {}", cfg.name, gpu.name());
                    assert_eq!(l1.comm_total, 0.0, "{ctx}");
                    assert_eq!(l1.comm_exposed, 0.0, "{ctx}");
                    assert_eq!(l1.step, l1.compute, "{ctx}: solo step must be pure compute");
                    assert_eq!(l1.compute, ln.compute, "{ctx}: rig width leaked into compute");
                    assert_eq!(l1.hidden_recompute, ln.hidden_recompute, "{ctx}");
                    assert!(ln.step >= l1.step, "{ctx}: adding devices made the step faster");
                }
            }
        }
    }
}

/// A uniform-residency plan with no rewrites on any layer.
fn residency_plan(cfg: &ModelConfig, residency: Vec<Residency>) -> SchedulePlan {
    SchedulePlan::from_placement(vec![OptimizationSet::none(); cfg.layers], residency, true)
}

#[test]
fn prop_offload_peak_never_above_serial_checkpoint() {
    // serial checkpointing still retains each layer's stored input on
    // the device; offload frees even that at store completion and its
    // loads land in-place right before each layer's backward, so at
    // equal batch the all-offload timeline can never peak above the
    // all-serial one
    let presets = [
        ModelConfig::bert_base(),
        ModelConfig::bert_large().with_seq_len(512),
        ModelConfig::gpt2(),
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
    ];
    let check = |cfg: &ModelConfig, b: u64| {
        let n = cfg.layers;
        let off = residency_plan(cfg, vec![Residency::Offload; n]);
        let ser = residency_plan(cfg, vec![Residency::Checkpoint(CkptStyle::Serial); n]);
        let p_off = schedule_summary(cfg, &off).peak_bytes(b);
        let p_ser = schedule_summary(cfg, &ser).peak_bytes(b);
        assert!(p_off <= p_ser, "{} B={b}: offload {p_off} > serial {p_ser}", cfg.name);
    };
    for cfg in &presets {
        for b in [1u64, 4, 32] {
            check(cfg, b);
        }
    }
    cases(40, 11, |rng, _| {
        let cfg = random_config(rng);
        check(&cfg, rng.range(1, 17) as u64);
    });
}

#[test]
fn prop_offload_peak_monotone_in_offloaded_layers() {
    // offloading one more bottom layer only removes retained inventory
    // from the device timeline (the load is charged in place, where the
    // layer's own backward transient already lives), so the peak is
    // monotone non-increasing in the number of offloaded layers
    let presets = [ModelConfig::bert_mini(), ModelConfig::bert_base(), ModelConfig::bert_tiny()];
    for cfg in &presets {
        let n = cfg.layers;
        for b in [1u64, 4, 32] {
            let mut prev = u64::MAX;
            for c in 0..=n {
                let mut residency = vec![Residency::Resident; n];
                for arm in residency.iter_mut().take(c) {
                    *arm = Residency::Offload;
                }
                let peak = schedule_summary(cfg, &residency_plan(cfg, residency)).peak_bytes(b);
                assert!(
                    peak <= prev,
                    "{} B={b}: offloading layer {c} raised the peak {prev} -> {peak}",
                    cfg.name
                );
                prev = peak;
            }
        }
    }
}

#[test]
fn prop_infinite_host_link_converges_to_no_offload_compute() {
    // as the host link speeds up, every transfer window's exposure
    // max(0, d - cover) collapses to zero, and the offload plan's step
    // converges to its resident twin's pure compute time (same census,
    // no recompute, no retained-inventory difference in *time*)
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let n = cfg.layers;
    let off = residency_plan(&cfg, vec![Residency::Offload; n]);
    let res = residency_plan(&cfg, vec![Residency::Resident; n]);
    let mut spec = Gpu::Rtx2080Ti.spec().with_devices(1);
    spec.host_link_bw = 1.0e30;
    for b in [1usize, 4, 32] {
        let lt_off = plan_lane_times(&cfg, &off, &spec, b);
        let lt_res = plan_lane_times(&cfg, &res, &spec, b);
        assert!(lt_off.host_total > 0.0, "B={b}: offload plan must ship bytes");
        assert!(lt_off.host_total < 1.0e-12, "B={b}: infinite link still takes time");
        assert!(
            lt_off.host_exposed <= lt_off.host_total,
            "B={b}: exposed beyond the transfer total"
        );
        assert_eq!(lt_res.step, lt_res.compute, "B={b}: solo resident step is pure compute");
        let diff = (lt_off.step - lt_res.compute).abs();
        assert!(
            diff <= 1.0e-9 * lt_res.compute,
            "B={b}: offload step {} did not converge to compute {}",
            lt_off.step,
            lt_res.compute
        );
    }
}

#[test]
fn prop_offload_free_plans_price_a_zero_host_lane() {
    // the residency refactor is invisible to every plan that does not
    // offload: the host lane prices to exactly 0.0 and the step
    // decomposition collapses to the pre-refactor two-lane form
    // (bit-identity against the PR 6 fold is pinned in
    // tests/residency_equivalence.rs)
    let presets = [
        ModelConfig::bert_base(),
        ModelConfig::bert_large().with_seq_len(512),
        ModelConfig::gpt2(),
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
    ];
    for cfg in &presets {
        let n = cfg.layers;
        let mut plans: Vec<SchedulePlan> = Technique::all()
            .iter()
            .map(|&t| SchedulePlan::for_technique(cfg, t, true))
            .collect();
        plans.push(residency_plan(cfg, vec![Residency::Checkpoint(CkptStyle::Serial); n]));
        for plan in &plans {
            assert!(!plan.any_offload());
            for b in [1usize, 4, 32] {
                for gpu in Gpu::all() {
                    let lt = plan_lane_times(cfg, plan, &gpu.spec(), b);
                    let ctx = format!("{} B={b} {}", cfg.name, gpu.name());
                    assert_eq!(lt.host_total, 0.0, "{ctx}");
                    assert_eq!(lt.host_exposed, 0.0, "{ctx}");
                    assert_eq!(lt.step, lt.compute + lt.comm_exposed, "{ctx}");
                }
            }
        }
    }
}

/// The shard degrees `cfg`'s dimensions divide by (always includes 1).
fn permitted_degrees(cfg: &ModelConfig) -> Vec<usize> {
    [1usize, 2, 4, 8].into_iter().filter(|&d| cfg.tp_permitted(d)).collect()
}

#[test]
fn prop_peak_monotone_non_increasing_in_shard_degree() {
    // a higher permitted degree shards every per-item inventory and the
    // vocab-parallel head by a larger factor while the unsharded model
    // states stay fixed, so the per-device timeline peak can never grow
    // as the degree rises
    let check = |cfg: &ModelConfig, b: u64| {
        let n = cfg.layers;
        let mut prev = u64::MAX;
        for d in permitted_degrees(cfg) {
            let plan = residency_plan(cfg, vec![Residency::Shard; n]).with_tp(d);
            assert_eq!(plan.resolved_tp(cfg), d);
            let peak = schedule_summary(cfg, &plan).peak_bytes(b);
            assert!(
                peak <= prev,
                "{} B={b}: tp {d} raised the peak {prev} -> {peak}",
                cfg.name
            );
            prev = peak;
        }
    };
    for cfg in [ModelConfig::bert_mini(), ModelConfig::bert_large().with_seq_len(512)] {
        for b in [1u64, 4, 32] {
            check(&cfg, b);
        }
    }
    cases(40, 12, |rng, _| {
        let cfg = random_config(rng);
        check(&cfg, rng.range(1, 17) as u64);
    });
}

#[test]
fn prop_tp_exposure_monotone_in_link_slowness() {
    // a faster TP link shortens every collective, so the lane total
    // strictly falls and the per-collective unhidden tails never grow
    // (the covering compute windows are bandwidth-free)
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let n = cfg.layers;
    let plan = residency_plan(&cfg, vec![Residency::Shard; n]).with_tp(8);
    for b in [1usize, 4] {
        let mut prev_total = f64::INFINITY;
        let mut prev_exposed = f64::INFINITY;
        for bw in [10.0e9, 65.0e9, 250.0e9, 600.0e9, 2.4e12] {
            let mut spec = Gpu::A100.spec();
            spec.tp_bw = bw;
            let lt = plan_lane_times(&cfg, &plan, &spec, b);
            assert!(lt.tp_total < prev_total, "bw {bw} B={b}: total not strictly decreasing");
            assert!(
                lt.tp_exposed <= prev_exposed,
                "bw {bw} B={b}: exposure grew as the link sped up"
            );
            prev_total = lt.tp_total;
            prev_exposed = lt.tp_exposed;
        }
    }
}

#[test]
fn prop_tp_exposure_bounded_by_the_collective_total() {
    // each collective pays max(0, d − cover): never negative, never
    // more than its own raw transfer time — so the lane sum is bounded
    // by the raw total, and the four lanes decompose the step exactly
    cases(60, 13, |rng, i| {
        let cfg = random_config(rng);
        let degrees = permitted_degrees(&cfg);
        let d = degrees[rng.below(degrees.len())];
        let gpu = Gpu::all()[rng.below(3)];
        let b = rng.range(1, 16);
        let n = cfg.layers;
        let plan = residency_plan(&cfg, vec![Residency::Shard; n]).with_tp(d);
        let lt = plan_lane_times(&cfg, &plan, &gpu.spec(), b);
        assert!(
            lt.tp_exposed >= 0.0 && lt.tp_exposed <= lt.tp_total,
            "case {i}: exposed {} ∉ [0, {}]",
            lt.tp_exposed,
            lt.tp_total
        );
        assert_eq!(
            lt.step,
            lt.compute + lt.comm_exposed + lt.host_exposed + lt.tp_exposed,
            "case {i}: lanes must sum to the step"
        );
        if d > 1 {
            assert!(lt.tp_total > 0.0, "case {i}: sharded plan priced a silent tp lane");
        } else {
            assert_eq!(lt.tp_total, 0.0, "case {i}: unsharded plan priced a tp lane");
        }
    });
}

#[test]
fn prop_degree_one_pricing_is_the_pre_tp_fold() {
    // random mixed plans at shard degree 1 (Shard arms resolve to
    // Resident) price with a zero TP lane and the pre-TP three-lane
    // step decomposition, and an explicit with_tp(1) is bit-identical
    // to the default (the verbatim-oracle pin is
    // tests/tp_equivalence.rs; this is its random-plan closure)
    let arms = [
        Residency::Resident,
        Residency::Checkpoint(CkptStyle::Overlapped),
        Residency::Checkpoint(CkptStyle::Serial),
        Residency::Offload,
        Residency::Shard,
    ];
    cases(60, 14, |rng, i| {
        let cfg = random_config(rng);
        let subsets = OptimizationSet::all_subsets();
        let per_layer: Vec<OptimizationSet> =
            (0..cfg.layers).map(|_| subsets[rng.below(subsets.len())]).collect();
        let residency: Vec<Residency> =
            (0..cfg.layers).map(|_| arms[rng.below(arms.len())]).collect();
        let plan = SchedulePlan::from_placement(per_layer, residency, true);
        let b = rng.range(1, 16);
        let gpu = Gpu::all()[rng.below(3)];
        let lt = plan_lane_times(&cfg, &plan, &gpu.spec(), b);
        assert_eq!(lt.tp_total, 0.0, "case {i}");
        assert_eq!(lt.tp_exposed, 0.0, "case {i}");
        assert_eq!(
            lt.step,
            lt.compute + lt.comm_exposed + lt.host_exposed,
            "case {i}: degree-1 step must decompose over three lanes"
        );
        let explicit = plan_lane_times(&cfg, &plan.clone().with_tp(1), &gpu.spec(), b);
        assert_eq!(lt, explicit, "case {i}: with_tp(1) diverged from the default");
    });
}

#[test]
fn prop_rng_streams_are_independent() {
    cases(50, 8, |rng, _| {
        let mut a = rng.fork(1);
        let mut b = rng.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "forked streams correlate");
    });
}
