//! Shared fixtures for the equivalence and search suites.
//!
//! The preset lists and the pre-refactor closed-form byte oracles were
//! copy-pasted across `schedule_equivalence.rs`, `graph_equivalence.rs`,
//! `residency_equivalence.rs` and `placement_search.rs`; they live here
//! once. The oracles are **golden**: they are the pre-graph-refactor
//! `memmodel` closed forms verbatim, and the equivalence suites compare
//! the lowered folds against them bit-identically — do not "simplify"
//! an expression here without re-deriving why every consumer still
//! pins the same bits.

// Each integration-test crate includes this module separately and uses
// its own slice of the fixtures.
#![allow(dead_code)]

use tempo::config::{ModelConfig, ModelKind, OptimizationSet};

pub const F32: u64 = 4;
pub const MASK: u64 = 1;

/// The batch grid every bit-identity suite sweeps.
pub const BATCHES: [usize; 3] = [1, 4, 32];

/// All paper presets plus the Fig 7/8 ablation shapes (widened/long
/// variants) — the grid the closed-form equivalence suites sweep.
pub fn presets_full() -> Vec<ModelConfig> {
    vec![
        ModelConfig::bert_base(),
        ModelConfig::bert_large(),
        ModelConfig::gpt2(),
        ModelConfig::roberta_large(),
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
        // the Fig 7/8 ablation shapes exercise widened/long variants
        ModelConfig::bert_base().with_hidden(2048).unwrap(),
        ModelConfig::bert_large().with_layers(12).with_seq_len(1024),
        ModelConfig::bert_large().with_seq_len(512),
    ]
}

/// The lane-pricing grid: the small shapes plus the flagship and the
/// GPT-2 special case — every plan family gets priced on each.
pub fn presets_pricing() -> Vec<ModelConfig> {
    vec![
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
        ModelConfig::bert_base(),
        ModelConfig::bert_large().with_seq_len(512),
        ModelConfig::gpt2(),
    ]
}

/// The placement-search grid: small enough that the joint family stays
/// enumerable, plus the paper's memory-bound flagship.
pub fn presets_search() -> Vec<ModelConfig> {
    vec![
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
        ModelConfig::bert_base(),
        ModelConfig::bert_large().with_seq_len(512),
    ]
}

// ---------------------------------------------------------------------------
// Golden oracles: the pre-schedule closed forms, verbatim.
// ---------------------------------------------------------------------------

/// Per-encoder-layer (float, mask, stat) bytes — the pre-refactor
/// `memmodel::layer` closed form.
pub fn oracle_layer_bytes(
    cfg: &ModelConfig,
    batch: usize,
    opts: OptimizationSet,
) -> (u64, u64, u64) {
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let a = cfg.heads as u64;
    let i = cfg.intermediate as u64;
    let bsh = b * s * h;
    let bsi = b * s * i;
    let bass = b * a * s * s;

    let mut float_elems: u64 = 0;
    let mut mask_bytes: u64 = 0;
    let mut stat_bytes: u64 = 0;

    float_elems += bsh; // x
    float_elems += 3 * bsh; // Q, K, V
    if !opts.softmax_outonly {
        float_elems += bass; // scores
        if cfg.kind == ModelKind::Gpt2 {
            float_elems += 2 * bass; // HF unfused-attention copies
        }
    }
    float_elems += bass; // softmax output
    mask_bytes += bass * MASK; // attention dropout mask
    if !opts.dropout_recompute {
        float_elems += bass; // dropped probs
    }
    float_elems += bsh; // context
    mask_bytes += bsh * MASK; // hidden dropout mask (proj)
    if !opts.inplace_layernorm {
        float_elems += bsh; // LN1 input
        stat_bytes += 2 * b * s * F32;
    } else {
        stat_bytes += b * s * F32;
    }
    float_elems += bsh; // LN1 output
    if opts.inplace_gelu {
        mask_bytes += bsi * MASK;
    } else {
        float_elems += bsi; // GELU input
    }
    float_elems += bsi; // GELU output
    mask_bytes += bsh * MASK; // hidden dropout mask (FC2)
    if !opts.inplace_layernorm {
        float_elems += bsh; // LN2 input
        stat_bytes += 2 * b * s * F32;
    } else {
        stat_bytes += b * s * F32;
    }
    (float_elems * F32, mask_bytes, stat_bytes)
}

/// Embedding-block activation bytes (pre-refactor closed form).
pub fn oracle_embedding_bytes(cfg: &ModelConfig, opts: OptimizationSet, batch: usize) -> u64 {
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let ln_in = if opts.inplace_layernorm { 0 } else { b * s * h };
    (b * s * h + ln_in + b * s * h) * F32 + b * s * h * MASK
}

/// Head activation bytes (pre-refactor closed form; MLM vs fine-tune).
pub fn oracle_head_bytes(cfg: &ModelConfig, opts: OptimizationSet, batch: usize, mlm: bool) -> u64 {
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    if !mlm {
        return 3 * b * h * F32;
    }
    let v = cfg.vocab_size as u64;
    let gelu_in = if opts.inplace_gelu { b * s * h * MASK } else { b * s * h * F32 };
    let ln_in = if opts.inplace_layernorm { 0 } else { b * s * h * F32 };
    (3 * b * s * h + 2 * b * s * v) * F32 + gelu_in + ln_in
}

/// fp32 params + fp32 grads + Adam (m, v).
pub fn oracle_states(cfg: &ModelConfig) -> u64 {
    4 * cfg.param_count() as u64 * F32
}
