//! Rewrite-gradient-parity property tests for the kernel backend: every
//! Tempo rewrite subset must reproduce the unrewritten lowering's
//! gradients on real numerics, at tiny dims, in the default test leg
//! (no feature flags — `cargo test -q` exercises the whole path).
//!
//! The contract (DESIGN.md §Kernels):
//!
//! * Subsets of {layernorm, dropout, softmax} are **bit-equal** to the
//!   baseline lowering: the backward kernels are output-based or
//!   recompute-identical regardless of the plan, so a rewrite only
//!   changes *what is retained*, never the arithmetic.
//! * Any subset containing the in-place GELU matches within a small
//!   relative tolerance: its backward inverts the f32-rounded output
//!   (exact Newton, not the paper's lossy polynomials), which perturbs
//!   the backward factor at the rounding scale.
//! * Residency arms (checkpoint, host offload) never change values at
//!   all — replay uses positional op seeds and offload round-trips
//!   buffers — so they are bit-equal to the resident plan with the
//!   same rewrite sets.

use tempo::autotempo::probe_config;
use tempo::config::{ModelConfig, OptimizationSet};
use tempo::coordinator::ExperimentEngine;
use tempo::graph::{CkptStyle, Residency, SchedulePlan};
use tempo::runtime::{init_params, step_trace, Manifest, StepBatch, StepTrace};

/// In-place GELU tolerance: |a − b| ≤ REL · (1 + |b|) per grad element.
const GELU_REL: f64 = 1e-5;

fn tiny() -> ModelConfig {
    // toy dims, full structure (the measured probe's shrink)
    probe_config(&ModelConfig::bert_tiny())
}

fn manifest(cfg: &ModelConfig) -> Manifest {
    Manifest::synthetic("rewrite_parity", "mlm", "tempo", "kernel", 2, cfg, 2)
}

fn run(m: &Manifest, plan: &SchedulePlan) -> StepTrace {
    let engine = ExperimentEngine::new(2);
    let mut params = init_params(m, 11);
    let batch = StepBatch::synthetic(m, 5);
    step_trace(m, plan, &engine, &mut params, &batch, 0, 21, 1e-3).unwrap()
}

fn grad_bits(t: &StepTrace) -> Vec<Vec<u32>> {
    t.grads.iter().map(|g| g.iter().map(|v| v.to_bits()).collect()).collect()
}

fn subset(bits: u32, names: [&str; 3]) -> OptimizationSet {
    let mut opts = OptimizationSet::none();
    for (i, name) in names.iter().enumerate() {
        if bits & (1 << i) != 0 {
            opts = opts.union(OptimizationSet::only(name).expect("known rewrite"));
        }
    }
    opts
}

#[test]
fn non_gelu_rewrite_subsets_reproduce_baseline_gradients_bitwise() {
    let cfg = tiny();
    let m = manifest(&cfg);
    let base = run(&m, &SchedulePlan::uniform(&cfg, OptimizationSet::none(), true));
    let base_bits = grad_bits(&base);
    for bits in 1u32..8 {
        let opts = subset(bits, ["layernorm", "dropout", "softmax"]);
        let t = run(&m, &SchedulePlan::uniform(&cfg, opts, true));
        assert_eq!(t.loss.to_bits(), base.loss.to_bits(), "loss under {}", opts.label());
        assert_eq!(grad_bits(&t), base_bits, "gradients under {}", opts.label());
    }
}

#[test]
fn gelu_bearing_subsets_match_baseline_within_rel_tolerance() {
    let cfg = tiny();
    let m = manifest(&cfg);
    let base = run(&m, &SchedulePlan::uniform(&cfg, OptimizationSet::none(), true));
    for bits in 0u32..8 {
        let opts = subset(bits, ["layernorm", "dropout", "softmax"])
            .union(OptimizationSet::only("gelu").expect("known rewrite"));
        let t = run(&m, &SchedulePlan::uniform(&cfg, opts, true));
        let label = opts.label();
        assert!(
            (t.loss - base.loss).abs() <= GELU_REL * (1.0 + base.loss.abs()),
            "loss under {label}: {} vs {}",
            t.loss,
            base.loss
        );
        for (leaf, (a, b)) in t.grads.iter().zip(&base.grads).enumerate() {
            for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
                let diff = (f64::from(x) - f64::from(y)).abs();
                assert!(
                    diff <= GELU_REL * (1.0 + f64::from(y).abs()),
                    "grad[{leaf}][{i}] under {label}: {x} vs {y} (diff {diff:e})"
                );
            }
        }
    }
}

#[test]
fn residency_arms_reproduce_resident_gradients_bitwise() {
    let cfg = tiny();
    let m = manifest(&cfg);
    // checkpointed layers replay the *unoptimized* block, so compare
    // against the rewrite-free resident plan
    let plain = run(&m, &SchedulePlan::uniform(&cfg, OptimizationSet::none(), true));
    let plain_bits = grad_bits(&plain);
    for style in [CkptStyle::Overlapped, CkptStyle::Serial] {
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Checkpoint(style); cfg.layers],
            true,
        );
        let t = run(&m, &plan);
        assert_eq!(t.loss.to_bits(), plain.loss.to_bits(), "{style:?} loss");
        assert_eq!(grad_bits(&t), plain_bits, "{style:?} gradients");
    }
    // offload keeps each layer's own rewrites — bit-equal to the
    // resident plan with the same (full) rewrite set
    let full = run(&m, &SchedulePlan::uniform(&cfg, OptimizationSet::full(), true));
    let offload = run(
        &m,
        &SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            vec![Residency::Offload; cfg.layers],
            true,
        ),
    );
    assert_eq!(offload.loss.to_bits(), full.loss.to_bits(), "offload loss");
    assert_eq!(grad_bits(&offload), grad_bits(&full), "offload gradients");
    assert!(offload.host_peak_bytes > 0, "offload must actually stage to the host");
}

#[test]
fn rewrite_parity_holds_for_the_classification_head() {
    // same property on the fine-tune lowering (CLS head, loss in fwd)
    let cfg = tiny();
    let m = Manifest::synthetic("rewrite_parity_cls", "cls", "tempo", "kernel", 2, &cfg, 3);
    let base = run(&m, &SchedulePlan::uniform(&cfg, OptimizationSet::none(), false));
    let t = run(
        &m,
        &SchedulePlan::uniform(
            &cfg,
            subset(0b111, ["layernorm", "dropout", "softmax"]),
            false,
        ),
    );
    assert_eq!(t.loss.to_bits(), base.loss.to_bits());
    assert_eq!(grad_bits(&t), grad_bits(&base));
}
