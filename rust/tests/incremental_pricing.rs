//! Incremental-pricing contract (DESIGN.md §Schedule "Segment
//! summaries"): the composed segment-chunk fold behind
//! `graph::schedule_summary` must reproduce the full
//! `lower_step(..).summarize_step()` event-tape fold **bit-identically**
//! on every plan in the joint family — peak and high-water op, the
//! per-class byte vectors, the work census, and the lane profile that
//! feeds `plan_lane_times` and the placement search's dominance keys.
//! A random walk over per-layer arm mutations exercises exactly the
//! re-pricing pattern the search's O(Δ-layer) claim rests on: each step
//! changes one layer's `(rewrite subset, Residency)` arm and re-prices
//! through the warm chunk cache.
//!
//! The second contract: `placement_search_jobs` is bit-identical to the
//! serial search at any worker count (parallel summarize/price cells,
//! serial prune + selection fold in enumeration order).

use tempo::autotempo::{placement_search_jobs, PlacementMode, TpPolicy};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::coordinator::ExperimentEngine;
use tempo::graph::{self, CkptStyle, Lowering, Residency, SchedulePlan};
use tempo::perfmodel::plan_lane_times;

/// Deterministic PCG-style LCG — no rand dependency, reproducible
/// failures.
fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// Every per-layer residency arm the joint family places (the Shard
/// arm resolves to Resident at shard degree 1, so it participates in
/// the walk at every degree).
const ARMS: [Residency; 5] = [
    Residency::Resident,
    Residency::Checkpoint(CkptStyle::Overlapped),
    Residency::Checkpoint(CkptStyle::Serial),
    Residency::Offload,
    Residency::Shard,
];

fn random_plan(layers: usize, rng: &mut u64) -> (Vec<OptimizationSet>, Vec<Residency>) {
    let subsets = OptimizationSet::all_subsets();
    let per_layer =
        (0..layers).map(|_| subsets[(lcg(rng) as usize) % subsets.len()]).collect();
    let residency = (0..layers).map(|_| ARMS[(lcg(rng) as usize) % ARMS.len()]).collect();
    (per_layer, residency)
}

#[test]
fn composed_pricing_matches_the_full_fold_under_random_arm_mutations() {
    for cfg in [ModelConfig::bert_tiny(), ModelConfig::bert_mini()] {
        let lowering = Lowering::for_model(&cfg);
        // the shard degrees this model's dimensions divide by, plus 1
        let degrees: Vec<usize> =
            [1usize, 2, 4, 8].into_iter().filter(|&d| cfg.tp_permitted(d)).collect();
        let mut rng: u64 = 0x7e3b_0a11 + cfg.layers as u64;
        let (mut per_layer, mut residency) = random_plan(cfg.layers, &mut rng);
        let mut tp = 1usize;
        for step in 0..40 {
            let plan = SchedulePlan::from_placement(per_layer.clone(), residency.clone(), true)
                .with_tp(tp);
            let composed = graph::schedule_summary(&cfg, &plan);
            let full = graph::lower_step(&cfg, &plan, lowering).summarize_step();
            // full PartialEq: peak/high-water/class vectors/census/
            // events/lanes — everything `plan_lane_times` and the
            // dominance keys are computed from
            assert_eq!(
                *composed, full,
                "{} walk step {step} tp {tp}: composed != full fold",
                cfg.name
            );
            for b in [1usize, 4, 32] {
                assert_eq!(
                    composed.peak_bytes(b),
                    full.peak_bytes(b),
                    "{} walk step {step} tp {tp}: peak diverges at B={b}",
                    cfg.name
                );
            }
            // mutate ONE layer's arm (or the plan-wide shard degree) —
            // the O(Δ-layer) re-pricing shape
            match lcg(&mut rng) % 3 {
                0 => {
                    let l = (lcg(&mut rng) as usize) % cfg.layers;
                    let subsets = OptimizationSet::all_subsets();
                    per_layer[l] = subsets[(lcg(&mut rng) as usize) % subsets.len()];
                }
                1 => {
                    let l = (lcg(&mut rng) as usize) % cfg.layers;
                    residency[l] = ARMS[(lcg(&mut rng) as usize) % ARMS.len()];
                }
                _ => tp = degrees[(lcg(&mut rng) as usize) % degrees.len()],
            }
        }
    }
}

#[test]
fn lane_pricing_through_the_composed_summary_is_deterministic() {
    // the composed summary feeds plan_lane_times; pin that pricing a
    // random mixed plan is bit-stable across repeat calls on every rig
    // shape × batch the property matrix cares about
    let cfg = ModelConfig::bert_mini();
    let mut rng: u64 = 0xfeed_f00d;
    let (per_layer, residency) = random_plan(cfg.layers, &mut rng);
    let plan = SchedulePlan::from_placement(per_layer, residency, true);
    for gpu in Gpu::all() {
        for devices in [1usize, 4] {
            let spec = gpu.spec().with_devices(devices);
            for b in [1usize, 4, 32] {
                let lt = plan_lane_times(&cfg, &plan, &spec, b);
                assert!(lt.step.is_finite(), "{} x{devices} B={b}", gpu.name());
                assert_eq!(
                    lt.step,
                    lt.compute + lt.comm_exposed + lt.host_exposed + lt.tp_exposed,
                    "{} x{devices} B={b}: lanes must decompose the step",
                    gpu.name()
                );
                let again = plan_lane_times(&cfg, &plan, &spec, b);
                assert_eq!(lt, again, "{} x{devices} B={b}: repeat pricing diverged", gpu.name());
            }
        }
    }
}

#[test]
fn parallel_placement_search_is_bit_identical_to_serial() {
    let cfg = ModelConfig::bert_mini();
    let serial = ExperimentEngine::new(1);
    let par = ExperimentEngine::new(4);
    for (mode, tp, target) in [
        (PlacementMode::Uniform, TpPolicy::Fixed(1), None),
        (PlacementMode::Joint, TpPolicy::Fixed(1), None),
        (PlacementMode::Joint, TpPolicy::Fixed(1), Some(8)),
        (PlacementMode::Joint, TpPolicy::Auto, None),
    ] {
        let a = placement_search_jobs(&cfg, Gpu::Rtx2080Ti, mode, tp, target, true, &serial);
        let b = placement_search_jobs(&cfg, Gpu::Rtx2080Ti, mode, tp, target, true, &par);
        let what = format!("{} tp={tp:?} target={target:?}", mode.name());
        assert_eq!(a.plan, b.plan, "{what}: winners diverged");
        assert_eq!(a.max_batch, b.max_batch, "{what}");
        assert_eq!(a.eval_batch, b.eval_batch, "{what}");
        assert_eq!(
            a.throughput.to_bits(),
            b.throughput.to_bits(),
            "{what}: throughput must match to the bit"
        );
        assert_eq!(a.rationale, b.rationale, "{what}");
        assert_eq!(a.stats, b.stats, "{what}: the prune funnel is jobs-invariant");
    }
}
