//! Seeded-determinism contracts: same seed → bit-identical artifacts
//! of every random substrate (RNG streams, tensor draws, MLM masking)
//! and of the sim backend end-to-end (golden loss traces).

use tempo::config::TrainingConfig;
use tempo::coordinator::{finetune_trials, ExperimentEngine, Trainer, TrainerOptions};
use tempo::data::{Corpus, CorpusConfig, MlmBatcher, MlmConfig};
use tempo::runtime::{ArtifactIndex, SimBackend};
use tempo::tensor::Rng;

// ---- tensor::rng -----------------------------------------------------------

#[test]
fn rng_same_seed_identical_stream() {
    let mut a = Rng::new(0xDEAD_BEEF);
    let mut b = Rng::new(0xDEAD_BEEF);
    let xs: Vec<u64> = (0..256).map(|_| a.next_u64()).collect();
    let ys: Vec<u64> = (0..256).map(|_| b.next_u64()).collect();
    assert_eq!(xs, ys);
}

#[test]
fn rng_different_seed_different_stream() {
    let mut a = Rng::new(1);
    let mut b = Rng::new(2);
    let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
    let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
    assert_ne!(xs, ys);
}

#[test]
fn rng_normal_draws_reproduce_bitwise() {
    // Box–Muller goes through transcendental libm calls; the contract is
    // still bit-identical f64s for the same seed on the same platform.
    let draw = |seed: u64| -> Vec<u64> {
        let mut r = Rng::new(seed);
        (0..128).map(|_| r.normal().to_bits()).collect()
    };
    assert_eq!(draw(42), draw(42));
    assert_ne!(draw(42), draw(43));
}

#[test]
fn rng_forked_streams_reproduce() {
    let fork_trace = |seed: u64, tag: u64| -> Vec<u64> {
        let mut base = Rng::new(seed);
        let mut f = base.fork(tag);
        (0..32).map(|_| f.next_u64()).collect()
    };
    assert_eq!(fork_trace(9, 1), fork_trace(9, 1));
    assert_ne!(fork_trace(9, 1), fork_trace(9, 2));
}

// ---- data::mlm -------------------------------------------------------------

fn mlm_batches(seed: u64, n: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    let corpus = Corpus::new(CorpusConfig::default(), 5);
    let mut gen = MlmBatcher::new(corpus, MlmConfig::default(), 4, 64, seed);
    (0..n)
        .map(|_| {
            let b = gen.next_batch().unwrap();
            (
                b.input_ids.as_i32().unwrap().to_vec(),
                b.labels.as_i32().unwrap().to_vec(),
            )
        })
        .collect()
}

#[test]
fn mlm_masking_same_seed_identical() {
    assert_eq!(mlm_batches(11, 4), mlm_batches(11, 4));
}

#[test]
fn mlm_masking_different_seed_differs() {
    assert_ne!(mlm_batches(11, 4), mlm_batches(12, 4));
}

// ---- SimBackend golden run -------------------------------------------------

fn sim_loss_trace(cfg: &TrainingConfig) -> Vec<u64> {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open(&cfg.artifact).unwrap();
    let mut trainer =
        Trainer::new(&backend, artifact, cfg.clone(), TrainerOptions::default()).unwrap();
    trainer.run().unwrap();
    trainer
        .metrics()
        .records()
        .iter()
        .map(|r| r.loss.to_bits())
        .collect()
}

#[test]
fn sim_trainer_golden_bit_identical_traces() {
    // Two full Trainer runs with the same TrainingConfig must produce
    // bit-identical loss traces — the sim backend has no hidden state.
    let cfg = TrainingConfig {
        artifact: "bert_tiny_tempo".into(),
        steps: 25,
        warmup_steps: 3,
        peak_lr: 1.5e-3,
        seed: 1234,
        eval_every: 10,
        log_every: 1000,
    };
    let a = sim_loss_trace(&cfg);
    let b = sim_loss_trace(&cfg);
    assert_eq!(a.len(), 25);
    assert_eq!(a, b, "sim loss traces must be bit-identical for one config");

    // ... and any config change must actually show up.
    let mut other = cfg.clone();
    other.seed = 4321;
    assert_ne!(a, sim_loss_trace(&other));
}

#[test]
fn eval_every_does_not_perturb_training_trace() {
    // Evaluation draws from a dedicated held-out batcher, so turning it
    // on (at any cadence) must leave the training loss trace bit-equal.
    let base = TrainingConfig {
        artifact: "bert_tiny_baseline".into(),
        steps: 24,
        warmup_steps: 2,
        peak_lr: 1.2e-3,
        seed: 77,
        eval_every: 0,
        log_every: 1000,
    };
    let no_eval = sim_loss_trace(&base);
    for eval_every in [1usize, 3, 7] {
        let mut cfg = base.clone();
        cfg.eval_every = eval_every;
        assert_eq!(
            no_eval,
            sim_loss_trace(&cfg),
            "eval_every={eval_every} shifted the training data stream"
        );
    }
}

#[test]
fn finetune_base_seeds_do_not_alias_mod_2_32() {
    // `seed as i32` used to truncate the trial seed into the ABI scalar,
    // so base seeds 2³² apart produced identical trials. The SplitMix64
    // fold keeps all 64 bits live.
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("cls_tiny_tempo").unwrap();
    let engine = ExperimentEngine::serial();
    let run = |base_seed: u64| {
        finetune_trials(&backend, &artifact, 2, 12, 6, 1e-3, base_seed, &engine, false)
            .unwrap()
            .trials
            .iter()
            .map(|t| t.accuracy.iter().map(|a| a.to_bits()).collect::<Vec<u64>>())
            .collect::<Vec<_>>()
    };
    let lo = run(42);
    let hi = run(42 + (1u64 << 32)); // aliases 42 under `as i32`
    assert_ne!(lo, hi, "base seeds 2^32 apart must give distinct trials");
    // …while the same base seed stays bit-identical.
    assert_eq!(lo, run(42));
}

#[test]
fn trainer_init_seeds_do_not_alias_mod_2_32() {
    // The trainer folds cfg.seed into the i32 ABI scalar the same way
    // finetune does; init draws only see that scalar, so this isolates
    // the fold (the data stream already used the full u64).
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let init_state = |seed: u64| {
        let cfg = TrainingConfig {
            artifact: "bert_tiny_baseline".into(),
            steps: 1,
            seed,
            ..Default::default()
        };
        let t = Trainer::new(
            &backend,
            idx.open("bert_tiny_baseline").unwrap(),
            cfg,
            TrainerOptions::default(),
        )
        .unwrap();
        t.state().unwrap().leaves
    };
    assert_ne!(
        init_state(9),
        init_state(9 + (1u64 << 32)),
        "ABI seeds 2^32 apart must give distinct inits"
    );
    assert_eq!(init_state(9), init_state(9));
}

// ---- kernel backend: dropout seeds and trace determinism -------------------

/// Three kernel-backend training steps at toy dims; returns every bit
/// the run produced (losses, then the updated parameter banks).
fn kernel_trace_bits(jobs: usize, seed: u64) -> Vec<u64> {
    use tempo::config::{ModelConfig, Technique};
    use tempo::graph::SchedulePlan;
    use tempo::runtime::{init_params, step_trace, Manifest, StepBatch};

    let cfg = tempo::autotempo::probe_config(&ModelConfig::bert_tiny());
    let m = Manifest::synthetic("kernel_det", "mlm", "kernel", "kernel", 2, &cfg, 2);
    let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
    let engine = ExperimentEngine::new(jobs);
    let mut params = init_params(&m, seed);
    let batch = StepBatch::synthetic(&m, seed);
    let mut bits = Vec::new();
    for step in 0..3i64 {
        let t = step_trace(&m, &plan, &engine, &mut params, &batch, step, seed, 1e-3).unwrap();
        bits.push(t.loss.to_bits());
    }
    for leaf in &params {
        bits.extend(leaf.iter().map(|v| u64::from(v.to_bits())));
    }
    bits
}

#[test]
fn kernel_traces_bit_identical_across_runs_and_worker_counts() {
    // dropout masks are keyed (step seed, segment, op, element index) —
    // never tape position or worker id — so the whole multi-step trace
    // is one deterministic function of (seed, plan).
    let a = kernel_trace_bits(1, 33);
    assert_eq!(a, kernel_trace_bits(1, 33), "same seed must replay bitwise");
    assert_eq!(a, kernel_trace_bits(3, 33), "worker count must not leak into the trace");
    assert_ne!(a, kernel_trace_bits(1, 34), "seed must matter");
}

#[test]
fn kernel_dropout_streams_fold_the_step_index() {
    use tempo::config::{ModelConfig, Technique};
    use tempo::graph::SchedulePlan;
    use tempo::runtime::{init_params, step_trace, Manifest, StepBatch};

    // fresh identical params each time, same batch: the only thing the
    // step index can change is the per-op dropout seeds — losses must
    // differ across steps and replay bitwise within one
    let cfg = tempo::autotempo::probe_config(&ModelConfig::bert_tiny());
    let m = Manifest::synthetic("kernel_det_step", "mlm", "kernel", "kernel", 2, &cfg, 2);
    let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
    let engine = ExperimentEngine::serial();
    let batch = StepBatch::synthetic(&m, 5);
    let loss_at = |step: i64| {
        let mut params = init_params(&m, 11);
        step_trace(&m, &plan, &engine, &mut params, &batch, step, 21, 1e-3).unwrap().loss
    };
    assert_eq!(loss_at(0).to_bits(), loss_at(0).to_bits());
    assert_ne!(
        loss_at(0).to_bits(),
        loss_at(1).to_bits(),
        "step index must reseed the dropout masks"
    );
}

#[test]
fn sim_init_reproduces_across_trainers() {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let cfg = TrainingConfig {
        artifact: "bert_tiny_baseline".into(),
        steps: 1,
        ..Default::default()
    };
    let t1 = Trainer::new(
        &backend,
        idx.open("bert_tiny_baseline").unwrap(),
        cfg.clone(),
        TrainerOptions::default(),
    )
    .unwrap();
    let t2 = Trainer::new(
        &backend,
        idx.open("bert_tiny_baseline").unwrap(),
        cfg,
        TrainerOptions::default(),
    )
    .unwrap();
    assert_eq!(t1.state().unwrap().leaves, t2.state().unwrap().leaves);
}
