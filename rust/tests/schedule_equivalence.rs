//! Schedule/closed-form equivalence suite.
//!
//! The execution-schedule PR replaced the static byte sum
//! (`params + grads + optimizer + activations + hand-written transient`)
//! with the exact peak of a liveness timeline folded over the lowered
//! fwd+bwd op schedule. This suite pins that refactor: the
//! **pre-schedule closed forms are copied here verbatim as golden
//! oracles**, and the timeline peak must equal them *bit-identically*
//! (exact `==` on u64 bytes) across all presets × batch ∈ {1, 4, 32} ×
//! every `OptimizationSet` subset × every technique × both heads.
//!
//! ## The divergence list
//!
//! Exactly ONE intentional divergence exists, and it is opt-in:
//!
//! * **Serial checkpointing** (`Residency::Checkpoint(CkptStyle::Serial)` via
//!   `SchedulePlan::serial`, PyTorch-style `torch.utils.checkpoint`:
//!   no re-forward prefetch).
//!   The static sum charged the head activations AND one block's
//!   recompute live set simultaneously; a serial schedule frees the
//!   head's B·S·V logits during the head backward *before* the first
//!   re-forward segment is spliced in, so its true peak undercuts the
//!   static sum by exactly `min(head bytes, block inventory bytes)`.
//!   The static sum **over-counted** serial checkpointing's true peak.
//!
//! The *default* checkpoint schedule prefetches the top block's
//! re-forward under the head backward (L2L-style overlap, which hides
//! recompute latency) — under that execution order the head and one
//! recomputed inventory genuinely coexist, which is why the legacy
//! static sum was correct and why Table 2 / §4.2 calibration pins stay
//! untouched. `calibration_paper.rs` remains green unchanged.

use tempo::autotempo::LayerPlan;
use tempo::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use tempo::graph::{lower_step, schedule_summary, EventKind, Lowering, MemClass, SchedulePlan};
use tempo::memmodel::{max_batch, ModelFootprint};

mod common;
use common::{
    oracle_embedding_bytes, oracle_head_bytes, oracle_layer_bytes, oracle_states,
    presets_full as presets, BATCHES, F32,
};

/// The pre-schedule `Breakdown::total()` for Baseline/Tempo/subsets:
/// static sum with the hand-written `2 × widest` transient.
fn oracle_total_plain(cfg: &ModelConfig, opts: OptimizationSet, batch: usize, mlm: bool) -> u64 {
    let (f, m, st) = oracle_layer_bytes(cfg, batch, opts);
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let wide = (b * s * cfg.intermediate as u64).max(b * cfg.heads as u64 * s * s);
    oracle_states(cfg)
        + cfg.layers as u64 * (f + m + st)
        + oracle_embedding_bytes(cfg, opts, batch)
        + oracle_head_bytes(cfg, opts, batch, mlm)
        + 2 * wide * F32
}

/// The pre-schedule `Breakdown::total()` for Checkpoint: stored block
/// inputs plus the hand-written `inventory + float volume` transient.
fn oracle_total_checkpoint(cfg: &ModelConfig, batch: usize, mlm: bool) -> u64 {
    let none = OptimizationSet::none();
    let (f, m, st) = oracle_layer_bytes(cfg, batch, none);
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    oracle_states(cfg)
        + cfg.layers as u64 * b * s * h * F32
        + oracle_embedding_bytes(cfg, none, batch)
        + oracle_head_bytes(cfg, none, batch, mlm)
        + (f + m + st)
        + f
}

fn peak(cfg: &ModelConfig, plan: &SchedulePlan, batch: usize) -> u64 {
    schedule_summary(cfg, plan).peak_bytes(batch as u64)
}

// ---------------------------------------------------------------------------
// The pin: timeline peak ≡ static sum, everywhere, bit-identically.
// ---------------------------------------------------------------------------

#[test]
fn timeline_peak_bit_identical_to_static_sum_for_every_rewrite_subset() {
    for cfg in presets() {
        for batch in BATCHES {
            for mlm in [true, false] {
                for opts in OptimizationSet::all_subsets() {
                    let plan = SchedulePlan::uniform(&cfg, opts, mlm);
                    assert_eq!(
                        peak(&cfg, &plan, batch),
                        oracle_total_plain(&cfg, opts, batch, mlm),
                        "{} B={batch} mlm={mlm} {opts:?}",
                        cfg.name
                    );
                }
            }
        }
    }
}

#[test]
fn timeline_peak_bit_identical_to_static_sum_for_checkpoint() {
    // the default (overlapped) checkpoint schedule prefetches the top
    // block's re-forward under the head backward, so the high-water
    // instant holds head + stored inputs + one recomputed inventory +
    // the gradient workspace — exactly the legacy static sum
    for cfg in presets() {
        for batch in BATCHES {
            for mlm in [true, false] {
                let plan = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, mlm);
                assert_eq!(
                    peak(&cfg, &plan, batch),
                    oracle_total_checkpoint(&cfg, batch, mlm),
                    "{} B={batch} mlm={mlm}",
                    cfg.name
                );
            }
        }
    }
}

#[test]
fn techniques_map_onto_the_subset_grid() {
    // Baseline ≡ the empty subset, Tempo ≡ the full subset — the
    // technique plans price identically to their subset plans.
    for cfg in [ModelConfig::bert_large().with_seq_len(512), ModelConfig::bert_tiny()] {
        for batch in BATCHES {
            let base = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
            assert_eq!(
                peak(&cfg, &base, batch),
                oracle_total_plain(&cfg, OptimizationSet::none(), batch, true)
            );
            let tempo = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
            assert_eq!(
                peak(&cfg, &tempo, batch),
                oracle_total_plain(&cfg, OptimizationSet::full(), batch, true)
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The enumerated divergence list. One entry:
//
//   1. Serial checkpointing (opt-in `CkptStyle::Serial`): the static
//      sum over-counted the true peak by min(head, block inventory),
//      because without the re-forward prefetch the head activations
//      and the recompute live set are never simultaneously alive —
//      the head backward frees the B·S·V logits first.
//
// Nothing else diverges: the serial flag is a no-op for plain plans.
// ---------------------------------------------------------------------------

#[test]
fn divergence_1_serial_checkpoint_undercuts_static_sum_by_min_head_inventory() {
    let none = OptimizationSet::none();
    for cfg in presets() {
        for batch in BATCHES {
            for mlm in [true, false] {
                let serial =
                    SchedulePlan::for_technique(&cfg, Technique::Checkpoint, mlm).serial();
                let got = peak(&cfg, &serial, batch);
                let static_sum = oracle_total_checkpoint(&cfg, batch, mlm);
                let (f, m, st) = oracle_layer_bytes(&cfg, batch, none);
                let inventory = f + m + st;
                let head = oracle_head_bytes(&cfg, none, batch, mlm);
                assert_eq!(
                    static_sum - got,
                    head.min(inventory),
                    "{} B={batch} mlm={mlm}: serial-checkpoint divergence",
                    cfg.name
                );
                assert!(got < static_sum, "{}: divergence must be an over-count", cfg.name);
            }
        }
    }
}

#[test]
fn serial_flag_is_a_noop_without_checkpointing() {
    for cfg in [ModelConfig::bert_base(), ModelConfig::bert_tiny()] {
        for opts in [OptimizationSet::none(), OptimizationSet::full()] {
            let plan = SchedulePlan::uniform(&cfg, opts, true);
            let serial = plan.clone().serial();
            for batch in BATCHES {
                assert_eq!(peak(&cfg, &plan, batch), peak(&cfg, &serial, batch));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Breakdown rows are the timeline's class decomposition.
// ---------------------------------------------------------------------------

#[test]
fn breakdown_rows_are_the_timeline_classes_and_sum_to_the_peak() {
    for cfg in [ModelConfig::bert_large().with_seq_len(512), ModelConfig::bert_mini()] {
        for tech in Technique::all() {
            for batch in [1usize, 8] {
                let fp = ModelFootprint::new(cfg.clone(), tech);
                let bd = fp.breakdown(batch);
                let s = schedule_summary(&cfg, &fp.plan());
                let b = batch as u64;
                assert_eq!(bd.params, s.class_bytes(MemClass::Params, b));
                assert_eq!(bd.encoder_activations, s.class_bytes(MemClass::EncoderAct, b));
                assert_eq!(bd.other_activations, s.class_bytes(MemClass::OtherAct, b));
                assert_eq!(bd.transient, s.class_bytes(MemClass::Workspace, b));
                assert_eq!(bd.total(), s.peak_bytes(b), "{tech:?} B={batch}");
            }
        }
    }
}

#[test]
fn batch_zero_collapses_to_model_states() {
    for cfg in [ModelConfig::bert_base(), ModelConfig::bert_tiny()] {
        for tech in Technique::all() {
            let fp = ModelFootprint::new(cfg.clone(), tech);
            assert_eq!(fp.total_bytes(0), oracle_states(&cfg), "{tech:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// The memoized summary prices every batch exactly like a fresh fold,
// and the high-water instant is where the semantics say it is.
// ---------------------------------------------------------------------------

#[test]
fn memoized_summary_equals_fresh_timeline_at_every_batch() {
    let cfg = ModelConfig::bert_mini();
    let lowering = Lowering::for_model(&cfg);
    for tech in Technique::all() {
        let plan = SchedulePlan::for_technique(&cfg, tech, true);
        let summary = schedule_summary(&cfg, &plan);
        let schedule = lower_step(&cfg, &plan, lowering);
        for batch in BATCHES {
            let tl = schedule.timeline(batch);
            assert_eq!(summary.peak_bytes(batch as u64), tl.peak_bytes, "{tech:?} B={batch}");
            assert_eq!(summary.peak_event, tl.peak_event, "{tech:?} B={batch}");
        }
    }
}

#[test]
fn high_water_lands_where_the_semantics_say() {
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let lowering = Lowering::for_model(&cfg);
    // plain step: the fwd→bwd turnaround, everything retained + workspace
    let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
    let s = lower_step(&cfg, &plan, lowering);
    let tl = s.timeline(4);
    assert_eq!(s.events[tl.peak_event].kind, EventKind::Turnaround);
    // overlapped checkpoint: inside the prefetched re-forward segment
    let ck = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
    let s = lower_step(&cfg, &ck, lowering);
    let tl = s.timeline(4);
    assert_eq!(s.events[tl.peak_event].kind, EventKind::Recompute);
    // and the prefetch precedes the first backward event
    let first_bwd = s.events.iter().position(|e| e.kind == EventKind::Backward).unwrap();
    assert!(tl.peak_event < first_bwd);
}

// ---------------------------------------------------------------------------
// Auto-Tempo agreement: max batch binary-searched against the timeline
// peak equals the capacity search on the paper presets, and mixed
// per-layer plans price as the sum of their layers.
// ---------------------------------------------------------------------------

#[test]
fn max_batch_against_timeline_peak_agrees_with_capacity_search() {
    for s in [128usize, 512] {
        let cfg = ModelConfig::bert_large().with_seq_len(s);
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            let budget = gpu.spec().usable_bytes();
            for tech in Technique::all() {
                let fit = max_batch(&cfg, tech, gpu);
                let plan = SchedulePlan::for_technique(&cfg, tech, true);
                let at_max = peak(&cfg, &plan, fit.max_batch);
                let over = peak(&cfg, &plan, fit.max_batch + 1);
                assert!(at_max <= budget, "{tech:?} S={s} {gpu:?}");
                assert!(over > budget, "{tech:?} S={s} {gpu:?}");
                assert_eq!(at_max, fit.bytes_at_max);
                assert_eq!(over, fit.bytes_over);
            }
        }
    }
}

#[test]
fn mixed_layer_plans_price_bit_identically_through_the_schedule() {
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let subsets = OptimizationSet::all_subsets();
    let per_layer: Vec<OptimizationSet> =
        (0..cfg.layers).map(|l| subsets[l % subsets.len()]).collect();
    let plan = LayerPlan::rewrites_only(per_layer.clone());
    let none = OptimizationSet::none();
    for batch in BATCHES {
        let b = batch as u64;
        let s = cfg.seq_len as u64;
        let wide = (b * s * cfg.intermediate as u64).max(b * cfg.heads as u64 * s * s);
        let oracle: u64 = oracle_states(&cfg)
            + per_layer
                .iter()
                .map(|o| {
                    let (f, m, st) = oracle_layer_bytes(&cfg, batch, *o);
                    f + m + st
                })
                .sum::<u64>()
            + oracle_embedding_bytes(&cfg, none, batch)
            + oracle_head_bytes(&cfg, none, batch, true)
            + 2 * wide * F32;
        assert_eq!(plan.total_bytes(&cfg, batch), oracle, "B={batch}");
    }
}

// ---------------------------------------------------------------------------
// Timeline well-formedness: the schedule is a closed system.
// ---------------------------------------------------------------------------

#[test]
fn timeline_is_well_formed_for_every_technique() {
    for cfg in [ModelConfig::bert_tiny(), ModelConfig::gpt2()] {
        for tech in Technique::all() {
            for mlm in [true, false] {
                let plan = SchedulePlan::for_technique(&cfg, tech, mlm);
                let schedule = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
                // (u64 underflow in the fold would panic in debug builds)
                let tl = schedule.timeline(3);
                assert_eq!(tl.points.len(), schedule.events.len());
                // after the last event's frees, only model states remain
                let last = tl.points.last().unwrap();
                assert_eq!(
                    last.live_bytes - last.free_bytes,
                    oracle_states(&cfg),
                    "{tech:?} mlm={mlm} leaks activations past the step"
                );
                // the peak is one of the sampled points
                assert_eq!(tl.points[tl.peak_event].live_bytes, tl.peak_bytes);
                assert!(tl.points.iter().all(|p| p.live_bytes <= tl.peak_bytes));
            }
        }
    }
}
