//! Property tests over the Auto-Tempo planning layer (`LayerPlan` and
//! the two search policies), driven by the in-tree SplitMix64 RNG over
//! seeded-random model configs (no proptest in the offline build).

use tempo::autotempo::{coarse_pass, fine_search, LayerPlan};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::tensor::Rng;

/// Run `body(rng, case_index)` for `n` seeded cases.
fn cases(n: usize, seed: u64, mut body: impl FnMut(&mut Rng, usize)) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let mut case_rng = rng.fork(i as u64);
        body(&mut case_rng, i);
    }
}

/// A random plausible transformer config.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let heads = [2usize, 4, 8, 12, 16][rng.below(5)];
    let hidden = heads * 64;
    ModelConfig {
        name: "rand".into(),
        kind: tempo::config::ModelKind::Bert,
        hidden,
        layers: rng.range(1, 25),
        heads,
        seq_len: [64usize, 128, 256, 512][rng.below(4)],
        intermediate: hidden * 4,
        vocab_size: rng.range(4096, 50000),
        max_position: 1024,
        type_vocab: 2,
        dropout_p: 0.1,
    }
}

/// A random per-layer optimization assignment (checkpoint-free).
fn random_plan(rng: &mut Rng, layers: usize) -> LayerPlan {
    let subsets = OptimizationSet::all_subsets();
    LayerPlan::rewrites_only(
        (0..layers).map(|_| subsets[rng.below(subsets.len())]).collect(),
    )
}

/// The single-optimization toggles in a fixed order.
fn toggles() -> [OptimizationSet; 4] {
    [
        OptimizationSet::only("gelu").unwrap(),
        OptimizationSet::only("layernorm").unwrap(),
        OptimizationSet::only("dropout").unwrap(),
        OptimizationSet::only("softmax").unwrap(),
    ]
}

fn merge(a: OptimizationSet, b: OptimizationSet) -> OptimizationSet {
    OptimizationSet {
        inplace_gelu: a.inplace_gelu || b.inplace_gelu,
        inplace_layernorm: a.inplace_layernorm || b.inplace_layernorm,
        dropout_recompute: a.dropout_recompute || b.dropout_recompute,
        softmax_outonly: a.softmax_outonly || b.softmax_outonly,
    }
}

#[test]
fn prop_total_bytes_non_increasing_as_optimizations_are_added() {
    // Start from a random plan, add the four optimizations one at a time
    // to one random layer: the whole-plan footprint must never grow.
    cases(120, 21, |rng, i| {
        let cfg = random_config(rng);
        let batch = rng.range(1, 9);
        let mut plan = random_plan(rng, cfg.layers);
        let layer = rng.below(cfg.layers);
        let mut order = toggles();
        rng.shuffle(&mut order);

        let mut prev = plan.total_bytes(&cfg, batch);
        for t in order {
            plan.per_layer[layer] = merge(plan.per_layer[layer], t);
            let now = plan.total_bytes(&cfg, batch);
            assert!(
                now <= prev,
                "case {i}: adding {:?} to layer {layer} grew the plan: {now} > {prev} ({cfg:?})",
                t.label()
            );
            prev = now;
        }
    });
}

#[test]
fn prop_full_plan_strictly_below_empty_plan() {
    cases(60, 22, |rng, i| {
        let cfg = random_config(rng);
        let batch = rng.range(1, 9);
        let empty = LayerPlan::uniform(cfg.layers, OptimizationSet::none());
        let full = LayerPlan::uniform(cfg.layers, OptimizationSet::full());
        assert!(
            full.total_bytes(&cfg, batch) < empty.total_bytes(&cfg, batch),
            "case {i}: full tempo saved nothing on {cfg:?}"
        );
    });
}

#[test]
fn prop_uniform_applied_layers_matches() {
    cases(100, 23, |rng, _| {
        let n = rng.range(1, 33);
        assert_eq!(LayerPlan::uniform(n, OptimizationSet::full()).applied_layers(), n);
        assert_eq!(LayerPlan::uniform(n, OptimizationSet::none()).applied_layers(), 0);
        let one = OptimizationSet::only("dropout").unwrap();
        assert_eq!(LayerPlan::uniform(n, one).applied_layers(), n);
    });
}

#[test]
fn prop_applied_layers_counts_nonempty_sets() {
    cases(100, 24, |rng, i| {
        let layers = rng.range(1, 25);
        let plan = random_plan(rng, layers);
        let expect = plan.per_layer.iter().filter(|s| s.count() > 0).count();
        assert_eq!(plan.applied_layers(), expect, "case {i}");
    });
}

#[test]
fn prop_searched_plan_fits_the_gpu_budget() {
    // Whatever the fine-grained search decides, its reported max batch
    // must actually fit the GPU budget under its own plan.
    cases(40, 25, |rng, i| {
        let cfg = random_config(rng);
        let gpu = Gpu::all()[rng.below(3)];
        let target = rng.range(1, 33);
        let d = fine_search(&cfg, gpu, target);
        if d.max_batch == 0 {
            return; // model doesn't fit at all on this GPU
        }
        let bytes = d.plan.total_bytes(&cfg, d.max_batch);
        let budget = gpu.spec().usable_bytes();
        assert!(
            bytes <= budget,
            "case {i}: searched plan exceeds budget on {} at B={}: {bytes} > {budget} \
             (target {target}, applied {}/{} layers, {cfg:?})",
            gpu.name(),
            d.max_batch,
            d.plan.applied_layers(),
            cfg.layers
        );
    });
}

#[test]
fn prop_coarse_plan_fits_the_gpu_budget() {
    // coarse_pass sizes its batch with the whole-model technique
    // accounting (all-or-nothing), so verify against the same model.
    cases(40, 26, |rng, i| {
        let cfg = random_config(rng);
        let gpu = Gpu::all()[rng.below(3)];
        let d = coarse_pass(&cfg, gpu);
        if d.max_batch == 0 {
            return;
        }
        let tech = if d.plan.applied_layers() > 0 {
            tempo::config::Technique::Tempo
        } else {
            tempo::config::Technique::Baseline
        };
        let bytes =
            tempo::memmodel::ModelFootprint::new(cfg.clone(), tech).total_bytes(d.max_batch);
        let budget = gpu.spec().usable_bytes();
        assert!(
            bytes <= budget,
            "case {i}: coarse decision exceeds budget on {}: {bytes} > {budget} ({cfg:?})",
            gpu.name()
        );
    });
}
