//! Determinism contracts of the concurrent experiment engine
//! (DESIGN.md §Concurrency): a sweep's output is a function of its
//! grid, never of its schedule — `--jobs 4` and `--jobs 1` must produce
//! bit-identical results, including when cells fail.

use tempo::config::TrainingConfig;
use tempo::coordinator::{compare_variants, finetune_trials, ExperimentEngine};
use tempo::report::{run_experiments, ALL_EXPERIMENTS};
use tempo::runtime::{ArtifactIndex, SimBackend};

fn cfg(steps: usize) -> TrainingConfig {
    TrainingConfig {
        artifact: String::new(),
        steps,
        warmup_steps: 2,
        peak_lr: 2e-3,
        seed: 7,
        eval_every: 3,
        log_every: 1000,
    }
}

/// The builtin MLM artifact matrix (every variant at both scales).
const MATRIX: [&str; 5] = [
    "bert_tiny_baseline",
    "bert_tiny_checkpoint",
    "bert_tiny_tempo",
    "bert_mini_baseline",
    "bert_mini_tempo",
];

fn compare_bits(names: &[&str], jobs: usize) -> (Vec<(String, Vec<u64>)>, Vec<(usize, String)>) {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let result = compare_variants(
        &backend,
        &idx,
        names,
        &cfg(10),
        &ExperimentEngine::new(jobs),
        false,
    )
    .unwrap();
    (
        result
            .curves
            .iter()
            .map(|c| {
                (c.artifact.clone(), c.losses.iter().map(|l| l.to_bits()).collect())
            })
            .collect(),
        result.failures.iter().map(|f| (f.index, f.error.clone())).collect(),
    )
}

#[test]
fn compare_parallel_matches_serial_bitwise() {
    let serial = compare_bits(&MATRIX, 1);
    let parallel = compare_bits(&MATRIX, 4);
    assert_eq!(serial, parallel);
    assert!(serial.1.is_empty());
    assert_eq!(serial.0.len(), MATRIX.len());
    // grid order, not completion order
    for (got, want) in serial.0.iter().zip(MATRIX) {
        assert_eq!(got.0, want);
    }
}

#[test]
fn compare_failing_cell_is_isolated_and_deterministic() {
    let names = [
        "bert_tiny_baseline",
        "no_such_artifact",
        "bert_tiny_tempo",
    ];
    let serial = compare_bits(&names, 1);
    let parallel = compare_bits(&names, 4);
    assert_eq!(serial, parallel, "failing-cell sweep must not depend on --jobs");
    let (curves, failures) = serial;
    assert_eq!(curves.len(), 2, "surviving cells must complete");
    assert_eq!(curves[0].0, "bert_tiny_baseline");
    assert_eq!(curves[1].0, "bert_tiny_tempo");
    assert_eq!(failures.len(), 1);
    assert_eq!(failures[0].0, 1, "failure carries its grid index");
    assert!(failures[0].1.contains("no_such_artifact"), "{}", failures[0].1);
    // the surviving curves are the same ones a clean sweep produces
    let clean = compare_bits(&["bert_tiny_baseline", "bert_tiny_tempo"], 1);
    assert_eq!(clean.0, curves);
}

fn finetune_bits(trials: usize, jobs: usize) -> Vec<(u64, Vec<u64>)> {
    let backend = SimBackend::new();
    let idx = ArtifactIndex::builtin();
    let artifact = idx.open("cls_tiny_tempo").unwrap();
    let result = finetune_trials(
        &backend,
        &artifact,
        trials,
        16,
        4,
        1e-3,
        11,
        &ExperimentEngine::new(jobs),
        false,
    )
    .unwrap();
    assert!(result.failures.is_empty());
    result
        .trials
        .iter()
        .map(|t| (t.seed, t.accuracy.iter().map(|a| a.to_bits()).collect()))
        .collect()
}

#[test]
fn finetune_parallel_matches_serial_bitwise() {
    let serial = finetune_bits(5, 1);
    let parallel = finetune_bits(5, 4);
    assert_eq!(serial.len(), 5);
    assert_eq!(serial, parallel);
    // trial order by seed grid
    for (i, (seed, _)) in serial.iter().enumerate() {
        assert_eq!(*seed, 11 + 1000 * i as u64);
    }
}

#[test]
fn experiments_parallel_matches_serial_rendering() {
    let ids: Vec<&str> = ALL_EXPERIMENTS.iter().map(|e| e.id).collect();
    let serial = run_experiments(&ids, &ExperimentEngine::serial());
    let parallel = run_experiments(&ids, &ExperimentEngine::new(4));
    assert_eq!(serial.len(), parallel.len());
    for ((id_s, t_s), (id_p, t_p)) in serial.iter().zip(&parallel) {
        assert_eq!(id_s, id_p);
        assert_eq!(
            t_s.as_ref().unwrap().render(),
            t_p.as_ref().unwrap().render(),
            "{id_s} diverged across --jobs"
        );
        assert_eq!(t_s.as_ref().unwrap().to_csv(), t_p.as_ref().unwrap().to_csv());
    }
}

#[test]
fn compare_is_schedule_free_across_worker_counts() {
    // 2, 3 and 8 workers over 5 cells exercise uneven work stealing.
    let reference = compare_bits(&MATRIX, 1);
    for jobs in [2usize, 3, 8] {
        assert_eq!(reference, compare_bits(&MATRIX, jobs), "jobs={jobs}");
    }
}
