//! Joint-placement search suite (ISSUE 5 acceptance pins).
//!
//! Three contracts:
//!
//! 1. **Joint ⊇ uniform** — the joint candidate family contains every
//!    uniform plan, so `placement_search(Joint)` can never return a
//!    plan worse (capacity or throughput) than
//!    `placement_search(Uniform)`, across presets × target batches.
//! 2. **Dominance pruning is lossless** — pruning only removes plans
//!    that lose to their dominator at every stage of the selection
//!    order, so the pruned search and the exhaustive (`prune: false`)
//!    search reach the *same* decision. Pinned exhaustively on the
//!    4-layer `bert-mini`.
//! 3. **The offload arm wins memory-bound capacity queries** — host
//!    offload retains no per-layer activation inventory on the device
//!    (stores free at completion, loads land just-in-time before each
//!    layer's backward), so its peak undercuts even serial
//!    checkpointing's stored-input floor. On the paper's memory-bound
//!    flagship (bert-large @ S=512 on the 11 GB card) the joint search
//!    must report a strictly higher max batch than the best
//!    rewrite+checkpoint plan — the ISSUE 7 acceptance pin.
//! 4. **The serial-vs-overlapped divergence flows through the plan
//!    axis** — `tests/schedule_equivalence.rs` pins that serial
//!    checkpointing peaks exactly `min(head, inventory)` below the
//!    overlapped schedule; the same delta shows through the uniform
//!    plans the search enumerates.
//! 5. **Tensor-parallel degrees win the big-card capacity query** —
//!    on the A100 box every shard degree divides the per-device
//!    inventory and the vocab-parallel head's B·S·V logits, so
//!    `TpPolicy::Auto` must select a degree > 1 whose max batch
//!    strictly exceeds the best tp=1 plan's — the ISSUE 10 acceptance
//!    pin. The dominance prune stays lossless with the shard axis in
//!    the family (degrees never cross-compare).

use tempo::autotempo::{
    placement_search, placement_search_jobs, placement_search_tp, placement_search_with,
    LayerPlan, PlacementMode, TpPolicy,
};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::coordinator::ExperimentEngine;
use tempo::graph::{encoder_summary, head_summary, CkptStyle, Residency};
use tempo::memmodel::{max_batch, max_batch_for_plan};

mod common;
use common::presets_search as presets;

const TARGETS: [usize; 3] = [1, 4, 32];

#[test]
fn joint_capacity_never_below_best_uniform() {
    for cfg in presets() {
        let uniform = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Uniform, None);
        let joint = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
        assert!(
            joint.max_batch >= uniform.max_batch,
            "{}: joint {} < uniform {}",
            cfg.name,
            joint.max_batch,
            uniform.max_batch
        );
        if joint.max_batch == uniform.max_batch {
            assert!(
                joint.throughput >= uniform.throughput,
                "{}: joint {} seq/s < uniform {}",
                cfg.name,
                joint.throughput,
                uniform.throughput
            );
        }
    }
}

#[test]
fn joint_target_never_below_best_uniform() {
    for cfg in presets() {
        for t in TARGETS {
            let uniform =
                placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Uniform, Some(t));
            let joint = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, Some(t));
            if uniform.max_batch >= t {
                assert!(
                    joint.max_batch >= t,
                    "{} target {t}: uniform reaches it but joint does not",
                    cfg.name
                );
                assert!(
                    joint.throughput >= uniform.throughput,
                    "{} target {t}: joint {} seq/s < uniform {}",
                    cfg.name,
                    joint.throughput,
                    uniform.throughput
                );
            } else {
                // neither family can beat physics; joint still matches
                // or beats the uniform fallback capacity
                assert!(joint.max_batch >= uniform.max_batch, "{} target {t}", cfg.name);
            }
        }
    }
}

#[test]
fn dominance_pruning_is_lossless_on_the_small_model() {
    // 4 layers: the exhaustive search prices every canonical candidate;
    // the pruned search must reach bit-identical decisions for every
    // mode × target
    let cfg = ModelConfig::bert_mini();
    for mode in [PlacementMode::Uniform, PlacementMode::Joint] {
        for target in [None, Some(1), Some(4), Some(32), Some(100_000)] {
            let pruned = placement_search_with(&cfg, Gpu::Rtx2080Ti, mode, target, true);
            let full = placement_search_with(&cfg, Gpu::Rtx2080Ti, mode, target, false);
            assert_eq!(
                pruned.plan, full.plan,
                "{mode:?} target {target:?}: pruned and exhaustive disagree\n  pruned: {}\n  full:   {}",
                pruned.rationale, full.rationale
            );
            assert_eq!(pruned.max_batch, full.max_batch, "{mode:?} target {target:?}");
            assert_eq!(pruned.eval_batch, full.eval_batch, "{mode:?} target {target:?}");
            assert!(
                (pruned.throughput - full.throughput).abs() == 0.0,
                "{mode:?} target {target:?}: throughput drifted"
            );
            // the prune really removed something, and nothing was lost
            assert!(pruned.stats.pruned > 0, "{mode:?} target {target:?}");
            assert_eq!(full.stats.pruned, 0);
            assert_eq!(
                pruned.stats.enumerated, full.stats.enumerated,
                "same candidate family either way"
            );
        }
    }
}

#[test]
fn memory_bound_capacity_query_is_won_by_an_offload_arm() {
    // bert-large @ S=512 on the 11 GB card is the paper's memory-bound
    // flagship. Serial checkpointing still retains each layer's stored
    // input on the device; offload ships even that over the host link
    // and frees it at store completion, so the offload arm's max batch
    // strictly exceeds the best rewrite+checkpoint plan's — the ISSUE 7
    // acceptance criterion.
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let gpu = Gpu::Rtx2080Ti;
    let d = placement_search(&cfg, gpu, PlacementMode::Joint, None);
    assert!(
        d.plan.residency.iter().any(|m| *m == Residency::Offload),
        "capacity winner carries no offload arm: {}",
        d.rationale
    );

    // strictly above the best checkpoint-only uniform plan (either style)
    let serial = LayerPlan::uniform_checkpoint(cfg.layers, CkptStyle::Serial);
    let over = LayerPlan::uniform_checkpoint(cfg.layers, CkptStyle::Overlapped);
    let b_serial = max_batch_for_plan(&cfg, &serial.schedule_plan(), gpu).max_batch;
    let b_over = max_batch_for_plan(&cfg, &over.schedule_plan(), gpu).max_batch;
    assert!(
        d.max_batch > b_serial.max(b_over),
        "offload {} !> checkpoint uniform {} / {}  ({})",
        d.max_batch,
        b_serial,
        b_over,
        d.rationale
    );
    // ... and ≥ every single-technique plan
    for t in tempo::config::Technique::all() {
        assert!(d.max_batch >= max_batch(&cfg, t, gpu).max_batch, "{t:?}");
    }
}

#[test]
fn tp_auto_wins_the_a100_capacity_query() {
    // ISSUE 10 acceptance pin: bert-large @ S=512 on the 40 GB A100.
    // Sharding divides both the encoder inventory and the vocab-
    // parallel head's B·S·V logits by the degree, while the best tp=1
    // plan is floored by its unshardable head activations — Auto must
    // pick a degree > 1 and strictly beat the tp=1 capacity winner.
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let tp1 = placement_search(&cfg, Gpu::A100, PlacementMode::Joint, None);
    assert_eq!(tp1.tp, 1, "the legacy entry point must stay shard-free");
    let auto = placement_search_tp(&cfg, Gpu::A100, PlacementMode::Joint, TpPolicy::Auto, None);
    assert!(auto.tp > 1, "auto capacity winner stayed at tp 1: {}", auto.rationale);
    assert!(
        auto.max_batch > tp1.max_batch,
        "tp {} max batch {} !> tp 1 max batch {}  ({})",
        auto.tp,
        auto.max_batch,
        tp1.max_batch,
        auto.rationale
    );
    // the winner really lowers sharded: its plan resolves to the
    // reported degree, and the degree is one the model's dims divide
    let sp = auto.plan.schedule_plan();
    assert_eq!(sp.resolved_tp(&cfg), auto.tp);
    assert!(cfg.tp_permitted(auto.tp));
}

#[test]
fn tp_auto_never_below_the_fixed_degree_searches() {
    // Auto explores the union of the per-degree families, so its
    // capacity can never fall below any fixed degree's
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let auto = placement_search_tp(&cfg, Gpu::A100, PlacementMode::Joint, TpPolicy::Auto, None);
    for d in [1usize, 2, 4, 8] {
        let fixed =
            placement_search_tp(&cfg, Gpu::A100, PlacementMode::Joint, TpPolicy::Fixed(d), None);
        assert!(
            auto.max_batch >= fixed.max_batch,
            "auto {} < fixed tp {d} {}",
            auto.max_batch,
            fixed.max_batch
        );
    }
}

#[test]
fn dominance_pruning_is_lossless_at_auto_shard_degrees() {
    // the shard axis adds per-degree families to the prune; degrees
    // never cross-compare (the DomKey carries the resolved degree), so
    // the pruned Auto search must still reach the exhaustive decision
    let cfg = ModelConfig::bert_mini();
    let engine = ExperimentEngine::new(1);
    for target in [None, Some(4), Some(100_000)] {
        let pruned = placement_search_jobs(
            &cfg,
            Gpu::A100,
            PlacementMode::Joint,
            TpPolicy::Auto,
            target,
            true,
            &engine,
        );
        let full = placement_search_jobs(
            &cfg,
            Gpu::A100,
            PlacementMode::Joint,
            TpPolicy::Auto,
            target,
            false,
            &engine,
        );
        assert_eq!(
            pruned.plan, full.plan,
            "target {target:?}: pruned and exhaustive disagree\n  pruned: {}\n  full:   {}",
            pruned.rationale, full.rationale
        );
        assert_eq!(pruned.max_batch, full.max_batch, "target {target:?}");
        assert_eq!(pruned.tp, full.tp, "target {target:?}");
        assert!(
            (pruned.throughput - full.throughput).abs() == 0.0,
            "target {target:?}: throughput drifted"
        );
        assert!(pruned.stats.pruned > 0, "target {target:?}");
        assert_eq!(
            pruned.stats.enumerated, full.stats.enumerated,
            "same candidate family either way"
        );
    }
}

#[test]
fn serial_divergence_flows_through_the_plan_axis() {
    // the all-serial uniform plan undercuts the overlapped uniform plan
    // by exactly min(head bytes, block inventory) — the enumerated
    // divergence of tests/schedule_equivalence.rs, surfaced through the
    // same LayerPlan constructors the search enumerates
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let serial = LayerPlan::uniform_checkpoint(cfg.layers, CkptStyle::Serial);
    let over = LayerPlan::uniform_checkpoint(cfg.layers, CkptStyle::Overlapped);
    let none = OptimizationSet::none();
    for batch in [1usize, 4, 32] {
        let b = batch as u64;
        let inventory = encoder_summary(&cfg, none).total_bytes(b);
        let head = head_summary(&cfg, none, true).total_bytes(b);
        assert_eq!(
            over.total_bytes(&cfg, batch) - serial.total_bytes(&cfg, batch),
            head.min(inventory),
            "B={batch}"
        );
    }
}
