//! Joint-placement search suite (ISSUE 5 acceptance pins).
//!
//! Three contracts:
//!
//! 1. **Joint ⊇ uniform** — the joint candidate family contains every
//!    uniform plan, so `placement_search(Joint)` can never return a
//!    plan worse (capacity or throughput) than
//!    `placement_search(Uniform)`, across presets × target batches.
//! 2. **Dominance pruning is lossless** — pruning only removes plans
//!    that lose to their dominator at every stage of the selection
//!    order, so the pruned search and the exhaustive (`prune: false`)
//!    search reach the *same* decision. Pinned exhaustively on the
//!    4-layer `bert-mini`.
//! 3. **The serial-vs-overlapped divergence flows through the search**
//!    — `tests/schedule_equivalence.rs` pins that serial checkpointing
//!    peaks exactly `min(head, inventory)` below the overlapped
//!    schedule; the search sees the same delta, so a memory-bound
//!    capacity query picks the all-serial placement and its peak
//!    undercuts the overlapped uniform plan by exactly that amount.

use tempo::autotempo::{placement_search, placement_search_with, LayerPlan, PlacementMode};
use tempo::config::{Gpu, ModelConfig, OptimizationSet};
use tempo::graph::{encoder_summary, head_summary, CkptMode};
use tempo::memmodel::{max_batch, max_batch_for_plan};

fn presets() -> Vec<ModelConfig> {
    vec![
        ModelConfig::bert_tiny(),
        ModelConfig::bert_mini(),
        ModelConfig::bert_base(),
        ModelConfig::bert_large().with_seq_len(512),
    ]
}

const TARGETS: [usize; 3] = [1, 4, 32];

#[test]
fn joint_capacity_never_below_best_uniform() {
    for cfg in presets() {
        let uniform = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Uniform, None);
        let joint = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
        assert!(
            joint.max_batch >= uniform.max_batch,
            "{}: joint {} < uniform {}",
            cfg.name,
            joint.max_batch,
            uniform.max_batch
        );
        if joint.max_batch == uniform.max_batch {
            assert!(
                joint.throughput >= uniform.throughput,
                "{}: joint {} seq/s < uniform {}",
                cfg.name,
                joint.throughput,
                uniform.throughput
            );
        }
    }
}

#[test]
fn joint_target_never_below_best_uniform() {
    for cfg in presets() {
        for t in TARGETS {
            let uniform =
                placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Uniform, Some(t));
            let joint = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, Some(t));
            if uniform.max_batch >= t {
                assert!(
                    joint.max_batch >= t,
                    "{} target {t}: uniform reaches it but joint does not",
                    cfg.name
                );
                assert!(
                    joint.throughput >= uniform.throughput,
                    "{} target {t}: joint {} seq/s < uniform {}",
                    cfg.name,
                    joint.throughput,
                    uniform.throughput
                );
            } else {
                // neither family can beat physics; joint still matches
                // or beats the uniform fallback capacity
                assert!(joint.max_batch >= uniform.max_batch, "{} target {t}", cfg.name);
            }
        }
    }
}

#[test]
fn dominance_pruning_is_lossless_on_the_small_model() {
    // 4 layers: the exhaustive search prices every canonical candidate;
    // the pruned search must reach bit-identical decisions for every
    // mode × target
    let cfg = ModelConfig::bert_mini();
    for mode in [PlacementMode::Uniform, PlacementMode::Joint] {
        for target in [None, Some(1), Some(4), Some(32), Some(100_000)] {
            let pruned = placement_search_with(&cfg, Gpu::Rtx2080Ti, mode, target, true);
            let full = placement_search_with(&cfg, Gpu::Rtx2080Ti, mode, target, false);
            assert_eq!(
                pruned.plan, full.plan,
                "{mode:?} target {target:?}: pruned and exhaustive disagree\n  pruned: {}\n  full:   {}",
                pruned.rationale, full.rationale
            );
            assert_eq!(pruned.max_batch, full.max_batch, "{mode:?} target {target:?}");
            assert_eq!(pruned.eval_batch, full.eval_batch, "{mode:?} target {target:?}");
            assert!(
                (pruned.throughput - full.throughput).abs() == 0.0,
                "{mode:?} target {target:?}: throughput drifted"
            );
            // the prune really removed something, and nothing was lost
            assert!(pruned.stats.pruned > 0, "{mode:?} target {target:?}");
            assert_eq!(full.stats.pruned, 0);
            assert_eq!(
                pruned.stats.enumerated, full.stats.enumerated,
                "same candidate family either way"
            );
        }
    }
}

#[test]
fn memory_bound_capacity_query_picks_the_serial_placement() {
    // bert-large @ S=512 on the 11 GB card is the paper's memory-bound
    // flagship: stored-input-only retention wins, and the serial arm's
    // lower peak beats the overlapped arm (equal census, no modeled
    // latency credit for the prefetch)
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let d = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
    assert_eq!(
        d.plan,
        LayerPlan::uniform_checkpoint(cfg.layers, CkptMode::Serial),
        "{}",
        d.rationale
    );

    // ≥ both uniform checkpoint modes, and ≥ every technique
    let serial = LayerPlan::uniform_checkpoint(cfg.layers, CkptMode::Serial);
    let over = LayerPlan::uniform_checkpoint(cfg.layers, CkptMode::Overlapped);
    let b_serial =
        max_batch_for_plan(&cfg, &serial.schedule_plan(), Gpu::Rtx2080Ti).max_batch;
    let b_over = max_batch_for_plan(&cfg, &over.schedule_plan(), Gpu::Rtx2080Ti).max_batch;
    assert_eq!(d.max_batch, b_serial);
    assert!(b_serial >= b_over);
    for t in tempo::config::Technique::all() {
        assert!(d.max_batch >= max_batch(&cfg, t, Gpu::Rtx2080Ti).max_batch, "{t:?}");
    }
}

#[test]
fn serial_divergence_flows_through_the_search_path() {
    // the chosen all-serial plan undercuts the overlapped uniform plan
    // by exactly min(head bytes, block inventory) — the enumerated
    // divergence of tests/schedule_equivalence.rs, now surfaced by the
    // search instead of a hand-built plan
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let d = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
    let over = LayerPlan::uniform_checkpoint(cfg.layers, CkptMode::Overlapped);
    let none = OptimizationSet::none();
    for batch in [1usize, 4, 32] {
        let b = batch as u64;
        let inventory = encoder_summary(&cfg, none).total_bytes(b);
        let head = head_summary(&cfg, none, true).total_bytes(b);
        assert_eq!(
            over.total_bytes(&cfg, batch) - d.plan.total_bytes(&cfg, batch),
            head.min(inventory),
            "B={batch}"
        );
    }
}
