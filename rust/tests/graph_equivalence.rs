//! Graph/closed-form equivalence suite.
//!
//! The PR that introduced `tempo::graph` replaced three independent
//! closed-form encodings of the transformer block (memmodel bytes,
//! perfmodel censuses, autotempo plan pricing) with folds over one
//! lowered layer graph. This suite pins the refactor: the **pre-refactor
//! closed forms are copied here verbatim as golden oracles**, and every
//! graph-derived number must match them *bit-identically* — exact `==`
//! on u64 bytes and on f64 censuses (every census term is an integer far
//! below 2⁵³, so f64 arithmetic is exact and fold order cannot perturb
//! it) — across all presets × batch ∈ {1, 4, 32} × every
//! `OptimizationSet` subset × every technique.

use tempo::autotempo::LayerPlan;
use tempo::config::{ModelConfig, ModelKind, OptimizationSet, Technique};
use tempo::memmodel::{layer_activation_bytes, ModelFootprint};
use tempo::perfmodel::{step_census, OpCensus};

mod common;
use common::{
    oracle_embedding_bytes, oracle_head_bytes, oracle_layer_bytes, presets_full as presets,
    BATCHES, F32,
};

// ---------------------------------------------------------------------------
// Golden oracle 1 (common::oracle_layer_bytes): the pre-refactor
// memmodel::layer closed form, pinned against the lowered fold here.
// ---------------------------------------------------------------------------

#[test]
fn layer_bytes_bit_identical_to_closed_form() {
    for cfg in presets() {
        for batch in BATCHES {
            for opts in OptimizationSet::all_subsets() {
                let got = layer_activation_bytes(&cfg, batch, opts);
                let (f, m, st) = oracle_layer_bytes(&cfg, batch, opts);
                assert_eq!(got.float_bytes, f, "{} B={batch} {opts:?}", cfg.name);
                assert_eq!(got.mask_bytes, m, "{} B={batch} {opts:?}", cfg.name);
                assert_eq!(got.stat_bytes, st, "{} B={batch} {opts:?}", cfg.name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Golden oracle 2: the pre-refactor perfmodel::ops closed forms.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
struct OracleCensus {
    matmul_flops: f64,
    vector_flops: f64,
    vector_bytes: f64,
    state_bytes: f64,
}

impl OracleCensus {
    fn zero() -> Self {
        OracleCensus { matmul_flops: 0.0, vector_flops: 0.0, vector_bytes: 0.0, state_bytes: 0.0 }
    }
    fn add(&mut self, o: OracleCensus) {
        self.matmul_flops += o.matmul_flops;
        self.vector_flops += o.vector_flops;
        self.vector_bytes += o.vector_bytes;
        self.state_bytes += o.state_bytes;
    }
    fn scale(mut self, f: f64) -> Self {
        self.matmul_flops *= f;
        self.vector_flops *= f;
        self.vector_bytes *= f;
        self.state_bytes *= f;
        self
    }
}

fn oracle_layer_forward(cfg: &ModelConfig, batch: usize) -> OracleCensus {
    let b = batch as f64;
    let s = cfg.seq_len as f64;
    let h = cfg.hidden as f64;
    let a = cfg.heads as f64;
    let i = cfg.intermediate as f64;
    let bsh = b * s * h;
    let bass = b * a * s * s;
    let matmul = 8.0 * bsh * h + 4.0 * b * s * s * h + 4.0 * bsh * i;
    let vector_bytes = 4.0 * (5.0 * bass + 8.0 * bsh + 3.0 * (b * s * i));
    let vector_flops = 4.0 * bass + 6.0 * bsh + 8.0 * (b * s * i);
    OracleCensus { matmul_flops: matmul, vector_flops, vector_bytes, state_bytes: 0.0 }
}

fn oracle_tempo_overhead(cfg: &ModelConfig, batch: usize) -> OracleCensus {
    let b = batch as f64;
    let s = cfg.seq_len as f64;
    let bass = b * cfg.heads as f64 * s * s;
    let bsi = b * s * cfg.intermediate as f64;
    OracleCensus {
        matmul_flops: 0.0,
        vector_flops: 26.0 * bsi + 2.0 * bass,
        vector_bytes: bass * 1.0 + bsi * 1.0,
        state_bytes: 0.0,
    }
}

fn oracle_head_forward(cfg: &ModelConfig, batch: usize) -> OracleCensus {
    let b = batch as f64;
    let s = cfg.seq_len as f64;
    let h = cfg.hidden as f64;
    let v = cfg.vocab_size as f64;
    OracleCensus {
        matmul_flops: 2.0 * b * s * h * h + 2.0 * b * s * h * v,
        vector_flops: 5.0 * b * s * v,
        vector_bytes: 4.0 * (4.0 * b * s * v + 6.0 * b * s * h),
        state_bytes: 0.0,
    }
}

fn oracle_step_census(cfg: &ModelConfig, technique: Technique, batch: usize) -> OracleCensus {
    let layers = cfg.layers as f64;
    let fwd = oracle_layer_forward(cfg, batch);
    let mut total = OracleCensus::zero();
    total.add(fwd.scale(3.0 * layers));
    total.add(oracle_head_forward(cfg, batch).scale(3.0));
    match technique {
        Technique::Checkpoint => {
            total.add(oracle_layer_forward(cfg, batch).scale(1.25 * layers));
        }
        Technique::Tempo => {
            total.add(oracle_tempo_overhead(cfg, batch).scale(layers));
        }
        Technique::Baseline => {}
    }
    let p = cfg.param_count() as f64;
    total.state_bytes += 4.0 * p * 9.0;
    total
}

fn assert_census_bits(got: OpCensus, want: OracleCensus, what: &str) {
    // exact f64 equality on purpose — see the module doc
    assert_eq!(got.matmul_flops, want.matmul_flops, "{what}: matmul_flops");
    assert_eq!(got.vector_flops, want.vector_flops, "{what}: vector_flops");
    assert_eq!(got.vector_bytes, want.vector_bytes, "{what}: vector_bytes");
    assert_eq!(got.state_bytes, want.state_bytes, "{what}: state_bytes");
}

#[test]
fn step_census_bit_identical_to_closed_form() {
    for cfg in presets() {
        for batch in BATCHES {
            for tech in Technique::all() {
                let got = step_census(&cfg, tech, batch);
                let want = oracle_step_census(&cfg, tech, batch);
                assert_census_bits(got, want, &format!("{} {tech:?} B={batch}", cfg.name));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Golden oracle 3 (common::oracle_{embedding,head}_bytes): the
// pre-refactor memmodel::model embedding / head / checkpoint closed
// forms, observed through `breakdown()`.
// ---------------------------------------------------------------------------

#[test]
fn breakdown_other_activations_bit_identical_to_closed_form() {
    for cfg in presets() {
        for batch in BATCHES {
            for opts in OptimizationSet::all_subsets() {
                for mlm in [true, false] {
                    let mut fp = ModelFootprint::with_opts(cfg.clone(), opts);
                    if !mlm {
                        fp = fp.finetune();
                    }
                    let bd = fp.breakdown(batch);
                    let want = oracle_embedding_bytes(&cfg, opts, batch)
                        + oracle_head_bytes(&cfg, opts, batch, mlm);
                    assert_eq!(
                        bd.other_activations, want,
                        "{} B={batch} mlm={mlm} {opts:?}",
                        cfg.name
                    );
                }
            }
        }
    }
}

#[test]
fn breakdown_encoder_and_transient_bit_identical_to_closed_form() {
    for cfg in presets() {
        for batch in BATCHES {
            // Baseline / Tempo / arbitrary subsets: encoder = L × layer
            // fold; transient = 2 × widest activation row.
            for opts in OptimizationSet::all_subsets() {
                let bd = ModelFootprint::with_opts(cfg.clone(), opts).breakdown(batch);
                let (f, m, st) = oracle_layer_bytes(&cfg, batch, opts);
                assert_eq!(
                    bd.encoder_activations,
                    cfg.layers as u64 * (f + m + st),
                    "{} B={batch} {opts:?}",
                    cfg.name
                );
                let b = batch as u64;
                let s = cfg.seq_len as u64;
                let wide =
                    (b * s * cfg.intermediate as u64).max(b * cfg.heads as u64 * s * s);
                assert_eq!(bd.transient, 2 * wide * F32, "{} B={batch}", cfg.name);
            }
            // Checkpoint: the segment-level rewrite stores only block
            // inputs; transient = full inventory + its float volume.
            let bd = ModelFootprint::new(cfg.clone(), Technique::Checkpoint).breakdown(batch);
            let b = batch as u64;
            let s = cfg.seq_len as u64;
            let h = cfg.hidden as u64;
            assert_eq!(bd.encoder_activations, cfg.layers as u64 * b * s * h * F32, "{}", cfg.name);
            let (f, m, st) = oracle_layer_bytes(&cfg, batch, OptimizationSet::none());
            assert_eq!(bd.transient, (f + m + st) + f, "{} B={batch}", cfg.name);
        }
    }
}

// ---------------------------------------------------------------------------
// Auto-Tempo plan pricing: the graph-backed fold must equal the sum of
// closed-form per-layer inventories for mixed plans.
// ---------------------------------------------------------------------------

#[test]
fn plan_bytes_bit_identical_for_mixed_plans() {
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let subsets = OptimizationSet::all_subsets();
    // a deliberately non-uniform plan cycling through all 16 subsets
    let per_layer: Vec<OptimizationSet> =
        (0..cfg.layers).map(|l| subsets[l % subsets.len()]).collect();
    let plan = LayerPlan::rewrites_only(per_layer.clone());
    for batch in BATCHES {
        let base = ModelFootprint::new(cfg.clone(), Technique::Baseline).breakdown(batch);
        let oracle_encoder: u64 = per_layer
            .iter()
            .map(|o| {
                let (f, m, s) = oracle_layer_bytes(&cfg, batch, *o);
                f + m + s
            })
            .sum();
        assert_eq!(
            plan.total_bytes(&cfg, batch),
            base.total() - base.encoder_activations + oracle_encoder,
            "B={batch}"
        );
    }
}

// ---------------------------------------------------------------------------
// The GPT2 special case is now a lowering rule — and only fires for GPT2.
// ---------------------------------------------------------------------------

#[test]
fn gpt2_unfused_penalty_preserved_exactly() {
    let gpt2 = ModelConfig::gpt2();
    let mut bert_shaped = ModelConfig::gpt2();
    bert_shaped.kind = ModelKind::Bert;
    for batch in BATCHES {
        let with = layer_activation_bytes(&gpt2, batch, OptimizationSet::none());
        let without = layer_activation_bytes(&bert_shaped, batch, OptimizationSet::none());
        let b = batch as u64;
        let bass = b * gpt2.heads as u64 * (gpt2.seq_len as u64).pow(2);
        assert_eq!(with.float_bytes - without.float_bytes, 2 * bass * F32);
        // and the output-only softmax deletes the penalty entirely
        let sm = OptimizationSet::only("softmax").unwrap();
        assert_eq!(
            layer_activation_bytes(&gpt2, batch, sm).float_bytes,
            layer_activation_bytes(&bert_shaped, batch, sm).float_bytes
        );
    }
}
