//! Environment-knob contract (DESIGN.md §Lanes): `TEMPO_UTIL_K`,
//! `TEMPO_AR_EXPOSE`, `TEMPO_HOST_BW` and `TEMPO_TP_BW` are parsed
//! **once per process** (`OnceLock`), a malformed value is a startup
//! error rather than a per-call panic,
//! and `TEMPO_AR_EXPOSE` reproduces the legacy latency-blind pricing
//! exactly.
//!
//! All in-process env mutation lives in ONE test — tests in a binary
//! run on parallel threads, and the whole point of the cache is that
//! the first read wins for the process lifetime. The other tests spawn
//! the `tempo` binary, so each probe gets a fresh cache.

use std::process::Command;

use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::graph::SchedulePlan;
use tempo::perfmodel::{plan_lane_times, utilization, validate_env_knobs};

#[test]
fn knobs_parse_once_and_legacy_exposure_reprices_the_old_model() {
    // both knobs set BEFORE the first pricing call in this process
    std::env::set_var("TEMPO_UTIL_K", "80.0");
    std::env::set_var("TEMPO_AR_EXPOSE", "0.3");
    assert!(validate_env_knobs().is_ok(), "well-formed knobs must validate");

    // --- TEMPO_UTIL_K is read once, then cached ---
    let spec = Gpu::V100.spec();
    let u1 = utilization(&spec, 2048.0);
    std::env::set_var("TEMPO_UTIL_K", "20.0");
    let u2 = utilization(&spec, 2048.0);
    assert_eq!(u1, u2, "knob changed mid-process must not change pricing");
    std::env::remove_var("TEMPO_UTIL_K");
    assert_eq!(u1, utilization(&spec, 2048.0), "unset mid-process must not either");

    // --- TEMPO_AR_EXPOSE: the legacy escape hatch prices the old
    // latency-blind model exactly: a flat `expose` fraction of the flat
    // 2·(4·params)/bw all-reduce, no hidden-recompute credit, and no
    // devices gate (the old model had no devices concept) ---
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
    let gpu = Gpu::Rtx2080Ti.spec();
    let bw = gpu.allreduce_bw.unwrap();
    let lt = plan_lane_times(&cfg, &plan, &gpu, 4);
    let expect_total = 2.0 * (cfg.param_count() as f64 * 4.0) / bw;
    assert_eq!(lt.comm_total, expect_total, "legacy flat all-reduce total");
    assert_eq!(lt.comm_exposed, 0.3 * expect_total, "legacy flat exposure fraction");
    assert_eq!(lt.hidden_recompute, 0.0, "legacy pricing credits no hidden recompute");
    assert_eq!(lt.step, lt.compute + lt.comm_exposed);
    let ckpt = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
    assert_eq!(
        plan_lane_times(&cfg, &ckpt, &gpu, 4).hidden_recompute,
        0.0,
        "even overlapped plans hide nothing under the legacy model"
    );
    let solo = plan_lane_times(&cfg, &plan, &gpu.with_devices(1), 4);
    assert_eq!(solo.comm_exposed, lt.comm_exposed, "legacy pricing ignores the devices knob");
    std::env::remove_var("TEMPO_AR_EXPOSE");
}

fn tempo_cmd() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_tempo"));
    c.env_remove("TEMPO_UTIL_K")
        .env_remove("TEMPO_AR_EXPOSE")
        .env_remove("TEMPO_HOST_BW")
        .env_remove("TEMPO_TP_BW");
    c
}

#[test]
fn malformed_knob_is_a_startup_error() {
    for (knob, value) in [
        // unparseable
        ("TEMPO_UTIL_K", "abc"),
        ("TEMPO_AR_EXPOSE", "0.3.5"),
        ("TEMPO_HOST_BW", "fast"),
        // parseable but out of the knob's accepted range
        ("TEMPO_UTIL_K", "0"),
        ("TEMPO_UTIL_K", "inf"),
        ("TEMPO_AR_EXPOSE", "-0.1"),
        ("TEMPO_HOST_BW", "-1e9"),
        ("TEMPO_HOST_BW", "NaN"),
        ("TEMPO_TP_BW", "slow"),
        ("TEMPO_TP_BW", "0"),
        ("TEMPO_TP_BW", "-inf"),
    ] {
        let out = tempo_cmd()
            .args(["max-batch", "--model", "bert-tiny"])
            .env(knob, value)
            .output()
            .expect("spawn tempo binary");
        assert!(!out.status.success(), "{knob}={value} must fail startup validation");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(knob), "{knob}: stderr should name the knob, got: {err}");
        assert!(
            err.contains("expected a finite"),
            "{knob}={value}: stderr should state the accepted range, got: {err}"
        );
    }
    // well-formed values pass the same gate
    let out = tempo_cmd()
        .args(["max-batch", "--model", "bert-tiny"])
        .env("TEMPO_UTIL_K", "75.5")
        .env("TEMPO_AR_EXPOSE", "0.15")
        .output()
        .expect("spawn tempo binary");
    assert!(
        out.status.success(),
        "valid knobs rejected: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn sweeps_stay_jobs_invariant_with_knobs_set() {
    // the concurrency contract (DESIGN.md §Concurrency) must survive
    // knob-driven pricing: stdout is bit-identical for every --jobs
    // value with the cached knobs in effect
    let run = |jobs: &str| {
        let out = tempo_cmd()
            .args(["compare", "--steps", "12", "--jobs", jobs])
            .env("TEMPO_UTIL_K", "80.0")
            .env("TEMPO_AR_EXPOSE", "0.15")
            .output()
            .expect("spawn tempo binary");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out.stdout
    };
    assert_eq!(run("1"), run("4"), "--jobs 4 stdout diverged from --jobs 1");
}
