//! ISSUE 7 tentpole pin: the `CkptMode → Residency` refactor is
//! **invisible** to every offload-free plan. This file embeds the PR 6
//! `plan_lane_times` fold verbatim as a golden oracle — same
//! expressions, same association order, so equality below is float
//! *bit*-identity, not tolerance — and checks every offload-free plan
//! family the search can produce against it across presets × rigs ×
//! batches. A plan that offloads nothing must price exactly as it did
//! before the host lane existed, and its timeline peak must be the
//! same schedule the pre-refactor lowering produced.
//!
//! (The style of `tests/schedule_equivalence.rs`: an independently
//! written model of the old behavior, not a snapshot of numbers.)

use tempo::config::{Gpu, GpuSpec, ModelConfig, OptimizationSet, Technique};
use tempo::graph::{schedule_summary, Census, CkptStyle, Residency, SchedulePlan};
use tempo::perfmodel::{plan_census, plan_lane_times, utilization, OpCensus, OVERLAP_EFF};

mod common;
use common::presets_pricing as presets;

/// PR 6 compute-lane core: seconds of a batch-scaled census.
fn census_seconds(c: Census, spec: &GpuSpec, util: f64) -> f64 {
    c.matmul_flops / (spec.peak_matmul_flops * util)
        + c.vector_flops / (spec.peak_vector_flops * 0.6)
        + c.vector_bytes / (spec.bandwidth * 0.75)
}

/// PR 6 full-step census fold (matmul + vector + state streams).
fn opcensus_seconds(census: &OpCensus, spec: &GpuSpec, util: f64) -> f64 {
    let t_matmul = census.matmul_flops / (spec.peak_matmul_flops * util);
    let t_vector = census.vector_flops / (spec.peak_vector_flops * 0.6)
        + census.vector_bytes / (spec.bandwidth * 0.75);
    let t_state = census.state_bytes / (spec.bandwidth * 0.75);
    t_matmul + t_vector + t_state
}

/// The PR 6 lane fold, verbatim: compute lane with the prefetch-hidden
/// credit, bucketed ring all-reduce with the carrying exposure fold,
/// and nothing else — the host lane did not exist.
/// Returns `(compute, hidden_recompute, comm_total, comm_exposed, step)`.
fn pr6_lane_times(
    cfg: &ModelConfig,
    plan: &SchedulePlan,
    spec: &GpuSpec,
    batch: usize,
) -> (f64, f64, f64, f64, f64) {
    let b = batch as f64;
    let tokens = b * cfg.seq_len as f64;
    let util = utilization(spec, tokens);
    let total = plan_census(cfg, plan, batch);
    let total_s = opcensus_seconds(&total, spec, util);
    let t_fixed = 0.7e-3 + cfg.layers as f64 * 60.0e-6;

    let summary = schedule_summary(cfg, plan);
    let hidden_s = OVERLAP_EFF * census_seconds(summary.lanes.hidden.scale(b), spec, util);
    let compute = total_s - hidden_s + t_fixed;

    let (comm_total, comm_exposed) = match spec.allreduce_bw {
        Some(bw) if spec.devices > 1 => {
            let ring = 2.0 * (spec.devices as f64 - 1.0) / spec.devices as f64;
            let durs: Vec<f64> =
                summary.lanes.buckets.iter().map(|bk| ring * bk.bytes as f64 / bw).collect();
            let total_comm: f64 = durs.iter().sum();
            let mut exposed = 0.0f64;
            let mut remaining = total_comm;
            for (bk, d) in summary.lanes.buckets.iter().zip(&durs) {
                let lag = census_seconds(bk.tail.scale(b), spec, util);
                exposed = exposed.max(remaining - lag);
                remaining -= d;
            }
            (total_comm, exposed.max(0.0))
        }
        _ => (0.0, 0.0),
    };

    (compute, hidden_s, comm_total, comm_exposed, compute + comm_exposed)
}

/// Every offload-free plan family: the three technique plans, their
/// serial twins, uniform rewrite plans, and mixed per-layer placements
/// with both checkpoint styles.
fn offload_free_plans(cfg: &ModelConfig) -> Vec<SchedulePlan> {
    let n = cfg.layers;
    let mut plans = Vec::new();
    for t in Technique::all() {
        let p = SchedulePlan::for_technique(cfg, t, true);
        plans.push(p.clone().serial());
        plans.push(p);
    }
    plans.push(SchedulePlan::uniform(cfg, OptimizationSet::none(), true));
    // mixed placement: rewrites everywhere, bottom half checkpointed in
    // alternating styles — the shape the joint search emits
    let mut residency = vec![Residency::Resident; n];
    for (l, arm) in residency.iter_mut().enumerate().take(n / 2 + 1) {
        *arm = if l % 2 == 0 {
            Residency::Checkpoint(CkptStyle::Overlapped)
        } else {
            Residency::Checkpoint(CkptStyle::Serial)
        };
    }
    plans.push(SchedulePlan::from_placement(
        vec![OptimizationSet::full(); n],
        residency,
        true,
    ));
    plans
}

#[test]
fn offload_free_plans_price_bit_identically_to_the_pr6_fold() {
    for cfg in presets() {
        for plan in offload_free_plans(&cfg) {
            assert!(!plan.any_offload(), "{}: fixture leaked an offload arm", cfg.name);
            for gpu in Gpu::all() {
                let spec = gpu.spec();
                for b in [1usize, 4, 32] {
                    let lt = plan_lane_times(&cfg, &plan, &spec, b);
                    let (compute, hidden, comm_total, comm_exposed, step) =
                        pr6_lane_times(&cfg, &plan, &spec, b);
                    let ctx =
                        format!("{} {} B={b} plan={}", cfg.name, gpu.name(), plan.label());
                    assert_eq!(lt.compute, compute, "{ctx}");
                    assert_eq!(lt.hidden_recompute, hidden, "{ctx}");
                    assert_eq!(lt.comm_total, comm_total, "{ctx}");
                    assert_eq!(lt.comm_exposed, comm_exposed, "{ctx}");
                    assert_eq!(lt.host_total, 0.0, "{ctx}");
                    assert_eq!(lt.host_exposed, 0.0, "{ctx}");
                    assert_eq!(lt.step, step, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn offload_free_timelines_have_no_host_lane_events() {
    // the lowering side of the same pin: a plan with no Offload arm
    // produces a schedule whose host-lane transfer lists are empty, so
    // the peak, the high-water event and every liveness fold are the
    // PR 6 schedule's — there is no event the old lowering would not
    // have emitted
    for cfg in presets() {
        for plan in offload_free_plans(&cfg) {
            let s = schedule_summary(&cfg, &plan);
            assert!(s.lanes.stores.is_empty(), "{} {}", cfg.name, plan.label());
            assert!(s.lanes.loads.is_empty(), "{} {}", cfg.name, plan.label());
        }
    }
}
