//! ISSUE 10 tentpole pin: the tensor-parallel lane is **invisible** at
//! shard degree 1. This file embeds the pre-TP `plan_lane_times` fold
//! verbatim as a golden oracle — the PR 8/9 model with compute, comm
//! and host lanes but no TP lane; same expressions, same association
//! order, so equality below is float *bit*-identity, not tolerance —
//! and checks every degree-1 plan family the search can produce
//! against it across presets × techniques × residency arms × rigs ×
//! batches. A plan that resolves to shard degree 1 (the default, an
//! explicit `with_tp(1)`, an impermissible degree, or `Residency::
//! Shard` arms resolving to `Resident`) must price exactly as it did
//! before the TP lane existed, and its lowered timeline must carry no
//! all-gather/reduce-scatter event the old lowering would not have
//! emitted.
//!
//! (The style of `tests/residency_equivalence.rs`: an independently
//! written model of the old behavior, not a snapshot of numbers.)

use tempo::config::{Gpu, GpuSpec, ModelConfig, OptimizationSet, Technique};
use tempo::graph::{schedule_summary, Census, CkptStyle, EventKind, Lowering, Residency, SchedulePlan};
use tempo::perfmodel::{plan_census, plan_lane_times, utilization, OpCensus, OVERLAP_EFF};

mod common;
use common::presets_pricing as presets;

/// Pre-TP compute-lane core: seconds of a batch-scaled census.
fn census_seconds(c: Census, spec: &GpuSpec, util: f64) -> f64 {
    c.matmul_flops / (spec.peak_matmul_flops * util)
        + c.vector_flops / (spec.peak_vector_flops * 0.6)
        + c.vector_bytes / (spec.bandwidth * 0.75)
}

/// Pre-TP full-step census fold (matmul + vector + state streams).
fn opcensus_seconds(census: &OpCensus, spec: &GpuSpec, util: f64) -> f64 {
    let t_matmul = census.matmul_flops / (spec.peak_matmul_flops * util);
    let t_vector = census.vector_flops / (spec.peak_vector_flops * 0.6)
        + census.vector_bytes / (spec.bandwidth * 0.75);
    let t_state = census.state_bytes / (spec.bandwidth * 0.75);
    t_matmul + t_vector + t_state
}

/// The PR 8/9 lane fold, verbatim: compute lane with the
/// prefetch-hidden credit, bucketed ring all-reduce with the carrying
/// exposure fold, host lane with the store-lag/load-tail fold — and no
/// TP lane, because it did not exist. Returns
/// `(compute, hidden, comm_total, comm_exposed, host_total,
/// host_exposed, step)`.
#[allow(clippy::type_complexity)]
fn pre_tp_lane_times(
    cfg: &ModelConfig,
    plan: &SchedulePlan,
    spec: &GpuSpec,
    batch: usize,
) -> (f64, f64, f64, f64, f64, f64, f64) {
    let b = batch as f64;
    let tokens = b * cfg.seq_len as f64;
    let util = utilization(spec, tokens);
    let total = plan_census(cfg, plan, batch);
    let total_s = opcensus_seconds(&total, spec, util);
    let t_fixed = 0.7e-3 + cfg.layers as f64 * 60.0e-6;

    let summary = schedule_summary(cfg, plan);
    let hidden_s = OVERLAP_EFF * census_seconds(summary.lanes.hidden.scale(b), spec, util);
    let compute = total_s - hidden_s + t_fixed;

    let (comm_total, comm_exposed) = match spec.allreduce_bw {
        Some(bw) if spec.devices > 1 => {
            let ring = 2.0 * (spec.devices as f64 - 1.0) / spec.devices as f64;
            let durs: Vec<f64> =
                summary.lanes.buckets.iter().map(|bk| ring * bk.bytes as f64 / bw).collect();
            let total_comm: f64 = durs.iter().sum();
            let mut exposed = 0.0f64;
            let mut remaining = total_comm;
            for (bk, d) in summary.lanes.buckets.iter().zip(&durs) {
                let lag = census_seconds(bk.tail.scale(b), spec, util);
                exposed = exposed.max(remaining - lag);
                remaining -= d;
            }
            (total_comm, exposed.max(0.0))
        }
        _ => (0.0, 0.0),
    };

    let host_bw = spec.host_link_bw;
    let mut host_total = 0.0f64;
    let mut store_lag = 0.0f64;
    for t in &summary.lanes.stores {
        let d = t.bytes as f64 * b / host_bw;
        let c = census_seconds(t.cover.scale(b), spec, util);
        host_total += d;
        store_lag = (store_lag + d - c).max(0.0);
    }
    let mut load_exposed = 0.0f64;
    for t in &summary.lanes.loads {
        let d = t.bytes as f64 * b / host_bw;
        let c = census_seconds(t.cover.scale(b), spec, util);
        host_total += d;
        load_exposed += (d - c).max(0.0);
    }
    let host_exposed = store_lag + load_exposed;

    (
        compute,
        hidden_s,
        comm_total,
        comm_exposed,
        host_total,
        host_exposed,
        compute + comm_exposed + host_exposed,
    )
}

/// Every plan family that resolves to shard degree 1: the technique
/// plans and their serial twins, uniform rewrite plans, mixed
/// checkpoint placements, offload placements, `Shard` arms at the
/// default degree (they resolve to `Resident`), an explicit
/// `with_tp(1)`, and an impermissible degree (resolves to 1).
fn degree_one_plans(cfg: &ModelConfig) -> Vec<(String, SchedulePlan)> {
    let n = cfg.layers;
    let mut plans: Vec<(String, SchedulePlan)> = Vec::new();
    for t in Technique::all() {
        let p = SchedulePlan::for_technique(cfg, t, true);
        plans.push((format!("{t:?}/serial"), p.clone().serial()));
        plans.push((format!("{t:?}"), p));
    }
    plans.push(("none".into(), SchedulePlan::uniform(cfg, OptimizationSet::none(), true)));
    // mixed residency: every arm family in one placement
    let mut residency = vec![Residency::Resident; n];
    for (l, arm) in residency.iter_mut().enumerate() {
        *arm = match l % 4 {
            0 => Residency::Checkpoint(CkptStyle::Overlapped),
            1 => Residency::Offload,
            2 => Residency::Checkpoint(CkptStyle::Serial),
            _ => Residency::Resident,
        };
    }
    plans.push((
        "mixed".into(),
        SchedulePlan::from_placement(vec![OptimizationSet::full(); n], residency, true),
    ));
    // Shard arms at degree 1 resolve to Resident
    plans.push((
        "shard-arms-tp1".into(),
        SchedulePlan::from_placement(
            vec![OptimizationSet::full(); n],
            vec![Residency::Shard; n],
            true,
        ),
    ));
    // explicit degree 1, and a degree the model's dims do not divide
    // (7 divides no preset's head count) — both resolve to 1
    let base = SchedulePlan::uniform(cfg, OptimizationSet::full(), true);
    plans.push(("with-tp-1".into(), base.clone().with_tp(1)));
    plans.push(("with-tp-7".into(), base.with_tp(7)));
    plans
}

#[test]
fn degree_one_plans_price_bit_identically_to_the_pre_tp_fold() {
    for cfg in presets() {
        for (label, plan) in degree_one_plans(&cfg) {
            assert_eq!(plan.resolved_tp(&cfg), 1, "{}: fixture must resolve unsharded", label);
            for gpu in Gpu::all() {
                let spec = gpu.spec();
                for b in [1usize, 4, 32] {
                    let lt = plan_lane_times(&cfg, &plan, &spec, b);
                    let (compute, hidden, comm_total, comm_exposed, host_total, host_exposed, step) =
                        pre_tp_lane_times(&cfg, &plan, &spec, b);
                    let ctx = format!("{} {} B={b} plan={label}", cfg.name, gpu.name());
                    assert_eq!(lt.compute, compute, "{ctx}");
                    assert_eq!(lt.hidden_recompute, hidden, "{ctx}");
                    assert_eq!(lt.comm_total, comm_total, "{ctx}");
                    assert_eq!(lt.comm_exposed, comm_exposed, "{ctx}");
                    assert_eq!(lt.host_total, host_total, "{ctx}");
                    assert_eq!(lt.host_exposed, host_exposed, "{ctx}");
                    assert_eq!(lt.tp_total, 0.0, "{ctx}");
                    assert_eq!(lt.tp_exposed, 0.0, "{ctx}");
                    assert_eq!(lt.step, step, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn degree_one_timelines_have_no_tp_lane_events() {
    // the lowering side of the same pin: a plan that resolves to shard
    // degree 1 produces a schedule whose TP collective list is empty
    // and whose event tape carries no all-gather/reduce-scatter — there
    // is no event the pre-TP lowering would not have emitted
    for cfg in presets() {
        for (label, plan) in degree_one_plans(&cfg) {
            let s = schedule_summary(&cfg, &plan);
            assert!(s.lanes.tp_links.is_empty(), "{} {label}", cfg.name);
            let schedule = tempo::graph::lower_step(&cfg, &plan, Lowering::for_model(&cfg));
            assert!(
                !schedule
                    .events
                    .iter()
                    .any(|e| matches!(e.kind, EventKind::AllGather | EventKind::ReduceScatter)),
                "{} {label}: tp collectives in an unsharded tape",
                cfg.name
            );
        }
    }
}

#[test]
fn shard_arms_at_degree_one_are_resident_bit_identically() {
    // `Residency::Shard` is meaningful only under a resolved degree;
    // at degree 1 the whole summary (peak, classes, census, lanes) must
    // equal the all-Resident plan's — this is what lets the search keep
    // the Shard arm in the walk at every degree
    for cfg in presets() {
        for subset in [OptimizationSet::none(), OptimizationSet::full()] {
            let n = cfg.layers;
            let shard = SchedulePlan::from_placement(
                vec![subset; n],
                vec![Residency::Shard; n],
                true,
            );
            let resident = SchedulePlan::from_placement(
                vec![subset; n],
                vec![Residency::Resident; n],
                true,
            );
            let a = schedule_summary(&cfg, &shard);
            let b = schedule_summary(&cfg, &resident);
            assert_eq!(*a, *b, "{} {subset:?}", cfg.name);
            for batch in [1u64, 4, 32] {
                assert_eq!(a.peak_bytes(batch), b.peak_bytes(batch), "{} B={batch}", cfg.name);
            }
        }
    }
}

#[test]
fn impermissible_degrees_price_as_the_unsharded_plan() {
    // bert-tiny has 2 heads: degrees 4 and 8 do not divide, so the
    // resolved degree is 1 and pricing is bit-identical to the default
    let cfg = ModelConfig::bert_tiny();
    let base = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
    for d in [4usize, 8] {
        let forced = base.clone().with_tp(d);
        assert_eq!(forced.resolved_tp(&cfg), 1);
        assert_eq!(*schedule_summary(&cfg, &forced), *schedule_summary(&cfg, &base));
        for gpu in Gpu::all() {
            let spec = gpu.spec();
            let lt = plan_lane_times(&cfg, &forced, &spec, 4);
            let lt_base = plan_lane_times(&cfg, &base, &spec, 4);
            assert_eq!(lt, lt_base, "{} tp {d}", gpu.name());
        }
    }
}
