//! `--json` CLI round-trip: `tempo graph --json` and `tempo schedule
//! --json` each emit a single JSON document whose embedded table
//! round-trips through `report::Table::from_json` and whose totals
//! match the library folds bit-for-bit.

use std::process::Command;

use tempo::config::{ModelConfig, OptimizationSet};
use tempo::report::Table;
use tempo::util::Json;

fn run(args: &[&str]) -> String {
    let out = Command::new(env!("CARGO_BIN_EXE_tempo"))
        .args(args)
        .output()
        .expect("spawn tempo binary");
    assert!(
        out.status.success(),
        "tempo {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).expect("utf8 stdout")
}

#[test]
fn graph_json_round_trips_and_matches_the_fold() {
    let text = run(&["graph", "bert-tiny", "--json", "--batch", "2"]);
    let doc = Json::parse(&text).expect("graph --json emits one JSON document");
    assert_eq!(doc.req("model").unwrap().as_str().unwrap(), "bert-tiny");
    assert_eq!(doc.req("batch").unwrap().as_usize().unwrap(), 2);

    // table round-trip: parse → from_json → to_json is stable
    let table = Table::from_json(doc.req("table").unwrap()).unwrap();
    assert!(!table.rows.is_empty());
    let reparsed = Json::parse(&table.to_json().pretty()).unwrap();
    assert_eq!(Table::from_json(&reparsed).unwrap().rows, table.rows);

    // totals agree with the library fold (default technique = tempo)
    let expect = tempo::memmodel::layer_activation_bytes(
        &ModelConfig::bert_tiny(),
        2,
        OptimizationSet::full(),
    );
    let totals = doc.req("totals").unwrap();
    assert_eq!(
        totals.req("total_bytes").unwrap().as_f64().unwrap() as u64,
        expect.total()
    );
    assert_eq!(
        totals.req("float_bytes").unwrap().as_f64().unwrap() as u64,
        expect.float_bytes
    );
}

#[test]
fn schedule_json_round_trips_and_matches_memmodel() {
    let text =
        run(&["schedule", "bert-tiny", "--json", "--batch", "4", "--technique", "checkpoint"]);
    let doc = Json::parse(&text).expect("schedule --json emits one JSON document");

    // the timeline peak IS the capacity model's total (default,
    // overlapped checkpoint semantics)
    let peak = doc.req("peak_bytes").unwrap().as_f64().unwrap() as u64;
    let fold = doc.req("memmodel_total_bytes").unwrap().as_f64().unwrap() as u64;
    assert_eq!(peak, fold);
    assert_eq!(doc.req("high_water").unwrap().as_str().unwrap(), "ckpt re-forward + grads");

    // table round-trip, with exactly one peak-marked event row
    let table = Table::from_json(doc.req("table").unwrap()).unwrap();
    assert_eq!(table.headers.len(), 9);
    let marked: Vec<usize> = table
        .rows
        .iter()
        .enumerate()
        .filter(|(_, r)| r[8] == "<- peak")
        .map(|(i, _)| i)
        .collect();
    assert_eq!(marked.len(), 1);
    assert_eq!(marked[0], doc.req("peak_event").unwrap().as_usize().unwrap());
    let reparsed = Json::parse(&table.to_json().pretty()).unwrap();
    assert_eq!(Table::from_json(&reparsed).unwrap().rows, table.rows);

    // the lane column round-trips through Lane::label(): every row
    // carries one of the three canonical tags, and an overlapped
    // checkpoint timeline uses both device lanes
    let lane_col = table.headers.iter().position(|h| h == "lane").expect("lane header");
    assert_eq!(lane_col, 2);
    for r in &table.rows {
        assert!(
            ["compute", "prefetch", "host"].contains(&r[lane_col].as_str()),
            "unknown lane tag {:?}",
            r[lane_col]
        );
    }
    assert!(table.rows.iter().any(|r| r[lane_col] == "prefetch"));
}

#[test]
fn schedule_json_reports_the_host_lane() {
    // the JSON document always carries the host-lane seconds; the CLI's
    // technique plans are offload-free, so both must be exactly zero
    let text = run(&["schedule", "bert-tiny", "--json", "--batch", "4"]);
    let doc = Json::parse(&text).expect("schedule --json emits one JSON document");
    // offload-free plans price a zero host lane
    assert_eq!(doc.req("host_total_s").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(doc.req("host_exposed_s").unwrap().as_f64().unwrap(), 0.0);
}

#[test]
fn schedule_json_reports_the_tp_lane() {
    // --tp 2 on bert-tiny (2 heads): a sharded timeline with in-block
    // all-gather/reduce-scatter events on the tp lane
    let text = run(&["schedule", "bert-tiny", "--json", "--batch", "4", "--tp", "2"]);
    let doc = Json::parse(&text).expect("schedule --json emits one JSON document");
    assert_eq!(doc.req("tp").unwrap().as_usize().unwrap(), 2);
    let total = doc.req("tp_total_s").unwrap().as_f64().unwrap();
    let exposed = doc.req("tp_exposed_s").unwrap().as_f64().unwrap();
    assert!(total > 0.0, "sharded timeline must pay collective time");
    assert!((0.0..=total).contains(&exposed), "exposed {exposed} ∉ [0, {total}]");
    let table = Table::from_json(doc.req("table").unwrap()).unwrap();
    assert!(
        table.rows.iter().any(|r| r[2] == "tp" && r[1] == "ag"),
        "expected all-gather events on the tp lane"
    );
    assert!(
        table.rows.iter().any(|r| r[2] == "tp" && r[1] == "rs"),
        "expected reduce-scatter events on the tp lane"
    );

    // the unsharded default reports degree 1 and a zero tp lane
    let text = run(&["schedule", "bert-tiny", "--json", "--batch", "4"]);
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.req("tp").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.req("tp_total_s").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(doc.req("tp_exposed_s").unwrap().as_f64().unwrap(), 0.0);
}

#[test]
fn placement_json_round_trips_and_matches_the_search() {
    let text = run(&["placement", "bert-tiny", "--json", "--gpu", "2080ti"]);
    let doc = Json::parse(&text).expect("placement --json emits one JSON document");
    assert_eq!(doc.req("model").unwrap().as_str().unwrap(), "bert-tiny");
    assert_eq!(doc.req("mode").unwrap().as_str().unwrap(), "joint");

    // one table row per encoder layer, round-tripping cleanly
    let table = Table::from_json(doc.req("table").unwrap()).unwrap();
    assert_eq!(table.rows.len(), ModelConfig::bert_tiny().layers);
    let reparsed = Json::parse(&table.to_json().pretty()).unwrap();
    assert_eq!(Table::from_json(&reparsed).unwrap().rows, table.rows);

    // numbers agree with the library search
    let d = tempo::autotempo::placement_search(
        &ModelConfig::bert_tiny(),
        tempo::config::Gpu::Rtx2080Ti,
        tempo::autotempo::PlacementMode::Joint,
        None,
    );
    assert_eq!(doc.req("max_batch").unwrap().as_usize().unwrap(), d.max_batch);
    assert_eq!(
        doc.req("checkpointed_layers").unwrap().as_usize().unwrap(),
        d.plan.checkpointed_layers()
    );
    assert_eq!(
        doc.req("candidates").unwrap().as_usize().unwrap(),
        d.stats.enumerated
    );
    // shard-free default: degree 1, no sharded layers
    assert_eq!(doc.req("tp").unwrap().as_usize().unwrap(), d.tp);
    assert_eq!(d.tp, 1);
    assert_eq!(doc.req("sharded_layers").unwrap().as_usize().unwrap(), 0);
}

#[test]
fn schedule_reports_the_comm_lane() {
    // default rig: 4×2080Ti — bucketed gradient all-reduce on the comm
    // lane, one bucket per parameter segment (L encoders + head + emb)
    let text = run(&["schedule", "bert-tiny", "--json", "--batch", "4"]);
    let doc = Json::parse(&text).expect("schedule --json emits one JSON document");
    let layers = ModelConfig::bert_tiny().layers;
    assert_eq!(doc.req("devices").unwrap().as_usize().unwrap(), 4);
    assert_eq!(doc.req("grad_buckets").unwrap().as_usize().unwrap(), layers + 2);
    let total = doc.req("comm_total_s").unwrap().as_f64().unwrap();
    let exposed = doc.req("comm_exposed_s").unwrap().as_f64().unwrap();
    let step = doc.req("step_s").unwrap().as_f64().unwrap();
    assert!(total > 0.0, "4-way PCIe rig must pay collective time");
    assert!((0.0..=total).contains(&exposed), "exposed {exposed} ∉ [0, {total}]");
    assert!(step > 0.0 && step.is_finite());

    // --devices 1 turns the collective off entirely
    let text = run(&["schedule", "bert-tiny", "--json", "--batch", "4", "--devices", "1"]);
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.req("devices").unwrap().as_usize().unwrap(), 1);
    assert_eq!(doc.req("comm_total_s").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(doc.req("comm_exposed_s").unwrap().as_f64().unwrap(), 0.0);
    let text = run(&["schedule", "bert-tiny", "--devices", "1"]);
    assert!(text.contains("single-device rig"), "text mode should say so");
}

#[test]
fn schedule_text_mode_cross_checks_against_memmodel() {
    for technique in ["baseline", "tempo", "checkpoint"] {
        let text = run(&["schedule", "bert-tiny", "--technique", technique]);
        assert!(
            text.contains("memmodel cross-check: OK"),
            "--technique {technique}: {}",
            text.lines().last().unwrap_or("")
        );
        assert!(text.contains("<- peak"));
    }
    // serial checkpointing prints the enumerated divergence instead
    let text = run(&["schedule", "bert-tiny", "--technique", "checkpoint", "--serial-checkpoint"]);
    assert!(text.contains("serial checkpointing peaks"));
}
