//! Integration tests over the real PJRT runtime + AOT artifacts
//! (`--features pjrt` only; the whole file compiles away otherwise).
//!
//! These additionally require `make artifacts` to have run; every test
//! no-ops (with a notice) if artifacts/ is absent so `cargo test
//! --features pjrt` stays green in a fresh checkout. The sim-backend
//! equivalents in `integration_sim.rs` run unconditionally.
#![cfg(feature = "pjrt")]

use std::sync::OnceLock;

use tempo::config::TrainingConfig;
use tempo::coordinator::{
    compare_variants, finetune_trials, ExperimentEngine, Trainer, TrainerOptions,
};
use tempo::runtime::{ArtifactIndex, PjrtBackend, TrainState};
use tempo::tensor::HostTensor;
use tempo::util::TempDir;

fn backend() -> &'static PjrtBackend {
    static RT: OnceLock<PjrtBackend> = OnceLock::new();
    RT.get_or_init(|| PjrtBackend::cpu().expect("PJRT CPU client"))
}

fn index() -> Option<ArtifactIndex> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactIndex::load(&root) {
        Ok(idx) => Some(idx),
        Err(_) => {
            eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
            None
        }
    }
}

fn quick_cfg(artifact: &str, steps: usize) -> TrainingConfig {
    TrainingConfig {
        artifact: artifact.into(),
        steps,
        warmup_steps: 2,
        peak_lr: 1e-3,
        seed: 7,
        eval_every: 0,
        log_every: 1000,
    }
}

#[test]
fn init_abi_matches_manifest() {
    let Some(idx) = index() else { return };
    let artifact = idx.open("bert_tiny_tempo").unwrap();
    let init = backend().runtime().load(artifact.init_path().unwrap()).unwrap();
    let outs = init.run(&[HostTensor::scalar_i32(3)]).unwrap();
    let state = TrainState::from_init(outs, &artifact.manifest).unwrap();
    assert_eq!(state.n_params, artifact.manifest.n_param_leaves);
    assert_eq!(state.param_count(), artifact.manifest.param_count());
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(idx) = index() else { return };
    let artifact = idx.open("bert_tiny_baseline").unwrap();
    let init = backend().runtime().load(artifact.init_path().unwrap()).unwrap();
    let a = init.run(&[HostTensor::scalar_i32(5)]).unwrap();
    let b = init.run(&[HostTensor::scalar_i32(5)]).unwrap();
    let c = init.run(&[HostTensor::scalar_i32(6)]).unwrap();
    assert_eq!(a, b, "same seed must reproduce exactly");
    // some leaves are seed-independent (zero biases, unit gammas); at
    // least one random-normal leaf must differ across seeds
    assert!(
        a.iter().zip(&c).any(|(x, y)| x != y),
        "different seeds produced identical parameters"
    );
}

#[test]
fn trainer_reduces_loss_on_tiny() {
    let Some(idx) = index() else { return };
    let artifact = idx.open("bert_tiny_tempo").unwrap();
    let mut cfg = quick_cfg("bert_tiny_tempo", 40);
    cfg.peak_lr = 2e-3;
    let mut trainer = Trainer::new(backend(), artifact, cfg, TrainerOptions::default()).unwrap();
    trainer.run().unwrap();
    let records = trainer.metrics().records();
    let first = records.first().unwrap().loss;
    let last = records.last().unwrap().loss;
    assert!(
        last < first - 0.6,
        "loss did not fall: {first:.3} → {last:.3}"
    );
}

#[test]
fn eval_returns_finite_loss() {
    let Some(idx) = index() else { return };
    let artifact = idx.open("bert_tiny_baseline").unwrap();
    let mut trainer = Trainer::new(
        backend(),
        artifact,
        quick_cfg("bert_tiny_baseline", 1),
        TrainerOptions::default(),
    )
    .unwrap();
    trainer.step().unwrap();
    let (loss, _) = trainer.evaluate().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "eval loss {loss}");
}

#[test]
fn checkpoint_resume_roundtrip() {
    let Some(idx) = index() else { return };
    let dir = TempDir::new().unwrap();
    let ck = dir.file("state.ck");

    // phase 1: train 6 steps, save
    let artifact = idx.open("bert_tiny_tempo").unwrap();
    let mut t1 = Trainer::new(
        backend(),
        artifact.clone(),
        quick_cfg("bert_tiny_tempo", 6),
        TrainerOptions { checkpoint_out: Some(ck.clone()), ..Default::default() },
    )
    .unwrap();
    t1.run().unwrap();

    // phase 2: resume and confirm the step counter and params carried over
    let t2 = Trainer::new(
        backend(),
        artifact,
        quick_cfg("bert_tiny_tempo", 6),
        TrainerOptions { resume_from: Some(ck), ..Default::default() },
    )
    .unwrap();
    assert_eq!(t2.state().unwrap().step, 6);
    assert_eq!(t2.state().unwrap().params()[0], t1.state().unwrap().params()[0]);
}

#[test]
fn variants_track_each_other_short_run() {
    // 12-step miniature of Fig 6a: same data, same masks → curves overlap
    let Some(idx) = index() else { return };
    let cfg = quick_cfg("", 12);
    let result = compare_variants(
        backend(),
        &idx,
        &["bert_tiny_baseline", "bert_tiny_tempo", "bert_tiny_checkpoint"],
        &cfg,
        &ExperimentEngine::serial(),
        false,
    )
    .unwrap();
    assert!(
        result.max_endpoint_rel_diff < 0.02,
        "variants deviate {:.4}",
        result.max_endpoint_rel_diff
    );
    // checkpoint must be bit-near-identical to baseline (same math)
    let b = &result.curves[0].losses;
    let c = &result.curves[2].losses;
    for (x, y) in b.iter().zip(c) {
        assert!((x - y).abs() < 2e-3, "baseline {x} vs checkpoint {y}");
    }
}

#[test]
fn finetune_learns_above_chance() {
    let Some(idx) = index() else { return };
    let artifact = idx.open("cls_tiny_tempo").unwrap();
    let result =
        finetune_trials(backend(), &artifact, 1, 50, 50, 2e-3, 11, &ExperimentEngine::serial(), false)
            .unwrap();
    let (_, med, _) = result.final_band();
    assert!(med > 0.7, "median accuracy {med:.3} not above chance");
}

#[test]
fn pallas_artifact_loads_and_steps() {
    // The L1 interpret-mode kernels compose through AOT → PJRT.
    let Some(idx) = index() else { return };
    let artifact = idx.open("pallas_smoke").unwrap();
    assert_eq!(artifact.manifest.impl_name, "pallas");
    let mut trainer = Trainer::new(
        backend(),
        artifact,
        quick_cfg("pallas_smoke", 2),
        TrainerOptions::default(),
    )
    .unwrap();
    let l1 = trainer.step().unwrap();
    let l2 = trainer.step().unwrap();
    assert!(l1.is_finite() && l2.is_finite());
}

#[test]
fn pallas_numerics_match_jnp_artifact() {
    // Same variant (tempo), same seeds: the pallas-lowered step must
    // produce (nearly) the same first-step loss as the jnp-lowered one,
    // modulo batch size differences — so compare against itself via the
    // eval path instead: loss after init must match across runs.
    let Some(idx) = index() else { return };
    let artifact = idx.open("pallas_smoke").unwrap();
    let mut a = Trainer::new(backend(), artifact.clone(), quick_cfg("pallas_smoke", 1), TrainerOptions::default()).unwrap();
    let mut b = Trainer::new(backend(), artifact, quick_cfg("pallas_smoke", 1), TrainerOptions::default()).unwrap();
    let la = a.step().unwrap();
    let lb = b.step().unwrap();
    assert!((la - lb).abs() < 1e-6, "pallas step not deterministic: {la} vs {lb}");
}
