//! Acceptance pin for the lane-aware roofline (DESIGN.md §Lanes): the
//! joint placement search selects an `Overlapped` checkpoint arm that
//! the pre-lane latency-blind census fold priced as strictly dominated
//! by its `Serial` twin — equal census, strictly lower peak — so the
//! old model could never have picked it. The lane-level explanation is
//! asserted alongside: the overlapped arm hides recompute under the
//! covering backward while the collective (same buckets, same bytes,
//! same link) is unchanged, so its step is strictly shorter.

use tempo::autotempo::{placement_search, PlacementMode};
use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::graph::{schedule_summary, CkptStyle, Residency};
use tempo::memmodel::max_batch;
use tempo::perfmodel::{plan_lane_times, plan_throughput_at};

#[test]
fn search_picks_an_overlapped_arm_the_latency_blind_fold_rejected() {
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let gpu = Gpu::Rtx2080Ti;
    let spec = gpu.spec();
    assert!(
        spec.allreduce_bw.is_some() && spec.devices > 1,
        "the pin needs a rig with a collective to hide"
    );

    // targets only checkpointing can reach: above every rewrite-only
    // plan's max batch, within the uniform (overlapped) checkpoint max
    let lo = max_batch(&cfg, Technique::Tempo, gpu).max_batch + 1;
    let hi = max_batch(&cfg, Technique::Checkpoint, gpu).max_batch;
    assert!(lo <= hi, "no checkpoint-only target range ({lo}..={hi})");

    let step = ((hi - lo) / 12).max(1);
    let found = (lo..=hi).step_by(step).find_map(|target| {
        let d = placement_search(&cfg, gpu, PlacementMode::Joint, Some(target));
        (d.max_batch >= target
            && d.plan.residency.iter().any(|m| *m == Residency::Checkpoint(CkptStyle::Overlapped)))
            .then_some(d)
    });
    let d = found.expect("no target in the checkpoint-only range selected an Overlapped arm");

    // its Serial twin: same rewrites, same checkpointed layers
    let mut twin = d.plan.clone();
    for m in twin.residency.iter_mut() {
        if *m == Residency::Checkpoint(CkptStyle::Overlapped) {
            *m = Residency::Checkpoint(CkptStyle::Serial);
        }
    }

    // what the pre-lane fold saw: identical work census, and the twin
    // holding the strictly lower peak — i.e. Serial strictly dominated
    // this plan, and it was pruned before pricing could ever choose it
    let s_over = schedule_summary(&cfg, &d.plan.schedule_plan());
    let s_twin = schedule_summary(&cfg, &twin.schedule_plan());
    assert_eq!(s_over.census, s_twin.census, "twins must do identical census work");
    assert!(
        s_over.peak_item_bytes > s_twin.peak_item_bytes,
        "overlap must pay prefetch co-residency"
    );

    // the lane-level explanation of why the new model disagrees
    let b = d.eval_batch;
    assert!(b > 0);
    let lt_over = plan_lane_times(&cfg, &d.plan.schedule_plan(), &spec, b);
    let lt_twin = plan_lane_times(&cfg, &twin.schedule_plan(), &spec, b);
    assert!(lt_over.hidden_recompute > 0.0, "chosen plan must hide recompute");
    assert_eq!(lt_twin.hidden_recompute, 0.0, "a serial twin hides nothing");
    assert_eq!(lt_over.comm_total, lt_twin.comm_total, "same gradient bytes, same link");
    assert!(
        lt_over.step < lt_twin.step,
        "hidden recompute must shorten the step: {} !< {}",
        lt_over.step,
        lt_twin.step
    );
    let thr_twin = plan_throughput_at(&cfg, &twin.schedule_plan(), gpu, b);
    assert!(
        d.throughput > thr_twin,
        "selection objective: overlapped {} !> serial twin {}",
        d.throughput,
        thr_twin
    );
}

#[test]
fn capacity_queries_never_pay_prefetch_co_residency() {
    // the flip is pricing-driven, not unconditional: with no target the
    // objective is max batch, where lower peaks win (Serial's
    // min(head, inventory) divergence, and now Offload's
    // free-at-store-completion inventory) — the lane-aware prune keeps
    // every arm alive precisely so each objective can pick its own
    // winner, and an Overlapped arm's prefetch co-residency can never
    // be part of a capacity winner
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let d = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
    assert!(
        d.plan.residency.iter().all(|m| *m != Residency::Checkpoint(CkptStyle::Overlapped)),
        "capacity mode picked an overlapped arm: {}",
        d.rationale
    );
}
