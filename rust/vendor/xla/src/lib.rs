//! API shim for the vendored PJRT `xla` crate.
//!
//! This crate exists so `cargo check --features pjrt` can type-check the
//! `tempo::runtime::pjrt` backend **offline**, keeping the feature-gated
//! code from bit-rotting in environments without the real PJRT C API
//! bindings. It mirrors exactly the API surface tempo uses — nothing
//! more — and every function panics at runtime.
//!
//! Deployments with the real vendored bindings replace this crate
//! (overwrite `rust/vendor/xla` or add a `[patch]` section); the tempo
//! side compiles unchanged against either.

use std::fmt;

const SHIM_MSG: &str =
    "xla shim: this is the type-check-only API surface; link the vendored PJRT bindings \
     (replace rust/vendor/xla) to execute on PJRT";

/// Error type of the PJRT bindings.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types tempo's ABI shuttles (subset of the real enum;
/// non-exhaustive so callers keep the wildcard arm the real crate needs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    S32,
}

/// Native host types convertible to/from literals.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Array shape: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal (dense tensor value).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unimplemented!("{SHIM_MSG}")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unimplemented!("{SHIM_MSG}")
    }
}

/// An XLA computation ready to compile.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        unimplemented!("{SHIM_MSG}")
    }
}

/// Device-resident buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unimplemented!("{SHIM_MSG}")
    }
}

/// PJRT client handle.
#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(SHIM_MSG.to_string()))
    }

    pub fn platform_name(&self) -> String {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unimplemented!("{SHIM_MSG}")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unimplemented!("{SHIM_MSG}")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Borrow-only execute (the leak-free path tempo uses; see
    /// `runtime::pjrt` LEAK NOTE).
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unimplemented!("{SHIM_MSG}")
    }
}
