//! Training state: the (params, m, v) leaf lists shuttled through the
//! `step` executable, plus checkpoint save/load in a tiny binary format.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::artifact::Manifest;
use crate::tensor::{Dtype, HostTensor};
use crate::{Error, Result};

/// Flat training state in manifest leaf order: `leaves = params ++ m ++ v`.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// 3n leaves (params, then Adam m, then Adam v).
    pub leaves: Vec<HostTensor>,
    /// Number of parameter leaves (n).
    pub n_params: usize,
    /// Global step counter (host-side; fed to the executable as a scalar).
    pub step: i64,
}

impl TrainState {
    /// Wrap the output of the `init` executable.
    pub fn from_init(outputs: Vec<HostTensor>, manifest: &Manifest) -> Result<Self> {
        let n = manifest.n_param_leaves;
        if outputs.len() != 3 * n {
            return Err(Error::Abi(format!(
                "init returned {} leaves, expected {}",
                outputs.len(),
                3 * n
            )));
        }
        // Validate shapes against the manifest (params section only —
        // m and v mirror params exactly).
        for (spec, leaf) in manifest.params.iter().zip(outputs.iter()) {
            if spec.shape != leaf.shape() {
                return Err(Error::Abi(format!(
                    "leaf {}: manifest shape {:?} != init shape {:?}",
                    spec.name,
                    spec.shape,
                    leaf.shape()
                )));
            }
        }
        Ok(TrainState { leaves: outputs, n_params: n, step: 0 })
    }

    /// Parameter leaves only.
    pub fn params(&self) -> &[HostTensor] {
        &self.leaves[..self.n_params]
    }

    /// Check that this state is shaped for `manifest`'s ABI: leaf count
    /// and every (params, m, v) leaf shape must match the manifest's
    /// parameter inventory (m and v mirror params exactly). Used by the
    /// resume path so a checkpoint from a different config fails up
    /// front with a precise message instead of a late ABI error.
    pub fn validate_manifest(&self, manifest: &Manifest) -> Result<()> {
        let n = manifest.n_param_leaves;
        if self.n_params != n {
            return Err(Error::Abi(format!(
                "checkpoint has {} parameter leaves, manifest expects {}",
                self.n_params, n
            )));
        }
        if self.leaves.len() != 3 * n {
            return Err(Error::Abi(format!(
                "checkpoint has {} leaves, manifest expects {} (params ++ m ++ v)",
                self.leaves.len(),
                3 * n
            )));
        }
        for (section, offset) in [("params", 0), ("adam m", n), ("adam v", 2 * n)] {
            for (spec, leaf) in
                manifest.params.iter().zip(&self.leaves[offset..offset + n])
            {
                if spec.shape != leaf.shape() {
                    return Err(Error::Abi(format!(
                        "{section} leaf {}: checkpoint shape {:?} != manifest shape {:?}",
                        spec.name,
                        leaf.shape(),
                        spec.shape
                    )));
                }
            }
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.params().iter().map(HostTensor::len).sum()
    }

    /// Replace state from the step executable's output
    /// (`params ++ m ++ v ++ [loss]`); returns the loss.
    pub fn absorb_step_output(&mut self, mut outputs: Vec<HostTensor>) -> Result<f64> {
        if outputs.len() != self.leaves.len() + 1 {
            return Err(Error::Abi(format!(
                "step returned {} leaves, expected {}",
                outputs.len(),
                self.leaves.len() + 1
            )));
        }
        let loss = outputs.pop().unwrap().first()?;
        self.leaves = outputs;
        self.step += 1;
        Ok(loss)
    }

    // -- checkpointing ------------------------------------------------------
    //
    // Format: magic, version, step, n_leaves, then per leaf:
    // dtype(u8), ndim(u32), dims(u64...), payload (LE bytes).

    const MAGIC: &'static [u8; 8] = b"TEMPOCK1";

    /// Serialize the full state to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.step as u64).to_le_bytes())?;
        w.write_all(&(self.n_params as u64).to_le_bytes())?;
        w.write_all(&(self.leaves.len() as u64).to_le_bytes())?;
        for leaf in &self.leaves {
            let dt: u8 = match leaf.dtype() {
                Dtype::F32 => 0,
                Dtype::I32 => 1,
            };
            w.write_all(&[dt])?;
            w.write_all(&(leaf.shape().len() as u32).to_le_bytes())?;
            for &d in leaf.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            match leaf {
                HostTensor::F32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                HostTensor::I32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Load a state produced by [`TrainState::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            return Err(Error::Parse("bad checkpoint magic".into()));
        }
        let step = read_u64(&mut r)? as i64;
        let n_params = read_u64(&mut r)? as usize;
        let n_leaves = read_u64(&mut r)? as usize;
        let mut leaves = Vec::with_capacity(n_leaves);
        for _ in 0..n_leaves {
            let mut dt = [0u8; 1];
            r.read_exact(&mut dt)?;
            let mut nd = [0u8; 4];
            r.read_exact(&mut nd)?;
            let ndim = u32::from_le_bytes(nd) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let n: usize = shape.iter().product();
            let leaf = match dt[0] {
                0 => {
                    let mut data = vec![0f32; n];
                    let mut buf = [0u8; 4];
                    for v in &mut data {
                        r.read_exact(&mut buf)?;
                        *v = f32::from_le_bytes(buf);
                    }
                    HostTensor::F32 { shape, data }
                }
                1 => {
                    let mut data = vec![0i32; n];
                    let mut buf = [0u8; 4];
                    for v in &mut data {
                        r.read_exact(&mut buf)?;
                        *v = i32::from_le_bytes(buf);
                    }
                    HostTensor::I32 { shape, data }
                }
                other => return Err(Error::Parse(format!("bad dtype tag {other}"))),
            };
            leaves.push(leaf);
        }
        Ok(TrainState { leaves, n_params, step })
    }
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let leaves = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.25]).unwrap(),
            HostTensor::f32(vec![3], vec![0.0; 3]).unwrap(),
            HostTensor::f32(vec![3], vec![9.0; 3]).unwrap(),
        ];
        let st = TrainState { leaves, n_params: 1, step: 42 };
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("ck.bin");
        st.save(&path).unwrap();
        let back = TrainState::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.n_params, 1);
        assert_eq!(back.leaves, st.leaves);
    }

    #[test]
    fn validate_manifest_accepts_matching_state() {
        let m = Manifest::parse(crate::runtime::artifact::TEST_MANIFEST).unwrap();
        let leaf = || HostTensor::f32(vec![2, 3], vec![0.0; 6]).unwrap();
        let st = TrainState { leaves: vec![leaf(), leaf(), leaf()], n_params: 1, step: 3 };
        assert!(st.validate_manifest(&m).is_ok());
    }

    #[test]
    fn validate_manifest_rejects_mismatches() {
        let m = Manifest::parse(crate::runtime::artifact::TEST_MANIFEST).unwrap();
        let leaf = |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            HostTensor::f32(shape, vec![0.0; n]).unwrap()
        };
        // wrong leaf count
        let st = TrainState { leaves: vec![leaf(vec![2, 3]); 2], n_params: 1, step: 0 };
        assert!(st.validate_manifest(&m).is_err());
        // wrong n_params
        let st = TrainState { leaves: vec![leaf(vec![2, 3]); 3], n_params: 2, step: 0 };
        assert!(st.validate_manifest(&m).is_err());
        // wrong shape in the adam-m section
        let st = TrainState {
            leaves: vec![leaf(vec![2, 3]), leaf(vec![3, 2]), leaf(vec![2, 3])],
            n_params: 1,
            step: 0,
        };
        let msg = st.validate_manifest(&m).unwrap_err().to_string();
        assert!(msg.contains("adam m"), "{msg}");
    }

    #[test]
    fn absorb_checks_arity() {
        let mut st = TrainState {
            leaves: vec![HostTensor::scalar_f32(1.0); 3],
            n_params: 1,
            step: 0,
        };
        // wrong arity
        assert!(st.absorb_step_output(vec![HostTensor::scalar_f32(0.0); 3]).is_err());
        // right arity: 3 leaves + loss
        let mut outs = vec![HostTensor::scalar_f32(2.0); 3];
        outs.push(HostTensor::scalar_f32(0.5));
        let loss = st.absorb_step_output(outs).unwrap();
        assert_eq!(loss, 0.5);
        assert_eq!(st.step, 1);
    }
}
