//! Literal-resident training state — the §Perf-optimized hot path.
//!
//! [`super::TrainState`] stages every leaf through `HostTensor`s, which
//! costs several full-state memcpys per step (clone → vec1 → reshape →
//! buffer). `LiteralState` keeps the (params, m, v) leaves as
//! `xla::Literal`s across steps: the step executable consumes them by
//! reference and its output tuple decomposes straight back into the
//! next step's literals. Host conversions remain only for the batch in
//! and the scalar loss out. See EXPERIMENTS.md §Perf for before/after.

use crate::runtime::artifact::Manifest;
use crate::runtime::literal::{literal_to_tensor, tensor_to_literal};
use crate::runtime::state::TrainState;
use crate::{Error, Result};

/// Flat (params ++ m ++ v) state held as XLA literals.
pub struct LiteralState {
    pub leaves: Vec<xla::Literal>,
    pub n_params: usize,
    pub step: i64,
}

impl LiteralState {
    /// Wrap the output of the `init` executable (already literals).
    pub fn from_init(outputs: Vec<xla::Literal>, manifest: &Manifest) -> Result<Self> {
        let n = manifest.n_param_leaves;
        if outputs.len() != 3 * n {
            return Err(Error::Abi(format!(
                "init returned {} leaves, expected {}",
                outputs.len(),
                3 * n
            )));
        }
        Ok(LiteralState { leaves: outputs, n_params: n, step: 0 })
    }

    /// Convert a host-side state (e.g. a loaded checkpoint).
    pub fn from_host(state: &TrainState) -> Result<Self> {
        let leaves = state
            .leaves
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(LiteralState { leaves, n_params: state.n_params, step: state.step })
    }

    /// Materialize on the host (checkpointing, inspection).
    pub fn to_host(&self) -> Result<TrainState> {
        let leaves = self
            .leaves
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { leaves, n_params: self.n_params, step: self.step })
    }

    /// Borrow just the parameter leaves (for eval).
    pub fn params(&self) -> &[xla::Literal] {
        &self.leaves[..self.n_params]
    }

    /// Replace state from the step output (`params ++ m ++ v ++ [loss]`);
    /// returns the loss. The state leaves are *moved*, not copied.
    pub fn absorb_step_output(&mut self, mut outputs: Vec<xla::Literal>) -> Result<f64> {
        if outputs.len() != self.leaves.len() + 1 {
            return Err(Error::Abi(format!(
                "step returned {} leaves, expected {}",
                outputs.len(),
                self.leaves.len() + 1
            )));
        }
        let loss_lit = outputs.pop().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0] as f64;
        self.leaves = outputs;
        self.step += 1;
        Ok(loss)
    }
}
