//! Artifact manifests: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! Each artifact directory holds `init.hlo.txt`, `step.hlo.txt`,
//! `eval.hlo.txt` and a `manifest.json` describing the flat parameter
//! leaf order, batch tensor shapes and scalar inputs.

use std::path::{Path, PathBuf};

use crate::util::Json;
use crate::{Error, Result};

/// One parameter/batch leaf: name, shape, dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    /// Total elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(LeafSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// Model hyperparameters echoed into the manifest (for reports/sanity).
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    pub name: String,
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq_len: usize,
    pub intermediate: usize,
    pub dropout_p: f64,
    pub num_classes: usize,
}

/// Files within an artifact directory.
#[derive(Debug, Clone)]
pub struct ManifestFiles {
    pub init: String,
    pub step: String,
    pub eval: String,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub task: String,
    pub variant: String,
    /// Kernel path the artifact was lowered with ("jnp" | "pallas").
    pub impl_name: String,
    pub batch_size: usize,
    pub config: ManifestConfig,
    pub n_param_leaves: usize,
    pub params: Vec<LeafSpec>,
    pub batch_inputs: Vec<LeafSpec>,
    pub files: ManifestFiles,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let cfg = v.req("config")?;
        let manifest = Manifest {
            name: v.req("name")?.as_str()?.to_string(),
            task: v.req("task")?.as_str()?.to_string(),
            variant: v.req("variant")?.as_str()?.to_string(),
            impl_name: v
                .get("impl")
                .and_then(|x| x.as_str().ok())
                .unwrap_or("jnp")
                .to_string(),
            batch_size: v.req("batch_size")?.as_usize()?,
            config: ManifestConfig {
                name: cfg.req("name")?.as_str()?.to_string(),
                vocab_size: cfg.req("vocab_size")?.as_usize()?,
                hidden: cfg.req("hidden")?.as_usize()?,
                layers: cfg.req("layers")?.as_usize()?,
                heads: cfg.req("heads")?.as_usize()?,
                seq_len: cfg.req("seq_len")?.as_usize()?,
                intermediate: cfg.req("intermediate")?.as_usize()?,
                dropout_p: cfg.req("dropout_p")?.as_f64()?,
                num_classes: cfg.req("num_classes")?.as_usize()?,
            },
            n_param_leaves: v.req("n_param_leaves")?.as_usize()?,
            params: v
                .req("params")?
                .as_arr()?
                .iter()
                .map(LeafSpec::from_json)
                .collect::<Result<_>>()?,
            batch_inputs: v
                .req("batch_inputs")?
                .as_arr()?
                .iter()
                .map(LeafSpec::from_json)
                .collect::<Result<_>>()?,
            files: ManifestFiles {
                init: v.req("files")?.req("init")?.as_str()?.to_string(),
                step: v.req("files")?.req("step")?.as_str()?.to_string(),
                eval: v.req("files")?.req("eval")?.as_str()?.to_string(),
            },
        };
        if manifest.params.len() != manifest.n_param_leaves {
            return Err(Error::Abi(format!(
                "manifest {}: n_param_leaves {} != params list {}",
                manifest.name,
                manifest.n_param_leaves,
                manifest.params.len()
            )));
        }
        if manifest.batch_inputs.len() != 4 {
            return Err(Error::Abi(format!(
                "manifest {}: expected 4 batch inputs, got {}",
                manifest.name,
                manifest.batch_inputs.len()
            )));
        }
        Ok(manifest)
    }

    /// Total parameter count (sum of leaf elements).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(LeafSpec::numel).sum()
    }
}

/// An artifact on disk: directory + parsed manifest.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifact {
    /// Load `<dir>/manifest.json` and validate basic invariants.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let manifest = Manifest::parse(&text)?;
        Ok(Artifact { dir, manifest })
    }

    pub fn init_path(&self) -> PathBuf {
        self.dir.join(&self.manifest.files.init)
    }

    pub fn step_path(&self) -> PathBuf {
        self.dir.join(&self.manifest.files.step)
    }

    pub fn eval_path(&self) -> PathBuf {
        self.dir.join(&self.manifest.files.eval)
    }
}

/// The `artifacts/index.json` listing.
#[derive(Debug, Clone)]
pub struct IndexEntry {
    pub name: String,
    pub dir: String,
    pub n_param_leaves: usize,
}

/// All artifacts below a root directory.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub root: PathBuf,
    pub entries: Vec<IndexEntry>,
}

impl ArtifactIndex {
    /// Read `<root>/index.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("index.json"))?;
        let v = Json::parse(&text)?;
        let entries = v
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(IndexEntry {
                    name: e.req("name")?.as_str()?.to_string(),
                    dir: e.req("dir")?.as_str()?.to_string(),
                    n_param_leaves: e.req("n_param_leaves")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(ArtifactIndex { root, entries })
    }

    /// Open one artifact by name.
    pub fn open(&self, name: &str) -> Result<Artifact> {
        let entry = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Invalid(format!("unknown artifact {name}")))?;
        Artifact::load(self.root.join(&entry.dir))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    const MANIFEST: &str = r#"{
        "name": "t", "task": "mlm", "variant": "tempo", "impl": "jnp",
        "batch_size": 8,
        "config": {"name": "bert-tiny", "vocab_size": 4096, "hidden": 128,
                   "layers": 2, "heads": 2, "seq_len": 64,
                   "intermediate": 512, "dropout_p": 0.1, "num_classes": 2},
        "n_param_leaves": 1,
        "params": [{"name": "w", "shape": [2, 3], "dtype": "float32"}],
        "batch_inputs": [
            {"name": "input_ids", "shape": [8, 64], "dtype": "int32"},
            {"name": "token_type_ids", "shape": [8, 64], "dtype": "int32"},
            {"name": "attention_mask", "shape": [8, 64], "dtype": "int32"},
            {"name": "labels", "shape": [8, 64], "dtype": "int32"}],
        "files": {"init": "init.hlo.txt", "step": "step.hlo.txt",
                  "eval": "eval.hlo.txt"}
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.param_count(), 6);
        assert_eq!(m.config.hidden, 128);
        assert_eq!(m.impl_name, "jnp");
        assert_eq!(m.batch_inputs[3].name, "labels");
    }

    #[test]
    fn leaf_count_mismatch_rejected() {
        let bad = MANIFEST.replace("\"n_param_leaves\": 1", "\"n_param_leaves\": 7");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn artifact_and_index_load() {
        let dir = TempDir::new().unwrap();
        let adir = dir.path().join("t");
        std::fs::create_dir_all(&adir).unwrap();
        std::fs::write(adir.join("manifest.json"), MANIFEST).unwrap();
        std::fs::write(
            dir.path().join("index.json"),
            r#"[{"name": "t", "dir": "t", "n_param_leaves": 1}]"#,
        )
        .unwrap();
        let idx = ArtifactIndex::load(dir.path()).unwrap();
        assert_eq!(idx.names(), vec!["t"]);
        let a = idx.open("t").unwrap();
        assert!(a.step_path().ends_with("step.hlo.txt"));
        assert!(idx.open("missing").is_err());
    }
}
