//! Artifact manifests: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! Each on-disk artifact directory holds `init.hlo.txt`, `step.hlo.txt`,
//! `eval.hlo.txt` and a `manifest.json` describing the flat parameter
//! leaf order, batch tensor shapes and scalar inputs. The sim backend
//! additionally synthesizes *builtin* artifacts — the same [`Manifest`]
//! structure, no files behind it — so every coordinator flow runs from
//! a fresh checkout with zero artifacts present.

use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::runtime::backend::Entry;
use crate::util::Json;
use crate::{Error, Result};

/// One parameter/batch leaf: name, shape, dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct LeafSpec {
    /// Leaf name (e.g. `bert/embeddings/word_embeddings`).
    pub name: String,
    /// Row-major shape.
    pub shape: Vec<usize>,
    /// Manifest dtype string (`float32` / `int32`).
    pub dtype: String,
}

impl LeafSpec {
    /// Total elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn f32(name: impl Into<String>, shape: Vec<usize>) -> Self {
        LeafSpec { name: name.into(), shape, dtype: "float32".into() }
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(LeafSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// Model hyperparameters echoed into the manifest (for reports/sanity
/// and the sim backend's capacity/roofline reconstruction).
#[derive(Debug, Clone)]
pub struct ManifestConfig {
    /// Model-config name.
    pub name: String,
    /// Vocabulary size V.
    pub vocab_size: usize,
    /// Hidden size H.
    pub hidden: usize,
    /// Encoder layers L.
    pub layers: usize,
    /// Attention heads A.
    pub heads: usize,
    /// Sequence length S.
    pub seq_len: usize,
    /// FFN inner size.
    pub intermediate: usize,
    /// Dropout probability.
    pub dropout_p: f64,
    /// Classification classes (cls task; 0 for MLM).
    pub num_classes: usize,
    /// Position-embedding table size (older manifests omit it; defaults
    /// to `max(seq_len, 512)`).
    pub max_position: usize,
    /// Token-type table size (older manifests omit it; defaults to 2).
    pub type_vocab: usize,
}

/// Files within an artifact directory.
#[derive(Debug, Clone)]
pub struct ManifestFiles {
    /// `init` HLO text file name.
    pub init: String,
    /// `step` HLO text file name.
    pub step: String,
    /// `eval` HLO text file name.
    pub eval: String,
}

/// Parsed `manifest.json` (or a synthesized builtin equivalent).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact name (e.g. `bert_tiny_tempo`).
    pub name: String,
    /// Task (`mlm` | `cls`).
    pub task: String,
    /// Variant (`baseline` | `checkpoint` | `tempo`).
    pub variant: String,
    /// Kernel path the artifact was lowered with ("jnp" | "pallas").
    pub impl_name: String,
    /// Per-step batch size the executables were lowered for.
    pub batch_size: usize,
    /// Model hyperparameters echo.
    pub config: ManifestConfig,
    /// Number of parameter leaves (n; the step ABI carries 3n).
    pub n_param_leaves: usize,
    /// Parameter-leaf specs, in flat ABI order.
    pub params: Vec<LeafSpec>,
    /// Batch-input specs, in ABI order.
    pub batch_inputs: Vec<LeafSpec>,
    /// HLO file names (on-disk artifacts).
    pub files: ManifestFiles,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let cfg = v.req("config")?;
        let seq_len = cfg.req("seq_len")?.as_usize()?;
        let manifest = Manifest {
            name: v.req("name")?.as_str()?.to_string(),
            task: v.req("task")?.as_str()?.to_string(),
            variant: v.req("variant")?.as_str()?.to_string(),
            impl_name: v
                .get("impl")
                .and_then(|x| x.as_str().ok())
                .unwrap_or("jnp")
                .to_string(),
            batch_size: v.req("batch_size")?.as_usize()?,
            config: ManifestConfig {
                name: cfg.req("name")?.as_str()?.to_string(),
                vocab_size: cfg.req("vocab_size")?.as_usize()?,
                hidden: cfg.req("hidden")?.as_usize()?,
                layers: cfg.req("layers")?.as_usize()?,
                heads: cfg.req("heads")?.as_usize()?,
                seq_len,
                intermediate: cfg.req("intermediate")?.as_usize()?,
                dropout_p: cfg.req("dropout_p")?.as_f64()?,
                num_classes: cfg.req("num_classes")?.as_usize()?,
                // absent in older manifests (defaulted); present-but-
                // malformed is an error like every other config field
                max_position: match cfg.get("max_position") {
                    Some(x) => x.as_usize()?,
                    None => seq_len.max(512),
                },
                type_vocab: match cfg.get("type_vocab") {
                    Some(x) => x.as_usize()?,
                    None => 2,
                },
            },
            n_param_leaves: v.req("n_param_leaves")?.as_usize()?,
            params: v
                .req("params")?
                .as_arr()?
                .iter()
                .map(LeafSpec::from_json)
                .collect::<Result<_>>()?,
            batch_inputs: v
                .req("batch_inputs")?
                .as_arr()?
                .iter()
                .map(LeafSpec::from_json)
                .collect::<Result<_>>()?,
            files: ManifestFiles {
                init: v.req("files")?.req("init")?.as_str()?.to_string(),
                step: v.req("files")?.req("step")?.as_str()?.to_string(),
                eval: v.req("files")?.req("eval")?.as_str()?.to_string(),
            },
        };
        if manifest.params.len() != manifest.n_param_leaves {
            return Err(Error::Abi(format!(
                "manifest {}: n_param_leaves {} != params list {}",
                manifest.name,
                manifest.n_param_leaves,
                manifest.params.len()
            )));
        }
        if manifest.batch_inputs.len() != 4 {
            return Err(Error::Abi(format!(
                "manifest {}: expected 4 batch inputs, got {}",
                manifest.name,
                manifest.batch_inputs.len()
            )));
        }
        Ok(manifest)
    }

    /// Synthesize a manifest from a model config — the BERT-family leaf
    /// inventory `python/compile/model.py` lowers, with no files behind
    /// it. This is what the sim backend executes analytically.
    ///
    /// `task` is "mlm" (pre-training head) or "cls" (`num_classes`-way
    /// classification head); `variant` one of "baseline" | "checkpoint"
    /// | "tempo".
    pub fn synthetic(
        name: &str,
        task: &str,
        variant: &str,
        impl_name: &str,
        batch_size: usize,
        cfg: &ModelConfig,
        num_classes: usize,
    ) -> Self {
        let h = cfg.hidden;
        let i = cfg.intermediate;
        let mut params = vec![
            LeafSpec::f32("embeddings.word", vec![cfg.vocab_size, h]),
            LeafSpec::f32("embeddings.position", vec![cfg.max_position, h]),
            LeafSpec::f32("embeddings.token_type", vec![cfg.type_vocab.max(1), h]),
            LeafSpec::f32("embeddings.ln.gamma", vec![h]),
            LeafSpec::f32("embeddings.ln.beta", vec![h]),
        ];
        for l in 0..cfg.layers {
            for (suffix, shape) in [
                ("attn.q_w", vec![h, h]),
                ("attn.q_b", vec![h]),
                ("attn.k_w", vec![h, h]),
                ("attn.k_b", vec![h]),
                ("attn.v_w", vec![h, h]),
                ("attn.v_b", vec![h]),
                ("attn.out_w", vec![h, h]),
                ("attn.out_b", vec![h]),
                ("attn.ln.gamma", vec![h]),
                ("attn.ln.beta", vec![h]),
                ("ffn.in_w", vec![h, i]),
                ("ffn.in_b", vec![i]),
                ("ffn.out_w", vec![i, h]),
                ("ffn.out_b", vec![h]),
                ("ffn.ln.gamma", vec![h]),
                ("ffn.ln.beta", vec![h]),
            ] {
                params.push(LeafSpec::f32(format!("encoder.{l}.{suffix}"), shape));
            }
        }
        if task == "cls" {
            params.push(LeafSpec::f32("pooler.w", vec![h, h]));
            params.push(LeafSpec::f32("pooler.b", vec![h]));
            params.push(LeafSpec::f32("classifier.w", vec![h, num_classes.max(2)]));
            params.push(LeafSpec::f32("classifier.b", vec![num_classes.max(2)]));
        } else {
            params.push(LeafSpec::f32("mlm.transform_w", vec![h, h]));
            params.push(LeafSpec::f32("mlm.transform_b", vec![h]));
            params.push(LeafSpec::f32("mlm.ln.gamma", vec![h]));
            params.push(LeafSpec::f32("mlm.ln.beta", vec![h]));
            params.push(LeafSpec::f32("mlm.decoder_bias", vec![cfg.vocab_size]));
        }
        let batch_shape = vec![batch_size, cfg.seq_len];
        let batch_inputs = ["input_ids", "token_type_ids", "attention_mask", "labels"]
            .iter()
            .map(|n| LeafSpec { name: n.to_string(), shape: batch_shape.clone(), dtype: "int32".into() })
            .collect();
        let n_param_leaves = params.len();
        Manifest {
            name: name.to_string(),
            task: task.to_string(),
            variant: variant.to_string(),
            impl_name: impl_name.to_string(),
            batch_size,
            config: ManifestConfig {
                name: cfg.name.clone(),
                vocab_size: cfg.vocab_size,
                hidden: h,
                layers: cfg.layers,
                heads: cfg.heads,
                seq_len: cfg.seq_len,
                intermediate: i,
                dropout_p: cfg.dropout_p,
                num_classes: if task == "cls" { num_classes.max(2) } else { 0 },
                max_position: cfg.max_position,
                type_vocab: cfg.type_vocab.max(1),
            },
            n_param_leaves,
            params,
            batch_inputs,
            files: ManifestFiles {
                init: "init.hlo.txt".into(),
                step: "step.hlo.txt".into(),
                eval: "eval.hlo.txt".into(),
            },
        }
    }

    /// Total parameter count (sum of leaf elements).
    pub fn param_count(&self) -> usize {
        self.params.iter().map(LeafSpec::numel).sum()
    }
}

/// An artifact: a manifest plus (for on-disk artifacts) the directory
/// holding its HLO text files. Builtin sim artifacts have no directory.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// `None` for synthetic builtin artifacts (sim backend only).
    pub dir: Option<PathBuf>,
    /// The (parsed or synthesized) manifest.
    pub manifest: Manifest,
}

impl Artifact {
    /// Load `<dir>/manifest.json` and validate basic invariants.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let manifest = Manifest::parse(&text)?;
        Ok(Artifact { dir: Some(dir), manifest })
    }

    /// Wrap a synthesized manifest (no on-disk files; sim backend only).
    pub fn synthetic(manifest: Manifest) -> Self {
        Artifact { dir: None, manifest }
    }

    /// True when this artifact has no HLO files behind it.
    pub fn is_synthetic(&self) -> bool {
        self.dir.is_none()
    }

    fn file(&self, name: &str) -> Result<PathBuf> {
        match &self.dir {
            Some(d) => Ok(d.join(name)),
            None => Err(Error::Invalid(format!(
                "artifact {} is synthetic (builtin sim manifest) — no on-disk HLO files; \
                 run it on the sim backend or `make artifacts` for PJRT",
                self.manifest.name
            ))),
        }
    }

    /// Path of one entry point's HLO text file.
    pub fn entry_path(&self, entry: Entry) -> Result<PathBuf> {
        match entry {
            Entry::Init => self.init_path(),
            Entry::Step => self.step_path(),
            Entry::Eval => self.eval_path(),
        }
    }

    /// Path of the `init` HLO text file.
    pub fn init_path(&self) -> Result<PathBuf> {
        self.file(&self.manifest.files.init)
    }

    /// Path of the `step` HLO text file.
    pub fn step_path(&self) -> Result<PathBuf> {
        self.file(&self.manifest.files.step)
    }

    /// Path of the `eval` HLO text file.
    pub fn eval_path(&self) -> Result<PathBuf> {
        self.file(&self.manifest.files.eval)
    }
}

/// One `artifacts/index.json` listing entry.
#[derive(Debug, Clone)]
pub struct IndexEntry {
    /// Artifact name.
    pub name: String,
    /// Directory (relative to the index root).
    pub dir: String,
    /// Parameter-leaf count, for quick listings.
    pub n_param_leaves: usize,
}

/// All artifacts visible to the coordinator: the on-disk set below a
/// root directory, the builtin sim set, or (after `load_or_builtin`)
/// whichever of the two exists.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    root: Option<PathBuf>,
    entries: Vec<IndexEntry>,
    builtin: Vec<Manifest>,
}

impl ArtifactIndex {
    /// Read `<root>/index.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("index.json"))?;
        let v = Json::parse(&text)?;
        let entries = v
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(IndexEntry {
                    name: e.req("name")?.as_str()?.to_string(),
                    dir: e.req("dir")?.as_str()?.to_string(),
                    n_param_leaves: e.req("n_param_leaves")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(ArtifactIndex { root: Some(root), entries, builtin: Vec::new() })
    }

    /// The builtin sim artifact set (zero files needed).
    pub fn builtin() -> Self {
        ArtifactIndex {
            root: None,
            entries: Vec::new(),
            builtin: crate::runtime::sim::builtin_manifests(),
        }
    }

    /// On-disk index when present, builtin sim set otherwise — the
    /// fresh-checkout default. Only a *missing* index falls through
    /// silently; a corrupt one is surfaced before falling back, so a
    /// broken artifacts/ dir can't be mistaken for a fresh checkout.
    pub fn load_or_builtin(root: impl AsRef<Path>) -> Self {
        match Self::load(&root) {
            Ok(idx) => idx,
            Err(e) => {
                let missing = matches!(
                    &e,
                    Error::Io(io) if io.kind() == std::io::ErrorKind::NotFound
                );
                if !missing {
                    eprintln!(
                        "warning: artifact index at {} is unusable ({e}); \
                         falling back to the builtin sim set",
                        root.as_ref().display()
                    );
                }
                Self::builtin()
            }
        }
    }

    /// True when this index serves builtin manifests (no artifacts/ dir).
    pub fn is_builtin(&self) -> bool {
        self.root.is_none()
    }

    /// Open one artifact by name.
    pub fn open(&self, name: &str) -> Result<Artifact> {
        if let Some(entry) = self.entries.iter().find(|e| e.name == name) {
            let root = self.root.as_ref().expect("disk entries imply a root");
            return Artifact::load(root.join(&entry.dir));
        }
        if let Some(m) = self.builtin.iter().find(|m| m.name == name) {
            return Ok(Artifact::synthetic(m.clone()));
        }
        Err(Error::Invalid(format!("unknown artifact {name}")))
    }

    /// Every artifact name this index can open.
    pub fn names(&self) -> Vec<&str> {
        self.entries
            .iter()
            .map(|e| e.name.as_str())
            .chain(self.builtin.iter().map(|m| m.name.as_str()))
            .collect()
    }
}

/// Shared fixture for runtime unit tests.
#[cfg(test)]
pub(crate) const TEST_MANIFEST: &str = r#"{
    "name": "t", "task": "mlm", "variant": "tempo", "impl": "jnp",
    "batch_size": 8,
    "config": {"name": "bert-tiny", "vocab_size": 4096, "hidden": 128,
               "layers": 2, "heads": 2, "seq_len": 64,
               "intermediate": 512, "dropout_p": 0.1, "num_classes": 2},
    "n_param_leaves": 1,
    "params": [{"name": "w", "shape": [2, 3], "dtype": "float32"}],
    "batch_inputs": [
        {"name": "input_ids", "shape": [8, 64], "dtype": "int32"},
        {"name": "token_type_ids", "shape": [8, 64], "dtype": "int32"},
        {"name": "attention_mask", "shape": [8, 64], "dtype": "int32"},
        {"name": "labels", "shape": [8, 64], "dtype": "int32"}],
    "files": {"init": "init.hlo.txt", "step": "step.hlo.txt",
              "eval": "eval.hlo.txt"}
}"#;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(TEST_MANIFEST).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.param_count(), 6);
        assert_eq!(m.config.hidden, 128);
        assert_eq!(m.impl_name, "jnp");
        assert_eq!(m.batch_inputs[3].name, "labels");
    }

    #[test]
    fn leaf_count_mismatch_rejected() {
        let bad = TEST_MANIFEST.replace("\"n_param_leaves\": 1", "\"n_param_leaves\": 7");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn artifact_and_index_load() {
        let dir = TempDir::new().unwrap();
        let adir = dir.path().join("t");
        std::fs::create_dir_all(&adir).unwrap();
        std::fs::write(adir.join("manifest.json"), TEST_MANIFEST).unwrap();
        std::fs::write(
            dir.path().join("index.json"),
            r#"[{"name": "t", "dir": "t", "n_param_leaves": 1}]"#,
        )
        .unwrap();
        let idx = ArtifactIndex::load(dir.path()).unwrap();
        assert!(!idx.is_builtin());
        assert_eq!(idx.names(), vec!["t"]);
        let a = idx.open("t").unwrap();
        assert!(!a.is_synthetic());
        assert!(a.step_path().unwrap().ends_with("step.hlo.txt"));
        assert!(idx.open("missing").is_err());
    }

    #[test]
    fn synthetic_manifest_matches_bert_inventory() {
        let cfg = crate::config::ModelConfig::bert_tiny();
        let m = Manifest::synthetic("bt", "mlm", "tempo", "jnp", 8, &cfg, 0);
        assert_eq!(m.n_param_leaves, m.params.len());
        // 5 embedding leaves + 16 per layer + 5 MLM-head leaves
        assert_eq!(m.params.len(), 5 + 16 * cfg.layers + 5);
        assert_eq!(m.batch_inputs.len(), 4);
        assert_eq!(m.batch_inputs[0].shape, vec![8, cfg.seq_len]);
        // leaf 0 is the word embedding — the sim backend's progress proxy
        assert_eq!(m.params[0].shape, vec![cfg.vocab_size, cfg.hidden]);
        // close to the analytical param_count (synthetic adds the pos/type
        // tables at max_position, exactly like the python model)
        let analytic = cfg.param_count();
        let got = m.param_count();
        let rel = (got as f64 - analytic as f64).abs() / analytic as f64;
        assert!(rel < 0.05, "synthetic {got} vs analytic {analytic}");
    }

    #[test]
    fn synthetic_cls_head() {
        let cfg = crate::config::ModelConfig::bert_tiny();
        let m = Manifest::synthetic("ct", "cls", "baseline", "jnp", 4, &cfg, 2);
        assert_eq!(m.config.num_classes, 2);
        assert_eq!(m.params.last().unwrap().name, "classifier.b");
    }

    #[test]
    fn builtin_index_opens_synthetic_artifacts() {
        let idx = ArtifactIndex::builtin();
        assert!(idx.is_builtin());
        assert!(idx.names().contains(&"bert_tiny_tempo"));
        let a = idx.open("bert_tiny_tempo").unwrap();
        assert!(a.is_synthetic());
        assert!(a.step_path().is_err(), "synthetic artifacts have no files");
    }
}
