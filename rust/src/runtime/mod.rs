//! L3↔L2 bridge: load AOT HLO-text artifacts and run them on PJRT.
//!
//! The python side (`python/compile/aot.py`) lowers `init` / `step` /
//! `eval` per (model config, variant) to HLO **text** plus a
//! `manifest.json` describing the flat-leaf ABI. This module loads the
//! text with `HloModuleProto::from_text_file`, compiles it once on the
//! PJRT CPU client, and shuttles `HostTensor`s in and out as literals.

mod artifact;
mod client;
mod literal;
mod litstate;
mod state;

pub use artifact::{Artifact, ArtifactIndex, LeafSpec, Manifest};
pub use client::{Executable, Runtime};
pub use literal::{literal_to_tensor, tensor_to_literal};
pub use litstate::LiteralState;
pub use state::TrainState;
