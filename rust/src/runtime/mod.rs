//! Execution layer: artifacts, pluggable backends, training state.
//!
//! The python side (`python/compile/aot.py`) lowers `init` / `step` /
//! `eval` per (model config, variant) to HLO **text** plus a
//! `manifest.json` describing the flat-leaf ABI. This module exposes
//! that ABI behind the [`Backend`] / [`Program`] traits with two
//! implementations:
//!
//! * [`SimBackend`] (always available, the default) — executes the ABI
//!   analytically: deterministic seeded init, a calibrated synthetic
//!   loss trajectory, and latency/memory drawn from `perfmodel` /
//!   `memmodel`. Runs from a fresh checkout with zero artifacts.
//! * `PjrtBackend` (`--features pjrt`) — loads the HLO text with
//!   `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//!   client, and shuttles `HostTensor`s in and out as literals.
//!
//! See DESIGN.md §Backends for the feature matrix.

mod artifact;
mod backend;
mod kernel;
#[cfg(feature = "pjrt")]
mod pjrt;
mod sim;
mod state;

pub use artifact::{
    Artifact, ArtifactIndex, IndexEntry, LeafSpec, Manifest, ManifestConfig, ManifestFiles,
};
pub use backend::{Backend, DeviceState, Entry, Program};
pub use kernel::{init_params, step_trace, KernelBackend, KernelProgram, StepBatch, StepTrace};
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_to_tensor, tensor_to_literal, Executable, PjrtBackend, Runtime};
pub use sim::{builtin_manifests, SimBackend, SimProgram, SIM_INIT_STD};
pub use state::TrainState;
