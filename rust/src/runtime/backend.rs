//! Pluggable execution backends.
//!
//! The coordinator is written against two small traits instead of the
//! PJRT client directly:
//!
//! * [`Backend`] — prepares an [`Artifact`] entry point for execution
//!   and moves tensors across the host/device boundary. The associated
//!   `Value` type is the backend's *device-resident* representation
//!   (`Arc<HostTensor>` for the sim backend, `xla::Literal`s for
//!   PJRT), which preserves the §Perf literal-resident hot path: the
//!   (params, m, v) training state never round-trips through the host
//!   between steps on either backend.
//! * [`Program`] — one prepared entry point; `run` consumes borrowed
//!   leaves and produces the owned output leaves of the ABI.
//!
//! Implementations: [`super::SimBackend`] (default; pure Rust,
//! deterministic, zero artifacts needed) and `super::PjrtBackend`
//! (`--features pjrt`; compiles the AOT HLO text on the PJRT client).

use std::sync::Arc;
use std::time::Duration;

use crate::runtime::artifact::{Artifact, Manifest};
use crate::tensor::HostTensor;
use crate::{Error, Result};

/// The three entry points of the artifact ABI (see `runtime::artifact`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Entry {
    /// `init(seed) -> params ++ m ++ v`
    Init,
    /// `step(params ++ m ++ v ++ batch[4] ++ step ++ seed ++ lr)
    ///  -> params' ++ m' ++ v' ++ [loss]`
    Step,
    /// `eval(params ++ batch[4] ++ seed) -> [loss, metric]`
    Eval,
}

impl Entry {
    /// Entry-point name (`init` / `step` / `eval`).
    pub fn name(self) -> &'static str {
        match self {
            Entry::Init => "init",
            Entry::Step => "step",
            Entry::Eval => "eval",
        }
    }
}

/// A prepared (compiled or analytically modeled) artifact entry point.
pub trait Program: Send + Sync {
    /// Device-resident value type (matches the owning backend's).
    type Value;

    /// Run with borrowed inputs; returns the flattened output leaves.
    fn run(&self, inputs: &[&Self::Value]) -> Result<Vec<Self::Value>>;
}

/// An execution engine for artifact ABIs.
///
/// `Send + Sync` is part of the contract: one backend instance is
/// shared by every worker of the concurrent experiment engine
/// (`coordinator::ExperimentEngine`), so `prepare`/`upload`/`download`
/// may be called from several threads at once and implementations must
/// synchronize any internal mutable state (the PJRT executable cache
/// does this with a mutex; the sim backend is stateless).
pub trait Backend: Send + Sync {
    /// Device-resident value (host tensors for sim, literals for PJRT).
    /// Deliberately unbounded: PJRT literal wrappers are not `Send` —
    /// the experiment engine respects this by creating and dropping
    /// each sweep cell's values on a single worker thread.
    type Value;
    /// The backend's program type.
    type Prog: Program<Value = Self::Value>;

    /// Short backend identifier ("sim", "pjrt") for diagnostics.
    fn name(&self) -> &'static str;

    /// Prepare one entry point of an artifact for repeated execution.
    ///
    /// The DESIGN.md §Backends contract, executable (the sim backend
    /// runs this from a fresh checkout with zero artifacts):
    ///
    /// ```
    /// use tempo::runtime::{ArtifactIndex, Backend, Entry, Program, SimBackend};
    /// use tempo::tensor::HostTensor;
    ///
    /// let backend = SimBackend::new();
    /// let artifact = ArtifactIndex::builtin().open("bert_tiny_tempo")?;
    /// let init = backend.prepare(&artifact, Entry::Init)?;
    ///
    /// // init(seed) -> params ++ m ++ v : 3n flat device leaves
    /// let seed = backend.upload(&HostTensor::scalar_i32(42))?;
    /// let state = init.run(&[&seed])?;
    /// assert_eq!(state.len(), 3 * artifact.manifest.n_param_leaves);
    ///
    /// // host <-> device round-trip is the backend's other half
    /// let leaf0 = backend.download(&state[0])?;
    /// assert_eq!(leaf0.shape(), &artifact.manifest.params[0].shape[..]);
    /// # Ok::<(), tempo::Error>(())
    /// ```
    fn prepare(&self, artifact: &Artifact, entry: Entry) -> Result<Arc<Self::Prog>>;

    /// Host tensor → device value.
    fn upload(&self, t: &HostTensor) -> Result<Self::Value>;

    /// Device value → host tensor.
    fn download(&self, v: &Self::Value) -> Result<HostTensor>;

    /// First element of a scalar output as f64 (loss readback).
    fn scalar(&self, v: &Self::Value) -> Result<f64> {
        self.download(v)?.first()
    }

    /// Per-step latency when the backend models time analytically
    /// instead of measuring it (the sim backend draws this from
    /// `perfmodel`); `None` means "measure wall clock".
    fn modeled_step_time(&self, _artifact: &Artifact) -> Option<Duration> {
        None
    }
}

/// Flat `(params ++ m ++ v)` training state in backend value space.
///
/// Generalizes the §Perf-optimized literal-resident state: the step
/// program consumes the leaves by reference and its output tuple
/// becomes the next step's leaves with no host round-trip. Host
/// conversions remain only for batches in and the scalar loss out.
pub struct DeviceState<V> {
    /// 3n leaves (params, then Adam m, then Adam v).
    pub leaves: Vec<V>,
    /// Number of parameter leaves (n).
    pub n_params: usize,
    /// Global step counter (host-side; fed to the step program as a scalar).
    pub step: i64,
}

impl<V> DeviceState<V> {
    /// Wrap the output of the `init` program.
    pub fn from_init(outputs: Vec<V>, manifest: &Manifest) -> Result<Self> {
        let n = manifest.n_param_leaves;
        if outputs.len() != 3 * n {
            return Err(Error::Abi(format!(
                "init returned {} leaves, expected {}",
                outputs.len(),
                3 * n
            )));
        }
        Ok(DeviceState { leaves: outputs, n_params: n, step: 0 })
    }

    /// Borrow just the parameter leaves (for eval).
    pub fn params(&self) -> &[V] {
        &self.leaves[..self.n_params]
    }

    /// Replace state from the step output (`params ++ m ++ v ++ [loss]`);
    /// returns the loss leaf. The state leaves are *moved*, not copied.
    pub fn absorb_step_output(&mut self, mut outputs: Vec<V>) -> Result<V> {
        if outputs.len() != self.leaves.len() + 1 {
            return Err(Error::Abi(format!(
                "step returned {} leaves, expected {}",
                outputs.len(),
                self.leaves.len() + 1
            )));
        }
        let loss = outputs.pop().unwrap();
        self.leaves = outputs;
        self.step += 1;
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_checks_arity_and_advances_step() {
        let mut st = DeviceState { leaves: vec![1.0f64; 3], n_params: 1, step: 0 };
        assert!(st.absorb_step_output(vec![0.0f64; 3]).is_err());
        let loss = st.absorb_step_output(vec![2.0, 2.0, 2.0, 0.5]).unwrap();
        assert_eq!(loss, 0.5);
        assert_eq!(st.step, 1);
        assert_eq!(st.leaves, vec![2.0; 3]);
    }

    #[test]
    fn from_init_checks_leaf_count() {
        let m = Manifest::parse(crate::runtime::artifact::TEST_MANIFEST).unwrap();
        assert!(DeviceState::from_init(vec![0.0f64; 3], &m).is_ok());
        assert!(DeviceState::from_init(vec![0.0f64; 2], &m).is_err());
        let st = DeviceState::from_init(vec![7.0f64, 0.0, 0.0], &m).unwrap();
        assert_eq!(st.params(), &[7.0]);
    }

    #[test]
    fn entry_names() {
        assert_eq!(Entry::Init.name(), "init");
        assert_eq!(Entry::Step.name(), "step");
        assert_eq!(Entry::Eval.name(), "eval");
    }
}
