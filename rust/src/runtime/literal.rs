//! HostTensor ⇄ xla::Literal conversion.

use crate::tensor::HostTensor;
use crate::{Error, Result};

/// Host → device-feedable literal.
pub fn tensor_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    };
    Ok(lit)
}

/// Literal → host tensor (f32 / s32 supported; everything the ABI emits).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => HostTensor::f32(dims, lit.to_vec::<f32>()?),
        xla::ElementType::S32 => HostTensor::i32(dims, lit.to_vec::<i32>()?),
        other => Err(Error::Abi(format!("unsupported literal type {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32_scalar() {
        let t = HostTensor::scalar_i32(-7);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(t, back);
    }
}
