//! Numeric CPU execution of the graph IR: [`KernelBackend`] interprets
//! the lowered [`StepSchedule`] tape with the real kernels in
//! [`crate::kernels`].
//!
//! Where [`super::SimBackend`] *prices* a plan analytically, this
//! backend *runs* it: every [`ScheduleEvent`] dispatches to real
//! forward/backward math, tensors are materialized and freed exactly
//! where the liveness timeline says they are, and the rewrite subset in
//! the [`SchedulePlan`] changes **what is stored**, not what is
//! computed:
//!
//! * in-place GELU keeps the 1-byte sign mask and inverts the output in
//!   backward ([`crate::kernels::gelu_bwd_inplace`]);
//! * in-place LayerNorm keeps only per-row `rstd` and runs the
//!   output-based backward;
//! * dropout recompute keeps the mask and replays the cheap apply in
//!   backward;
//! * softmax output-only drops the score matrix (softmax backward never
//!   needed it);
//! * [`Residency::Checkpoint`] re-forwards the layer from its stored
//!   input at the tape's `Recompute` events; [`Residency::Offload`]
//!   round-trips the layer's inventory through a host-side stash at the
//!   `Store`/`Load` events.
//!
//! Every kernel is bit-deterministic across worker counts and dropout
//! seeds are positional (derived from `(segment, op)` — never from tape
//! position), so a checkpointed replay or a rewritten plan reproduces
//! the stock plan's gradients bit-for-bit except where GELU inversion
//! legitimately rounds (see `tests/kernel_rewrite_parity.rs`).
//!
//! The interpreter also meters itself: after every event it samples
//! live bytes (params/grads/Adam + every buffer it holds) and reports
//! the high-water mark next to the analytic
//! [`schedule_summary`](crate::graph::schedule_summary) peak — the
//! measured probe `tempo autotempo --probe measured` is built on this.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{ModelConfig, OptimizationSet};
use crate::coordinator::ExperimentEngine;
use crate::graph::{
    lower_step, schedule_summary, EventKind, Lowering, Residency, SchedulePlan, ScheduleEvent,
    Segment, StepSchedule, Topology,
};
use crate::kernels::{
    add, attention_fwd, attn_context, attn_context_bwd, attn_scores, attn_scores_bwd, bias_grad,
    dropout_apply, dropout_mask, fill_rows, gelu_bwd, gelu_bwd_inplace, gelu_fwd, layernorm_bwd,
    layernorm_fwd, map_elems, matmul, matmul_at, matmul_bias, matmul_bt, rstd_from_var,
    softmax_bwd, softmax_fwd, AttnDims, LN_EPS,
};
use crate::runtime::{Artifact, Backend, Entry, Manifest, Program};
use crate::tensor::{mix64, HostTensor, Rng};
use crate::{Error, Result};

use super::sim::{model_config, technique};

/// Salt folded into the user seed for parameter init draws (distinct
/// from the sim backend's stream on purpose: real kernels want real
/// LayerNorm gains, see [`init_params`]).
const SALT_KERNEL_INIT: u64 = 0x4b52_4e4c_5f49_4e49;

/// Salt for [`StepBatch::synthetic`] draws.
const SALT_KERNEL_BATCH: u64 = 0x4b52_4e4c_5f42_4154;

/// Weight init scale (matches the sim backend / BERT convention).
const INIT_STD: f64 = 0.02;

/// Adam hyper-parameters baked into the step ABI (β₁, β₂, ε).
const ADAM: (f64, f64, f64) = (0.9, 0.999, 1e-8);

/// Numeric execution backend: runs `init`/`step`/`eval` with real CPU
/// kernels by interpreting the lowered schedule tape.
///
/// Construction picks the worker count (kernels parallelize across row
/// bands) and optionally pins a [`SchedulePlan`]; by default the plan
/// is derived from the manifest variant exactly like the analytic
/// models derive theirs, so `baseline`/`checkpoint`/`tempo` manifests
/// execute the corresponding schedules.
#[derive(Debug, Clone, Default)]
pub struct KernelBackend {
    jobs: usize,
    plan: Option<SchedulePlan>,
}

impl KernelBackend {
    /// Backend with the auto-detected worker count.
    pub fn new() -> Self {
        KernelBackend { jobs: ExperimentEngine::auto().jobs(), plan: None }
    }

    /// Backend with an explicit worker count (0 → auto).
    pub fn with_jobs(jobs: usize) -> Self {
        let jobs = if jobs == 0 { ExperimentEngine::auto().jobs() } else { jobs };
        KernelBackend { jobs, plan: None }
    }

    /// Pin the schedule plan instead of deriving it from the manifest
    /// variant (the measured probe executes candidate plans this way).
    pub fn with_plan(mut self, plan: SchedulePlan) -> Self {
        self.plan = Some(plan);
        self
    }
}

impl Backend for KernelBackend {
    type Value = Arc<HostTensor>;
    type Prog = KernelProgram;

    fn name(&self) -> &'static str {
        "kernel"
    }

    fn prepare(&self, artifact: &Artifact, entry: Entry) -> Result<Arc<KernelProgram>> {
        let m = artifact.manifest.clone();
        let cfg = model_config(&m);
        let lowering = Lowering::for_model(&cfg);
        if lowering.unfused_attention || matches!(lowering.topology, Topology::PreLn) {
            return Err(Error::Backend(format!(
                "kernel backend only executes fused post-LN lowerings (manifest {})",
                m.name
            )));
        }
        let plan = match &self.plan {
            Some(p) => p.clone(),
            None => SchedulePlan::for_technique(&cfg, technique(&m), m.task != "cls"),
        };
        Ok(Arc::new(KernelProgram {
            manifest: m,
            entry,
            plan,
            engine: ExperimentEngine::new(self.jobs),
        }))
    }

    fn upload(&self, host: &HostTensor) -> Result<Arc<HostTensor>> {
        Ok(Arc::new(host.clone()))
    }

    fn download(&self, value: &Arc<HostTensor>) -> Result<HostTensor> {
        Ok(value.as_ref().clone())
    }
}

/// One prepared entry point of the kernel backend.
#[derive(Debug)]
pub struct KernelProgram {
    manifest: Manifest,
    entry: Entry,
    plan: SchedulePlan,
    engine: ExperimentEngine,
}

impl Program for KernelProgram {
    type Value = Arc<HostTensor>;

    fn run(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        match self.entry {
            Entry::Init => self.run_init(inputs),
            Entry::Step => self.run_step(inputs),
            Entry::Eval => self.run_eval(inputs),
        }
    }
}

impl KernelProgram {
    fn check_arity(&self, got: usize, want: usize) -> Result<()> {
        if got != want {
            return Err(Error::Abi(format!(
                "kernel {} for {}: got {} inputs, expected {}",
                self.entry.name(),
                self.manifest.name,
                got,
                want
            )));
        }
        Ok(())
    }

    fn run_init(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        self.check_arity(inputs.len(), 1)?;
        let seed = scalar_i32(inputs[0])? as u64;
        let params = init_params(&self.manifest, seed);
        let mut out = Vec::with_capacity(3 * self.manifest.n_param_leaves);
        for (spec, data) in self.manifest.params.iter().zip(&params) {
            out.push(Arc::new(HostTensor::f32(spec.shape.clone(), data.clone())?));
        }
        for _ in 0..2 {
            for spec in &self.manifest.params {
                out.push(Arc::new(HostTensor::f32(
                    spec.shape.clone(),
                    vec![0f32; spec.numel()],
                )?));
            }
        }
        Ok(out)
    }

    fn run_step(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        let m = &self.manifest;
        let n = m.n_param_leaves;
        self.check_arity(inputs.len(), 3 * n + 7)?;
        let leaves = |base: usize| -> Result<Vec<Vec<f32>>> {
            (0..n).map(|i| Ok(inputs[base + i].as_f32()?.to_vec())).collect()
        };
        let params = leaves(0)?;
        let m_state = leaves(n)?;
        let v_state = leaves(2 * n)?;
        let batch = StepBatch::parse(m, &inputs[3 * n..3 * n + 4])?;
        let step = scalar_i32(inputs[3 * n + 4])? as i64;
        let seed = scalar_i32(inputs[3 * n + 5])? as u64;
        let lr = scalar_f32(inputs[3 * n + 6])?;

        let cfg = model_config(m);
        let tape = lower_step(&cfg, &self.plan, Lowering::for_model(&cfg));
        let mut interp =
            Interp::new(m, &cfg, &self.plan, &self.engine, &batch, params, m_state, v_state)?;
        interp.run(&tape, step, seed, lr)?;

        let mut out = Vec::with_capacity(3 * n + 1);
        for bank in [&interp.params, &interp.m_state, &interp.v_state] {
            for (spec, data) in m.params.iter().zip(bank) {
                out.push(Arc::new(HostTensor::f32(spec.shape.clone(), data.clone())?));
            }
        }
        out.push(Arc::new(HostTensor::scalar_f32(interp.loss as f32)));
        Ok(out)
    }

    fn run_eval(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        let m = &self.manifest;
        let n = m.n_param_leaves;
        self.check_arity(inputs.len(), n + 5)?;
        let params: Vec<Vec<f32>> =
            (0..n).map(|i| Ok(inputs[i].as_f32()?.to_vec())).collect::<Result<_>>()?;
        let batch = StepBatch::parse(m, &inputs[n..n + 4])?;
        let (loss, metric) = eval_forward(m, &self.engine, &params, &batch)?;
        Ok(vec![
            Arc::new(HostTensor::scalar_f32(loss as f32)),
            Arc::new(HostTensor::scalar_f32(metric as f32)),
        ])
    }
}

fn scalar_i32(t: &HostTensor) -> Result<i32> {
    t.as_i32()?.first().copied().ok_or_else(|| Error::Abi("empty scalar input".into()))
}

fn scalar_f32(t: &HostTensor) -> Result<f32> {
    t.as_f32()?.first().copied().ok_or_else(|| Error::Abi("empty scalar input".into()))
}

/// Deterministic parameter init for the numeric backend: LayerNorm
/// gains start at 1, every bias/shift at 0, and weight matrices draw
/// `N(0, 0.02²)` from a per-leaf forked stream — so the §3.2
/// output-based LayerNorm backward divides by O(1) gains from step 0.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    let mut root = Rng::new(seed ^ SALT_KERNEL_INIT);
    manifest
        .params
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let n = spec.numel();
            if spec.name.ends_with("gamma") {
                vec![1f32; n]
            } else if spec.name.ends_with("beta")
                || spec.name.ends_with("_b")
                || spec.name.ends_with(".b")
                || spec.name.ends_with("bias")
            {
                vec![0f32; n]
            } else {
                let mut rng = root.fork(i as u64);
                (0..n).map(|_| (INIT_STD * rng.normal()) as f32).collect()
            }
        })
        .collect()
}

/// One training batch in the step ABI's four-leaf layout.
#[derive(Debug, Clone)]
pub struct StepBatch {
    /// Token ids, `[B, S]` row-major.
    pub input_ids: Vec<i32>,
    /// Segment/type ids, `[B, S]`.
    pub token_type_ids: Vec<i32>,
    /// Attention mask (1 = attend), `[B, S]`.
    pub attention_mask: Vec<i32>,
    /// MLM targets (−1 = unlabeled) or classification labels, `[B, S]`.
    pub labels: Vec<i32>,
}

impl StepBatch {
    fn parse(m: &Manifest, inputs: &[&Arc<HostTensor>]) -> Result<StepBatch> {
        let want = m.batch_size * m.config.seq_len;
        let field = |i: usize, name: &str| -> Result<Vec<i32>> {
            let v = inputs[i].as_i32()?;
            if v.len() != want {
                return Err(Error::Abi(format!(
                    "kernel batch leaf {name}: got {} elements, expected {want}",
                    v.len()
                )));
            }
            Ok(v.to_vec())
        };
        Ok(StepBatch {
            input_ids: field(0, "input_ids")?,
            token_type_ids: field(1, "token_type_ids")?,
            attention_mask: field(2, "attention_mask")?,
            labels: field(3, "labels")?,
        })
    }

    /// Deterministic synthetic batch for tests and the measured probe:
    /// full attention, ~15% MLM label density (cls manifests read
    /// column 0 as the class label).
    pub fn synthetic(m: &Manifest, seed: u64) -> StepBatch {
        let c = &m.config;
        let n = m.batch_size * c.seq_len;
        let mut rng = Rng::new(seed ^ SALT_KERNEL_BATCH);
        let mut b = StepBatch {
            input_ids: Vec::with_capacity(n),
            token_type_ids: Vec::with_capacity(n),
            attention_mask: vec![1; n],
            labels: Vec::with_capacity(n),
        };
        let classes = c.num_classes.max(2);
        for _ in 0..n {
            b.input_ids.push(rng.below(c.vocab_size) as i32);
            b.token_type_ids.push(rng.below(c.type_vocab.max(1)) as i32);
            let label = if m.task == "cls" {
                rng.below(classes) as i32
            } else if rng.coin(0.15) {
                rng.below(c.vocab_size) as i32
            } else {
                -1
            };
            b.labels.push(label);
        }
        b
    }
}

/// What one metered training step observed — the measured probe's raw
/// material and the rewrite-parity tests' gradient source.
#[derive(Debug)]
pub struct StepTrace {
    /// Scalar training loss.
    pub loss: f64,
    /// Per-leaf parameter gradients (manifest leaf order), taken
    /// before the optimizer update.
    pub grads: Vec<Vec<f32>>,
    /// High-water device-side live bytes actually held by the
    /// interpreter (params/grads/Adam plus every activation buffer).
    pub measured_peak_bytes: u64,
    /// The analytic timeline's peak for the same plan and batch.
    pub modeled_peak_bytes: u64,
    /// High-water bytes parked in the host stash by offload plans.
    pub host_peak_bytes: u64,
}

/// Run one metered training step outside the `Program` ABI: used by the
/// rewrite-parity tests (gradient access) and the measured probe
/// (peak/wall-clock access). Parameters are updated in place.
#[allow(clippy::too_many_arguments)]
pub fn step_trace(
    manifest: &Manifest,
    plan: &SchedulePlan,
    engine: &ExperimentEngine,
    params: &mut Vec<Vec<f32>>,
    batch: &StepBatch,
    step: i64,
    seed: u64,
    lr: f32,
) -> Result<StepTrace> {
    let cfg = model_config(manifest);
    let zeros: Vec<Vec<f32>> = manifest.params.iter().map(|s| vec![0f32; s.numel()]).collect();
    let tape = lower_step(&cfg, plan, Lowering::for_model(&cfg));
    let mut interp = Interp::new(
        manifest,
        &cfg,
        plan,
        engine,
        batch,
        std::mem::take(params),
        zeros.clone(),
        zeros,
    )?;
    interp.run(&tape, step, seed, lr)?;
    let modeled = schedule_summary(&cfg, plan).peak_bytes(manifest.batch_size as u64);
    *params = interp.params;
    Ok(StepTrace {
        loss: interp.loss,
        grads: interp.grads,
        measured_peak_bytes: interp.peak_bytes,
        modeled_peak_bytes: modeled,
        host_peak_bytes: interp.host_peak_bytes,
    })
}

// ---------------------------------------------------------------------------
// Tape interpreter
// ---------------------------------------------------------------------------

/// Segment key usable in hash maps (Segment itself doesn't hash).
fn seg_key(seg: Segment) -> (u8, u32) {
    match seg {
        Segment::Setup => (0, 0),
        Segment::Embedding => (1, 0),
        Segment::Encoder(l) => (2, l as u32),
        Segment::Head => (3, 0),
        Segment::Step => (4, 0),
    }
}

/// FNV-1a over a byte string (op-seed derivation; stable, no deps).
fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stored buffer: activation values or a 1-byte mask.
#[derive(Debug)]
enum Buf {
    F(Vec<f32>),
    M(Vec<u8>),
}

impl Buf {
    fn bytes(&self) -> u64 {
        match self {
            Buf::F(v) => 4 * v.len() as u64,
            Buf::M(v) => v.len() as u64,
        }
    }
}

/// Store key: (segment kind, layer, op name, tensor name). Keyed by op
/// because `ln1`/`ln2` in one segment both retain tensors literally
/// named `mean_var`/`rstd`.
type StoreKey = (u8, u32, &'static str, &'static str);

/// The retained-tensor store with a running byte meter.
#[derive(Debug, Default)]
struct Store {
    map: HashMap<StoreKey, Buf>,
    bytes: u64,
}

impl Store {
    fn put(&mut self, key: StoreKey, buf: Buf) {
        self.bytes += buf.bytes();
        if let Some(old) = self.map.insert(key, buf) {
            self.bytes -= old.bytes();
        }
    }

    fn take(&mut self, key: &StoreKey) -> Option<Buf> {
        let buf = self.map.remove(key)?;
        self.bytes -= buf.bytes();
        Some(buf)
    }

    fn has(&self, key: &StoreKey) -> bool {
        self.map.contains_key(key)
    }

    /// Remove every entry of `(seg, op)` — mirrors a backward event's
    /// frees of its forward twin's allocations.
    fn free_op(&mut self, seg: (u8, u32), op: &str) {
        let keys: Vec<StoreKey> =
            self.map.keys().filter(|k| (k.0, k.1) == seg && k.2 == op).copied().collect();
        for k in keys {
            self.take(&k);
        }
    }

    /// Drain a whole segment (keep the checkpoint-stored input if
    /// `keep_ckpt`) — `ckpt.discard` and the offload store DMA.
    fn drain_segment(&mut self, seg: (u8, u32), keep_ckpt: bool) -> Vec<(StoreKey, Buf)> {
        let keys: Vec<StoreKey> = self
            .map
            .keys()
            .filter(|k| (k.0, k.1) == seg && !(keep_ckpt && k.2 == "ckpt"))
            .copied()
            .collect();
        keys.into_iter().map(|k| { let b = self.take(&k).expect("key listed"); (k, b) }).collect()
    }
}

/// One step's interpreter state.
struct Interp<'a> {
    plan: &'a SchedulePlan,
    engine: &'a ExperimentEngine,
    batch: &'a StepBatch,
    bsz: usize,
    seq: usize,
    hid: usize,
    inter: usize,
    vocab: usize,
    heads: usize,
    p_drop: f32,
    leaf_idx: HashMap<String, usize>,
    params: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    m_state: Vec<Vec<f32>>,
    v_state: Vec<Vec<f32>>,
    store: Store,
    host: HashMap<(u8, u32), Vec<(StoreKey, Buf)>>,
    flow: HashMap<&'static str, Vec<f32>>,
    bwdf: HashMap<&'static str, Vec<f32>>,
    xcur: Vec<f32>,
    gcur: Vec<f32>,
    vcur: Vec<f32>,
    head_input: Vec<f32>,
    loss: f64,
    step: i64,
    lr: f32,
    step_seed: u64,
    fixed_bytes: u64,
    host_bytes: u64,
    peak_bytes: u64,
    host_peak_bytes: u64,
}

impl<'a> Interp<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        m: &'a Manifest,
        cfg: &ModelConfig,
        plan: &'a SchedulePlan,
        engine: &'a ExperimentEngine,
        batch: &'a StepBatch,
        params: Vec<Vec<f32>>,
        m_state: Vec<Vec<f32>>,
        v_state: Vec<Vec<f32>>,
    ) -> Result<Interp<'a>> {
        if cfg.hidden % cfg.heads.max(1) != 0 {
            return Err(Error::Invalid(format!(
                "kernel backend: heads {} must divide hidden {}",
                cfg.heads, cfg.hidden
            )));
        }
        let leaf_idx: HashMap<String, usize> =
            m.params.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
        let grads: Vec<Vec<f32>> = m.params.iter().map(|s| vec![0f32; s.numel()]).collect();
        let total: u64 = m.params.iter().map(|s| 4 * s.numel() as u64).sum();
        Ok(Interp {
            plan,
            engine,
            batch,
            bsz: m.batch_size,
            seq: cfg.seq_len,
            hid: cfg.hidden,
            inter: cfg.intermediate,
            vocab: cfg.vocab_size,
            heads: cfg.heads,
            p_drop: cfg.dropout_p as f32,
            leaf_idx,
            params,
            grads,
            m_state,
            v_state,
            store: Store::default(),
            host: HashMap::new(),
            flow: HashMap::new(),
            bwdf: HashMap::new(),
            xcur: Vec::new(),
            gcur: Vec::new(),
            vcur: Vec::new(),
            head_input: Vec::new(),
            loss: 0.0,
            step: 0,
            lr: 0.0,
            step_seed: 0,
            fixed_bytes: 4 * total,
            host_bytes: 0,
            peak_bytes: 0,
            host_peak_bytes: 0,
        })
    }

    fn run(&mut self, tape: &StepSchedule, step: i64, seed: u64, lr: f32) -> Result<()> {
        self.step = step;
        self.lr = lr;
        self.step_seed = mix64(seed ^ mix64(step as u64));
        for e in &tape.events {
            self.exec_event(e)?;
            self.sample();
        }
        Ok(())
    }

    // -- bookkeeping --------------------------------------------------------

    fn sample(&mut self) {
        let held = |m: &HashMap<&'static str, Vec<f32>>| -> u64 {
            m.values().map(|v| 4 * v.len() as u64).sum()
        };
        let live = self.fixed_bytes
            + self.store.bytes
            + held(&self.flow)
            + held(&self.bwdf)
            + 4 * (self.xcur.len() + self.gcur.len() + self.vcur.len() + self.head_input.len())
                as u64;
        self.peak_bytes = self.peak_bytes.max(live);
        self.host_peak_bytes = self.host_peak_bytes.max(self.host_bytes);
    }

    fn leaf(&self, name: &str) -> Result<usize> {
        self.leaf_idx
            .get(name)
            .copied()
            .ok_or_else(|| Error::Abi(format!("kernel backend: no parameter leaf named {name}")))
    }

    fn layer_leaf(&self, l: u32, suffix: &str) -> Result<usize> {
        self.leaf(&format!("encoder.{l}.{suffix}"))
    }

    fn add_grad(&mut self, idx: usize, dv: &[f32]) {
        for (g, &d) in self.grads[idx].iter_mut().zip(dv) {
            *g += d;
        }
    }

    /// Per-op dropout seed: positional in `(segment, op)` — identical
    /// across plans, tape layouts and worker counts, so checkpoint
    /// replays regenerate the forward's exact mask.
    fn op_seed(&self, seg: Segment, op: &str) -> u64 {
        let (k, l) = seg_key(seg);
        let tag = fnv1a(
            [k]
                .into_iter()
                .chain(l.to_le_bytes())
                .chain([0xff])
                .chain(op.bytes()),
        );
        mix64(self.step_seed ^ tag)
    }

    fn dims(&self) -> AttnDims {
        AttnDims {
            batch: self.bsz,
            heads: self.heads,
            seq: self.seq,
            head_dim: self.hid / self.heads,
        }
    }

    /// Effective rewrite subset for a forward event: recomputes and
    /// checkpointed layers store the stock (`none`) inventory — the
    /// checkpoint transform replaces the rewrites for that layer.
    fn eff_opts(&self, seg: Segment, recompute: bool) -> OptimizationSet {
        match seg {
            Segment::Encoder(l) => {
                if recompute || matches!(self.plan.residency(l), Residency::Checkpoint(_)) {
                    OptimizationSet::none()
                } else {
                    self.plan.per_layer.get(l).copied().unwrap_or_else(OptimizationSet::none)
                }
            }
            _ => self.plan.other,
        }
    }

    fn store_f(&self, seg: Segment, op: &'static str, name: &'static str) -> Result<Vec<f32>> {
        let (k, l) = seg_key(seg);
        match self.store.map.get(&(k, l, op, name)) {
            Some(Buf::F(v)) => Ok(v.clone()),
            _ => Err(Error::Backend(format!(
                "kernel store: missing f32 tensor {name} of op {op} in {}",
                seg.label()
            ))),
        }
    }

    fn store_m(&self, seg: Segment, op: &'static str, name: &'static str) -> Result<Vec<u8>> {
        let (k, l) = seg_key(seg);
        match self.store.map.get(&(k, l, op, name)) {
            Some(Buf::M(v)) => Ok(v.clone()),
            _ => Err(Error::Backend(format!(
                "kernel store: missing mask {name} of op {op} in {}",
                seg.label()
            ))),
        }
    }

    fn put(&mut self, seg: Segment, op: &'static str, name: &'static str, buf: Buf) {
        let (k, l) = seg_key(seg);
        self.store.put((k, l, op, name), buf);
    }

    fn has(&self, seg: Segment, op: &'static str, name: &'static str) -> bool {
        let (k, l) = seg_key(seg);
        self.store.has(&(k, l, op, name))
    }

    fn free_op(&mut self, seg: Segment, op: &str) {
        self.store.free_op(seg_key(seg), op);
    }

    fn flow_take(&mut self, name: &'static str) -> Result<Vec<f32>> {
        self.flow
            .remove(name)
            .ok_or_else(|| Error::Backend(format!("kernel dataflow: missing edge {name}")))
    }

    fn bwdf_take(&mut self, name: &'static str) -> Result<Vec<f32>> {
        self.bwdf
            .remove(name)
            .ok_or_else(|| Error::Backend(format!("kernel backward dataflow: missing {name}")))
    }

    // -- event dispatch -----------------------------------------------------

    fn exec_event(&mut self, e: &ScheduleEvent) -> Result<()> {
        match e.kind {
            EventKind::Setup | EventKind::Turnaround => Ok(()),
            EventKind::Forward => match e.name {
                "ckpt.store" => {
                    let x = self.xcur.clone();
                    self.put(e.segment, "ckpt", "ckpt.stored_input", Buf::F(x));
                    Ok(())
                }
                "ckpt.discard" => {
                    self.store.drain_segment(seg_key(e.segment), true);
                    Ok(())
                }
                _ => self.forward_op(e.segment, e.name, false),
            },
            EventKind::Recompute => self.forward_op(e.segment, e.name, true),
            EventKind::Store => {
                let moved = self.store.drain_segment(seg_key(e.segment), false);
                let bytes: u64 = moved.iter().map(|(_, b)| b.bytes()).sum();
                self.host_bytes += bytes;
                self.host.insert(seg_key(e.segment), moved);
                Ok(())
            }
            EventKind::Load => {
                let moved = self.host.remove(&seg_key(e.segment)).ok_or_else(|| {
                    Error::Backend(format!("kernel offload: nothing stashed for {}", e.segment.label()))
                })?;
                for (k, b) in moved {
                    self.host_bytes -= b.bytes();
                    self.store.put(k, b);
                }
                Ok(())
            }
            EventKind::Backward => self.backward_op(e.segment, e.name),
            // the numeric backend is single-shard: TP collectives model
            // interconnect traffic the CPU interpreter has no peers for
            EventKind::AllGather | EventKind::ReduceScatter => Err(Error::Backend(
                "kernel backend: tensor-parallel plans (tp > 1) are model-only; \
                 run the kernel backend on an unsharded plan"
                    .into(),
            )),
            EventKind::Optimizer => {
                self.adam();
                Ok(())
            }
        }
    }

    // -- forward ops --------------------------------------------------------

    fn forward_op(&mut self, seg: Segment, name: &'static str, recompute: bool) -> Result<()> {
        match seg {
            Segment::Embedding => self.fwd_embedding(name),
            Segment::Encoder(l) => self.fwd_encoder(seg, l as u32, name, recompute),
            Segment::Head => self.fwd_head(name),
            _ => Err(Error::Backend(format!(
                "kernel backend: unexpected forward op {name} in {}",
                seg.label()
            ))),
        }
    }

    fn fwd_embedding(&mut self, name: &'static str) -> Result<()> {
        let seg = Segment::Embedding;
        let opts = self.plan.other;
        let (bs, h) = (self.bsz * self.seq, self.hid);
        match name {
            "emb.sum" => {
                let wi = self.leaf("embeddings.word")?;
                let pi = self.leaf("embeddings.position")?;
                let ti = self.leaf("embeddings.token_type")?;
                let (word, pos, tok) = (&self.params[wi], &self.params[pi], &self.params[ti]);
                let (vocab, seq) = (self.vocab as i32, self.seq);
                let tv = (self.params[ti].len() / h) as i32;
                let (ids, tts) = (&self.batch.input_ids, &self.batch.token_type_ids);
                let x = fill_rows(self.engine, bs, h, |row, out| {
                    let id = ids[row].rem_euclid(vocab) as usize;
                    let s = row % seq;
                    let tt = tts[row].rem_euclid(tv) as usize;
                    for (j, o) in out.iter_mut().enumerate() {
                        *o = word[id * h + j] + pos[s * h + j] + tok[tt * h + j];
                    }
                });
                self.put(seg, "emb.sum", "emb.sum_output", Buf::F(x.clone()));
                self.xcur = x;
            }
            "emb.ln" => {
                let x = std::mem::take(&mut self.xcur);
                let gi = self.leaf("embeddings.ln.gamma")?;
                let bi = self.leaf("embeddings.ln.beta")?;
                let f = layernorm_fwd(
                    self.engine,
                    &x,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                if !opts.inplace_layernorm {
                    self.put(seg, "emb.ln", "emb.ln_input", Buf::F(x));
                }
                self.put(seg, "emb.ln", "emb.ln_output", Buf::F(f.y.clone()));
                self.xcur = f.y;
            }
            "emb.dropout" => {
                let mask = dropout_mask(
                    self.engine,
                    bs * h,
                    self.p_drop,
                    self.op_seed(seg, "emb.dropout"),
                );
                self.xcur = dropout_apply(self.engine, &self.xcur, &mask, self.p_drop);
                self.put(seg, "emb.dropout", "emb.drop_mask", Buf::M(mask));
            }
            _ => {
                return Err(Error::Backend(format!("kernel backend: unknown embedding op {name}")))
            }
        }
        Ok(())
    }

    fn fwd_encoder(
        &mut self,
        seg: Segment,
        l: u32,
        name: &'static str,
        recompute: bool,
    ) -> Result<()> {
        let opts = self.eff_opts(seg, recompute);
        let (bs, h, inter) = (self.bsz * self.seq, self.hid, self.inter);
        let srows = self.bsz * self.heads * self.seq;
        match name {
            "attn.qkv" => {
                let x = if recompute {
                    self.store_f(seg, "ckpt", "ckpt.stored_input")?
                } else {
                    std::mem::take(&mut self.xcur)
                };
                for (wn, bn, out) in [
                    ("attn.q_w", "attn.q_b", "attn.q"),
                    ("attn.k_w", "attn.k_b", "attn.k"),
                    ("attn.v_w", "attn.v_b", "attn.v"),
                ] {
                    let wi = self.layer_leaf(l, wn)?;
                    let bi = self.layer_leaf(l, bn)?;
                    let y = matmul_bias(
                        self.engine,
                        &x,
                        &self.params[wi],
                        Some(&self.params[bi]),
                        bs,
                        h,
                        h,
                    );
                    self.put(seg, "attn.qkv", out, Buf::F(y));
                }
                self.put(seg, "attn.qkv", "attn.input", Buf::F(x));
            }
            "attn.scores" => {
                let q = self.store_f(seg, "attn.qkv", "attn.q")?;
                let k = self.store_f(seg, "attn.qkv", "attn.k")?;
                let scores =
                    attn_scores(self.engine, &q, &k, Some(&self.batch.attention_mask), self.dims());
                self.flow.insert("scores", scores);
            }
            "attn.softmax" => {
                let scores = self.flow_take("scores")?;
                let probs = softmax_fwd(self.engine, &scores, srows, self.seq);
                if !opts.softmax_outonly {
                    self.put(seg, "attn.softmax", "attn.scores", Buf::F(scores));
                }
                self.put(seg, "attn.softmax", "attn.probs", Buf::F(probs));
            }
            "attn.dropout" => {
                let probs = self.store_f(seg, "attn.softmax", "attn.probs")?;
                let mask = dropout_mask(
                    self.engine,
                    probs.len(),
                    self.p_drop,
                    self.op_seed(seg, "attn.dropout"),
                );
                let dropped = dropout_apply(self.engine, &probs, &mask, self.p_drop);
                self.put(seg, "attn.dropout", "attn.drop_mask", Buf::M(mask));
                if opts.dropout_recompute {
                    self.flow.insert("probs_dropped", dropped);
                } else {
                    self.put(seg, "attn.dropout", "attn.probs_dropped", Buf::F(dropped));
                }
            }
            "attn.pv" => {
                let dropped = match self.flow.remove("probs_dropped") {
                    Some(x) => x,
                    None => self.store_f(seg, "attn.dropout", "attn.probs_dropped")?,
                };
                let v = self.store_f(seg, "attn.qkv", "attn.v")?;
                let ctx = attn_context(self.engine, &dropped, &v, self.dims());
                self.put(seg, "attn.pv", "attn.context", Buf::F(ctx));
            }
            "attn.proj" => {
                let ctx = self.store_f(seg, "attn.pv", "attn.context")?;
                let wi = self.layer_leaf(l, "attn.out_w")?;
                let bi = self.layer_leaf(l, "attn.out_b")?;
                let proj = matmul_bias(
                    self.engine,
                    &ctx,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    bs,
                    h,
                    h,
                );
                self.flow.insert("proj", proj);
            }
            "attn.proj_dropout" => {
                let proj = self.flow_take("proj")?;
                let mask = dropout_mask(
                    self.engine,
                    proj.len(),
                    self.p_drop,
                    self.op_seed(seg, "attn.proj_dropout"),
                );
                let dropped = dropout_apply(self.engine, &proj, &mask, self.p_drop);
                self.put(seg, "attn.proj_dropout", "attn.proj_drop_mask", Buf::M(mask));
                self.flow.insert("proj_dropped", dropped);
            }
            "attn.residual" => {
                let dropped = self.flow_take("proj_dropped")?;
                let x = self.store_f(seg, "attn.qkv", "attn.input")?;
                let res = add(self.engine, &dropped, &x);
                self.flow.insert("res1", res);
            }
            "ln1" => {
                let res1 = self.flow_take("res1")?;
                let gi = self.layer_leaf(l, "attn.ln.gamma")?;
                let bi = self.layer_leaf(l, "attn.ln.beta")?;
                let f = layernorm_fwd(
                    self.engine,
                    &res1,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                if opts.inplace_layernorm {
                    self.put(seg, "ln1", "rstd", Buf::F(f.rstd));
                } else {
                    self.put(seg, "ln1", "ln1.input", Buf::F(res1));
                    let mut mv = f.mean;
                    mv.extend_from_slice(&f.var);
                    self.put(seg, "ln1", "mean_var", Buf::F(mv));
                }
                self.put(seg, "ln1", "ln1.output", Buf::F(f.y));
            }
            "ffn.fc1" => {
                let a = self.store_f(seg, "ln1", "ln1.output")?;
                let wi = self.layer_leaf(l, "ffn.in_w")?;
                let bi = self.layer_leaf(l, "ffn.in_b")?;
                let fc1 = matmul_bias(
                    self.engine,
                    &a,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    bs,
                    h,
                    inter,
                );
                self.flow.insert("fc1", fc1);
            }
            "ffn.gelu" => {
                let fc1 = self.flow_take("fc1")?;
                let (y, mask) = gelu_fwd(self.engine, &fc1);
                if opts.inplace_gelu {
                    self.put(seg, "ffn.gelu", "ffn.gelu_mask", Buf::M(mask));
                } else {
                    self.put(seg, "ffn.gelu", "ffn.gelu_input", Buf::F(fc1));
                }
                self.put(seg, "ffn.gelu", "ffn.gelu_output", Buf::F(y));
            }
            "ffn.fc2" => {
                let a = self.store_f(seg, "ffn.gelu", "ffn.gelu_output")?;
                let wi = self.layer_leaf(l, "ffn.out_w")?;
                let bi = self.layer_leaf(l, "ffn.out_b")?;
                let fc2 = matmul_bias(
                    self.engine,
                    &a,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    bs,
                    inter,
                    h,
                );
                self.flow.insert("fc2", fc2);
            }
            "ffn.fc2_dropout" => {
                let fc2 = self.flow_take("fc2")?;
                let mask = dropout_mask(
                    self.engine,
                    fc2.len(),
                    self.p_drop,
                    self.op_seed(seg, "ffn.fc2_dropout"),
                );
                let dropped = dropout_apply(self.engine, &fc2, &mask, self.p_drop);
                self.put(seg, "ffn.fc2_dropout", "ffn.drop_mask", Buf::M(mask));
                self.flow.insert("fc2d", dropped);
            }
            "ffn.residual" => {
                let dropped = self.flow_take("fc2d")?;
                let a = self.store_f(seg, "ln1", "ln1.output")?;
                let res = add(self.engine, &dropped, &a);
                self.flow.insert("res2", res);
            }
            "ln2" => {
                let res2 = self.flow_take("res2")?;
                let gi = self.layer_leaf(l, "ffn.ln.gamma")?;
                let bi = self.layer_leaf(l, "ffn.ln.beta")?;
                let f = layernorm_fwd(
                    self.engine,
                    &res2,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                if opts.inplace_layernorm {
                    self.put(seg, "ln2", "rstd", Buf::F(f.rstd));
                } else {
                    self.put(seg, "ln2", "ln2.input", Buf::F(res2));
                    let mut mv = f.mean;
                    mv.extend_from_slice(&f.var);
                    self.put(seg, "ln2", "mean_var", Buf::F(mv));
                }
                if !recompute {
                    self.xcur = f.y;
                }
            }
            _ => {
                return Err(Error::Backend(format!(
                    "kernel backend: unknown encoder op {name}"
                )))
            }
        }
        Ok(())
    }

    fn fwd_head(&mut self, name: &'static str) -> Result<()> {
        let seg = Segment::Head;
        let opts = self.plan.other;
        let (bs, h, v) = (self.bsz * self.seq, self.hid, self.vocab);
        match name {
            "head.transform" => {
                self.head_input = std::mem::take(&mut self.xcur);
                let wi = self.leaf("mlm.transform_w")?;
                let bi = self.leaf("mlm.transform_b")?;
                let t = matmul_bias(
                    self.engine,
                    &self.head_input,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    bs,
                    h,
                    h,
                );
                self.put(seg, "head.transform", "head.transform_out", Buf::F(t));
            }
            "head.gelu" => {
                let t = self.store_f(seg, "head.transform", "head.transform_out")?;
                let (y, mask) = gelu_fwd(self.engine, &t);
                if opts.inplace_gelu {
                    self.put(seg, "head.gelu", "head.gelu_mask", Buf::M(mask));
                } else {
                    self.put(seg, "head.gelu", "head.gelu_input", Buf::F(t));
                }
                self.put(seg, "head.gelu", "head.gelu_output", Buf::F(y));
            }
            "head.ln" => {
                let x = self.store_f(seg, "head.gelu", "head.gelu_output")?;
                let gi = self.leaf("mlm.ln.gamma")?;
                let bi = self.leaf("mlm.ln.beta")?;
                let f = layernorm_fwd(
                    self.engine,
                    &x,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                if !opts.inplace_layernorm {
                    self.put(seg, "head.ln", "head.ln_input", Buf::F(x));
                }
                self.put(seg, "head.ln", "head.ln_output", Buf::F(f.y));
            }
            "head.decoder" => {
                let x = self.store_f(seg, "head.ln", "head.ln_output")?;
                let wi = self.leaf("embeddings.word")?;
                let bi = self.leaf("mlm.decoder_bias")?;
                let mut logits = matmul_bt(self.engine, &x, &self.params[wi], bs, h, v);
                let bias = &self.params[bi];
                for row in logits.chunks_exact_mut(v) {
                    for (o, &b) in row.iter_mut().zip(bias) {
                        *o += b;
                    }
                }
                self.put(seg, "head.decoder", "head.logits", Buf::F(logits));
            }
            "head.loss" => {
                let logits = self.store_f(seg, "head.decoder", "head.logits")?;
                let ls = log_softmax_rows(self.engine, &logits, bs, v);
                let (mut acc, mut cnt) = (0f64, 0u64);
                for (row, &label) in self.batch.labels.iter().enumerate() {
                    if label >= 0 {
                        let idx = label.rem_euclid(v as i32) as usize;
                        acc -= f64::from(ls[row * v + idx]);
                        cnt += 1;
                    }
                }
                self.loss = if cnt > 0 { acc / cnt as f64 } else { 0.0 };
                self.put(seg, "head.loss", "head.log_softmax", Buf::F(ls));
            }
            "cls.pool" => {
                self.head_input = std::mem::take(&mut self.xcur);
                let wi = self.leaf("pooler.w")?;
                let bi = self.leaf("pooler.b")?;
                let x0 = gather_first_tokens(&self.head_input, self.bsz, self.seq, h);
                let pooled = matmul_bias(
                    self.engine,
                    &x0,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    self.bsz,
                    h,
                    h,
                );
                self.put(seg, "cls.pool", "cls.pooled", Buf::F(pooled));
            }
            "cls.tanh" => {
                let pooled = self.store_f(seg, "cls.pool", "cls.pooled")?;
                let t = map_elems(self.engine, &pooled, |_, x| f64::from(x).tanh() as f32);
                self.put(seg, "cls.tanh", "cls.tanh_out", Buf::F(t));
            }
            "cls.logits" => {
                let t = self.store_f(seg, "cls.tanh", "cls.tanh_out")?;
                let wi = self.leaf("classifier.w")?;
                let bi = self.leaf("classifier.b")?;
                let classes = self.params[bi].len();
                let logits = matmul_bias(
                    self.engine,
                    &t,
                    &self.params[wi],
                    Some(&self.params[bi]),
                    self.bsz,
                    h,
                    classes,
                );
                let ls = log_softmax_rows(self.engine, &logits, self.bsz, classes);
                let mut acc = 0f64;
                for b in 0..self.bsz {
                    let label =
                        self.batch.labels[b * self.seq].rem_euclid(classes as i32) as usize;
                    acc -= f64::from(ls[b * classes + label]);
                }
                self.loss = acc / self.bsz as f64;
                self.put(seg, "cls.logits", "cls.logits", Buf::F(logits));
            }
            _ => {
                return Err(Error::Backend(format!("kernel backend: unknown head op {name}")))
            }
        }
        Ok(())
    }

    // -- backward ops -------------------------------------------------------

    /// Per-row rstd for a LayerNorm backward: stored directly by the
    /// in-place rewrite, else recomputed from the stored `mean_var`
    /// pair — bit-identical by construction (forward derives rstd from
    /// the f32-rounded variance).
    fn ln_rstd(&self, seg: Segment, op: &'static str, rows: usize) -> Result<Vec<f32>> {
        if self.has(seg, op, "rstd") {
            return self.store_f(seg, op, "rstd");
        }
        let mv = self.store_f(seg, op, "mean_var")?;
        if mv.len() != 2 * rows {
            return Err(Error::Backend(format!(
                "kernel store: mean_var of {op} has {} elements, expected {}",
                mv.len(),
                2 * rows
            )));
        }
        Ok(rstd_from_var(&mv[rows..], LN_EPS))
    }

    fn backward_op(&mut self, seg: Segment, name: &'static str) -> Result<()> {
        match seg {
            Segment::Embedding => self.bwd_embedding(name),
            Segment::Encoder(l) => self.bwd_encoder(seg, l as u32, name),
            Segment::Head => self.bwd_head(name),
            _ => Err(Error::Backend(format!(
                "kernel backend: unexpected backward op {name} in {}",
                seg.label()
            ))),
        }
    }

    fn bwd_encoder(&mut self, seg: Segment, l: u32, name: &'static str) -> Result<()> {
        let (bs, h, inter) = (self.bsz * self.seq, self.hid, self.inter);
        let srows = self.bsz * self.heads * self.seq;
        match name {
            "ln2" => {
                let y = std::mem::take(&mut self.vcur);
                let rstd = self.ln_rstd(seg, "ln2", bs)?;
                let gi = self.layer_leaf(l, "ffn.ln.gamma")?;
                let bi = self.layer_leaf(l, "ffn.ln.beta")?;
                let g = std::mem::take(&mut self.gcur);
                let b = layernorm_bwd(
                    self.engine,
                    &g,
                    &y,
                    &self.params[gi],
                    &self.params[bi],
                    &rstd,
                    bs,
                    h,
                );
                self.add_grad(gi, &b.dgamma);
                self.add_grad(bi, &b.dbeta);
                self.gcur = b.dx;
            }
            "ffn.residual" => {
                self.bwdf.insert("res_ln1", self.gcur.clone());
            }
            "ffn.fc2_dropout" => {
                let mask = self.store_m(seg, "ffn.fc2_dropout", "ffn.drop_mask")?;
                self.gcur = dropout_apply(self.engine, &self.gcur, &mask, self.p_drop);
            }
            "ffn.fc2" => {
                let a = self.store_f(seg, "ffn.gelu", "ffn.gelu_output")?;
                let g = std::mem::take(&mut self.gcur);
                let wi = self.layer_leaf(l, "ffn.out_w")?;
                let bi = self.layer_leaf(l, "ffn.out_b")?;
                let dw = matmul_at(self.engine, &a, &g, bs, inter, h);
                let db = bias_grad(&g, bs, h);
                let dx = matmul_bt(self.engine, &g, &self.params[wi], bs, h, inter);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                self.gcur = dx;
            }
            "ffn.gelu" => {
                let g = std::mem::take(&mut self.gcur);
                self.gcur = if self.has(seg, "ffn.gelu", "ffn.gelu_input") {
                    let x = self.store_f(seg, "ffn.gelu", "ffn.gelu_input")?;
                    gelu_bwd(self.engine, &g, &x)
                } else {
                    let y = self.store_f(seg, "ffn.gelu", "ffn.gelu_output")?;
                    let mask = self.store_m(seg, "ffn.gelu", "ffn.gelu_mask")?;
                    gelu_bwd_inplace(self.engine, &g, &y, &mask)
                };
            }
            "ffn.fc1" => {
                let a = self.store_f(seg, "ln1", "ln1.output")?;
                let g = std::mem::take(&mut self.gcur);
                let wi = self.layer_leaf(l, "ffn.in_w")?;
                let bi = self.layer_leaf(l, "ffn.in_b")?;
                let dw = matmul_at(self.engine, &a, &g, bs, h, inter);
                let db = bias_grad(&g, bs, inter);
                let dx = matmul_bt(self.engine, &g, &self.params[wi], bs, inter, h);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                let res = self.bwdf_take("res_ln1")?;
                self.gcur = add(self.engine, &dx, &res);
            }
            "ln1" => {
                let y = self.store_f(seg, "ln1", "ln1.output")?;
                let rstd = self.ln_rstd(seg, "ln1", bs)?;
                let gi = self.layer_leaf(l, "attn.ln.gamma")?;
                let bi = self.layer_leaf(l, "attn.ln.beta")?;
                let g = std::mem::take(&mut self.gcur);
                let b = layernorm_bwd(
                    self.engine,
                    &g,
                    &y,
                    &self.params[gi],
                    &self.params[bi],
                    &rstd,
                    bs,
                    h,
                );
                self.add_grad(gi, &b.dgamma);
                self.add_grad(bi, &b.dbeta);
                self.gcur = b.dx;
            }
            "attn.residual" => {
                self.bwdf.insert("res_x", self.gcur.clone());
            }
            "attn.proj_dropout" => {
                let mask = self.store_m(seg, "attn.proj_dropout", "attn.proj_drop_mask")?;
                self.gcur = dropout_apply(self.engine, &self.gcur, &mask, self.p_drop);
            }
            "attn.proj" => {
                let ctx = self.store_f(seg, "attn.pv", "attn.context")?;
                let g = std::mem::take(&mut self.gcur);
                let wi = self.layer_leaf(l, "attn.out_w")?;
                let bi = self.layer_leaf(l, "attn.out_b")?;
                let dw = matmul_at(self.engine, &ctx, &g, bs, h, h);
                let db = bias_grad(&g, bs, h);
                let dx = matmul_bt(self.engine, &g, &self.params[wi], bs, h, h);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                self.gcur = dx;
            }
            "attn.pv" => {
                let dropped = if self.has(seg, "attn.dropout", "attn.probs_dropped") {
                    self.store_f(seg, "attn.dropout", "attn.probs_dropped")?
                } else {
                    // §3.3 dropout recompute: replay the cheap apply
                    // from the kept probs + mask (bit-identical).
                    let probs = self.store_f(seg, "attn.softmax", "attn.probs")?;
                    let mask = self.store_m(seg, "attn.dropout", "attn.drop_mask")?;
                    dropout_apply(self.engine, &probs, &mask, self.p_drop)
                };
                let v = self.store_f(seg, "attn.qkv", "attn.v")?;
                let g = std::mem::take(&mut self.gcur);
                let (dprobs, dv) = attn_context_bwd(self.engine, &g, &dropped, &v, self.dims());
                self.bwdf.insert("dv", dv);
                self.gcur = dprobs;
            }
            "attn.dropout" => {
                let mask = self.store_m(seg, "attn.dropout", "attn.drop_mask")?;
                self.gcur = dropout_apply(self.engine, &self.gcur, &mask, self.p_drop);
            }
            "attn.softmax" => {
                let probs = self.store_f(seg, "attn.softmax", "attn.probs")?;
                let g = std::mem::take(&mut self.gcur);
                self.gcur = softmax_bwd(self.engine, &g, &probs, srows, self.seq);
            }
            "attn.scores" => {
                let q = self.store_f(seg, "attn.qkv", "attn.q")?;
                let k = self.store_f(seg, "attn.qkv", "attn.k")?;
                let g = std::mem::take(&mut self.gcur);
                let (dq, dk) = attn_scores_bwd(self.engine, &g, &q, &k, self.dims());
                self.bwdf.insert("dq", dq);
                self.bwdf.insert("dk", dk);
            }
            "attn.qkv" => {
                let x = self.store_f(seg, "attn.qkv", "attn.input")?;
                let mut total = self.bwdf_take("res_x")?;
                for (dn, wn, bn) in [
                    ("dq", "attn.q_w", "attn.q_b"),
                    ("dk", "attn.k_w", "attn.k_b"),
                    ("dv", "attn.v_w", "attn.v_b"),
                ] {
                    let dg = self.bwdf_take(dn)?;
                    let wi = self.layer_leaf(l, wn)?;
                    let bi = self.layer_leaf(l, bn)?;
                    let dw = matmul_at(self.engine, &x, &dg, bs, h, h);
                    let db = bias_grad(&dg, bs, h);
                    let dx = matmul_bt(self.engine, &dg, &self.params[wi], bs, h, h);
                    self.add_grad(wi, &dw);
                    self.add_grad(bi, &db);
                    total = add(self.engine, &total, &dx);
                }
                self.gcur = total;
                // The layer input IS the lower segment's ln2 output —
                // stash its value before the frees take it (the lower
                // LN backward is output-based).
                self.vcur = x;
                let (k, li) = seg_key(seg);
                self.store.take(&(k, li, "ckpt", "ckpt.stored_input"));
            }
            _ => {
                return Err(Error::Backend(format!(
                    "kernel backend: unknown encoder backward op {name}"
                )))
            }
        }
        self.free_op(seg, name);
        Ok(())
    }

    fn bwd_embedding(&mut self, name: &'static str) -> Result<()> {
        let seg = Segment::Embedding;
        let (bs, h) = (self.bsz * self.seq, self.hid);
        match name {
            "emb.dropout" => {
                self.vcur = Vec::new();
                let mask = self.store_m(seg, "emb.dropout", "emb.drop_mask")?;
                self.gcur = dropout_apply(self.engine, &self.gcur, &mask, self.p_drop);
            }
            "emb.ln" => {
                let y = self.store_f(seg, "emb.ln", "emb.ln_output")?;
                let x = self.store_f(seg, "emb.sum", "emb.sum_output")?;
                let gi = self.leaf("embeddings.ln.gamma")?;
                let bi = self.leaf("embeddings.ln.beta")?;
                // Stats are always recomputed here (the tape never
                // retains them for the embedding LN) — bit-identical to
                // the forward's, same kernel, same input.
                let f = layernorm_fwd(
                    self.engine,
                    &x,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                let g = std::mem::take(&mut self.gcur);
                let b = layernorm_bwd(
                    self.engine,
                    &g,
                    &y,
                    &self.params[gi],
                    &self.params[bi],
                    &f.rstd,
                    bs,
                    h,
                );
                self.add_grad(gi, &b.dgamma);
                self.add_grad(bi, &b.dbeta);
                self.gcur = b.dx;
            }
            "emb.sum" => {
                let g = std::mem::take(&mut self.gcur);
                let wi = self.leaf("embeddings.word")?;
                let pi = self.leaf("embeddings.position")?;
                let ti = self.leaf("embeddings.token_type")?;
                let mut dword = vec![0f32; self.grads[wi].len()];
                let mut dpos = vec![0f32; self.grads[pi].len()];
                let mut dtok = vec![0f32; self.grads[ti].len()];
                let tv = (dtok.len() / h) as i32;
                for row in 0..bs {
                    let id = self.batch.input_ids[row].rem_euclid(self.vocab as i32) as usize;
                    let s = row % self.seq;
                    let tt = self.batch.token_type_ids[row].rem_euclid(tv) as usize;
                    let gr = &g[row * h..(row + 1) * h];
                    for (j, &gv) in gr.iter().enumerate() {
                        dword[id * h + j] += gv;
                        dpos[s * h + j] += gv;
                        dtok[tt * h + j] += gv;
                    }
                }
                self.add_grad(wi, &dword);
                self.add_grad(pi, &dpos);
                self.add_grad(ti, &dtok);
            }
            _ => {
                return Err(Error::Backend(format!(
                    "kernel backend: unknown embedding backward op {name}"
                )))
            }
        }
        self.free_op(seg, name);
        Ok(())
    }

    fn bwd_head(&mut self, name: &'static str) -> Result<()> {
        let seg = Segment::Head;
        let (bs, h, v) = (self.bsz * self.seq, self.hid, self.vocab);
        match name {
            "head.loss" => {
                let ls = self.store_f(seg, "head.loss", "head.log_softmax")?;
                let labels = &self.batch.labels;
                let cnt = labels.iter().filter(|&&x| x >= 0).count().max(1) as f32;
                self.gcur = fill_rows(self.engine, bs, v, |row, out| {
                    let label = labels[row];
                    if label >= 0 {
                        let idx = label.rem_euclid(v as i32) as usize;
                        let lr = &ls[row * v..(row + 1) * v];
                        for (j, o) in out.iter_mut().enumerate() {
                            let p = f64::from(lr[j]).exp() as f32;
                            *o = (p - if j == idx { 1.0 } else { 0.0 }) / cnt;
                        }
                    }
                });
            }
            "head.decoder" => {
                let x = self.store_f(seg, "head.ln", "head.ln_output")?;
                let g = std::mem::take(&mut self.gcur);
                let wi = self.leaf("embeddings.word")?;
                let bi = self.leaf("mlm.decoder_bias")?;
                let dh = matmul(self.engine, &g, &self.params[wi], bs, v, h);
                let dword = matmul_at(self.engine, &g, &x, bs, v, h);
                let db = bias_grad(&g, bs, v);
                self.add_grad(wi, &dword);
                self.add_grad(bi, &db);
                self.gcur = dh;
            }
            "head.ln" => {
                let y = self.store_f(seg, "head.ln", "head.ln_output")?;
                let x = self.store_f(seg, "head.gelu", "head.gelu_output")?;
                let gi = self.leaf("mlm.ln.gamma")?;
                let bi = self.leaf("mlm.ln.beta")?;
                let f = layernorm_fwd(
                    self.engine,
                    &x,
                    &self.params[gi],
                    &self.params[bi],
                    bs,
                    h,
                    LN_EPS,
                );
                let g = std::mem::take(&mut self.gcur);
                let b = layernorm_bwd(
                    self.engine,
                    &g,
                    &y,
                    &self.params[gi],
                    &self.params[bi],
                    &f.rstd,
                    bs,
                    h,
                );
                self.add_grad(gi, &b.dgamma);
                self.add_grad(bi, &b.dbeta);
                self.gcur = b.dx;
            }
            "head.gelu" => {
                let g = std::mem::take(&mut self.gcur);
                self.gcur = if self.has(seg, "head.gelu", "head.gelu_input") {
                    let x = self.store_f(seg, "head.gelu", "head.gelu_input")?;
                    gelu_bwd(self.engine, &g, &x)
                } else {
                    let y = self.store_f(seg, "head.gelu", "head.gelu_output")?;
                    let mask = self.store_m(seg, "head.gelu", "head.gelu_mask")?;
                    gelu_bwd_inplace(self.engine, &g, &y, &mask)
                };
            }
            "head.transform" => {
                let g = std::mem::take(&mut self.gcur);
                let wi = self.leaf("mlm.transform_w")?;
                let bi = self.leaf("mlm.transform_b")?;
                let dw = matmul_at(self.engine, &self.head_input, &g, bs, h, h);
                let db = bias_grad(&g, bs, h);
                let dx = matmul_bt(self.engine, &g, &self.params[wi], bs, h, h);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                self.gcur = dx;
                self.vcur = std::mem::take(&mut self.head_input);
            }
            "cls.logits" => {
                let logits = self.store_f(seg, "cls.logits", "cls.logits")?;
                let t = self.store_f(seg, "cls.tanh", "cls.tanh_out")?;
                let wi = self.leaf("classifier.w")?;
                let bi = self.leaf("classifier.b")?;
                let classes = self.params[bi].len();
                let ls = log_softmax_rows(self.engine, &logits, self.bsz, classes);
                let labels = &self.batch.labels;
                let (bsz, seq) = (self.bsz, self.seq);
                let dlogits = fill_rows(self.engine, bsz, classes, |b, out| {
                    let label = labels[b * seq].rem_euclid(classes as i32) as usize;
                    let lr = &ls[b * classes..(b + 1) * classes];
                    for (j, o) in out.iter_mut().enumerate() {
                        let p = f64::from(lr[j]).exp() as f32;
                        *o = (p - if j == label { 1.0 } else { 0.0 }) / bsz as f32;
                    }
                });
                let dw = matmul_at(self.engine, &t, &dlogits, bsz, h, classes);
                let db = bias_grad(&dlogits, bsz, classes);
                let dt = matmul_bt(self.engine, &dlogits, &self.params[wi], bsz, classes, h);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                self.gcur = dt;
            }
            "cls.tanh" => {
                let t = self.store_f(seg, "cls.tanh", "cls.tanh_out")?;
                let g = std::mem::take(&mut self.gcur);
                self.gcur = map_elems(self.engine, &g, |i, gv| gv * (1.0 - t[i] * t[i]));
            }
            "cls.pool" => {
                let g = std::mem::take(&mut self.gcur);
                let wi = self.leaf("pooler.w")?;
                let bi = self.leaf("pooler.b")?;
                let x0 = gather_first_tokens(&self.head_input, self.bsz, self.seq, h);
                let dw = matmul_at(self.engine, &x0, &g, self.bsz, h, h);
                let db = bias_grad(&g, self.bsz, h);
                let dx0 = matmul_bt(self.engine, &g, &self.params[wi], self.bsz, h, h);
                self.add_grad(wi, &dw);
                self.add_grad(bi, &db);
                let mut full = vec![0f32; bs * h];
                for b in 0..self.bsz {
                    full[b * self.seq * h..b * self.seq * h + h]
                        .copy_from_slice(&dx0[b * h..(b + 1) * h]);
                }
                self.gcur = full;
                self.vcur = std::mem::take(&mut self.head_input);
            }
            _ => {
                return Err(Error::Backend(format!(
                    "kernel backend: unknown head backward op {name}"
                )))
            }
        }
        self.free_op(seg, name);
        Ok(())
    }

    // -- optimizer ----------------------------------------------------------

    /// Bias-corrected Adam over every leaf (β₁=0.9, β₂=0.999, ε=1e-8;
    /// step counts from 0, so the correction uses `t = step + 1`).
    fn adam(&mut self) {
        let (b1, b2, eps) = ADAM;
        let t = (self.step + 1).max(1) as i32;
        let bc1 = 1.0 - b1.powi(t);
        let bc2 = 1.0 - b2.powi(t);
        let lr = f64::from(self.lr);
        for i in 0..self.params.len() {
            let gs = &self.grads[i];
            let ms = &mut self.m_state[i];
            let vs = &mut self.v_state[i];
            let ps = &mut self.params[i];
            for j in 0..ps.len() {
                let g = f64::from(gs[j]);
                let m = b1 * f64::from(ms[j]) + (1.0 - b1) * g;
                let v = b2 * f64::from(vs[j]) + (1.0 - b2) * g * g;
                ms[j] = m as f32;
                vs[j] = v as f32;
                let update = lr * (m / bc1) / ((v / bc2).sqrt() + eps);
                ps[j] = (f64::from(ps[j]) - update) as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared numeric helpers
// ---------------------------------------------------------------------------

/// Gather token 0 of every sequence: `[B·S, H] → [B, H]`.
fn gather_first_tokens(x: &[f32], bsz: usize, seq: usize, h: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(bsz * h);
    for b in 0..bsz {
        out.extend_from_slice(&x[b * seq * h..b * seq * h + h]);
    }
    out
}

/// Row-wise log-softmax (max-subtracted, f64 log-sum-exp).
fn log_softmax_rows(engine: &ExperimentEngine, x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    fill_rows(engine, rows, cols, |i, out| {
        let row = &x[i * cols..(i + 1) * cols];
        let mut m = f32::NEG_INFINITY;
        for &v in row {
            m = m.max(v);
        }
        let mut s = 0f64;
        for &v in row {
            s += f64::from(v - m).exp();
        }
        let lse = f64::from(m) + s.ln();
        for (o, &v) in out.iter_mut().zip(row) {
            *o = (f64::from(v) - lse) as f32;
        }
    })
}

/// Leaf lookup against a prebuilt name index (eval path).
fn lookup<'p>(
    idx: &HashMap<&str, usize>,
    params: &'p [Vec<f32>],
    name: &str,
) -> Result<&'p [f32]> {
    idx.get(name)
        .map(|&i| params[i].as_slice())
        .ok_or_else(|| Error::Abi(format!("kernel backend: no parameter leaf named {name}")))
}

/// Forward-only evaluation pass: dropout disabled, attention fused
/// (never materializing the `[B,A,S,S]` map). Returns `(loss, metric)`
/// — masked-token perplexity `exp(−loss)` proxy for MLM, accuracy for
/// classification.
fn eval_forward(
    m: &Manifest,
    engine: &ExperimentEngine,
    params: &[Vec<f32>],
    batch: &StepBatch,
) -> Result<(f64, f64)> {
    let cfg = model_config(m);
    if cfg.hidden % cfg.heads.max(1) != 0 {
        return Err(Error::Invalid(format!(
            "kernel backend: heads {} must divide hidden {}",
            cfg.heads, cfg.hidden
        )));
    }
    let leaf_idx: HashMap<&str, usize> =
        m.params.iter().enumerate().map(|(i, s)| (s.name.as_str(), i)).collect();
    let (bsz, seq, h, inter) = (m.batch_size, cfg.seq_len, cfg.hidden, cfg.intermediate);
    let (bs, v) = (bsz * seq, cfg.vocab_size);
    let dims = AttnDims { batch: bsz, heads: cfg.heads, seq, head_dim: h / cfg.heads };

    // Embeddings (dropout is a no-op in eval).
    let (word, pos, tok) = (
        lookup(&leaf_idx, params, "embeddings.word")?,
        lookup(&leaf_idx, params, "embeddings.position")?,
        lookup(&leaf_idx, params, "embeddings.token_type")?,
    );
    let tv = (tok.len() / h) as i32;
    let summed = fill_rows(engine, bs, h, |row, out| {
        let id = batch.input_ids[row].rem_euclid(v as i32) as usize;
        let s = row % seq;
        let tt = batch.token_type_ids[row].rem_euclid(tv) as usize;
        for (j, o) in out.iter_mut().enumerate() {
            *o = word[id * h + j] + pos[s * h + j] + tok[tt * h + j];
        }
    });
    let mut x = layernorm_fwd(
        engine,
        &summed,
        lookup(&leaf_idx, params, "embeddings.ln.gamma")?,
        lookup(&leaf_idx, params, "embeddings.ln.beta")?,
        bs,
        h,
        LN_EPS,
    )
    .y;

    for l in 0..cfg.layers {
        let q = matmul_bias(engine, &x, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.q_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.attn.q_b"))?), bs, h, h);
        let k = matmul_bias(engine, &x, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.k_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.attn.k_b"))?), bs, h, h);
        let val = matmul_bias(engine, &x, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.v_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.attn.v_b"))?), bs, h, h);
        let ctx = attention_fwd(engine, &q, &k, &val, Some(&batch.attention_mask), dims);
        let proj = matmul_bias(engine, &ctx, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.out_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.attn.out_b"))?), bs, h, h);
        let res1 = add(engine, &proj, &x);
        let a = layernorm_fwd(engine, &res1, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.ln.gamma"))?, lookup(&leaf_idx, params, &format!("encoder.{l}.attn.ln.beta"))?, bs, h, LN_EPS).y;
        let fc1 = matmul_bias(engine, &a, lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.in_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.in_b"))?), bs, h, inter);
        let act = gelu_fwd(engine, &fc1).0;
        let fc2 = matmul_bias(engine, &act, lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.out_w"))?, Some(lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.out_b"))?), bs, inter, h);
        let res2 = add(engine, &fc2, &a);
        x = layernorm_fwd(engine, &res2, lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.ln.gamma"))?, lookup(&leaf_idx, params, &format!("encoder.{l}.ffn.ln.beta"))?, bs, h, LN_EPS).y;
    }

    if m.task == "cls" {
        let x0 = gather_first_tokens(&x, bsz, seq, h);
        let pooled = matmul_bias(engine, &x0, lookup(&leaf_idx, params, "pooler.w")?, Some(lookup(&leaf_idx, params, "pooler.b")?), bsz, h, h);
        let t = map_elems(engine, &pooled, |_, p| f64::from(p).tanh() as f32);
        let classes = lookup(&leaf_idx, params, "classifier.b")?.len();
        let logits =
            matmul_bias(engine, &t, lookup(&leaf_idx, params, "classifier.w")?, Some(lookup(&leaf_idx, params, "classifier.b")?), bsz, h, classes);
        let ls = log_softmax_rows(engine, &logits, bsz, classes);
        let (mut acc, mut hits) = (0f64, 0u64);
        for b in 0..bsz {
            let label = batch.labels[b * seq].rem_euclid(classes as i32) as usize;
            let row = &ls[b * classes..(b + 1) * classes];
            acc -= f64::from(row[label]);
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.total_cmp(y.1))
                .map_or(0, |(i, _)| i);
            hits += u64::from(argmax == label);
        }
        Ok((acc / bsz as f64, hits as f64 / bsz as f64))
    } else {
        let t = matmul_bias(
            engine,
            &x,
            lookup(&leaf_idx, params, "mlm.transform_w")?,
            Some(lookup(&leaf_idx, params, "mlm.transform_b")?),
            bs,
            h,
            h,
        );
        let act = gelu_fwd(engine, &t).0;
        let normed =
            layernorm_fwd(engine, &act, lookup(&leaf_idx, params, "mlm.ln.gamma")?, lookup(&leaf_idx, params, "mlm.ln.beta")?, bs, h, LN_EPS).y;
        let mut logits = matmul_bt(engine, &normed, lookup(&leaf_idx, params, "embeddings.word")?, bs, h, v);
        let bias = lookup(&leaf_idx, params, "mlm.decoder_bias")?;
        for row in logits.chunks_exact_mut(v) {
            for (o, &b) in row.iter_mut().zip(bias) {
                *o += b;
            }
        }
        let ls = log_softmax_rows(engine, &logits, bs, v);
        let (mut acc, mut cnt) = (0f64, 0u64);
        for (row, &label) in batch.labels.iter().enumerate() {
            if label >= 0 {
                acc -= f64::from(ls[row * v + label.rem_euclid(v as i32) as usize]);
                cnt += 1;
            }
        }
        let loss = if cnt > 0 { acc / cnt as f64 } else { 0.0 };
        Ok((loss, (-loss).exp()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, Technique};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "kern-test".into(),
            kind: ModelKind::Bert,
            hidden: 64,
            layers: 2,
            heads: 2,
            seq_len: 16,
            intermediate: 128,
            vocab_size: 128,
            max_position: 32,
            type_vocab: 2,
            dropout_p: 0.1,
        }
    }

    fn tiny_manifest(task: &str, variant: &str) -> Manifest {
        Manifest::synthetic("kern_test", task, variant, "kernel", 2, &tiny_cfg(), 3)
    }

    fn run_trace(m: &Manifest, plan: &SchedulePlan, jobs: usize) -> (StepTrace, Vec<Vec<f32>>) {
        let engine = ExperimentEngine::new(jobs);
        let mut params = init_params(m, 11);
        let batch = StepBatch::synthetic(m, 5);
        let trace = step_trace(m, plan, &engine, &mut params, &batch, 0, 21, 1e-3)
            .expect("tiny step runs");
        (trace, params)
    }

    #[test]
    fn init_respects_parameter_roles() {
        let m = tiny_manifest("mlm", "baseline");
        let params = init_params(&m, 7);
        for (spec, p) in m.params.iter().zip(&params) {
            if spec.name.ends_with("gamma") {
                assert!(p.iter().all(|&v| v == 1.0), "{} should start at 1", spec.name);
            } else if spec.name.ends_with("beta") || spec.name.ends_with("_b") {
                assert!(p.iter().all(|&v| v == 0.0), "{} should start at 0", spec.name);
            }
        }
        let word = &params[0];
        assert!(word.iter().any(|&v| v != 0.0));
        assert!(word.iter().all(|&v| v.abs() < 0.5));
        assert_eq!(init_params(&m, 7), params, "same seed, same draw");
        assert_ne!(init_params(&m, 8), params, "seed moves the draw");
    }

    #[test]
    fn step_is_bit_identical_across_worker_counts() {
        let m = tiny_manifest("mlm", "tempo");
        let cfg = tiny_cfg();
        let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
        let (t1, p1) = run_trace(&m, &plan, 1);
        let (t3, p3) = run_trace(&m, &plan, 3);
        assert!(t1.loss.is_finite() && t1.loss > 0.0);
        assert_eq!(t1.loss.to_bits(), t3.loss.to_bits());
        assert_eq!(t1.grads, t3.grads);
        assert_eq!(p1, p3);
    }

    #[test]
    fn checkpoint_and_offload_replay_baseline_gradients_bitwise() {
        let m = tiny_manifest("mlm", "baseline");
        let cfg = tiny_cfg();
        let base = SchedulePlan::uniform(&cfg, OptimizationSet::none(), true);
        let (bt, bp) = run_trace(&m, &base, 2);
        let overlapped = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        let serial = overlapped.clone().serial();
        let offload = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Offload; cfg.layers],
            true,
        );
        for (label, plan) in
            [("overlapped", &overlapped), ("serial", &serial), ("offload", &offload)]
        {
            let (t, p) = run_trace(&m, plan, 2);
            assert_eq!(t.loss.to_bits(), bt.loss.to_bits(), "{label} loss");
            assert_eq!(t.grads, bt.grads, "{label} grads");
            assert_eq!(p, bp, "{label} params");
        }
        assert_eq!(bt.host_peak_bytes, 0);
        let (ot, _) = run_trace(&m, &offload, 2);
        assert!(ot.host_peak_bytes > 0, "offload parks bytes on the host");
    }

    #[test]
    fn program_abi_round_trips() {
        let m = tiny_manifest("mlm", "tempo");
        let n = m.n_param_leaves;
        let artifact = Artifact::synthetic(m);
        let backend = KernelBackend::with_jobs(2);
        let init = backend.prepare(&artifact, Entry::Init).unwrap();
        let seed = Arc::new(HostTensor::scalar_i32(7));
        let leaves = init.run(&[&seed]).unwrap();
        assert_eq!(leaves.len(), 3 * n);

        let am = &artifact.manifest;
        let batch = StepBatch::synthetic(am, 3);
        let shape = vec![am.batch_size, am.config.seq_len];
        let step = backend.prepare(&artifact, Entry::Step).unwrap();
        let mut inputs: Vec<Arc<HostTensor>> = leaves.clone();
        for data in [&batch.input_ids, &batch.token_type_ids, &batch.attention_mask, &batch.labels]
        {
            inputs.push(Arc::new(HostTensor::i32(shape.clone(), data.clone()).unwrap()));
        }
        inputs.push(Arc::new(HostTensor::scalar_i32(0)));
        inputs.push(Arc::new(HostTensor::scalar_i32(9)));
        inputs.push(Arc::new(HostTensor::scalar_f32(1e-3)));
        let refs: Vec<&Arc<HostTensor>> = inputs.iter().collect();
        let out = step.run(&refs).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        let loss = out[3 * n].first().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_ne!(out[0].as_f32().unwrap(), inputs[0].as_f32().unwrap(), "params moved");

        let eval = backend.prepare(&artifact, Entry::Eval).unwrap();
        let mut einputs: Vec<Arc<HostTensor>> = leaves[..n].to_vec();
        for data in [&batch.input_ids, &batch.token_type_ids, &batch.attention_mask, &batch.labels]
        {
            einputs.push(Arc::new(HostTensor::i32(shape.clone(), data.clone()).unwrap()));
        }
        einputs.push(Arc::new(HostTensor::scalar_i32(9)));
        let erefs: Vec<&Arc<HostTensor>> = einputs.iter().collect();
        let eout = eval.run(&erefs).unwrap();
        assert_eq!(eout.len(), 2);
        assert!(eout[0].first().unwrap().is_finite());
        assert!(eout[1].first().unwrap().is_finite());
    }

    #[test]
    fn cls_head_trains() {
        let m = tiny_manifest("cls", "tempo");
        let cfg = tiny_cfg();
        let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, false);
        let (t, _) = run_trace(&m, &plan, 2);
        assert!(t.loss.is_finite() && t.loss > 0.0);
        let pooler = m.params.iter().position(|s| s.name == "pooler.w").unwrap();
        assert!(t.grads[pooler].iter().any(|&g| g != 0.0));
        let word = m.params.iter().position(|s| s.name == "embeddings.word").unwrap();
        assert!(t.grads[word].iter().any(|&g| g != 0.0), "grad reaches the embeddings");

        let engine = ExperimentEngine::new(2);
        let params = init_params(&m, 11);
        let batch = StepBatch::synthetic(&m, 5);
        let (loss, acc) = eval_forward(&m, &engine, &params, &batch).unwrap();
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    }

    #[test]
    fn meter_tracks_plan_orderings() {
        let m = tiny_manifest("mlm", "baseline");
        let cfg = tiny_cfg();
        let base = SchedulePlan::uniform(&cfg, OptimizationSet::none(), true);
        let tempo = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
        let (bt, _) = run_trace(&m, &base, 1);
        let (tt, _) = run_trace(&m, &tempo, 1);
        assert!(bt.measured_peak_bytes > 0 && bt.modeled_peak_bytes > 0);
        assert!(
            tt.measured_peak_bytes < bt.measured_peak_bytes,
            "rewrites shrink the measured peak ({} !< {})",
            tt.measured_peak_bytes,
            bt.measured_peak_bytes
        );
    }
}
