//! Deterministic pure-Rust execution backend.
//!
//! `SimBackend` executes the `init`/`step`/`eval` ABI described by an
//! artifact manifest *analytically* — no HLO, no PJRT, no files:
//!
//! * **init(seed)** — parameter leaves drawn from the in-tree SplitMix64
//!   RNG ([`crate::tensor::Rng`]), seeded per `(seed, leaf index)` so the
//!   same seed reproduces bit-identically and different seeds differ;
//!   Adam `m`/`v` leaves are zeros, matching the real executable.
//! * **step(state ++ batch ++ step ++ seed ++ lr)** — a synthetic but
//!   fully deterministic training trajectory. The *word-embedding leaf*
//!   (leaf 0) is decayed by `(1 − lr)` each step, so training progress
//!   is physically encoded in the parameters that flow through the ABI;
//!   the loss is a calibrated exponential approach to a floor in that
//!   progress, plus small seeded per-step noise. Two runs with the same
//!   `TrainingConfig` therefore produce bit-identical loss traces, and
//!   checkpoints resume exactly like the real runtime.
//! * **eval(params ++ batch ++ seed)** — recovers the progress from the
//!   embedding-leaf RMS (no hidden state anywhere) and reports
//!   `[loss, metric]`: token probability for MLM, accuracy rising from
//!   chance toward ~0.95 for classification.
//!
//! Step *latency* is drawn from the roofline model
//! ([`crate::perfmodel::step_time`], the lane-aware roofline over the
//! execution schedule — compute lane plus any exposed collective time
//! on the modeled rig) and memory from the schedule's liveness
//! timeline ([`crate::graph::schedule_summary`], the exact peak the
//! capacity model also reports) — both memoized per (config, plan) —
//! so metrics/throughput numbers reported by the coordinator match the
//! paper-scale simulators instead of host wall-clock noise.

use std::sync::Arc;
use std::time::Duration;

use crate::config::{Gpu, ModelConfig, ModelKind, Technique};
use crate::graph::{self, SchedulePlan};
use crate::perfmodel::step_time;
use crate::runtime::artifact::{Artifact, Manifest};
use crate::runtime::backend::{Backend, Entry, Program};
use crate::tensor::{Dtype, HostTensor, Rng};
use crate::{Error, Result};

/// Std-dev of the simulated random-normal parameter init (BERT's 0.02).
pub const SIM_INIT_STD: f64 = 0.02;

/// Decay rate of `(loss − floor)` per unit of accumulated learning rate.
const SIM_RATE: f64 = 25.0;

/// Std-dev of the per-step training-loss noise.
const SIM_NOISE_STD: f64 = 0.02;

/// Domain-separation salts for the sim RNG streams.
const SALT_INIT: u64 = 0x5349_4D5F_494E_4954; // "SIM_INIT"
const SALT_NOISE: u64 = 0x5349_4D5F_4E4F_4953; // "SIM_NOIS"

/// The deterministic simulation backend (always available; the crate's
/// default execution engine).
pub struct SimBackend {
    /// GPU whose roofline/capacity models supply step latency and
    /// memory numbers.
    pub gpu: Gpu,
}

impl SimBackend {
    /// Sim backend modeling the default GPU (2080 Ti, the paper's
    /// smallest-memory platform).
    pub fn new() -> Self {
        SimBackend { gpu: Gpu::Rtx2080Ti }
    }

    /// Model latency/memory as this GPU instead of the default 2080 Ti.
    pub fn with_gpu(gpu: Gpu) -> Self {
        SimBackend { gpu }
    }

    /// Peak live bytes of one training step of this artifact (per
    /// GPU): the exact high-water mark of the execution schedule's
    /// liveness timeline (identical to `memmodel::ModelFootprint`,
    /// which folds the same schedule).
    pub fn modeled_memory_bytes(&self, artifact: &Artifact) -> u64 {
        let m = &artifact.manifest;
        let cfg = model_config(m);
        let plan = SchedulePlan::for_technique(&cfg, technique(m), m.task != "cls");
        self.modeled_memory_bytes_for_plan(artifact, &plan)
    }

    /// Peak live bytes of one training step under an arbitrary
    /// execution-schedule plan (e.g. a joint placement chosen by
    /// `autotempo::placement_search`, including per-layer
    /// checkpoint/offload residency arms) at the artifact's batch size
    /// — the same liveness-timeline fold the capacity model reports.
    /// Offloaded layers free their inventory at store completion, so
    /// their retained bytes never reach this peak.
    pub fn modeled_memory_bytes_for_plan(&self, artifact: &Artifact, plan: &SchedulePlan) -> u64 {
        let cfg = model_config(&artifact.manifest);
        graph::schedule_summary(&cfg, plan).peak_bytes(artifact.manifest.batch_size as u64)
    }

    /// Modeled step latency under an arbitrary execution-schedule plan
    /// at the artifact's batch size — the lane-aware roofline over the
    /// plan's own schedule census, including any exposed host-link
    /// offload tail (mirrors [`Backend::modeled_step_time`], which
    /// prices the technique-induced plan).
    pub fn modeled_step_time_for_plan(
        &self,
        artifact: &Artifact,
        plan: &SchedulePlan,
    ) -> Option<Duration> {
        let cfg = model_config(&artifact.manifest);
        let t = crate::perfmodel::plan_step_time(
            &cfg,
            plan,
            &self.gpu.spec(),
            artifact.manifest.batch_size,
        );
        if t.is_finite() && t > 0.0 {
            Some(Duration::from_secs_f64(t))
        } else {
            None
        }
    }
}

impl Default for SimBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SimBackend {
    /// `Arc` so shuttling the (params, m, v) state through the step ABI
    /// is a refcount bump per leaf, not a memcpy — the sim analogue of
    /// the PJRT backend's literal-resident hot path (§Perf): only the
    /// mutated progress leaf is actually rebuilt each step.
    type Value = Arc<HostTensor>;
    type Prog = SimProgram;

    fn name(&self) -> &'static str {
        "sim"
    }

    fn prepare(&self, artifact: &Artifact, entry: Entry) -> Result<Arc<SimProgram>> {
        Ok(Arc::new(SimProgram { manifest: artifact.manifest.clone(), entry }))
    }

    fn upload(&self, t: &HostTensor) -> Result<Arc<HostTensor>> {
        Ok(Arc::new(t.clone()))
    }

    fn download(&self, v: &Arc<HostTensor>) -> Result<HostTensor> {
        Ok((**v).clone())
    }

    fn scalar(&self, v: &Arc<HostTensor>) -> Result<f64> {
        v.first()
    }

    fn modeled_step_time(&self, artifact: &Artifact) -> Option<Duration> {
        let m = &artifact.manifest;
        let t = step_time(&model_config(m), technique(m), &self.gpu.spec(), m.batch_size);
        if t.is_finite() && t > 0.0 {
            Some(Duration::from_secs_f64(t))
        } else {
            None
        }
    }
}

/// One prepared entry point of a manifest, executed analytically.
pub struct SimProgram {
    manifest: Manifest,
    entry: Entry,
}

impl Program for SimProgram {
    type Value = Arc<HostTensor>;

    fn run(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        match self.entry {
            Entry::Init => self.run_init(inputs),
            Entry::Step => self.run_step(inputs),
            Entry::Eval => self.run_eval(inputs),
        }
    }
}

impl SimProgram {
    fn check_arity(&self, got: usize, want: usize) -> Result<()> {
        if got != want {
            return Err(Error::Abi(format!(
                "sim {} for {}: got {} inputs, expected {}",
                self.entry.name(),
                self.manifest.name,
                got,
                want
            )));
        }
        Ok(())
    }

    /// `init(seed) -> params ++ m ++ v`.
    fn run_init(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        self.check_arity(inputs.len(), 1)?;
        let seed = scalar_i32(inputs[0])? as i64 as u64;
        let m = &self.manifest;
        let mut out = Vec::with_capacity(3 * m.params.len());
        for (i, spec) in m.params.iter().enumerate() {
            let dtype = Dtype::parse(&spec.dtype)?;
            match dtype {
                Dtype::F32 => {
                    let mut base = Rng::new(seed ^ SALT_INIT);
                    let mut rng = base.fork(i as u64);
                    let data: Vec<f32> = (0..spec.numel())
                        .map(|_| (SIM_INIT_STD * rng.normal()) as f32)
                        .collect();
                    out.push(Arc::new(HostTensor::f32(spec.shape.clone(), data)?));
                }
                Dtype::I32 => out.push(Arc::new(HostTensor::zeros(dtype, spec.shape.clone()))),
            }
        }
        // Adam m and v start at zero, exactly like the real init.
        for _ in 0..2 {
            for spec in &m.params {
                out.push(Arc::new(HostTensor::zeros(
                    Dtype::parse(&spec.dtype)?,
                    spec.shape.clone(),
                )));
            }
        }
        Ok(out)
    }

    /// `step(params ++ m ++ v ++ batch[4] ++ step ++ seed ++ lr)
    ///  -> params' ++ m' ++ v' ++ [loss]`.
    fn run_step(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        let n = self.manifest.n_param_leaves;
        self.check_arity(inputs.len(), 3 * n + 7)?;
        let step = scalar_i32(inputs[3 * n + 4])? as i64;
        let seed = scalar_i32(inputs[3 * n + 5])? as i64 as u64;
        let lr = scalar_f32(inputs[3 * n + 6])? as f64;

        // Loss at the *incoming* parameters (pre-update), like the real
        // forward pass, plus seeded per-step noise.
        let p = progress(inputs[0])?;
        let mut nrng = Rng::new(
            seed ^ SALT_NOISE ^ (step as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
        );
        let noise = SIM_NOISE_STD * nrng.normal();
        let loss = (self.loss_at(p) + noise).max(0.01);

        // Unchanged leaves pass through as refcount bumps; only the
        // progress leaf is rebuilt (§Perf: no full-state memcpy).
        let mut out: Vec<Arc<HostTensor>> =
            inputs[..3 * n].iter().map(|t| Arc::clone(t)).collect();
        let mut leaf0 = (*out[0]).clone();
        decay_f32(&mut leaf0, 1.0 - lr.clamp(0.0, 0.5))?;
        out[0] = Arc::new(leaf0);
        out.push(Arc::new(HostTensor::scalar_f32(loss as f32)));
        Ok(out)
    }

    /// `eval(params ++ batch[4] ++ seed) -> [loss, metric]`.
    fn run_eval(&self, inputs: &[&Arc<HostTensor>]) -> Result<Vec<Arc<HostTensor>>> {
        let n = self.manifest.n_param_leaves;
        self.check_arity(inputs.len(), n + 5)?;
        let p = progress(inputs[0])?;
        let loss = self.loss_at(p);
        let metric = if self.manifest.task == "cls" {
            // accuracy: chance → ~0.95 as training progresses
            0.95 - 0.45 * (-SIM_RATE * p).exp()
        } else {
            // MLM: mean probability of the correct token, exp(-CE)
            (-loss).exp()
        };
        Ok(vec![
            Arc::new(HostTensor::scalar_f32(loss as f32)),
            Arc::new(HostTensor::scalar_f32(metric as f32)),
        ])
    }

    /// Noise-free loss at training progress `p` (accumulated lr).
    fn loss_at(&self, p: f64) -> f64 {
        let (l0, floor) = if self.manifest.task == "cls" {
            ((self.manifest.config.num_classes.max(2) as f64).ln(), 0.15)
        } else {
            ((self.manifest.config.vocab_size.max(2) as f64).ln(), 1.5)
        };
        floor + (l0 - floor) * (-SIM_RATE * p).exp()
    }
}

/// Training progress recovered from the embedding leaf: the step
/// program decays leaf 0 by `(1 − lr)` each step, so
/// `p = −ln(rms / SIM_INIT_STD) ≈ Σ lr_t`. At init `rms ≈ SIM_INIT_STD`
/// (the normal draw concentrates for large leaves), giving `p ≈ 0`.
fn progress(leaf0: &HostTensor) -> Result<f64> {
    let data = leaf0.as_f32()?;
    if data.is_empty() {
        return Ok(0.0);
    }
    let ms: f64 = data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / data.len() as f64;
    let ratio = (ms.sqrt() / SIM_INIT_STD).clamp(1e-9, 1e9);
    Ok((-ratio.ln()).max(0.0))
}

fn decay_f32(t: &mut HostTensor, factor: f64) -> Result<()> {
    match t {
        HostTensor::F32 { data, .. } => {
            let f = factor as f32;
            for v in data.iter_mut() {
                *v *= f;
            }
            Ok(())
        }
        _ => Err(Error::Abi("sim progress leaf must be f32".into())),
    }
}

fn scalar_i32(t: &HostTensor) -> Result<i32> {
    Ok(t.as_i32()?
        .first()
        .copied()
        .ok_or_else(|| Error::Abi("empty scalar input".into()))?)
}

fn scalar_f32(t: &HostTensor) -> Result<f32> {
    Ok(t.as_f32()?
        .first()
        .copied()
        .ok_or_else(|| Error::Abi("empty scalar input".into()))?)
}

/// Map a manifest variant onto the analytical technique (shared with
/// the kernel backend, which derives its default plan the same way).
pub(crate) fn technique(m: &Manifest) -> Technique {
    match m.variant.as_str() {
        "checkpoint" => Technique::Checkpoint,
        "tempo" => Technique::Tempo,
        _ => Technique::Baseline,
    }
}

/// Reconstruct a [`ModelConfig`] from the manifest echo (for the
/// capacity/roofline models; shared with the kernel backend).
pub(crate) fn model_config(m: &Manifest) -> ModelConfig {
    let c = &m.config;
    ModelConfig {
        name: c.name.clone(),
        kind: ModelKind::Bert,
        hidden: c.hidden,
        layers: c.layers,
        heads: c.heads,
        seq_len: c.seq_len,
        intermediate: c.intermediate,
        vocab_size: c.vocab_size,
        max_position: c.max_position,
        type_vocab: c.type_vocab,
        dropout_p: c.dropout_p,
    }
}

/// The builtin artifact set: the same (name, task, variant) matrix
/// `make artifacts` produces, synthesized so every coordinator flow and
/// test runs from a fresh checkout.
pub fn builtin_manifests() -> Vec<Manifest> {
    let tiny = ModelConfig::bert_tiny();
    let mini = ModelConfig::bert_mini();
    let mut out = Vec::new();
    for variant in ["baseline", "checkpoint", "tempo"] {
        out.push(Manifest::synthetic(
            &format!("bert_tiny_{variant}"),
            "mlm",
            variant,
            "jnp",
            8,
            &tiny,
            0,
        ));
    }
    for variant in ["baseline", "tempo"] {
        out.push(Manifest::synthetic(
            &format!("bert_mini_{variant}"),
            "mlm",
            variant,
            "jnp",
            8,
            &mini,
            0,
        ));
    }
    for variant in ["baseline", "tempo"] {
        out.push(Manifest::synthetic(
            &format!("cls_tiny_{variant}"),
            "cls",
            variant,
            "jnp",
            8,
            &tiny,
            2,
        ));
    }
    out.push(Manifest::synthetic("pallas_smoke", "mlm", "tempo", "pallas", 4, &tiny, 0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ArtifactIndex;

    fn tiny_artifact(name: &str) -> Artifact {
        ArtifactIndex::builtin().open(name).unwrap()
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let b = SimBackend::new();
        let a = tiny_artifact("bert_tiny_tempo");
        let init = b.prepare(&a, Entry::Init).unwrap();
        let s5 = Arc::new(HostTensor::scalar_i32(5));
        let s6 = Arc::new(HostTensor::scalar_i32(6));
        let x = init.run(&[&s5]).unwrap();
        let y = init.run(&[&s5]).unwrap();
        let z = init.run(&[&s6]).unwrap();
        assert_eq!(x, y, "same seed must reproduce exactly");
        assert!(x.iter().zip(&z).any(|(a, b)| a != b), "seeds must differ");
        assert_eq!(x.len(), 3 * a.manifest.n_param_leaves);
    }

    #[test]
    fn init_leaf0_rms_near_init_std() {
        let b = SimBackend::new();
        let a = tiny_artifact("bert_tiny_baseline");
        let init = b.prepare(&a, Entry::Init).unwrap();
        let s = Arc::new(HostTensor::scalar_i32(3));
        let out = init.run(&[&s]).unwrap();
        let p = progress(&out[0]).unwrap();
        assert!(p < 0.02, "fresh init should read as ~zero progress, got {p}");
    }

    #[test]
    fn step_decays_progress_leaf_and_emits_loss() {
        let b = SimBackend::new();
        let a = tiny_artifact("bert_tiny_tempo");
        let m = &a.manifest;
        let n = m.n_param_leaves;
        let init = b.prepare(&a, Entry::Init).unwrap();
        let step = b.prepare(&a, Entry::Step).unwrap();
        let seed_in = Arc::new(HostTensor::scalar_i32(7));
        let state = init.run(&[&seed_in]).unwrap();

        let batch =
            Arc::new(HostTensor::zeros(Dtype::I32, vec![m.batch_size, m.config.seq_len]));
        let step_s = Arc::new(HostTensor::scalar_i32(0));
        let lr_s = Arc::new(HostTensor::scalar_f32(0.1));
        let mut refs: Vec<&Arc<HostTensor>> = state.iter().collect();
        for _ in 0..4 {
            refs.push(&batch);
        }
        refs.push(&step_s);
        refs.push(&seed_in);
        refs.push(&lr_s);
        let out = step.run(&refs).unwrap();
        assert_eq!(out.len(), 3 * n + 1);
        let loss = out.last().unwrap().first().unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        // unchanged leaves pass through by reference, not by copy
        assert!(Arc::ptr_eq(&out[1], &state[1]), "leaf 1 should be shared");
        // progress advanced by ≈ lr
        let p = progress(&out[0]).unwrap();
        assert!((p - 0.105).abs() < 0.02, "p={p}"); // -ln(0.9) ≈ 0.105
    }

    #[test]
    fn modeled_time_and_memory_come_from_the_simulators() {
        let b = SimBackend::new();
        let a = tiny_artifact("bert_tiny_tempo");
        let dt = b.modeled_step_time(&a).expect("sim models step time");
        let expect = step_time(
            &model_config(&a.manifest),
            Technique::Tempo,
            &Gpu::Rtx2080Ti.spec(),
            a.manifest.batch_size,
        );
        assert!((dt.as_secs_f64() - expect).abs() < 1e-12);
        assert!(b.modeled_memory_bytes(&a) > 0);
    }

    #[test]
    fn modeled_memory_is_the_schedule_peak() {
        // the sim's memory number is the exact liveness-timeline peak —
        // identical to the capacity model, which folds the same schedule
        let b = SimBackend::new();
        for name in ["bert_tiny_baseline", "bert_tiny_checkpoint", "bert_tiny_tempo"] {
            let a = tiny_artifact(name);
            let m = &a.manifest;
            let fp = crate::memmodel::ModelFootprint::new(model_config(m), technique(m));
            assert_eq!(b.modeled_memory_bytes(&a), fp.total_bytes(m.batch_size), "{name}");
        }
    }

    #[test]
    fn plan_shaped_pricing_matches_the_technique_path() {
        let b = SimBackend::new();
        let a = tiny_artifact("bert_tiny_checkpoint");
        let m = &a.manifest;
        let cfg = model_config(m);
        let plan = SchedulePlan::for_technique(&cfg, technique(m), m.task != "cls");
        assert_eq!(b.modeled_memory_bytes_for_plan(&a, &plan), b.modeled_memory_bytes(&a));
        let dt = b.modeled_step_time_for_plan(&a, &plan).unwrap();
        assert_eq!(dt, b.modeled_step_time(&a).unwrap());
        // a serial placement of the same plan never needs more memory
        let serial = plan.clone().serial();
        assert!(b.modeled_memory_bytes_for_plan(&a, &serial) <= b.modeled_memory_bytes(&a));
    }

    #[test]
    fn builtin_matrix_is_complete() {
        let names: Vec<String> =
            builtin_manifests().iter().map(|m| m.name.clone()).collect();
        for want in [
            "bert_tiny_baseline",
            "bert_tiny_checkpoint",
            "bert_tiny_tempo",
            "bert_mini_baseline",
            "bert_mini_tempo",
            "cls_tiny_baseline",
            "cls_tiny_tempo",
            "pallas_smoke",
        ] {
            assert!(names.iter().any(|n| n == want), "missing builtin {want}");
        }
    }
}
