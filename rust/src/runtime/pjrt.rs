//! PJRT execution backend (`--features pjrt`): compile AOT HLO text
//! once on the PJRT CPU client, execute many times.
//!
//! All `xla::` usage in the crate lives in this module; the default
//! build never compiles it. The [`PjrtBackend`] keeps the training
//! state device-resident as `xla::Literal`s across steps (the §Perf
//! hot path — see `runtime::backend::DeviceState`), so per-step host
//! conversions are only the batch tensors in and the scalar loss out.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::runtime::artifact::Artifact;
use crate::runtime::backend::{Backend, Entry, Program};
use crate::tensor::HostTensor;
use crate::{Error, Result};

// SAFETY: the PJRT C API objects wrapped by the `xla` crate (client,
// loaded executable) are documented thread-safe; the crate just doesn't
// mark its raw-pointer wrappers. All mutation on our side is behind a
// Mutex. These impls are what lets one PjrtBackend serve all workers of
// the concurrent experiment engine; `xla::Literal` (Backend::Value)
// stays non-Send, which the engine honors by keeping every cell's
// values on one worker thread.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for PjrtProgram {}
unsafe impl Sync for PjrtProgram {}

/// Host → device-feedable literal.
pub fn tensor_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    };
    Ok(lit)
}

/// Literal → host tensor (f32 / s32 supported; everything the ABI emits).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => HostTensor::f32(dims, lit.to_vec::<f32>()?),
        xla::ElementType::S32 => HostTensor::i32(dims, lit.to_vec::<i32>()?),
        other => Err(Error::Abi(format!("unsupported literal type {other:?}"))),
    }
}

/// A compiled XLA executable plus bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Client handle for host→device buffer staging.
    client: xla::PjRtClient,
    /// Source path, for diagnostics.
    pub source: String,
    /// Wall time spent compiling.
    pub compile_time: std::time::Duration,
}

impl Executable {
    /// Run with host tensors; returns the flattened output tuple.
    ///
    /// The AOT pipeline lowers with `return_tuple=True`, so execution
    /// yields a single tuple literal we decompose into leaves.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Run with pre-converted literals (hot path: params stay as literals
    /// across steps, only the batch tensors are re-converted).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let outs = self.run_literals_raw(literals)?;
        outs.iter().map(literal_to_tensor).collect()
    }

    /// Run and keep the outputs as literals (avoids host copies when the
    /// results are immediately fed back in, e.g. the training loop).
    pub fn run_literals_raw(&self, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_refs(&refs)
    }

    /// Hot path: borrowed-literal inputs → literal outputs. The training
    /// loop keeps params/optimizer state as literals across steps, so
    /// the only per-step host conversions are the batch tensors in and
    /// the scalar loss out (see coordinator::Trainer).
    ///
    /// LEAK NOTE: the vendored crate's literal-input `execute` stages
    /// each input into a PJRT buffer it `release()`s and never frees —
    /// one full state copy leaked per training step (found via the
    /// /proc RSS probe, see EXPERIMENTS.md §Perf). We stage the buffers
    /// ourselves (owned `PjRtBuffer`s, freed on drop) and call the
    /// borrow-only `execute_b` instead.
    pub fn run_refs(&self, literals: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let buffers: Vec<xla::PjRtBuffer> = literals
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, xla::Error>>()?;
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Backend("executable produced no outputs".into()))?;
        let tuple = first.to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// Shared PJRT CPU client + executable cache.
///
/// Compilation of the training step is expensive (seconds); the cache
/// makes `load` idempotent per path so examples/benches can re-enter.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, cache: std::sync::Mutex::new(HashMap::new()) })
    }

    /// Platform string, e.g. "cpu" (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by canonical path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let path = path.as_ref();
        let key = path
            .canonicalize()
            .unwrap_or_else(|_| path.to_path_buf())
            .to_string_lossy()
            .into_owned();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let built = Arc::new(Executable {
            exe,
            client: self.client.clone(),
            source: key.clone(),
            compile_time: t0.elapsed(),
        });
        self.cache.lock().unwrap().insert(key, built.clone());
        Ok(built)
    }
}

/// [`Backend`] implementation over the PJRT runtime.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// PJRT CPU client backend.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtBackend { rt: Runtime::cpu()? })
    }

    /// Wrap an existing runtime (shares its executable cache).
    pub fn from_runtime(rt: Runtime) -> Self {
        PjrtBackend { rt }
    }

    /// The underlying PJRT runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}

/// One compiled entry point.
pub struct PjrtProgram {
    exe: Arc<Executable>,
}

impl Program for PjrtProgram {
    type Value = xla::Literal;

    fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.exe.run_refs(inputs)
    }
}

impl Backend for PjrtBackend {
    type Value = xla::Literal;
    type Prog = PjrtProgram;

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, artifact: &Artifact, entry: Entry) -> Result<Arc<PjrtProgram>> {
        let path = artifact.entry_path(entry)?;
        Ok(Arc::new(PjrtProgram { exe: self.rt.load(path)? }))
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::Literal> {
        tensor_to_literal(t)
    }

    fn download(&self, v: &xla::Literal) -> Result<HostTensor> {
        literal_to_tensor(v)
    }

    fn scalar(&self, v: &xla::Literal) -> Result<f64> {
        v.to_vec::<f32>()?
            .first()
            .map(|&x| x as f64)
            .ok_or_else(|| Error::Abi("empty scalar output leaf".into()))
    }
}
