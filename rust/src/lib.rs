//! # Tempo — memory-footprint-optimized Transformer training (NeurIPS 2022)
//!
//! Rust + JAX + Pallas reproduction of *"Tempo: Accelerating
//! Transformer-Based Model Training through Memory Footprint Reduction"*
//! (Andoorveedu et al., NeurIPS 2022).
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — training coordinator, GPU memory-capacity
//!   simulator, roofline throughput simulator, Auto-Tempo search, report
//!   harness regenerating every paper table/figure. All three analytical
//!   models fold one shared layer-graph IR ([`graph`]): the transformer
//!   block lowers once to typed ops annotated with retained tensors and
//!   work censuses, Tempo's techniques are graph rewrites, and the whole
//!   model chains into a fwd+bwd **execution schedule** whose liveness
//!   timeline yields exact peak memory, the step census and Auto-Tempo's
//!   max-batch answers (DESIGN.md §Graph IR, §Schedule).
//! * **L2/L1 (build-time python)** — JAX BERT with Tempo `custom_vjp`
//!   layers and Pallas kernels, AOT-lowered to HLO text artifacts.
//!
//! ## Execution backends
//!
//! The coordinator is generic over [`runtime::Backend`]:
//!
//! * [`runtime::SimBackend`] — the default. Pure Rust, deterministic,
//!   zero dependencies: executes the `init`/`step`/`eval` ABI
//!   analytically from (builtin or on-disk) manifests, with step
//!   latency from [`perfmodel`] and memory from [`memmodel`]. A fresh
//!   checkout runs `cargo test`, every example and every coordinator
//!   flow offline with no artifacts present.
//! * `runtime::PjrtBackend` (`--features pjrt`) — loads the AOT HLO
//!   text artifacts produced by `make artifacts` and executes them via
//!   the PJRT C API (`xla` crate). Python never runs on the training
//!   path: after `make artifacts`, the `tempo` binary is self-contained.
//!
//! All `xla::` usage compiles only under `--features pjrt`
//! (`runtime::pjrt` is the single module that touches it).

#![warn(missing_docs)]

pub mod autotempo;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod graph;
pub mod kernels;
pub mod memmodel;
pub mod perfmodel;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
