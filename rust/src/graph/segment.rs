//! Compositional plan pricing: per-chunk schedule summaries that
//! recombine to the exact [`ScheduleSummary`] the full
//! [`lower_step`] + `summarize_step` fold computes.
//!
//! **Why.** `placement_search` prices ~1.5k joint arms on BERT-LARGE,
//! and neighbouring arms differ in exactly one layer's
//! `(rewrites, residency)` pair — yet each arm used to pay a full
//! O(L)-event lowering + liveness fold. This module factors the step
//! timeline at its natural seams (setup | embedding fwd | one chunk
//! per encoder layer per phase | head | turnaround | prefetch runs |
//! backward mirror | optimizer) into [`ChunkSummary`] values that form
//! a **monoid under concatenation**: each chunk carries its net
//! per-class live-byte deltas, its first-strict-max prefix peak
//! *relative to chunk entry* (total, per-class item/fixed snapshots,
//! event kind and offset), its work census split by lane, and its
//! host-link payloads. Folding L chunk summaries left-to-right
//! reproduces the full fold's peak, high-water op, per-class
//! breakdown, census, and the whole [`LaneProfile`] (prefetch/hidden
//! pairs, bucket tails as suffix sums at chunk boundaries, store/load
//! covering windows) — bit-identically, because every census term is
//! a multiple of ¼ far below 2⁵³ so f64 folds are exact in any order,
//! and byte accounting is integer arithmetic.
//!
//! **What composes and what can't.** A chunk's *contents* depend only
//! on (model dims, lowering, the layer's own rewrite set, its
//! residency arm, and for the turnaround/optimizer whether *any*
//! layer checkpoints) — never on the other layers' arms. What does
//! depend on the neighbours is the chunk *sequence*: which prefetch
//! runs exist and whether a checkpointed layer's re-forward is
//! prefetched or in-place is decided by [`build_pieces`], a pure
//! replay of `lower_step`'s one-deep pending-prefetch state machine.
//! So the per-arm work is O(L) cache lookups + an O(L) recombine; the
//! expensive lowering runs once per *distinct chunk shape*, not per
//! plan.
//!
//! **Memo contract (donor slicing).** Chunks are never synthesized
//! from scratch: on a cache miss the module lowers a small *donor*
//! plan (a uniform placement whose timeline exhibits the requested
//! [`ChunkKind`]) through the real `lower_step`, slices the donor's
//! event stream at piece boundaries, folds every slice, and inserts
//! them all into a process-global bounded cache keyed by
//! (dims, lowering, embedding/head rewrites, head kind, chunk kind).
//! Equality with the full fold is therefore structural — the chunks
//! *are* real lowering output — and `tests/incremental_pricing.rs`
//! plus the in-file tests pin it across presets and random per-layer
//! mutations. The joint family needs only ~34 donors (one per
//! distinct uniform arm) to cover all ~1.5k candidates.

use std::sync::{Arc, OnceLock};

use crate::config::{ModelConfig, OptimizationSet};

use super::liveness::{
    high_water_label, min_census, CommBucket, HostTransfer, LaneProfile, ScheduleSummary,
};
use super::lower::Lowering;
use super::memo::{BoundedCache, CacheStats};
use super::op::Census;
use super::schedule::{
    lower_step, CkptStyle, EventKind, Lane, Residency, SchedTensor, ScheduleEvent, SchedulePlan,
    Segment, StepSchedule, MEM_CLASS_COUNT,
};

/// The distinct chunk shapes a step timeline is built from. Two chunks
/// with the same kind (under the same dims/lowering/other/head) are
/// byte-identical regardless of which layer index they serve — layer
/// position enters only through the piece [`Role`], never the summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum ChunkKind {
    /// The step-setup event (params/grads/optimizer states).
    Setup,
    /// Embedding block forward.
    EmbFwd,
    /// One resident encoder layer's forward under its rewrite set.
    LayerFwdPlain(OptimizationSet),
    /// One tensor-parallel sharded layer's forward (inventory and
    /// census ÷ the key's `tp`, in-block collectives on the TP lane).
    LayerFwdShard(OptimizationSet),
    /// One checkpointed layer's forward (store input, full inventory,
    /// discard at exit). Rewrites are ignored by the transform.
    LayerFwdCkpt,
    /// One offloaded layer's forward + its store DMA (rewrites shrink
    /// the shipped bytes).
    LayerFwdOffload(OptimizationSet),
    /// Head block forward.
    HeadFwd,
    /// The fwd→bwd turnaround; the workspace shape depends on whether
    /// any layer in the plan checkpoints.
    Turnaround {
        /// Whether the plan checkpoints at least one layer.
        any_ckpt: bool,
    },
    /// A hoisted `Overlapped` re-forward run on the prefetch lane.
    PrefetchRun,
    /// Head block backward.
    HeadBwd,
    /// One resident layer's backward under its rewrite set.
    LayerBwdPlain(OptimizationSet),
    /// One sharded layer's backward (mirrored in-block collectives).
    LayerBwdShard(OptimizationSet),
    /// A checkpointed layer's backward consuming a prefetched
    /// re-forward (the recompute ran earlier, on the prefetch lane).
    LayerBwdCkptPrefetched,
    /// A checkpointed layer's in-place recompute + backward (serial
    /// style, or an overlapped arm whose upstream neighbour could not
    /// host the prefetch).
    LayerBwdCkptInPlace,
    /// One offloaded layer's load DMA + backward.
    LayerBwdOffload(OptimizationSet),
    /// Embedding block backward.
    EmbBwd,
    /// The optimizer step (frees the turnaround workspace, whose shape
    /// depends on `any_ckpt`).
    Optimizer {
        /// Whether the plan checkpoints at least one layer.
        any_ckpt: bool,
    },
}

/// Cache key: everything a chunk's contents depend on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ChunkKey {
    hidden: usize,
    heads: usize,
    seq_len: usize,
    intermediate: usize,
    vocab: usize,
    max_position: usize,
    type_vocab: usize,
    layers: usize,
    lowering: Lowering,
    other: OptimizationSet,
    mlm_head: bool,
    /// The plan's *resolved* shard degree. Every chunk is keyed by it:
    /// shard chunks genuinely depend on it, and at `tp > 1` the head
    /// chunks do too (vocab-parallel lowering), so one key axis keeps
    /// every donor slice self-consistent.
    tp: usize,
    kind: ChunkKind,
}

fn chunk_key(
    cfg: &ModelConfig,
    other: OptimizationSet,
    mlm_head: bool,
    tp: usize,
    lowering: Lowering,
    kind: ChunkKind,
) -> ChunkKey {
    ChunkKey {
        hidden: cfg.hidden,
        heads: cfg.heads,
        seq_len: cfg.seq_len,
        intermediate: cfg.intermediate,
        vocab: cfg.vocab_size,
        max_position: cfg.max_position,
        type_vocab: cfg.type_vocab,
        layers: cfg.layers,
        lowering,
        other,
        mlm_head,
        tp,
        kind,
    }
}

/// One chunk's contribution to every fold the full walk computes —
/// the monoid element. All byte accounting is *relative to chunk
/// entry* (signed: backward chunks free tensors allocated in earlier
/// chunks), which is what makes concatenation associative.
#[derive(Debug, Clone, PartialEq)]
struct ChunkSummary {
    /// Number of schedule events in the chunk.
    events: usize,
    /// Net per-class per-item live-byte delta (allocs − frees).
    delta_item: [i64; MEM_CLASS_COUNT],
    /// Net per-class fixed live-byte delta.
    delta_fixed: [i64; MEM_CLASS_COUNT],
    /// First-strict-max prefix peak of the per-item instantaneous
    /// total, relative to chunk entry (can be negative).
    best_rel_total: i64,
    /// Chunk-local event index of that peak.
    best_event: usize,
    /// Per-class per-item instantaneous vector at the peak (includes
    /// in-op tensors), relative to chunk entry.
    best_rel_item: [i64; MEM_CLASS_COUNT],
    /// Per-class fixed vector at the peak, relative to chunk entry.
    best_rel_fixed: [i64; MEM_CLASS_COUNT],
    /// Event kind at the peak (the high-water label source).
    best_kind: EventKind,
    /// Work census over all lanes (what `ScheduleSummary::census`
    /// accumulates).
    census_total: Census,
    /// Compute-lane census only (store/load covering windows).
    census_compute: Census,
    /// Prefetch-lane census only (hidden-work pairing).
    census_prefetch: Census,
    /// Host-link bytes shipped out by this chunk's `Store`s.
    store_bytes: u64,
    /// Host-link bytes shipped back by this chunk's `Load`s.
    load_bytes: u64,
    /// This chunk's TP-lane collectives in tape order: per-item wire
    /// payload and the compute-lane census accrued since the previous
    /// in-chunk collective (the *first* entry's window is completed by
    /// the cross-chunk carry at recombination time).
    tp_events: Vec<(u64, Census)>,
    /// Compute-lane census after the chunk's last TP collective — the
    /// carry seeding the next chunk's first window. Equal to the whole
    /// compute census when `tp_events` is empty.
    tp_tail: Census,
}

/// Fold one contiguous event slice into its chunk summary. This is
/// `summarize_step`'s inner loop re-based to the chunk entry, plus the
/// lane splits `lane_profile` needs.
fn fold_chunk(tensors: &[SchedTensor], events: &[ScheduleEvent]) -> ChunkSummary {
    let mut rel_item = [0i64; MEM_CLASS_COUNT];
    let mut rel_fixed = [0i64; MEM_CLASS_COUNT];
    let mut have_best = false;
    let mut best_rel_total = 0i64;
    let mut best_event = 0usize;
    let mut best_rel_item = [0i64; MEM_CLASS_COUNT];
    let mut best_rel_fixed = [0i64; MEM_CLASS_COUNT];
    let mut best_kind = EventKind::Setup;
    let mut census_total = Census::ZERO;
    let mut census_compute = Census::ZERO;
    let mut census_prefetch = Census::ZERO;
    let mut store_bytes = 0u64;
    let mut load_bytes = 0u64;
    let mut tp_events: Vec<(u64, Census)> = Vec::new();
    let mut tp_win = Census::ZERO;
    for (i, e) in events.iter().enumerate() {
        for &id in &e.allocs {
            let t = &tensors[id as usize];
            rel_fixed[t.class.index()] += t.fixed_bytes as i64;
            rel_item[t.class.index()] += t.item_bytes as i64;
        }
        let mut inst = rel_item;
        for &id in &e.inplace {
            let t = &tensors[id as usize];
            inst[t.class.index()] += t.item_bytes as i64;
        }
        let inst_total: i64 = inst.iter().sum();
        // first strict max, seeded by the first event (the relative
        // peak can be negative in backward chunks)
        if !have_best || inst_total > best_rel_total {
            have_best = true;
            best_rel_total = inst_total;
            best_event = i;
            best_rel_item = inst;
            best_rel_fixed = rel_fixed;
            best_kind = e.kind;
        }
        census_total.add(e.census);
        match e.lane {
            Lane::Compute => {
                census_compute.add(e.census);
                tp_win.add(e.census);
            }
            Lane::Prefetch => census_prefetch.add(e.census),
            Lane::HostLink => {}
            Lane::TpLink => {
                tp_events.push((e.comm_item_bytes, tp_win));
                tp_win = Census::ZERO;
            }
        }
        match e.kind {
            EventKind::Store => {
                store_bytes +=
                    e.frees.iter().map(|&id| tensors[id as usize].item_bytes).sum::<u64>();
            }
            EventKind::Load => {
                load_bytes +=
                    e.allocs.iter().map(|&id| tensors[id as usize].item_bytes).sum::<u64>();
            }
            _ => {}
        }
        for &id in &e.frees {
            let t = &tensors[id as usize];
            rel_fixed[t.class.index()] -= t.fixed_bytes as i64;
            rel_item[t.class.index()] -= t.item_bytes as i64;
        }
    }
    assert!(have_best, "a chunk holds at least one event");
    ChunkSummary {
        events: events.len(),
        delta_item: rel_item,
        delta_fixed: rel_fixed,
        best_rel_total,
        best_event,
        best_rel_item,
        best_rel_fixed,
        best_kind,
        census_total,
        census_compute,
        census_prefetch,
        store_bytes,
        load_bytes,
        tp_events,
        tp_tail: tp_win,
    }
}

/// Where a chunk sits in the step — the position-dependent half the
/// summary deliberately does not carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    /// The setup event.
    Setup,
    /// Embedding forward.
    EmbFwd,
    /// Encoder layer `l` forward.
    LayerFwd(usize),
    /// Head forward.
    HeadFwd,
    /// The turnaround event.
    Turnaround,
    /// Hoisted re-forward for layer `target`.
    Prefetch {
        /// The layer whose inventory the run recomputes.
        target: usize,
    },
    /// Head backward.
    HeadBwd,
    /// Encoder layer `l` backward (incl. any in-place recompute or
    /// load DMA).
    LayerBwd(usize),
    /// Embedding backward.
    EmbBwd,
    /// The optimizer event.
    Optimizer,
}

/// One slot of a plan's chunk sequence.
#[derive(Debug, Clone, Copy)]
struct Piece {
    kind: ChunkKind,
    role: Role,
}

/// Replay `lower_step`'s structure for a resolved plan: which chunk
/// kinds appear, in what order, serving which layer. This mirrors the
/// lowering's one-deep pending-prefetch state machine exactly — an
/// `Overlapped` layer prefetches under the preceding segment's
/// backward only when that segment is the head or a resident layer
/// and no other prefetch is in flight; otherwise it recomputes in
/// place.
fn build_pieces(layers: usize, resolved: &[(OptimizationSet, Residency)]) -> Vec<Piece> {
    debug_assert_eq!(resolved.len(), layers);
    let opts = |l: usize| resolved[l].0;
    let mode = |l: usize| resolved[l].1;
    let any_ckpt = resolved.iter().any(|&(_, r)| r.is_checkpoint());

    let mut pieces = Vec::with_capacity(2 * layers + 8);
    pieces.push(Piece { kind: ChunkKind::Setup, role: Role::Setup });
    pieces.push(Piece { kind: ChunkKind::EmbFwd, role: Role::EmbFwd });
    for l in 0..layers {
        let kind = match mode(l) {
            Residency::Checkpoint(_) => ChunkKind::LayerFwdCkpt,
            Residency::Offload => ChunkKind::LayerFwdOffload(opts(l)),
            Residency::Resident => ChunkKind::LayerFwdPlain(opts(l)),
            Residency::Shard => ChunkKind::LayerFwdShard(opts(l)),
        };
        pieces.push(Piece { kind, role: Role::LayerFwd(l) });
    }
    pieces.push(Piece { kind: ChunkKind::HeadFwd, role: Role::HeadFwd });
    pieces.push(Piece { kind: ChunkKind::Turnaround { any_ckpt }, role: Role::Turnaround });

    let mut pending: Option<usize> = None;
    if layers > 0 && mode(layers - 1) == Residency::Checkpoint(CkptStyle::Overlapped) {
        let top = layers - 1;
        pieces.push(Piece { kind: ChunkKind::PrefetchRun, role: Role::Prefetch { target: top } });
        pending = Some(top);
    }
    pieces.push(Piece { kind: ChunkKind::HeadBwd, role: Role::HeadBwd });
    for l in (0..layers).rev() {
        match mode(l) {
            // a sharded layer hosts a neighbour's prefetch exactly like
            // a resident one: its backward runs on the compute lane and
            // holds no checkpoint/offload machinery of its own
            Residency::Resident | Residency::Shard => {
                if l > 0
                    && mode(l - 1) == Residency::Checkpoint(CkptStyle::Overlapped)
                    && pending.is_none()
                {
                    pieces.push(Piece {
                        kind: ChunkKind::PrefetchRun,
                        role: Role::Prefetch { target: l - 1 },
                    });
                    pending = Some(l - 1);
                }
                let kind = if mode(l) == Residency::Shard {
                    ChunkKind::LayerBwdShard(opts(l))
                } else {
                    ChunkKind::LayerBwdPlain(opts(l))
                };
                pieces.push(Piece { kind, role: Role::LayerBwd(l) });
            }
            Residency::Offload => {
                pieces
                    .push(Piece { kind: ChunkKind::LayerBwdOffload(opts(l)), role: Role::LayerBwd(l) });
            }
            Residency::Checkpoint(_) => {
                let kind = match pending.take() {
                    Some(pl) => {
                        debug_assert_eq!(pl, l, "prefetch must be one segment deep");
                        ChunkKind::LayerBwdCkptPrefetched
                    }
                    None => ChunkKind::LayerBwdCkptInPlace,
                };
                pieces.push(Piece { kind, role: Role::LayerBwd(l) });
            }
        }
    }
    pieces.push(Piece { kind: ChunkKind::EmbBwd, role: Role::EmbBwd });
    pieces.push(Piece { kind: ChunkKind::Optimizer { any_ckpt }, role: Role::Optimizer });
    pieces
}

/// Whether an event belongs to a piece. Adjacent pieces always differ
/// under this predicate (different segment, or compute vs prefetch
/// lane), so greedy sequential consumption slices unambiguously.
fn piece_matches(p: &Piece, e: &ScheduleEvent) -> bool {
    match p.role {
        Role::Setup => e.segment == Segment::Setup,
        Role::EmbFwd | Role::EmbBwd => e.segment == Segment::Embedding,
        Role::LayerFwd(l) | Role::LayerBwd(l) => e.segment == Segment::Encoder(l),
        Role::HeadFwd | Role::HeadBwd => e.segment == Segment::Head,
        Role::Turnaround | Role::Optimizer => e.segment == Segment::Step,
        Role::Prefetch { target } => {
            e.lane == Lane::Prefetch && e.segment == Segment::Encoder(target)
        }
    }
}

/// Slice a lowered step into per-piece chunk summaries. Consumes the
/// event stream greedily piece by piece and asserts full coverage.
fn slice_step(s: &StepSchedule, pieces: &[Piece]) -> Vec<ChunkSummary> {
    let mut out = Vec::with_capacity(pieces.len());
    let mut i = 0usize;
    for p in pieces {
        let start = i;
        while i < s.events.len() && piece_matches(p, &s.events[i]) {
            i += 1;
        }
        assert!(i > start, "empty chunk for {:?}/{:?}", p.kind, p.role);
        out.push(fold_chunk(&s.tensors, &s.events[start..i]));
    }
    assert_eq!(i, s.events.len(), "donor events not fully consumed");
    out
}

/// The uniform (rewrites, residency) arm whose lowering exhibits a
/// given chunk kind.
fn donor_arm(kind: ChunkKind) -> (OptimizationSet, Residency) {
    let none = OptimizationSet::none();
    match kind {
        ChunkKind::Setup
        | ChunkKind::EmbFwd
        | ChunkKind::HeadFwd
        | ChunkKind::HeadBwd
        | ChunkKind::EmbBwd
        | ChunkKind::Turnaround { any_ckpt: false }
        | ChunkKind::Optimizer { any_ckpt: false } => (none, Residency::Resident),
        ChunkKind::LayerFwdPlain(s) | ChunkKind::LayerBwdPlain(s) => (s, Residency::Resident),
        ChunkKind::LayerFwdCkpt
        | ChunkKind::PrefetchRun
        | ChunkKind::LayerBwdCkptPrefetched
        | ChunkKind::Turnaround { any_ckpt: true }
        | ChunkKind::Optimizer { any_ckpt: true } => {
            (none, Residency::Checkpoint(CkptStyle::Overlapped))
        }
        ChunkKind::LayerBwdCkptInPlace => (none, Residency::Checkpoint(CkptStyle::Serial)),
        ChunkKind::LayerFwdOffload(s) | ChunkKind::LayerBwdOffload(s) => (s, Residency::Offload),
        ChunkKind::LayerFwdShard(s) | ChunkKind::LayerBwdShard(s) => (s, Residency::Shard),
    }
}

const CHUNK_CACHE_CAP: usize = 8192;

fn cache() -> &'static BoundedCache<ChunkKey, ChunkSummary> {
    static CACHE: OnceLock<BoundedCache<ChunkKey, ChunkSummary>> = OnceLock::new();
    CACHE.get_or_init(|| BoundedCache::new(CHUNK_CACHE_CAP))
}

/// Hit/miss/size counters of the chunk cache (`tempo placement
/// --stats`, bench annotations).
pub(crate) fn chunk_cache_stats() -> CacheStats {
    cache().stats(|_| std::mem::size_of::<ChunkSummary>())
}

/// Drop every cached chunk (cold-start benchmarking).
pub(crate) fn clear_chunk_cache() {
    cache().clear();
}

/// Fetch one chunk, lowering and slicing its donor plan on a miss.
/// Every chunk the donor exhibits is inserted (first insert wins), so
/// one donor lowering typically satisfies many future kinds.
fn chunk(
    cfg: &ModelConfig,
    other: OptimizationSet,
    mlm_head: bool,
    tp: usize,
    lowering: Lowering,
    kind: ChunkKind,
) -> Arc<ChunkSummary> {
    let key = chunk_key(cfg, other, mlm_head, tp, lowering, kind);
    if let Some(hit) = cache().get(&key) {
        return hit;
    }
    let (opts, res) = donor_arm(kind);
    let donor = SchedulePlan {
        per_layer: vec![opts; cfg.layers],
        residency: vec![res; cfg.layers],
        other,
        mlm_head,
        tp,
    };
    let donor_resolved: Vec<(OptimizationSet, Residency)> =
        (0..cfg.layers).map(|_| (opts, res)).collect();
    let donor_pieces = build_pieces(cfg.layers, &donor_resolved);
    let lowered = lower_step(cfg, &donor, lowering);
    let sliced = slice_step(&lowered, &donor_pieces);
    let mut wanted: Option<Arc<ChunkSummary>> = None;
    for (p, c) in donor_pieces.iter().zip(sliced) {
        let k = chunk_key(cfg, other, mlm_head, tp, lowering, p.kind);
        let shared = cache().insert(k, Arc::new(c.clone()));
        // same-kind chunks are byte-identical wherever they appear
        debug_assert_eq!(*shared, c, "duplicate chunk diverged: {:?}", p.kind);
        if p.kind == kind {
            wanted = Some(shared);
        }
    }
    wanted.expect("donor plan exhibits the requested chunk kind")
}

/// Price a resolved plan by composing cached chunk summaries —
/// bit-identical to `lower_step(cfg, plan, lowering).summarize_step()`
/// (the oracle `tests/incremental_pricing.rs` pins), at O(L) lookups +
/// one O(L) recombine per call instead of a full lowering.
pub(crate) fn composed_summary(
    cfg: &ModelConfig,
    resolved: &[(OptimizationSet, Residency)],
    other: OptimizationSet,
    mlm_head: bool,
    tp: usize,
    lowering: Lowering,
) -> ScheduleSummary {
    let pieces = build_pieces(cfg.layers, resolved);
    let chunks: Vec<Arc<ChunkSummary>> =
        pieces.iter().map(|p| chunk(cfg, other, mlm_head, tp, lowering, p.kind)).collect();

    // --- peak / classes / census / events (summarize_step replay) ---
    let mut base_item = [0i64; MEM_CLASS_COUNT];
    let mut base_fixed = [0i64; MEM_CLASS_COUNT];
    let mut base_total = 0i64;
    let mut census = Census::ZERO;
    let mut events = 0usize;
    // init mirrors summarize_step exactly: zero peak at event 0, whose
    // kind is the setup event's (never beaten only on an empty model)
    let mut best_total = 0i64;
    let mut best_event = 0usize;
    let mut best_item = [0i64; MEM_CLASS_COUNT];
    let mut best_fixed = [0i64; MEM_CLASS_COUNT];
    let mut best_kind = EventKind::Setup;
    for c in &chunks {
        // within a chunk the base is constant, so the chunk's local
        // first-strict-max is the global first-strict-max candidate;
        // strict `>` across chunks keeps the earliest on ties
        let cand = base_total + c.best_rel_total;
        if cand > best_total {
            best_total = cand;
            best_event = events + c.best_event;
            for k in 0..MEM_CLASS_COUNT {
                best_item[k] = base_item[k] + c.best_rel_item[k];
                best_fixed[k] = base_fixed[k] + c.best_rel_fixed[k];
            }
            best_kind = c.best_kind;
        }
        census.add(c.census_total);
        events += c.events;
        for k in 0..MEM_CLASS_COUNT {
            base_item[k] += c.delta_item[k];
            base_fixed[k] += c.delta_fixed[k];
        }
        base_total += c.delta_item.iter().sum::<i64>();
    }
    debug_assert!(base_item.iter().all(|&v| v == 0), "activations leak past the step");
    let to_u64 = |v: [i64; MEM_CLASS_COUNT]| -> [u64; MEM_CLASS_COUNT] {
        let mut out = [0u64; MEM_CLASS_COUNT];
        for (o, &x) in out.iter_mut().zip(v.iter()) {
            debug_assert!(x >= 0, "negative class bytes at the peak");
            *o = x as u64;
        }
        out
    };
    let class_fixed = to_u64(best_fixed);
    let class_item = to_u64(best_item);

    ScheduleSummary {
        fixed_bytes: class_fixed.iter().sum(),
        peak_item_bytes: best_total as u64,
        peak_event: best_event,
        class_fixed,
        class_item,
        high_water: high_water_label(best_kind),
        census,
        events,
        lanes: compose_lanes(cfg, &pieces, &chunks),
    }
}

/// Recombine the chunk sequence into the exact [`LaneProfile`] the
/// full `lane_profile` walk computes.
fn compose_lanes(
    cfg: &ModelConfig,
    pieces: &[Piece],
    chunks: &[Arc<ChunkSummary>],
) -> LaneProfile {
    let n = pieces.len();

    // prefetch/hidden: a run's covering window is exactly the next
    // chunk's compute (the head backward or the hoisting resident
    // layer's backward) — the chunk after that opens with the target's
    // own backward, which closes the window before contributing
    let mut prefetch = Census::ZERO;
    let mut hidden = Census::ZERO;
    for i in 0..n {
        if matches!(pieces[i].role, Role::Prefetch { .. }) {
            prefetch.add(chunks[i].census_prefetch);
            hidden.add(min_census(chunks[i].census_prefetch, chunks[i + 1].census_compute));
        }
    }

    // bucket tails: every backward chunk ends with its segment's last
    // Backward event, so the full fold's suffix-at-event is our
    // suffix-at-chunk-boundary
    let mut suffix = vec![Census::ZERO; n + 1];
    for i in (0..n).rev() {
        let mut acc = suffix[i + 1];
        acc.add(chunks[i].census_total);
        suffix[i] = acc;
    }
    let mut head_bwd = 0usize;
    let mut emb_bwd = 0usize;
    let mut layer_bwd = vec![0usize; cfg.layers];
    for (i, p) in pieces.iter().enumerate() {
        match p.role {
            Role::HeadBwd => head_bwd = i,
            Role::LayerBwd(l) => layer_bwd[l] = i,
            Role::EmbBwd => emb_bwd = i,
            _ => {}
        }
    }
    let (emb_params, layer_params, head_params) = cfg.param_count_split();
    let mut buckets = Vec::with_capacity(cfg.layers + 2);
    buckets.push(CommBucket {
        segment: Segment::Head,
        bytes: head_params as u64 * 4,
        tail: suffix[head_bwd + 1],
    });
    for l in (0..cfg.layers).rev() {
        buckets.push(CommBucket {
            segment: Segment::Encoder(l),
            bytes: layer_params as u64 * 4,
            tail: suffix[layer_bwd[l] + 1],
        });
    }
    buckets.push(CommBucket {
        segment: Segment::Embedding,
        bytes: emb_params as u64 * 4,
        tail: suffix[emb_bwd + 1],
    });

    // stores: a store DMA sits last in its layer's forward chunk, so a
    // chunk's compute accrues to the *previous* open store window and
    // the window closes at the turnaround
    let mut stores: Vec<HostTransfer> = Vec::new();
    for (i, p) in pieces.iter().enumerate() {
        if p.role == Role::Turnaround {
            break;
        }
        if let Some(last) = stores.last_mut() {
            last.cover.add(chunks[i].census_compute);
        }
        if let (ChunkKind::LayerFwdOffload(_), Role::LayerFwd(l)) = (p.kind, p.role) {
            stores.push(HostTransfer {
                segment: Segment::Encoder(l),
                bytes: chunks[i].store_bytes,
                cover: Census::ZERO,
            });
        }
    }

    // loads: a load DMA opens its layer's backward chunk, so it is
    // covered by the compute accumulated since the previous load (or
    // the turnaround) and its own chunk's backward seeds the next
    // window
    let mut loads: Vec<HostTransfer> = Vec::new();
    let mut load_cover = Census::ZERO;
    let mut past_turn = false;
    for (i, p) in pieces.iter().enumerate() {
        if p.role == Role::Turnaround {
            past_turn = true;
            continue;
        }
        if !past_turn {
            continue;
        }
        if let (ChunkKind::LayerBwdOffload(_), Role::LayerBwd(l)) = (p.kind, p.role) {
            loads.push(HostTransfer {
                segment: Segment::Encoder(l),
                bytes: chunks[i].load_bytes,
                cover: load_cover,
            });
            load_cover = chunks[i].census_compute;
        } else {
            load_cover.add(chunks[i].census_compute);
        }
    }

    // TP collectives: a chunk carries its collectives' *within-chunk*
    // covering prefixes plus a compute tail; recombination completes
    // each chunk's first window with the compute carried since the
    // previous collective anywhere in the step (the full fold never
    // resets at the turnaround, and neither do we)
    let mut tp_links: Vec<HostTransfer> = Vec::new();
    let mut tp_carry = Census::ZERO;
    for (i, p) in pieces.iter().enumerate() {
        let c = &chunks[i];
        if c.tp_events.is_empty() {
            tp_carry.add(c.census_compute);
        } else {
            let segment = match p.role {
                Role::LayerFwd(l) | Role::LayerBwd(l) => Segment::Encoder(l),
                Role::HeadFwd | Role::HeadBwd => Segment::Head,
                _ => unreachable!("TP collectives only appear in layer/head chunks"),
            };
            for (j, &(bytes, cover)) in c.tp_events.iter().enumerate() {
                let mut window = cover;
                if j == 0 {
                    window.add(tp_carry);
                }
                tp_links.push(HostTransfer { segment, bytes, cover: window });
            }
            tp_carry = c.tp_tail;
        }
    }

    LaneProfile { prefetch, hidden, buckets, stores, loads, tp_links }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Technique;

    fn resolve(plan: &SchedulePlan, cfg: &ModelConfig) -> Vec<(OptimizationSet, Residency)> {
        let tp = plan.resolved_tp(cfg);
        (0..cfg.layers)
            .map(|l| {
                let mode = match plan.residency(l) {
                    Residency::Shard if tp == 1 => Residency::Resident,
                    m => m,
                };
                (plan.per_layer.get(l).copied().unwrap_or_else(OptimizationSet::none), mode)
            })
            .collect()
    }

    fn assert_composed_matches(cfg: &ModelConfig, plan: &SchedulePlan) {
        let lowering = Lowering::for_model(cfg);
        let resolved = resolve(plan, cfg);
        let tp = plan.resolved_tp(cfg);
        let composed = composed_summary(cfg, &resolved, plan.other, plan.mlm_head, tp, lowering);
        let full = lower_step(cfg, plan, lowering).summarize_step();
        assert_eq!(composed, full, "composed summary diverged for {}", plan.label());
    }

    #[test]
    fn composed_matches_full_fold_on_uniform_plans() {
        let cfg = ModelConfig::bert_tiny();
        for technique in Technique::all() {
            let plan = SchedulePlan::for_technique(&cfg, technique, true);
            assert_composed_matches(&cfg, &plan);
        }
        // serial checkpointing and the classification head too
        let plan = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, false).serial();
        assert_composed_matches(&cfg, &plan);
    }

    #[test]
    fn composed_matches_full_fold_on_a_mixed_placement() {
        let cfg = ModelConfig::bert_mini();
        assert!(cfg.layers >= 4, "need one layer per residency arm");
        let mut per_layer = vec![OptimizationSet::none(); cfg.layers];
        per_layer[0] = OptimizationSet::full();
        per_layer[3] = OptimizationSet { inplace_gelu: true, ..OptimizationSet::none() };
        let mut residency = vec![Residency::Resident; cfg.layers];
        residency[1] = Residency::Checkpoint(CkptStyle::Overlapped);
        residency[2] = Residency::Checkpoint(CkptStyle::Serial);
        residency[3] = Residency::Offload;
        let plan = SchedulePlan::from_placement(per_layer, residency, true);
        assert_composed_matches(&cfg, &plan);
    }

    #[test]
    fn composed_matches_when_the_top_layer_prefetches() {
        // top-layer Overlapped exercises the pre-head prefetch hoist;
        // stacked Overlapped exercises the in-place fallback
        let cfg = ModelConfig::bert_mini();
        let mut residency = vec![Residency::Checkpoint(CkptStyle::Overlapped); cfg.layers];
        residency[1] = Residency::Resident;
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            residency,
            true,
        );
        assert_composed_matches(&cfg, &plan);
    }

    #[test]
    fn composed_matches_full_fold_on_sharded_plans() {
        // every permitted degree, uniform Shard
        let cfg = ModelConfig::bert_mini();
        for tp in [2usize, 4] {
            assert!(cfg.tp_permitted(tp), "tp={tp}");
            let plan = SchedulePlan::from_placement(
                vec![OptimizationSet::full(); cfg.layers],
                vec![Residency::Shard; cfg.layers],
                true,
            )
            .with_tp(tp);
            assert_composed_matches(&cfg, &plan);
        }
        // mixed residency around sharded layers, incl. a prefetch
        // hosted by a sharded backward
        let mut residency = vec![Residency::Shard; cfg.layers];
        residency[1] = Residency::Checkpoint(CkptStyle::Overlapped);
        residency[3] = Residency::Offload;
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            residency,
            true,
        )
        .with_tp(2);
        assert_composed_matches(&cfg, &plan);
        // impermissible degree resolves to 1: Shard lowers as Resident
        let odd = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Shard; cfg.layers],
            true,
        )
        .with_tp(3);
        assert_composed_matches(&cfg, &odd);
    }

    #[test]
    fn chunk_cache_serves_repeat_compositions() {
        let cfg = ModelConfig::bert_tiny();
        let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
        let resolved = resolve(&plan, &cfg);
        let lowering = Lowering::for_model(&cfg);
        let a = composed_summary(&cfg, &resolved, plan.other, plan.mlm_head, 1, lowering);
        let before = chunk_cache_stats();
        let b = composed_summary(&cfg, &resolved, plan.other, plan.mlm_head, 1, lowering);
        let after = chunk_cache_stats();
        assert_eq!(a, b);
        assert!(after.entries >= 1);
        assert!(after.hits > before.hits, "second composition must hit the cache");
    }
}
