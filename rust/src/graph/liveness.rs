//! Liveness folds over a [`StepSchedule`]: the exact peak of the
//! training step's live-bytes curve, its high-water op, and the
//! per-class breakdown at that instant.
//!
//! Two folds share one walk:
//!
//! * [`StepSchedule::timeline`] — the full curve at a concrete batch
//!   (what `tempo schedule` prints): live bytes sampled at every event,
//!   *after* the event's allocations and in-op tensors appear and
//!   *before* its frees run, so an op is charged for everything it
//!   holds while executing.
//! * [`StepSchedule::summarize_step`] — the batch-free summary sweeps
//!   memoize: model states are batch-independent and constant over the
//!   step, every activation scales linearly in B, so the argmax
//!   instant is the same for every batch and one unit-batch walk
//!   prices all of them exactly (`peak(B) = fixed + item·B`, integer ×
//!   integer).
//!
//! `memmodel::ModelFootprint` reads its whole breakdown (including the
//! once hand-written `transient` row) off [`ScheduleSummary`];
//! `perfmodel::step_census` reads the folded work census;
//! `autotempo` binary-searches max batch against
//! [`ScheduleSummary::peak_bytes`].

use super::op::Census;
use super::schedule::{EventKind, Lane, MemClass, Segment, StepSchedule, MEM_CLASS_COUNT};

/// Live-bytes sample at one schedule event (at a concrete batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LivePoint {
    /// Bytes live while the event runs (its allocs and in-op tensors
    /// included, its frees not yet applied).
    pub live_bytes: u64,
    /// Bytes this event brings into existence (persistent + in-op).
    pub alloc_bytes: u64,
    /// Bytes released when the event completes (frees + in-op).
    pub free_bytes: u64,
}

/// The full liveness curve of one step at a concrete batch.
#[derive(Debug, Clone)]
pub struct LivenessTimeline {
    /// One sample per schedule event, in order.
    pub points: Vec<LivePoint>,
    /// The curve's maximum (the step's true footprint).
    pub peak_bytes: u64,
    /// Index (into `points`/the schedule's events) of the first
    /// high-water sample.
    pub peak_event: usize,
}

/// One comm-lane gradient bucket as the exposure fold sees it: its
/// interconnect payload and the compute census still ahead of the step
/// when the bucket becomes ready (its segment's last backward op
/// completes). The tail is what the collective can hide under — a
/// bucket with an empty tail (the embedding bucket) is pure exposed
/// time on a multi-device rig.
#[derive(Debug, Clone, PartialEq)]
pub struct CommBucket {
    /// Which segment's gradients this bucket carries.
    pub segment: Segment,
    /// Interconnect payload in bytes (fp32 gradients).
    pub bytes: u64,
    /// Per-batch-item compute census issued *after* this bucket is
    /// ready (all lanes — in-flight recompute work also covers comm).
    pub tail: Census,
}

/// One host-link transfer (an offload `Store` or `Load`) as the
/// exposure fold sees it: its PCIe payload and the compute-lane
/// census of the window the DMA can hide under before its in-tape
/// deadline. Stores drain during the forward that follows them;
/// loads drain during the backward window since the previous load
/// (or the turnaround). Prefetch-lane recompute does not cover host
/// traffic — both contend for the same covering compute.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTransfer {
    /// Which layer's retained inventory this transfer carries.
    pub segment: Segment,
    /// Per-batch-item payload in bytes (the layer's shipped
    /// activations after rewrites shrink them).
    pub bytes: u64,
    /// Per-item compute-lane census of the covering window.
    pub cover: Census,
}

/// The concurrency profile of a schedule: what the latency fold
/// (`perfmodel::plan_lane_times`) needs beyond the scalar census.
///
/// Liveness (peak bytes) is lane-blind; this profile is the *time*
/// side of the lanes — how much prefetched recompute work can hide
/// under the covering backward, when each gradient bucket's
/// all-reduce can start relative to the remaining backward compute,
/// and how much compute each host-link transfer can drain under.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneProfile {
    /// Per-item census of all [`Lane::Prefetch`] events (hoisted
    /// overlapped re-forwards).
    pub prefetch: Census,
    /// The part of `prefetch` that fits under its covering backward
    /// window, componentwise per resource (`min(prefetch, cover)` per
    /// prefetch pair) — the recompute work an overlap-aware roofline
    /// does not charge on the critical path.
    pub hidden: Census,
    /// Gradient buckets in readiness order (mirrors
    /// `StepSchedule::grad_buckets`), each with its compute tail.
    pub buckets: Vec<CommBucket>,
    /// Host-link store transfers in tape order (forward phase), each
    /// covered by the forward compute up to the next store or the
    /// turnaround. Empty on offload-free schedules.
    pub stores: Vec<HostTransfer>,
    /// Host-link load transfers in tape order (backward phase), each
    /// covered by the backward compute since the previous load (or
    /// the turnaround). Empty on offload-free schedules.
    pub loads: Vec<HostTransfer>,
    /// Tensor-parallel collectives ([`Lane::TpLink`]) in tape order,
    /// each with the compute-lane census since the previous collective
    /// (the window an async collective can pipeline under before its
    /// op-coupled issue point). `bytes` is the *full* tensor payload
    /// per item; the exposure fold applies the `(tp−1)/tp` ring
    /// factor. Empty at resolved `tp == 1`.
    pub tp_links: Vec<HostTransfer>,
}

/// Batch-free fold of a schedule: peak, high-water op, per-class bytes
/// at the peak, and the step's total work census.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleSummary {
    /// Batch-independent live bytes (model states; constant over the
    /// step, so it never moves the argmax).
    pub fixed_bytes: u64,
    /// Batch-scaled live bytes at the high-water instant.
    pub peak_item_bytes: u64,
    /// Event index of the (first) high-water instant.
    pub peak_event: usize,
    /// Per-[`MemClass`] batch-independent bytes at the peak.
    pub class_fixed: [u64; MEM_CLASS_COUNT],
    /// Per-[`MemClass`] per-batch-item bytes at the peak.
    pub class_item: [u64; MEM_CLASS_COUNT],
    /// What the high-water op is doing — the derived label for the
    /// breakdown row that used to be the hand-written `transient`.
    pub high_water: &'static str,
    /// Total work census per batch item (fwd + bwd + recompute +
    /// rewrite overheads; optimizer state traffic stays in perfmodel).
    pub census: Census,
    /// Number of events in the schedule (bench introspection).
    pub events: usize,
    /// Concurrency profile: prefetch-hidden work and comm-bucket tails
    /// for the exposure fold. Empty/zero on single-lane schedules.
    pub lanes: LaneProfile,
}

impl ScheduleSummary {
    /// Exact peak live bytes at batch `b` (integer × integer).
    pub fn peak_bytes(&self, batch: u64) -> u64 {
        self.fixed_bytes + self.peak_item_bytes * batch
    }

    /// Bytes of one memory class at the high-water instant, at batch
    /// `b` — the `memmodel::Breakdown` rows.
    pub fn class_bytes(&self, class: MemClass, batch: u64) -> u64 {
        let i = class.index();
        self.class_fixed[i] + self.class_item[i] * batch
    }
}

/// Breakdown-row label for a high-water event kind. Shared with the
/// segment composer (`graph::segment`), which must derive the same
/// label from a chunk-local best event.
pub(crate) fn high_water_label(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Setup => "model states",
        EventKind::Forward => "fwd transient",
        EventKind::Turnaround => "bwd working set",
        EventKind::Recompute => "ckpt re-forward + grads",
        EventKind::Backward => "bwd in flight",
        EventKind::Optimizer => "optimizer step",
        // a Store only frees, so the previous sample ties or beats it;
        // a Load materializes the reloaded inventory under backward
        EventKind::Store => "offload store",
        EventKind::Load => "offload load + bwd in flight",
        // TP collectives hold no device memory (allocs/inplace empty),
        // so they can never be the strict high-water instant; the arms
        // exist for match exhaustiveness only
        EventKind::AllGather => "tp all-gather",
        EventKind::ReduceScatter => "tp reduce-scatter",
    }
}

impl StepSchedule {
    /// Fold the full liveness curve at a concrete batch.
    pub fn timeline(&self, batch: usize) -> LivenessTimeline {
        let b = batch as u64;
        let mut live = 0u64;
        let mut peak = 0u64;
        let mut peak_event = 0usize;
        let mut points = Vec::with_capacity(self.events.len());
        for (i, e) in self.events.iter().enumerate() {
            let mut alloc = 0u64;
            for &id in &e.allocs {
                alloc += self.tensors[id as usize].bytes_at(b);
            }
            let mut inop = 0u64;
            for &id in &e.inplace {
                inop += self.tensors[id as usize].bytes_at(b);
            }
            let mut freed = 0u64;
            for &id in &e.frees {
                freed += self.tensors[id as usize].bytes_at(b);
            }
            live += alloc;
            let inst = live + inop;
            if inst > peak {
                peak = inst;
                peak_event = i;
            }
            points.push(LivePoint {
                live_bytes: inst,
                alloc_bytes: alloc + inop,
                free_bytes: freed + inop,
            });
            live -= freed;
        }
        LivenessTimeline { points, peak_bytes: peak, peak_event }
    }

    /// Fold the batch-free summary (see module doc for why one walk at
    /// unit batch prices every batch exactly).
    pub fn summarize_step(&self) -> ScheduleSummary {
        let mut fixed = [0u64; MEM_CLASS_COUNT];
        let mut item = [0u64; MEM_CLASS_COUNT];
        let mut census = Census::ZERO;
        let mut best_item = 0u64;
        let mut best_event = 0usize;
        let mut best_fixed = [0u64; MEM_CLASS_COUNT];
        let mut best_classes = [0u64; MEM_CLASS_COUNT];
        for (i, e) in self.events.iter().enumerate() {
            for &id in &e.allocs {
                let t = &self.tensors[id as usize];
                fixed[t.class.index()] += t.fixed_bytes;
                item[t.class.index()] += t.item_bytes;
            }
            let mut inst = item;
            for &id in &e.inplace {
                let t = &self.tensors[id as usize];
                inst[t.class.index()] += t.item_bytes;
            }
            let inst_total: u64 = inst.iter().sum();
            if inst_total > best_item {
                best_item = inst_total;
                best_event = i;
                best_fixed = fixed;
                best_classes = inst;
            }
            census.add(e.census);
            for &id in &e.frees {
                let t = &self.tensors[id as usize];
                fixed[t.class.index()] -= t.fixed_bytes;
                item[t.class.index()] -= t.item_bytes;
            }
        }
        debug_assert!(item.iter().all(|&v| v == 0), "activations leak past the step");
        ScheduleSummary {
            fixed_bytes: best_fixed.iter().sum(),
            peak_item_bytes: best_item,
            peak_event: best_event,
            class_fixed: best_fixed,
            class_item: best_classes,
            high_water: high_water_label(self.events[best_event].kind),
            census,
            events: self.events.len(),
            lanes: self.lane_profile(),
        }
    }

    /// Fold the concurrency profile: per-resource prefetch hiding and
    /// per-bucket compute tails (see [`LaneProfile`]).
    pub fn lane_profile(&self) -> LaneProfile {
        // census strictly after each event (suffix sums, exact folds)
        let mut tail_after = vec![Census::ZERO; self.events.len() + 1];
        for i in (0..self.events.len()).rev() {
            let mut acc = tail_after[i + 1];
            acc.add(self.events[i].census);
            tail_after[i] = acc;
        }

        // prefetch pairs: a contiguous run of Prefetch events for
        // segment `s` hides under the compute events that follow it, up
        // to (not including) the first Backward op of `s` itself — the
        // covering window the hoist placed it under. The lowering keeps
        // at most one prefetch in flight (the one-segment-deep
        // invariant), so a simple state machine folds every pair.
        let mut prefetch = Census::ZERO;
        let mut hidden = Census::ZERO;
        let mut run: Option<(Segment, Census)> = None; // open prefetch run
        let mut covering: Option<(Segment, Census, Census)> = None; // (seg, p, cover)

        // host-link transfers: stores drain under the forward compute
        // up to the next store (or the turnaround); loads drain under
        // the backward compute since the previous load (or the
        // turnaround). Tape position is the completion deadline — the
        // fold only records the covering window, `plan_lane_times`
        // prices the unhidden tail.
        let mut stores: Vec<HostTransfer> = Vec::new();
        let mut loads: Vec<HostTransfer> = Vec::new();
        let mut store_open = false;
        let mut load_cover = Census::ZERO;
        let mut past_turn = false;
        // TP collectives pipeline under the compute since the previous
        // collective (op-coupled issue points; no turnaround reset —
        // the last forward collective drains under the turnaround gap)
        let mut tp_links: Vec<HostTransfer> = Vec::new();
        let mut tp_cover = Census::ZERO;
        for e in &self.events {
            match e.lane {
                Lane::Prefetch => {
                    prefetch.add(e.census);
                    match &mut run {
                        Some((seg, p)) if *seg == e.segment => p.add(e.census),
                        _ => run = Some((e.segment, e.census)),
                    }
                }
                Lane::HostLink => match e.kind {
                    EventKind::Store => {
                        let bytes: u64 = e
                            .frees
                            .iter()
                            .map(|&id| self.tensors[id as usize].item_bytes)
                            .sum();
                        stores.push(HostTransfer { segment: e.segment, bytes, cover: Census::ZERO });
                        store_open = true;
                    }
                    EventKind::Load => {
                        let bytes: u64 = e
                            .allocs
                            .iter()
                            .map(|&id| self.tensors[id as usize].item_bytes)
                            .sum();
                        loads.push(HostTransfer { segment: e.segment, bytes, cover: load_cover });
                        load_cover = Census::ZERO;
                    }
                    _ => {}
                },
                Lane::TpLink => {
                    tp_links.push(HostTransfer {
                        segment: e.segment,
                        bytes: e.comm_item_bytes,
                        cover: tp_cover,
                    });
                    tp_cover = Census::ZERO;
                }
                Lane::Compute => {
                    if e.kind == EventKind::Turnaround {
                        store_open = false;
                        past_turn = true;
                    }
                    if store_open {
                        if let Some(t) = stores.last_mut() {
                            t.cover.add(e.census);
                        }
                    }
                    if past_turn {
                        load_cover.add(e.census);
                    }
                    tp_cover.add(e.census);
                    if let Some((seg, p)) = run.take() {
                        if let Some((_, p2, c2)) = covering.take() {
                            hidden.add(min_census(p2, c2));
                        }
                        covering = Some((seg, p, Census::ZERO));
                    }
                    if let Some((seg, p, cover)) = &mut covering {
                        if e.kind == EventKind::Backward && e.segment == *seg {
                            // the prefetched layer's own backward starts:
                            // the window is over; credit the overlap per
                            // resource (min of demand and cover)
                            hidden.add(min_census(*p, *cover));
                            covering = None;
                        } else {
                            cover.add(e.census);
                        }
                    }
                }
            }
        }
        if let Some((_, p, cover)) = covering {
            hidden.add(min_census(p, cover));
        }

        // bucket tails: compute census after each segment's last
        // backward op (when that bucket's gradients are final)
        let buckets = self
            .grad_buckets
            .iter()
            .map(|&(segment, bytes)| {
                let tail = self
                    .events
                    .iter()
                    .rposition(|e| e.kind == EventKind::Backward && e.segment == segment)
                    .map(|i| tail_after[i + 1])
                    .unwrap_or(Census::ZERO);
                CommBucket { segment, bytes, tail }
            })
            .collect();

        LaneProfile { prefetch, hidden, buckets, stores, loads, tp_links }
    }
}

/// Componentwise minimum of two censuses (per-resource overlap).
/// Shared with the segment composer's hidden-work recombine.
pub(crate) fn min_census(a: Census, b: Census) -> Census {
    Census {
        matmul_flops: a.matmul_flops.min(b.matmul_flops),
        vector_flops: a.vector_flops.min(b.vector_flops),
        vector_bytes: a.vector_bytes.min(b.vector_bytes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, OptimizationSet, Technique};
    use crate::graph::{lower_step, Lowering, SchedulePlan};

    fn sched(cfg: &ModelConfig, technique: Technique) -> StepSchedule {
        let plan = SchedulePlan::for_technique(cfg, technique, true);
        lower_step(cfg, &plan, Lowering::for_model(cfg))
    }

    #[test]
    fn timeline_ends_with_states_only() {
        let cfg = ModelConfig::bert_tiny();
        for technique in Technique::all() {
            let s = sched(&cfg, technique);
            let tl = s.timeline(4);
            let states = 4 * cfg.param_count() as u64 * 4;
            // after the optimizer event's frees, only states remain
            let last = tl.points.last().unwrap();
            assert_eq!(last.live_bytes - last.free_bytes, states, "{technique:?}");
        }
    }

    #[test]
    fn summary_prices_every_batch_exactly_like_a_fresh_fold() {
        let cfg = ModelConfig::bert_mini();
        for technique in Technique::all() {
            let s = sched(&cfg, technique);
            let summary = s.summarize_step();
            for batch in [0usize, 1, 4, 32] {
                let tl = s.timeline(batch);
                assert_eq!(
                    summary.peak_bytes(batch as u64),
                    tl.peak_bytes,
                    "{technique:?} B={batch}"
                );
            }
            // the high-water instant is batch-independent
            assert_eq!(summary.peak_event, s.timeline(7).peak_event, "{technique:?}");
        }
    }

    #[test]
    fn class_rows_sum_to_the_peak() {
        let cfg = ModelConfig::bert_tiny();
        for technique in Technique::all() {
            let plan = SchedulePlan::for_technique(&cfg, technique, true);
            let summary = lower_step(&cfg, &plan, Lowering::for_model(&cfg)).summarize_step();
            for b in [1u64, 8] {
                let sum: u64 = (0..MEM_CLASS_COUNT)
                    .map(|i| summary.class_fixed[i] + summary.class_item[i] * b)
                    .sum();
                assert_eq!(sum, summary.peak_bytes(b), "{technique:?} B={b}");
            }
        }
    }

    #[test]
    fn high_water_labels_tell_the_technique_story() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let plain = sched(&cfg, Technique::Tempo).summarize_step();
        assert_eq!(plain.high_water, "bwd working set");
        let ck = sched(&cfg, Technique::Checkpoint).summarize_step();
        assert_eq!(ck.high_water, "ckpt re-forward + grads");
    }

    #[test]
    fn lane_profile_hides_nothing_without_prefetches() {
        let cfg = ModelConfig::bert_mini();
        for technique in [Technique::Baseline, Technique::Tempo] {
            let lanes = sched(&cfg, technique).summarize_step().lanes;
            assert_eq!(lanes.prefetch, Census::ZERO, "{technique:?}");
            assert_eq!(lanes.hidden, Census::ZERO, "{technique:?}");
        }
        // serial checkpointing recomputes in place: still nothing hidden
        let plan = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true).serial();
        let lanes = lower_step(&cfg, &plan, Lowering::for_model(&cfg)).summarize_step().lanes;
        assert_eq!(lanes.prefetch, Census::ZERO);
        assert_eq!(lanes.hidden, Census::ZERO);
        // no offload arm anywhere above: the host lane is silent
        assert!(lanes.stores.is_empty() && lanes.loads.is_empty());
        // and no shard arm: the TP lane is silent too
        assert!(lanes.tp_links.is_empty());
    }

    #[test]
    fn host_transfers_carry_their_covering_windows() {
        use crate::graph::Residency;
        let cfg = ModelConfig::bert_tiny();
        let n = cfg.layers;
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); n],
            vec![Residency::Offload; n],
            true,
        );
        let lanes = lower_step(&cfg, &plan, Lowering::for_model(&cfg)).summarize_step().lanes;
        assert_eq!(lanes.stores.len(), n);
        assert_eq!(lanes.loads.len(), n);
        // round trip: every byte shipped out comes back in
        let out: u64 = lanes.stores.iter().map(|t| t.bytes).sum();
        let back: u64 = lanes.loads.iter().map(|t| t.bytes).sum();
        assert_eq!(out, back);
        // every store except the last is covered by at least the next
        // layer's forward; the last store's window runs to turnaround
        for t in &lanes.stores {
            assert!(t.bytes > 0, "{:?} ships nothing", t.segment);
        }
        for t in lanes.stores.iter().take(n - 1) {
            assert!(t.cover.matmul_flops > 0.0, "{:?} store uncovered", t.segment);
        }
        // the first load (top layer) hides under the head backward;
        // later loads hide under the previous layer's backward
        for t in &lanes.loads {
            assert!(t.cover.matmul_flops > 0.0, "{:?} load uncovered", t.segment);
        }
    }

    #[test]
    fn lane_profile_bounds_hidden_by_prefetch() {
        let cfg = ModelConfig::bert_mini();
        let lanes = sched(&cfg, Technique::Checkpoint).summarize_step().lanes;
        // the top layer's re-forward is hoisted under the head backward
        assert!(lanes.prefetch.matmul_flops > 0.0);
        assert!(lanes.hidden.matmul_flops > 0.0, "head bwd covers some recompute");
        for (h, p) in [
            (lanes.hidden.matmul_flops, lanes.prefetch.matmul_flops),
            (lanes.hidden.vector_flops, lanes.prefetch.vector_flops),
            (lanes.hidden.vector_bytes, lanes.prefetch.vector_bytes),
        ] {
            assert!(h >= 0.0 && h <= p, "hidden {h} out of [0, {p}]");
        }
    }

    #[test]
    fn bucket_tails_shrink_along_readiness_order() {
        let cfg = ModelConfig::bert_mini();
        for technique in Technique::all() {
            let lanes = sched(&cfg, technique).summarize_step().lanes;
            assert_eq!(lanes.buckets.len(), cfg.layers + 2, "{technique:?}");
            // later-ready buckets have less compute left to hide under
            for w in lanes.buckets.windows(2) {
                assert!(w[0].tail.matmul_flops >= w[1].tail.matmul_flops, "{technique:?}");
                assert!(w[0].tail.vector_flops >= w[1].tail.vector_flops, "{technique:?}");
                assert!(w[0].tail.vector_bytes >= w[1].tail.vector_bytes, "{technique:?}");
            }
            // the embedding bucket is ready at the end of backward: its
            // tail is empty (the optimizer event carries no census), so
            // its collective is pure exposed time on a multi-device rig
            let emb = lanes.buckets.last().unwrap();
            assert_eq!(emb.segment, crate::graph::Segment::Embedding, "{technique:?}");
            assert_eq!(emb.tail, Census::ZERO, "{technique:?}");
        }
    }

    #[test]
    fn in_op_tensors_count_at_their_event_only() {
        let cfg = ModelConfig::bert_tiny();
        let plan = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let tl = s.timeline(1);
        // find the first encoder GELU forward: its sample includes the
        // in-op rewritten input, the next event's does not
        let idx = s
            .events
            .iter()
            .position(|e| e.name == "ffn.gelu" && e.kind == EventKind::Forward)
            .unwrap();
        let inop_bytes: u64 =
            s.events[idx].inplace.iter().map(|&id| s.tensors[id as usize].bytes_at(1)).sum();
        assert!(inop_bytes > 0);
        let next_alloc = tl.points[idx + 1].alloc_bytes;
        assert_eq!(
            tl.points[idx + 1].live_bytes,
            tl.points[idx].live_bytes - inop_bytes + next_alloc
        );
    }
}
