//! Lowering rules: `ModelConfig` → typed op graph per block.
//!
//! One declarative description of the transformer block (paper Fig 1),
//! lowered once per (config, lowering, rewrite set) and folded by every
//! consumer — `memmodel` sums retained bytes, `perfmodel` sums op
//! censuses, `autotempo` searches per-layer rewrite plans, the sim
//! backend prices steps through both.
//!
//! Architecture differences are **lowering rules**, not inline `if`s:
//!
//! * [`Lowering::unfused_attention`] — HF GPT2's unfused attention
//!   materializes (and autograd retains) the causal-masked scores and
//!   an fp32 upcast copy; the fused Tempo core doesn't. Default on for
//!   `ModelKind::Gpt2`, matching the legacy closed form.
//! * [`Topology::PreLn`] — GPT2's real block order (LN before each
//!   sub-layer). Re-wires *which* tensors are retained (the block input
//!   feeds LN1, the residual sum feeds LN2) but the per-class byte
//!   totals coincide with post-LN under every rewrite subset — asserted
//!   in the tests below.
//! * [`Lowering::causal_census`] — decoder-only causal attention
//!   touches only the lower triangle of every S×S map: the S²-class
//!   FLOPs and traffic halve. Retained *bytes* do not change (the
//!   buffers are stored dense). Opt-in: the legacy closed forms (and
//!   the paper calibration pins) price GPT2 dense.
//!
//! All census terms are integer-valued and far below 2⁵³, so f64 folds
//! are exact in any order — the graph reproduces the legacy closed
//! forms bit-identically (pinned by `tests/graph_equivalence.rs`).

use crate::config::{ModelConfig, ModelKind, OptimizationSet};

use super::op::{Census, Op, OpKind};
use super::tensor::{RetainedTensor, RewriteKind, TensorClass};

/// Where the LayerNorms sit relative to the sub-layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// BERT/RoBERTa (and the paper's accounting): residual → LN.
    PostLn,
    /// GPT2's real block order: LN → sub-layer → residual.
    PreLn,
}

/// Architecture-specific lowering rules for one encoder/decoder block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Lowering {
    /// Where the LayerNorms sit (post-LN BERT vs pre-LN GPT2).
    pub topology: Topology,
    /// HF GPT2 unfused attention: retain 2 extra B·A·S² score copies.
    pub unfused_attention: bool,
    /// Halve S²-class FLOPs/traffic (causal lower-triangle work).
    pub causal_census: bool,
}

impl Lowering {
    /// Legacy-compatible defaults: post-LN, dense census; the unfused-
    /// attention penalty for GPT2 (exactly the old `ModelKind::Gpt2`
    /// special case, now a lowering rule).
    pub fn for_model(cfg: &ModelConfig) -> Lowering {
        Lowering {
            topology: Topology::PostLn,
            unfused_attention: cfg.kind == ModelKind::Gpt2,
            causal_census: false,
        }
    }

    /// GPT2 as it really is: pre-LN blocks, unfused HF attention,
    /// causal (half) S² work.
    pub fn gpt2_native() -> Lowering {
        Lowering {
            topology: Topology::PreLn,
            unfused_attention: true,
            causal_census: true,
        }
    }
}

/// A lowered transformer block: ops in dataflow order.
#[derive(Debug, Clone)]
pub struct BlockGraph {
    /// Block kind (`encoder` / `embedding` / `mlm-head` / `cls-head`).
    pub name: &'static str,
    /// Ops in dataflow order.
    pub ops: Vec<Op>,
    /// The lowering rules this block was built under.
    pub lowering: Lowering,
    /// Elements (per batch item) of the block's input tensor — what a
    /// segment-level checkpoint rewrite stores instead of the inventory.
    pub input_elems: u64,
}

/// Folded per-block summary under one rewrite set, at unit batch.
/// Everything scales linearly in B, so one summary prices any batch.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockSummary {
    /// fp32 feature-map elements retained per batch item.
    pub map_elems: u64,
    /// 1-byte mask elements retained per batch item.
    pub mask_elems: u64,
    /// fp32 per-row statistic elements retained per batch item.
    pub stat_elems: u64,
    /// Widest single fp32 map in the block (rewrite-independent: the
    /// backward working set holds activation *gradients* of the widest
    /// rows whether or not the forward copy was rewritten away).
    pub widest_map_elems: u64,
    /// Block-input elements (checkpoint segment storage).
    pub input_elems: u64,
    /// Forward census per batch item.
    pub fwd: Census,
    /// Extra backward census per batch item from enabled rewrites.
    pub overhead: Census,
}

impl BlockGraph {
    /// Apply a rewrite set (a pure filter over the superset inventory)
    /// and fold.
    pub fn summarize(&self, opts: OptimizationSet) -> BlockSummary {
        let mut map_elems = 0u64;
        let mut mask_elems = 0u64;
        let mut stat_elems = 0u64;
        let mut widest = 0u64;
        let mut fwd = Census::ZERO;
        let mut overhead = Census::ZERO;
        for op in &self.ops {
            map_elems += op.retained_elems(TensorClass::F32Map, &opts);
            mask_elems += op.retained_elems(TensorClass::Mask, &opts);
            stat_elems += op.retained_elems(TensorClass::RowStat, &opts);
            for t in &op.retained {
                if t.class == TensorClass::F32Map {
                    widest = widest.max(t.elems());
                }
            }
            fwd.add(op.fwd);
            if let Some((rw, c)) = op.overhead {
                if rw.enabled(&opts) {
                    overhead.add(c);
                }
            }
        }
        BlockSummary {
            map_elems,
            mask_elems,
            stat_elems,
            widest_map_elems: widest,
            input_elems: self.input_elems,
            fwd,
            overhead,
        }
    }
}

impl BlockSummary {
    /// Retained fp32 feature-map bytes at batch B.
    pub fn float_bytes(&self, batch: u64) -> u64 {
        self.map_elems * batch * 4
    }

    /// Retained 1-byte-mask bytes at batch B.
    pub fn mask_bytes(&self, batch: u64) -> u64 {
        self.mask_elems * batch
    }

    /// Retained per-row-statistic bytes at batch B.
    pub fn stat_bytes(&self, batch: u64) -> u64 {
        self.stat_elems * batch * 4
    }

    /// All retained bytes at batch B.
    pub fn total_bytes(&self, batch: u64) -> u64 {
        self.float_bytes(batch) + self.mask_bytes(batch) + self.stat_bytes(batch)
    }

    /// Forward census at batch B (exact: integer × integer).
    pub fn fwd_at(&self, batch: usize) -> Census {
        self.fwd.scale(batch as f64)
    }

    /// Rewrite-overhead census at batch B.
    pub fn overhead_at(&self, batch: usize) -> Census {
        self.overhead.scale(batch as f64)
    }
}

/// Whole-segment checkpointing as a **segment-level** rewrite: instead
/// of filtering the per-op inventory, the rewrite replaces a block's
/// entire retained set with its input tensor and pays a re-forward
/// during backward. The backward live set holds the recomputed block
/// inventory PLUS the activation gradients flowing through it (≈ the
/// float volume again) — the doubled transient that caps checkpointing
/// at long S in Table 2.
#[derive(Debug, Clone)]
pub struct SegmentCheckpoint {
    /// Stored per checkpointed block (elements per batch item).
    pub stored_elems: u64,
    /// Baseline inventory bytes per batch item (recompute live set).
    full_total_per_item: u64,
    full_float_per_item: u64,
    /// Re-forward census per batch item (the caller applies the
    /// recompute-inefficiency factor — a roofline calibration knob).
    pub recompute_fwd: Census,
}

impl SegmentCheckpoint {
    /// Rewrite a block (summarized under `OptimizationSet::none()` —
    /// checkpointing recomputes the *unoptimized* layer).
    pub fn of(full: &BlockSummary) -> SegmentCheckpoint {
        SegmentCheckpoint {
            stored_elems: full.input_elems,
            full_total_per_item: full.total_bytes(1),
            full_float_per_item: full.float_bytes(1),
            recompute_fwd: full.fwd,
        }
    }

    /// Bytes stored per checkpointed block at batch B.
    pub fn stored_bytes(&self, batch: u64) -> u64 {
        self.stored_elems * batch * 4
    }

    /// Transient live set while one block's backward is in flight.
    pub fn transient_bytes(&self, batch: u64) -> u64 {
        (self.full_total_per_item + self.full_float_per_item) * batch
    }
}

/// Attention core ops, shared by both topologies. `cf` is the causal
/// census factor (0.5 when only the lower triangle is touched).
fn attention_core(cfg: &ModelConfig, lowering: Lowering) -> Vec<Op> {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let a = cfg.heads as u64;
    let ass = a * s * s;
    let sf = s as f64;
    let hf = h as f64;
    let assf = ass as f64;
    let cf = if lowering.causal_census { 0.5 } else { 1.0 };

    let mut scores_op = Op::new(
        OpKind::Softmax,
        "attn.softmax",
        Census::vector(cf * 3.0 * assf, cf * 12.0 * assf),
    )
    .retain(RetainedTensor::removed_by(
        "attn.scores",
        vec![a, s, s],
        TensorClass::F32Map,
        RewriteKind::SoftmaxOutputOnly,
    ));
    if lowering.unfused_attention {
        // HF GPT2's unfused attention additionally materializes (and
        // autograd retains) the causal-masked scores and the fp32
        // upcast copy — both vanish with the output-only softmax, which
        // implies the fused Tempo core.
        scores_op = scores_op
            .retain(RetainedTensor::removed_by(
                "attn.scores_masked",
                vec![a, s, s],
                TensorClass::F32Map,
                RewriteKind::SoftmaxOutputOnly,
            ))
            .retain(RetainedTensor::removed_by(
                "attn.scores_fp32",
                vec![a, s, s],
                TensorClass::F32Map,
                RewriteKind::SoftmaxOutputOnly,
            ));
    }
    scores_op = scores_op.retain(RetainedTensor::always(
        "attn.probs",
        vec![a, s, s],
        TensorClass::F32Map,
    ));

    vec![
        // scores = QKᵀ/√d
        Op::new(OpKind::Matmul, "attn.scores", Census::matmul(cf * 2.0 * sf * sf * hf)),
        scores_op,
        // attention-prob dropout: mask always retained; the dropped map
        // is discarded and recomputed (one fused multiply in the dV
        // prologue) under §3.3.
        Op::new(
            OpKind::Dropout,
            "attn.dropout",
            Census::vector(cf * assf, cf * 8.0 * assf),
        )
        .retain(RetainedTensor::always("attn.drop_mask", vec![a, s, s], TensorClass::Mask))
        .retain(RetainedTensor::removed_by(
            "attn.probs_dropped",
            vec![a, s, s],
            TensorClass::F32Map,
            RewriteKind::DropoutRecompute,
        ))
        .with_overhead(
            RewriteKind::DropoutRecompute,
            Census::vector(cf * 2.0 * assf, cf * assf),
        ),
        // context = probs·V
        Op::new(OpKind::Matmul, "attn.pv", Census::matmul(cf * 2.0 * sf * sf * hf))
            .retain(RetainedTensor::always("attn.context", vec![s, h], TensorClass::F32Map)),
        // output projection
        Op::new(OpKind::Matmul, "attn.proj", Census::matmul(2.0 * sf * hf * hf)),
        // hidden dropout after the projection
        Op::new(OpKind::Dropout, "attn.proj_dropout", Census::vector(0.0, 4.0 * sf * hf))
            .retain(RetainedTensor::always("attn.proj_drop_mask", vec![s, h], TensorClass::Mask)),
    ]
}

/// QKV projection op; `with_input` additionally retains the block input
/// (post-LN wiring, where x feeds QKV and the residual directly).
fn qkv_op(cfg: &ModelConfig, with_input: bool) -> Op {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let sf = s as f64;
    let hf = h as f64;
    let mut op = Op::new(OpKind::Matmul, "attn.qkv", Census::matmul(6.0 * sf * hf * hf));
    if with_input {
        op = op.retain(RetainedTensor::always("attn.input", vec![s, h], TensorClass::F32Map));
    }
    op.retain(RetainedTensor::always("attn.q", vec![s, h], TensorClass::F32Map))
        .retain(RetainedTensor::always("attn.k", vec![s, h], TensorClass::F32Map))
        .retain(RetainedTensor::always("attn.v", vec![s, h], TensorClass::F32Map))
}

/// A LayerNorm op with the §3.2 rewrite wiring. `input_name` documents
/// *what* the LN input is in this topology (residual sum vs block
/// input); `retain_output` is false when the output is the next block's
/// input (counted there).
fn layernorm_op(
    cfg: &ModelConfig,
    name: &'static str,
    input_name: &'static str,
    output_name: &'static str,
    retain_output: bool,
) -> Op {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let sf = s as f64;
    let hf = h as f64;
    let mut op = Op::new(OpKind::LayerNorm, name, Census::vector(2.0 * sf * hf, 8.0 * sf * hf))
        .retain(RetainedTensor::removed_by(
            input_name,
            vec![s, h],
            TensorClass::F32Map,
            RewriteKind::InplaceLayerNorm,
        ))
        // mean + var retained by stock LN; the in-place variant
        // reconstructs x̂ from the output and keeps rstd only (App. D)
        .retain(RetainedTensor::removed_by(
            "mean_var",
            vec![2, s],
            TensorClass::RowStat,
            RewriteKind::InplaceLayerNorm,
        ))
        .retain(RetainedTensor::added_by(
            "rstd",
            vec![s],
            TensorClass::RowStat,
            RewriteKind::InplaceLayerNorm,
        ));
    if retain_output {
        op = op.retain(RetainedTensor::always(output_name, vec![s, h], TensorClass::F32Map));
    }
    op
}

/// Feed-forward ops (FC1 → GELU → FC2 → dropout), shared by both
/// topologies.
fn ffn_ops(cfg: &ModelConfig) -> Vec<Op> {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let i = cfg.intermediate as u64;
    let sf = s as f64;
    let hf = h as f64;
    let if_ = i as f64;
    vec![
        Op::new(OpKind::Matmul, "ffn.fc1", Census::matmul(2.0 * sf * hf * if_)),
        // FC1 output X = GELU input: the §3.1 rewrite swaps the fp32 map
        // for a 1-byte sign mask and pays the polynomial (deg ≤ 13)
        // backward over B·S·I.
        Op::new(OpKind::Gelu, "ffn.gelu", Census::vector(8.0 * sf * if_, 12.0 * sf * if_))
            .retain(RetainedTensor::removed_by(
                "ffn.gelu_input",
                vec![s, i],
                TensorClass::F32Map,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::added_by(
                "ffn.gelu_mask",
                vec![s, i],
                TensorClass::Mask,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::always("ffn.gelu_output", vec![s, i], TensorClass::F32Map))
            .with_overhead(
                RewriteKind::InplaceGelu,
                Census::vector(26.0 * sf * if_, sf * if_),
            ),
        Op::new(OpKind::Matmul, "ffn.fc2", Census::matmul(2.0 * sf * hf * if_)),
        Op::new(OpKind::Dropout, "ffn.fc2_dropout", Census::vector(0.0, 4.0 * sf * hf))
            .retain(RetainedTensor::always("ffn.drop_mask", vec![s, h], TensorClass::Mask)),
    ]
}

fn residual_op(cfg: &ModelConfig, name: &'static str) -> Op {
    let sf = cfg.seq_len as f64;
    let hf = cfg.hidden as f64;
    Op::new(OpKind::Residual, name, Census::vector(sf * hf, 4.0 * sf * hf))
}

/// Lower one encoder/decoder block with the model's default rules.
pub fn encoder_block(cfg: &ModelConfig) -> BlockGraph {
    encoder_block_with(cfg, Lowering::for_model(cfg))
}

/// Lower one encoder/decoder block under explicit lowering rules.
pub fn encoder_block_with(cfg: &ModelConfig, lowering: Lowering) -> BlockGraph {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let mut ops = Vec::new();
    match lowering.topology {
        Topology::PostLn => {
            // x → QKV → attention → proj → dropout → +x → LN1
            //   → FC1 → GELU → FC2 → dropout → +LN1 → LN2 → next block
            ops.push(qkv_op(cfg, true));
            ops.extend(attention_core(cfg, lowering));
            ops.push(residual_op(cfg, "attn.residual"));
            // LN1 input is the residual sum; LN1 output feeds FC1.
            ops.push(layernorm_op(cfg, "ln1", "ln1.input", "ln1.output", true));
            ops.extend(ffn_ops(cfg));
            ops.push(residual_op(cfg, "ffn.residual"));
            // LN2 output is the next block's input — counted there.
            ops.push(layernorm_op(cfg, "ln2", "ln2.input", "ln2.output", false));
        }
        Topology::PreLn => {
            // x → LN1 → QKV → attention → proj → dropout → +x
            //   → LN2 → FC1 → GELU → FC2 → dropout → +res → next block
            // LN1's input IS the block input; its output feeds QKV.
            ops.push(layernorm_op(cfg, "ln1", "ln1.input", "ln1.output", true));
            ops.push(qkv_op(cfg, false));
            ops.extend(attention_core(cfg, lowering));
            ops.push(residual_op(cfg, "attn.residual"));
            // LN2 input is the first residual sum; its output feeds FC1.
            ops.push(layernorm_op(cfg, "ln2", "ln2.input", "ln2.output", true));
            ops.extend(ffn_ops(cfg));
            // Block output (second residual sum) is the next block's
            // input — counted there.
            ops.push(residual_op(cfg, "ffn.residual"));
        }
    }
    BlockGraph { name: "encoder", ops, lowering, input_elems: s * h }
}

/// Embedding block (gather-sum → LN → dropout). Census is zero: the
/// legacy roofline folds embedding traffic into the head estimate, and
/// the closed form elides the embedding LN's B·S stats as negligible —
/// the lowering reproduces that accounting exactly.
pub fn embedding_block(cfg: &ModelConfig) -> BlockGraph {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let ops = vec![
        Op::new(OpKind::Residual, "emb.sum", Census::ZERO)
            .retain(RetainedTensor::always("emb.sum_output", vec![s, h], TensorClass::F32Map)),
        Op::new(OpKind::LayerNorm, "emb.ln", Census::ZERO)
            .retain(RetainedTensor::removed_by(
                "emb.ln_input",
                vec![s, h],
                TensorClass::F32Map,
                RewriteKind::InplaceLayerNorm,
            ))
            .retain(RetainedTensor::always("emb.ln_output", vec![s, h], TensorClass::F32Map)),
        Op::new(OpKind::Dropout, "emb.dropout", Census::ZERO)
            .retain(RetainedTensor::always("emb.drop_mask", vec![s, h], TensorClass::Mask)),
    ];
    BlockGraph {
        name: "embedding",
        ops,
        lowering: Lowering::for_model(cfg),
        input_elems: s * h,
    }
}

/// MLM head (transform → GELU → LN → tied decoder → log-softmax). The
/// B·S·V logits and log-softmax dominate non-encoder memory for real
/// vocabularies.
pub fn mlm_head_block(cfg: &ModelConfig) -> BlockGraph {
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let v = cfg.vocab_size as u64;
    let sf = s as f64;
    let hf = h as f64;
    let vf = v as f64;
    let ops = vec![
        // transform (H→H); its vector traffic entry also carries the
        // GELU/LN passes of the head, matching the legacy lumped term.
        Op::new(
            OpKind::Matmul,
            "head.transform",
            Census {
                matmul_flops: 2.0 * sf * hf * hf,
                vector_flops: 0.0,
                vector_bytes: 24.0 * sf * hf,
            },
        )
        .retain(RetainedTensor::always("head.transform_out", vec![s, h], TensorClass::F32Map)),
        Op::new(OpKind::Gelu, "head.gelu", Census::ZERO)
            .retain(RetainedTensor::removed_by(
                "head.gelu_input",
                vec![s, h],
                TensorClass::F32Map,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::added_by(
                "head.gelu_mask",
                vec![s, h],
                TensorClass::Mask,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::always("head.gelu_output", vec![s, h], TensorClass::F32Map)),
        Op::new(OpKind::LayerNorm, "head.ln", Census::ZERO)
            .retain(RetainedTensor::removed_by(
                "head.ln_input",
                vec![s, h],
                TensorClass::F32Map,
                RewriteKind::InplaceLayerNorm,
            ))
            .retain(RetainedTensor::always("head.ln_output", vec![s, h], TensorClass::F32Map)),
        Op::new(OpKind::Matmul, "head.decoder", Census::matmul(2.0 * sf * hf * vf))
            .retain(RetainedTensor::always("head.logits", vec![s, v], TensorClass::F32Map)),
        Op::new(OpKind::Softmax, "head.loss", Census::vector(5.0 * sf * vf, 16.0 * sf * vf))
            .retain(RetainedTensor::always("head.log_softmax", vec![s, v], TensorClass::F32Map)),
    ];
    BlockGraph {
        name: "mlm-head",
        ops,
        lowering: Lowering::for_model(cfg),
        input_elems: s * h,
    }
}

/// Classification head (pooled [CLS] → tanh → logits) — tiny; the
/// legacy closed form sizes all three rows at H.
pub fn cls_head_block(cfg: &ModelConfig) -> BlockGraph {
    let h = cfg.hidden as u64;
    let ops = vec![
        Op::new(OpKind::Matmul, "cls.pool", Census::ZERO)
            .retain(RetainedTensor::always("cls.pooled", vec![h], TensorClass::F32Map)),
        Op::new(OpKind::Gelu, "cls.tanh", Census::ZERO)
            .retain(RetainedTensor::always("cls.tanh_out", vec![h], TensorClass::F32Map)),
        Op::new(OpKind::Matmul, "cls.logits", Census::ZERO)
            .retain(RetainedTensor::always("cls.logits", vec![h], TensorClass::F32Map)),
    ];
    BlockGraph {
        name: "cls-head",
        ops,
        lowering: Lowering::for_model(cfg),
        input_elems: h,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn base() -> ModelConfig {
        ModelConfig::bert_base().with_seq_len(128)
    }

    #[test]
    fn baseline_inventory_matches_fig1_counts() {
        // 8 B·S·H maps + 3 B·A·S² maps + 2 B·S·I maps, 1 S² mask +
        // 2 S·H masks, 2 LNs worth of mean/var.
        let g = encoder_block(&base());
        let s = g.summarize(OptimizationSet::none());
        let (sq, h, a, i) = (128u64, 768u64, 12u64, 3072u64);
        assert_eq!(s.map_elems, 8 * sq * h + 3 * a * sq * sq + 2 * sq * i);
        assert_eq!(s.mask_elems, a * sq * sq + 2 * sq * h);
        assert_eq!(s.stat_elems, 2 * 2 * sq);
        assert_eq!(s.input_elems, sq * h);
        assert_eq!(s.widest_map_elems, (a * sq * sq).max(sq * i));
    }

    #[test]
    fn each_rewrite_touches_its_tensors() {
        let g = encoder_block(&base());
        let none = g.summarize(OptimizationSet::none());
        let (sq, h, a, i) = (128u64, 768u64, 12u64, 3072u64);

        let gelu = g.summarize(OptimizationSet::only("gelu").unwrap());
        assert_eq!(none.map_elems - gelu.map_elems, sq * i);
        assert_eq!(gelu.mask_elems - none.mask_elems, sq * i);

        let ln = g.summarize(OptimizationSet::only("layernorm").unwrap());
        assert_eq!(none.map_elems - ln.map_elems, 2 * sq * h);
        assert_eq!(ln.stat_elems, 2 * sq); // rstd only, both LNs

        let drop = g.summarize(OptimizationSet::only("dropout").unwrap());
        assert_eq!(none.map_elems - drop.map_elems, a * sq * sq);
        assert_eq!(drop.mask_elems, none.mask_elems);

        let sm = g.summarize(OptimizationSet::only("softmax").unwrap());
        assert_eq!(none.map_elems - sm.map_elems, a * sq * sq);
    }

    #[test]
    fn unfused_attention_is_a_lowering_rule_not_a_model_if() {
        let bert = base();
        let mut gpt_like = base();
        gpt_like.kind = crate::config::ModelKind::Gpt2;
        let (sq, a) = (128u64, 12u64);

        let fused = encoder_block(&bert).summarize(OptimizationSet::none());
        let unfused = encoder_block(&gpt_like).summarize(OptimizationSet::none());
        assert_eq!(unfused.map_elems - fused.map_elems, 2 * a * sq * sq);

        // the output-only softmax deletes all three score copies
        let sm = OptimizationSet::only("softmax").unwrap();
        assert_eq!(
            encoder_block(&gpt_like).summarize(sm).map_elems,
            encoder_block(&bert).summarize(sm).map_elems
        );
        // and an explicit lowering overrides the model default
        let forced = encoder_block_with(
            &bert,
            Lowering { unfused_attention: true, ..Lowering::for_model(&bert) },
        );
        assert_eq!(forced.summarize(OptimizationSet::none()).map_elems, unfused.map_elems);
    }

    #[test]
    fn pre_ln_rewires_but_byte_totals_coincide() {
        // Pre-LN changes *which* tensors are retained (block input feeds
        // LN1, residual sum feeds LN2) but the per-class totals match
        // post-LN under every rewrite subset — both retain 8 B·S·H maps
        // at baseline and drop the same 2 under in-place LN.
        let cfg = base();
        let post = encoder_block_with(
            &cfg,
            Lowering { topology: Topology::PostLn, ..Lowering::for_model(&cfg) },
        );
        let pre = encoder_block_with(
            &cfg,
            Lowering { topology: Topology::PreLn, ..Lowering::for_model(&cfg) },
        );
        for opts in OptimizationSet::all_subsets() {
            let a = post.summarize(opts);
            let b = pre.summarize(opts);
            assert_eq!(a.map_elems, b.map_elems, "{opts:?}");
            assert_eq!(a.mask_elems, b.mask_elems, "{opts:?}");
            assert_eq!(a.stat_elems, b.stat_elems, "{opts:?}");
            assert_eq!(a.fwd, b.fwd, "{opts:?}");
            assert_eq!(a.overhead, b.overhead, "{opts:?}");
        }
        // and the tensor *names* really differ: pre-LN has no separate
        // attn.input (LN1's input is the block input).
        let names: Vec<&str> =
            pre.ops.iter().flat_map(|o| o.retained.iter().map(|t| t.name)).collect();
        assert!(!names.contains(&"attn.input"));
        assert!(names.contains(&"ln1.input"));
    }

    #[test]
    fn causal_census_halves_s2_work_but_not_bytes() {
        let cfg = base();
        let dense = encoder_block_with(&cfg, Lowering::for_model(&cfg));
        let causal = encoder_block_with(
            &cfg,
            Lowering { causal_census: true, ..Lowering::for_model(&cfg) },
        );
        let d = dense.summarize(OptimizationSet::none());
        let c = causal.summarize(OptimizationSet::none());
        // bytes unchanged (dense storage)
        assert_eq!(d.map_elems, c.map_elems);
        assert_eq!(d.mask_elems, c.mask_elems);
        // S²-class census exactly halved: the delta is the S² share
        let (sq, h, a, i) = (128f64, 768f64, 12f64, 3072f64);
        let s2_mm = 4.0 * sq * sq * h; // scores + PV
        let s2_vf = 4.0 * a * sq * sq; // softmax + dropout passes
        let s2_vb = 20.0 * a * sq * sq;
        assert_eq!(d.fwd.matmul_flops - c.fwd.matmul_flops, 0.5 * s2_mm);
        assert_eq!(d.fwd.vector_flops - c.fwd.vector_flops, 0.5 * s2_vf);
        assert_eq!(d.fwd.vector_bytes - c.fwd.vector_bytes, 0.5 * s2_vb);
        // non-S² work untouched
        let shh = 8.0 * sq * h * h + 4.0 * sq * h * i;
        assert_eq!(c.fwd.matmul_flops, shh + 0.5 * s2_mm);
        // dropout-recompute overhead halves too (triangle-aware kernel)
        let full = OptimizationSet::full();
        let od = dense.summarize(full).overhead;
        let oc = causal.summarize(full).overhead;
        assert_eq!(od.vector_flops - oc.vector_flops, 0.5 * 2.0 * a * sq * sq);
        // GELU overhead (no S² term) identical
        assert_eq!(od.vector_flops - 2.0 * a * sq * sq, oc.vector_flops - a * sq * sq);
    }

    #[test]
    fn gpt2_native_lowering_composes_all_three_rules() {
        let l = Lowering::gpt2_native();
        assert_eq!(l.topology, Topology::PreLn);
        assert!(l.unfused_attention);
        assert!(l.causal_census);
        let g = encoder_block_with(&ModelConfig::gpt2(), l);
        let s = g.summarize(OptimizationSet::none());
        assert!(s.map_elems > 0 && s.fwd.matmul_flops > 0.0);
    }

    #[test]
    fn checkpoint_segment_stores_input_and_doubles_float_transient() {
        let cfg = base();
        let full = encoder_block(&cfg).summarize(OptimizationSet::none());
        let ck = SegmentCheckpoint::of(&full);
        assert_eq!(ck.stored_elems, 128 * 768);
        assert_eq!(ck.stored_bytes(4), 4 * 128 * 768 * 4);
        assert_eq!(ck.transient_bytes(2), full.total_bytes(2) + full.float_bytes(2));
        assert_eq!(ck.recompute_fwd, full.fwd);
    }

    #[test]
    fn superset_tags_are_consistent() {
        // no tensor is both removed_by and added_by; every added tensor
        // has a remover-side counterpart story (mask/rstd swaps)
        for g in [
            encoder_block(&base()),
            embedding_block(&base()),
            mlm_head_block(&base()),
            cls_head_block(&base()),
        ] {
            for op in &g.ops {
                for t in &op.retained {
                    assert!(
                        !(t.removed_by.is_some() && t.added_by.is_some()),
                        "{}.{} is tagged both ways",
                        op.name,
                        t.name
                    );
                }
            }
        }
    }

    #[test]
    fn head_and_embedding_inventories_match_legacy_shapes() {
        let cfg = base();
        let (sq, h, v) = (128u64, 768u64, 30522u64);
        let emb = embedding_block(&cfg).summarize(OptimizationSet::none());
        assert_eq!(emb.map_elems, 3 * sq * h);
        assert_eq!(emb.mask_elems, sq * h);
        assert_eq!(emb.stat_elems, 0); // legacy closed form elides these
        let head = mlm_head_block(&cfg).summarize(OptimizationSet::none());
        assert_eq!(head.map_elems, 5 * sq * h + 2 * sq * v);
        let cls = cls_head_block(&cfg).summarize(OptimizationSet::full());
        assert_eq!(cls.map_elems, 3 * h); // opts don't touch the cls head
    }
}
