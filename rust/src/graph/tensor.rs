//! Retained-tensor descriptions: what a lowered op stashes for backward.
//!
//! Every tensor is declared once, in the *superset* form: the lowering
//! emits the union of everything any rewrite configuration retains, and
//! each entry carries which rewrite removes it (`removed_by`) or which
//! rewrite introduces it (`added_by`). Applying an [`OptimizationSet`]
//! is then a pure filter — no per-technique arithmetic anywhere.

use crate::config::OptimizationSet;

/// Storage class of a retained tensor (paper §3 accounting, footnote 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorClass {
    /// fp32 feature map (4 B/element).
    F32Map,
    /// 1-byte mask (dropout keep-mask, Tempo's GELU sign mask).
    Mask,
    /// Small per-row fp32 statistic (LN mean/var or rstd; 4 B/element).
    RowStat,
}

impl TensorClass {
    /// Storage width in bytes per element.
    pub fn bytes_per_elem(self) -> u64 {
        match self {
            TensorClass::F32Map => 4,
            TensorClass::Mask => 1,
            TensorClass::RowStat => 4,
        }
    }

    /// Display dtype for the Fig 1 table (`f32` / `u8`).
    pub fn dtype_name(self) -> &'static str {
        match self {
            TensorClass::F32Map => "f32",
            TensorClass::Mask => "u8",
            TensorClass::RowStat => "f32",
        }
    }
}

/// One of Tempo's four graph rewrites (§3.1–3.4). Whole-segment
/// checkpointing is a separate, block-level rewrite
/// ([`super::SegmentCheckpoint`]) — it changes *which blocks* retain
/// anything, not the per-op inventory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RewriteKind {
    /// §3.1: swap the retained fp32 GELU input for a 1-byte sign mask.
    InplaceGelu,
    /// §3.2: drop LN inputs + mean/var, keep one per-row rstd.
    InplaceLayerNorm,
    /// §3.3: drop the dropped-probs map, recompute it in backward.
    DropoutRecompute,
    /// §3.4: delete the retained softmax input (scores).
    SoftmaxOutputOnly,
}

impl RewriteKind {
    /// Is this rewrite enabled under `opts`?
    pub fn enabled(self, opts: &OptimizationSet) -> bool {
        match self {
            RewriteKind::InplaceGelu => opts.inplace_gelu,
            RewriteKind::InplaceLayerNorm => opts.inplace_layernorm,
            RewriteKind::DropoutRecompute => opts.dropout_recompute,
            RewriteKind::SoftmaxOutputOnly => opts.softmax_outonly,
        }
    }

    /// Human-readable rewrite name (paper §3 terminology).
    pub fn name(self) -> &'static str {
        match self {
            RewriteKind::InplaceGelu => "in-place GELU",
            RewriteKind::InplaceLayerNorm => "in-place LayerNorm",
            RewriteKind::DropoutRecompute => "dropout recompute",
            RewriteKind::SoftmaxOutputOnly => "output-only softmax",
        }
    }
}

/// One tensor an op retains for its backward pass.
///
/// `dims` are per-batch-item (every retained activation scales linearly
/// in B — the lowering is done once at unit batch and priced at any
/// batch by multiplication, which is what makes the summary cache
/// batch-independent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetainedTensor {
    /// Tensor name, e.g. `attn.scores`.
    pub name: &'static str,
    /// Per-batch-item dimensions (displayed as `B×d0×d1×…`).
    pub dims: Vec<u64>,
    /// Storage class (fp32 map / mask / per-row stat).
    pub class: TensorClass,
    /// `Some(rw)` — this tensor exists in the baseline inventory and is
    /// deleted when `rw` is enabled.
    pub removed_by: Option<RewriteKind>,
    /// `Some(rw)` — this tensor only exists when `rw` is enabled (e.g.
    /// the GELU sign mask, the LN rstd).
    pub added_by: Option<RewriteKind>,
}

impl RetainedTensor {
    /// Baseline tensor, retained under every configuration.
    pub fn always(name: &'static str, dims: Vec<u64>, class: TensorClass) -> Self {
        RetainedTensor { name, dims, class, removed_by: None, added_by: None }
    }

    /// Baseline tensor deleted by `rw`.
    pub fn removed_by(name: &'static str, dims: Vec<u64>, class: TensorClass, rw: RewriteKind) -> Self {
        RetainedTensor { name, dims, class, removed_by: Some(rw), added_by: None }
    }

    /// Tensor introduced by `rw` (absent from the baseline inventory).
    pub fn added_by(name: &'static str, dims: Vec<u64>, class: TensorClass, rw: RewriteKind) -> Self {
        RetainedTensor { name, dims, class, removed_by: None, added_by: Some(rw) }
    }

    /// Elements per batch item.
    pub fn elems(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Bytes per batch item.
    pub fn bytes_per_item(&self) -> u64 {
        self.elems() * self.class.bytes_per_elem()
    }

    /// Is this tensor live (actually retained) under `opts`?
    pub fn live(&self, opts: &OptimizationSet) -> bool {
        if let Some(rw) = self.removed_by {
            if rw.enabled(opts) {
                return false;
            }
        }
        if let Some(rw) = self.added_by {
            if !rw.enabled(opts) {
                return false;
            }
        }
        true
    }

    /// Shape rendered with the symbolic batch dimension: `B×A×S×S`.
    pub fn shape_string(&self) -> String {
        let mut s = String::from("B");
        for d in &self.dims {
            s.push('×');
            s.push_str(&d.to_string());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_widths_match_paper_accounting() {
        assert_eq!(TensorClass::F32Map.bytes_per_elem(), 4);
        assert_eq!(TensorClass::Mask.bytes_per_elem(), 1);
        assert_eq!(TensorClass::RowStat.bytes_per_elem(), 4);
    }

    #[test]
    fn liveness_follows_rewrite_toggles() {
        let gone = RetainedTensor::removed_by(
            "x",
            vec![4, 8],
            TensorClass::F32Map,
            RewriteKind::InplaceGelu,
        );
        let born = RetainedTensor::added_by(
            "m",
            vec![4, 8],
            TensorClass::Mask,
            RewriteKind::InplaceGelu,
        );
        let off = OptimizationSet::none();
        let on = OptimizationSet::only("gelu").unwrap();
        assert!(gone.live(&off) && !gone.live(&on));
        assert!(!born.live(&off) && born.live(&on));
        assert_eq!(gone.elems(), 32);
        assert_eq!(gone.bytes_per_item(), 128);
        assert_eq!(born.bytes_per_item(), 32);
    }

    #[test]
    fn shape_string_prefixes_batch() {
        let t = RetainedTensor::always("t", vec![12, 512, 512], TensorClass::F32Map);
        assert_eq!(t.shape_string(), "B×12×512×512");
    }

    #[test]
    fn every_rewrite_maps_to_one_toggle() {
        let all = [
            RewriteKind::InplaceGelu,
            RewriteKind::InplaceLayerNorm,
            RewriteKind::DropoutRecompute,
            RewriteKind::SoftmaxOutputOnly,
        ];
        for rw in all {
            assert!(!rw.enabled(&OptimizationSet::none()), "{rw:?}");
            assert!(rw.enabled(&OptimizationSet::full()), "{rw:?}");
        }
        // each `only` subset enables exactly one rewrite
        for which in ["gelu", "layernorm", "dropout", "softmax"] {
            let opts = OptimizationSet::only(which).unwrap();
            let n = all.iter().filter(|rw| rw.enabled(&opts)).count();
            assert_eq!(n, 1, "{which}");
        }
    }
}
