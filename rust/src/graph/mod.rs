//! Layer-graph IR: one declarative transformer-block description shared
//! by `memmodel`, `perfmodel`, `autotempo` and the sim backend.
//!
//! The paper's whole argument is an inventory of which tensors a
//! transformer block retains for backward (Fig 1) and what each Tempo
//! technique does to that inventory (§3.1–3.4). This module is that
//! inventory, stated **once**:
//!
//! * `lower` — `ModelConfig` lowers to a typed op graph per block
//!   (`Matmul`, `Softmax`, `Dropout`, `LayerNorm`, `Gelu`, `Residual`),
//!   each op annotated with its retained-for-backward tensors (shape ×
//!   dtype: fp32 map, 1-byte mask, per-row stat) and its forward
//!   FLOP/traffic census. Architecture differences (GPT2's unfused
//!   attention, pre-LN topology, causal-attention census) are lowering
//!   rules, not inline `if`s.
//! * `tensor` — Tempo's four techniques are **graph rewrites**
//!   ([`RewriteKind`]): in-place GELU swaps a retained fp32 map for a
//!   mask, output-only softmax deletes the scores tensor, dropout
//!   recomputation drops a map and adds backward vector work, in-place
//!   LayerNorm trades mean/var + input for one rstd. Whole-segment
//!   checkpointing is the block-level rewrite [`SegmentCheckpoint`].
//! * `memo` — summaries are memoized per
//!   `(block, dims, lowering, rewrite set)` at unit batch (everything
//!   scales linearly in B), so sweeps that re-price thousands of cells
//!   fold cached `Arc<BlockSummary>`s instead of re-lowering.
//! * `table` — the Fig 1 reproduction behind `tempo graph`: every
//!   tensor with shape, dtype, bytes, and which rewrite removed/added
//!   it.
//! * `schedule` + `liveness` — the whole-model chain (embedding →
//!   N blocks → head) lowered to a time-ordered fwd+bwd **event
//!   timeline** with tensor alloc/free edges; rewrites move frees into
//!   the op, `SegmentCheckpoint` moves frees to the block exit and
//!   splices re-forward segments into backward. Peak memory, the step
//!   census and Auto-Tempo's max-batch search are folds over this one
//!   schedule, pinned bit-identical to the legacy static sums by
//!   `tests/schedule_equivalence.rs` (DESIGN.md §Schedule).
//!
//! Consumers fold, they don't recompute: `memmodel` sums retained
//! bytes, `perfmodel` sums op censuses, `autotempo` searches per-layer
//! rewrite plans, and the sim backend prices steps through both. The
//! folds reproduce the pre-refactor closed forms **bit-identically**
//! (every census term is an integer far below 2⁵³, so f64 folds are
//! exact in any order) — pinned by `tests/graph_equivalence.rs` against
//! the old formulas as golden oracles. Adding an architecture or a
//! technique is one lowering rule or one rewrite here, priced and
//! searched everywhere for free — see DESIGN.md §Graph IR.

mod liveness;
mod lower;
mod memo;
mod op;
mod schedule;
mod segment;
mod table;
mod tensor;

pub use lower::{
    cls_head_block, embedding_block, encoder_block, encoder_block_with, mlm_head_block,
    BlockGraph, BlockSummary, Lowering, SegmentCheckpoint, Topology,
};
pub use memo::{
    block_cache_stats, cache_len, checkpoint_summary, embedding_summary, encoder_summary,
    encoder_summary_with, head_summary, CacheStats,
};
pub use liveness::{
    CommBucket, HostTransfer, LaneProfile, LivePoint, LivenessTimeline, ScheduleSummary,
};
pub use op::{Census, Op, OpKind};
pub use schedule::{
    clear_schedule_cache, lower_step, schedule_cache_len, schedule_cache_stats, schedule_summary,
    schedule_summary_with, CkptStyle, EventKind, Lane, MemClass, Residency, SchedTensor,
    ScheduleEvent, SchedulePlan, Segment, StepSchedule, MEM_CLASS_COUNT,
};
pub use table::{block_rows, live_totals, tensor_table, tensor_table_with, ClassTotals, TensorRow};
pub use tensor::{RetainedTensor, RewriteKind, TensorClass};

/// Hit/miss/size counters of every process-global plan-pricing cache,
/// in pricing order: `block` (per-block summaries), `schedule`
/// (whole-plan summaries), `chunk` (per-segment chunk summaries the
/// compositional pricer folds). Surfaced by `tempo placement --stats`
/// and annotated into the bench JSON.
pub fn cache_stats() -> Vec<(&'static str, CacheStats)> {
    vec![
        ("block", block_cache_stats()),
        ("schedule", schedule_cache_stats()),
        ("chunk", segment::chunk_cache_stats()),
    ]
}

/// [`cache_stats`] scoped to the work done since `baseline` (an earlier
/// [`cache_stats`] snapshot): hit/miss counters become deltas via
/// [`CacheStats::since`], entry/byte columns stay absolute. Caches
/// missing from the baseline (e.g. one added after the snapshot was
/// serialized) are reported against a zero baseline.
pub fn cache_stats_since(baseline: &[(&'static str, CacheStats)]) -> Vec<(&'static str, CacheStats)> {
    cache_stats()
        .into_iter()
        .map(|(name, now)| {
            let base = baseline
                .iter()
                .find(|(n, _)| *n == name)
                .map(|(_, s)| *s)
                .unwrap_or_default();
            (name, now.since(&base))
        })
        .collect()
}

/// Drop every cached plan-pricing summary (schedule + chunk caches) —
/// cold-start benchmarking. Block summaries are left in place: they
/// belong to the IR layer, not the plan pricer.
pub fn clear_plan_caches() {
    clear_schedule_cache();
    segment::clear_chunk_cache();
}
