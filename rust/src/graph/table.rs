//! Fig 1 reproduction: the per-layer retained-tensor table.
//!
//! The IR's debugging surface (`tempo graph <model>`): every tensor the
//! lowering declares, with its shape, dtype, bytes at the requested
//! batch, and — when a rewrite set is applied — which rewrite removed
//! or added it.

use crate::config::{ModelConfig, OptimizationSet};

use super::lower::{encoder_block_with, BlockGraph, Lowering};

/// One row of the retained-tensor table.
#[derive(Debug, Clone)]
pub struct TensorRow {
    /// Owning op, e.g. `attn.softmax`.
    pub op: &'static str,
    /// Tensor name, e.g. `attn.scores`.
    pub tensor: &'static str,
    /// `B×…` shape string.
    pub shape: String,
    /// Display dtype (`f32` / `u8`).
    pub dtype: &'static str,
    /// Bytes this tensor occupies (or would occupy) at the batch.
    pub bytes: u64,
    /// Is the tensor actually retained under the applied rewrites?
    pub live: bool,
    /// `retained` / `removed by …` / `added by …`.
    pub status: String,
}

/// Per-class byte totals of the live tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassTotals {
    /// fp32 feature-map bytes.
    pub float_bytes: u64,
    /// 1-byte mask bytes.
    pub mask_bytes: u64,
    /// Per-row statistic bytes.
    pub stat_bytes: u64,
}

impl ClassTotals {
    /// All live bytes (maps + masks + stats).
    pub fn total(&self) -> u64 {
        self.float_bytes + self.mask_bytes + self.stat_bytes
    }
}

/// Retained-tensor rows of one encoder block under `opts` at `batch`,
/// using the model's default lowering.
pub fn tensor_table(cfg: &ModelConfig, opts: OptimizationSet, batch: usize) -> Vec<TensorRow> {
    tensor_table_with(cfg, Lowering::for_model(cfg), opts, batch)
}

/// Retained-tensor rows under explicit lowering rules.
pub fn tensor_table_with(
    cfg: &ModelConfig,
    lowering: Lowering,
    opts: OptimizationSet,
    batch: usize,
) -> Vec<TensorRow> {
    block_rows(&encoder_block_with(cfg, lowering), opts, batch)
}

/// Rows for an arbitrary lowered block (also used for heads).
pub fn block_rows(graph: &BlockGraph, opts: OptimizationSet, batch: usize) -> Vec<TensorRow> {
    let b = batch as u64;
    let mut rows = Vec::new();
    for op in &graph.ops {
        for t in &op.retained {
            let live = t.live(&opts);
            // a rewrite-added tensor that the rewrite set never creates
            // is not part of the story at all — skip it
            if !live && t.added_by.is_some() {
                continue;
            }
            let status = if let Some(rw) = t.added_by {
                format!("added by {}", rw.name())
            } else if let Some(rw) = t.removed_by {
                if live {
                    // removable, but the rewrite is off
                    format!("retained ({} off)", rw.name())
                } else {
                    format!("removed by {}", rw.name())
                }
            } else {
                "retained".to_string()
            };
            rows.push(TensorRow {
                op: op.name,
                tensor: t.name,
                shape: t.shape_string(),
                dtype: t.class.dtype_name(),
                bytes: t.bytes_per_item() * b,
                live,
                status,
            });
        }
    }
    rows
}

/// Per-class totals over the live rows — the same fold
/// `memmodel::layer_activation_bytes` performs, so the table and the
/// capacity model can never disagree.
pub fn live_totals(graph: &BlockGraph, opts: OptimizationSet, batch: usize) -> ClassTotals {
    let s = graph.summarize(opts);
    let b = batch as u64;
    ClassTotals {
        float_bytes: s.float_bytes(b),
        mask_bytes: s.mask_bytes(b),
        stat_bytes: s.stat_bytes(b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encoder_block;
    use crate::memmodel::layer_activation_bytes;

    fn base() -> ModelConfig {
        ModelConfig::bert_base().with_seq_len(128)
    }

    #[test]
    fn baseline_table_has_no_rewrite_rows() {
        let rows = tensor_table(&base(), OptimizationSet::none(), 1);
        assert!(rows.iter().all(|r| r.live));
        assert!(rows.iter().any(|r| r.tensor == "attn.scores"));
        assert!(rows.iter().any(|r| r.tensor == "ffn.gelu_input"));
        // rewrite-added tensors (mask, rstd) are absent from the
        // baseline story
        assert!(!rows.iter().any(|r| r.tensor == "ffn.gelu_mask"));
        assert!(!rows.iter().any(|r| r.tensor == "rstd"));
    }

    #[test]
    fn full_tempo_table_annotates_every_rewrite() {
        let rows = tensor_table(&base(), OptimizationSet::full(), 4);
        let status_of = |name: &str| {
            rows.iter().find(|r| r.tensor == name).map(|r| r.status.clone()).unwrap()
        };
        assert_eq!(status_of("attn.scores"), "removed by output-only softmax");
        assert_eq!(status_of("attn.probs_dropped"), "removed by dropout recompute");
        assert_eq!(status_of("ffn.gelu_input"), "removed by in-place GELU");
        assert_eq!(status_of("ffn.gelu_mask"), "added by in-place GELU");
        assert_eq!(status_of("ln1.input"), "removed by in-place LayerNorm");
        assert_eq!(status_of("rstd"), "added by in-place LayerNorm");
        // bytes scale with the requested batch
        let probs = rows.iter().find(|r| r.tensor == "attn.probs").unwrap();
        assert_eq!(probs.bytes, 4 * 12 * 128 * 128 * 4);
        assert_eq!(probs.shape, "B×12×128×128");
    }

    #[test]
    fn live_totals_match_the_memmodel_fold() {
        for opts in OptimizationSet::all_subsets() {
            for batch in [1usize, 4] {
                let g = encoder_block(&base());
                let t = live_totals(&g, opts, batch);
                let l = layer_activation_bytes(&base(), batch, opts);
                assert_eq!(t.float_bytes, l.float_bytes, "{opts:?} B={batch}");
                assert_eq!(t.mask_bytes, l.mask_bytes, "{opts:?} B={batch}");
                assert_eq!(t.stat_bytes, l.stat_bytes, "{opts:?} B={batch}");
                assert_eq!(t.total(), l.total());
            }
        }
    }
}
