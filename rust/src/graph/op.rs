//! Typed ops and their forward/backward work censuses.

use crate::config::OptimizationSet;

use super::tensor::{RetainedTensor, RewriteKind, TensorClass};

/// The op vocabulary of a transformer block (paper Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // variants are the standard transformer op names
pub enum OpKind {
    Matmul,
    Softmax,
    Dropout,
    LayerNorm,
    Gelu,
    Residual,
}

impl OpKind {
    /// Lower-case op-kind name.
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Matmul => "matmul",
            OpKind::Softmax => "softmax",
            OpKind::Dropout => "dropout",
            OpKind::LayerNorm => "layernorm",
            OpKind::Gelu => "gelu",
            OpKind::Residual => "residual",
        }
    }
}

/// Work census of one op (per batch item).
///
/// Every field is an exactly-representable integer in f64 (products of
/// model dimensions, far below 2⁵³), so folds over ops are exact and
/// order-independent — this is what lets the graph reproduce the legacy
/// closed forms *bit-identically* (see `tests/graph_equivalence.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Census {
    /// Tensor-core matmul FLOPs.
    pub matmul_flops: f64,
    /// CUDA-core elementwise FLOPs.
    pub vector_flops: f64,
    /// HBM bytes moved by bandwidth-bound passes.
    pub vector_bytes: f64,
}

impl Census {
    /// The zero census (no work).
    pub const ZERO: Census = Census { matmul_flops: 0.0, vector_flops: 0.0, vector_bytes: 0.0 };

    /// Pure tensor-core work.
    pub fn matmul(flops: f64) -> Census {
        Census { matmul_flops: flops, ..Census::ZERO }
    }

    /// Pure elementwise work (FLOPs + HBM traffic).
    pub fn vector(flops: f64, bytes: f64) -> Census {
        Census { matmul_flops: 0.0, vector_flops: flops, vector_bytes: bytes }
    }

    /// Componentwise accumulate.
    pub fn add(&mut self, o: Census) {
        self.matmul_flops += o.matmul_flops;
        self.vector_flops += o.vector_flops;
        self.vector_bytes += o.vector_bytes;
    }

    /// Componentwise scale (batch, backward 2×, recompute 1.25×).
    pub fn scale(mut self, f: f64) -> Census {
        self.matmul_flops *= f;
        self.vector_flops *= f;
        self.vector_bytes *= f;
        self
    }
}

/// One lowered op: kind, the tensors its backward needs (superset form,
/// see [`RetainedTensor`]), its forward census, and — for rewrites that
/// trade memory for recompute — the extra backward work the rewrite
/// adds when enabled.
#[derive(Debug, Clone)]
pub struct Op {
    /// Op vocabulary entry.
    pub kind: OpKind,
    /// Instance name in dataflow order, e.g. `ffn.gelu`.
    pub name: &'static str,
    /// Superset retained-tensor inventory (filtered by rewrite sets).
    pub retained: Vec<RetainedTensor>,
    /// Forward work per batch item (backward ≈ 2× forward is applied at
    /// the step level, exactly like the legacy closed form).
    pub fwd: Census,
    /// Extra backward work when the rewrite is enabled (e.g. the GELU
    /// polynomial backward, the dropout-recompute multiply).
    pub overhead: Option<(RewriteKind, Census)>,
}

impl Op {
    /// A new op with its forward census and an empty inventory.
    pub fn new(kind: OpKind, name: &'static str, fwd: Census) -> Op {
        Op { kind, name, retained: Vec::new(), fwd, overhead: None }
    }

    /// Builder: add a retained tensor.
    pub fn retain(mut self, t: RetainedTensor) -> Op {
        self.retained.push(t);
        self
    }

    /// Builder: attach a rewrite's extra backward census.
    pub fn with_overhead(mut self, rw: RewriteKind, c: Census) -> Op {
        self.overhead = Some((rw, c));
        self
    }

    /// Retained elements per batch item of `class` under `opts`.
    pub fn retained_elems(&self, class: TensorClass, opts: &OptimizationSet) -> u64 {
        self.retained
            .iter()
            .filter(|t| t.class == class && t.live(opts))
            .map(|t| t.elems())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimizationSet;

    #[test]
    fn census_fold_is_exact_for_integer_terms() {
        let mut acc = Census::ZERO;
        for c in [Census::matmul(6.0e9), Census::vector(3.0, 12.0), Census::vector(1.0, 8.0)] {
            acc.add(c);
        }
        assert_eq!(acc.matmul_flops, 6.0e9);
        assert_eq!(acc.vector_flops, 4.0);
        assert_eq!(acc.vector_bytes, 20.0);
        let s = acc.scale(3.0);
        assert_eq!(s.vector_bytes, 60.0);
    }

    #[test]
    fn op_filters_retained_by_class_and_opts() {
        let op = Op::new(OpKind::Gelu, "g", Census::ZERO)
            .retain(RetainedTensor::removed_by(
                "in",
                vec![10],
                TensorClass::F32Map,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::added_by(
                "mask",
                vec![10],
                TensorClass::Mask,
                RewriteKind::InplaceGelu,
            ))
            .retain(RetainedTensor::always("out", vec![10], TensorClass::F32Map));
        let off = OptimizationSet::none();
        let on = OptimizationSet::only("gelu").unwrap();
        assert_eq!(op.retained_elems(TensorClass::F32Map, &off), 20);
        assert_eq!(op.retained_elems(TensorClass::Mask, &off), 0);
        assert_eq!(op.retained_elems(TensorClass::F32Map, &on), 10);
        assert_eq!(op.retained_elems(TensorClass::Mask, &on), 10);
    }
}
