//! Memoized block summaries.
//!
//! Sweeps re-price thousands of (config, plan, batch) cells — Table 2
//! alone binary-searches max batch per cell, and Auto-Tempo's fine
//! search prices every prefix plan. Lowering allocates op/tensor
//! vectors, so it runs **once** per distinct
//! `(block kind, dims, lowering, rewrite set)` and the folded
//! [`BlockSummary`] is cached behind an `Arc`. Batch never enters the
//! key: every retained tensor and census term scales linearly in B, so
//! one unit-batch summary prices any batch by multiplication (exact —
//! all values are integers far below 2⁵³).
//!
//! The cache is a process-global `RwLock<HashMap>` shared by all sweep
//! workers (reads dominate; a miss takes the write lock once). Its size
//! is bounded by the number of distinct blocks a run prices — sweep
//! grids, not batches, so a few hundred entries at most.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use crate::config::{ModelConfig, OptimizationSet};

use super::lower::{
    cls_head_block, embedding_block, encoder_block_with, mlm_head_block, BlockSummary, Lowering,
    SegmentCheckpoint,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum BlockType {
    Encoder,
    Embedding,
    MlmHead,
    ClsHead,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct BlockKey {
    block: BlockType,
    hidden: usize,
    heads: usize,
    seq_len: usize,
    intermediate: usize,
    vocab: usize,
    lowering: Lowering,
    opts: OptimizationSet,
}

fn cache() -> &'static RwLock<HashMap<BlockKey, Arc<BlockSummary>>> {
    static CACHE: OnceLock<RwLock<HashMap<BlockKey, Arc<BlockSummary>>>> = OnceLock::new();
    CACHE.get_or_init(|| RwLock::new(HashMap::new()))
}

fn key_for(block: BlockType, cfg: &ModelConfig, lowering: Lowering, opts: OptimizationSet) -> BlockKey {
    BlockKey {
        block,
        hidden: cfg.hidden,
        heads: cfg.heads,
        seq_len: cfg.seq_len,
        intermediate: cfg.intermediate,
        vocab: cfg.vocab_size,
        lowering,
        opts,
    }
}

fn summary(block: BlockType, cfg: &ModelConfig, lowering: Lowering, opts: OptimizationSet) -> Arc<BlockSummary> {
    let key = key_for(block, cfg, lowering, opts);
    if let Some(hit) = cache().read().expect("graph cache poisoned").get(&key) {
        return Arc::clone(hit);
    }
    let graph = match block {
        BlockType::Encoder => encoder_block_with(cfg, lowering),
        BlockType::Embedding => embedding_block(cfg),
        BlockType::MlmHead => mlm_head_block(cfg),
        BlockType::ClsHead => cls_head_block(cfg),
    };
    let built = Arc::new(graph.summarize(opts));
    let mut w = cache().write().expect("graph cache poisoned");
    // a racing worker may have built the same key; first insert wins so
    // every caller shares one Arc
    Arc::clone(w.entry(key).or_insert(built))
}

/// Memoized encoder-block summary under the model's default lowering.
pub fn encoder_summary(cfg: &ModelConfig, opts: OptimizationSet) -> Arc<BlockSummary> {
    summary(BlockType::Encoder, cfg, Lowering::for_model(cfg), opts)
}

/// Memoized encoder-block summary under explicit lowering rules.
pub fn encoder_summary_with(
    cfg: &ModelConfig,
    lowering: Lowering,
    opts: OptimizationSet,
) -> Arc<BlockSummary> {
    summary(BlockType::Encoder, cfg, lowering, opts)
}

/// Memoized embedding-block summary.
pub fn embedding_summary(cfg: &ModelConfig, opts: OptimizationSet) -> Arc<BlockSummary> {
    summary(BlockType::Embedding, cfg, Lowering::for_model(cfg), opts)
}

/// Memoized head summary: MLM (pre-training) or classification
/// (fine-tuning) head.
pub fn head_summary(cfg: &ModelConfig, opts: OptimizationSet, mlm: bool) -> Arc<BlockSummary> {
    let block = if mlm { BlockType::MlmHead } else { BlockType::ClsHead };
    summary(block, cfg, Lowering::for_model(cfg), opts)
}

/// Segment-level checkpoint rewrite of the (unoptimized) encoder block.
pub fn checkpoint_summary(cfg: &ModelConfig) -> SegmentCheckpoint {
    SegmentCheckpoint::of(&encoder_summary(cfg, OptimizationSet::none()))
}

/// Number of distinct lowered blocks currently cached (bench/test
/// introspection).
pub fn cache_len() -> usize {
    cache().read().expect("graph cache poisoned").len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn second_lookup_shares_the_same_arc() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let a = encoder_summary(&cfg, OptimizationSet::full());
        let b = encoder_summary(&cfg, OptimizationSet::full());
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn distinct_opts_and_lowerings_get_distinct_entries() {
        let cfg = ModelConfig::bert_base();
        let none = encoder_summary(&cfg, OptimizationSet::none());
        let full = encoder_summary(&cfg, OptimizationSet::full());
        assert!(!Arc::ptr_eq(&none, &full));
        assert!(none.map_elems > full.map_elems);
        let native = encoder_summary_with(&cfg, Lowering::gpt2_native(), OptimizationSet::none());
        assert!(native.map_elems != 0);
        assert!(!Arc::ptr_eq(&none, &native));
    }

    #[test]
    fn memoized_summary_equals_fresh_lowering() {
        let cfg = ModelConfig::bert_mini();
        for opts in OptimizationSet::all_subsets() {
            let cached = encoder_summary(&cfg, opts);
            let fresh = super::super::lower::encoder_block(&cfg).summarize(opts);
            assert_eq!(*cached, fresh, "{opts:?}");
        }
    }

    #[test]
    fn concurrent_lookups_agree() {
        let cfg = ModelConfig::bert_tiny();
        let summaries: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| encoder_summary(&cfg, OptimizationSet::full())))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for s in &summaries[1..] {
            assert_eq!(**s, *summaries[0]);
        }
    }
}
