//! Memoized block summaries.
//!
//! Sweeps re-price thousands of (config, plan, batch) cells — Table 2
//! alone binary-searches max batch per cell, and Auto-Tempo's fine
//! search prices every prefix plan. Lowering allocates op/tensor
//! vectors, so it runs **once** per distinct
//! `(block kind, dims, lowering, rewrite set)` and the folded
//! [`BlockSummary`] is cached behind an `Arc`. Batch never enters the
//! key: every retained tensor and census term scales linearly in B, so
//! one unit-batch summary prices any batch by multiplication (exact —
//! all values are integers far below 2⁵³).
//!
//! The cache is a process-global [`BoundedCache`] shared by all sweep
//! workers (reads dominate; a miss takes the write lock once). Size is
//! bounded by two-generation rotation — see the type's docs — and the
//! hit/miss/bytes counters surface via [`block_cache_stats`]
//! (`tempo placement --stats`, `BENCH_placement.json`).

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::config::{ModelConfig, OptimizationSet};

use super::lower::{
    cls_head_block, embedding_block, encoder_block_with, mlm_head_block, BlockSummary, Lowering,
    SegmentCheckpoint,
};

/// Hit/miss/size counters of one process-global memo cache, as
/// `tempo placement --stats` and the placement-bench annotations
/// report them (see [`crate::graph::cache_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Distinct entries currently resident (both generations).
    pub entries: usize,
    /// Lookups answered from the cache since process start.
    pub hits: u64,
    /// Lookups that missed and had to build (and insert) a fresh value.
    pub misses: u64,
    /// Approximate heap footprint of the resident values, in bytes.
    pub approx_bytes: u64,
}

impl CacheStats {
    /// Counters scoped to the work done since `baseline` was
    /// snapshotted: the monotone `hits`/`misses` columns become deltas
    /// (saturating, so a stale baseline cannot underflow), while
    /// `entries`/`approx_bytes` stay absolute — they describe what is
    /// resident *now*, not a rate. `tempo placement --stats` and the
    /// placement bench report these scoped rows so one search's cache
    /// behaviour is readable even late in a long-lived process (see
    /// [`crate::graph::cache_stats_since`]).
    pub fn since(&self, baseline: &CacheStats) -> CacheStats {
        CacheStats {
            entries: self.entries,
            hits: self.hits.saturating_sub(baseline.hits),
            misses: self.misses.saturating_sub(baseline.misses),
            approx_bytes: self.approx_bytes,
        }
    }
}

struct Generations<K, V> {
    current: HashMap<K, Arc<V>>,
    previous: HashMap<K, Arc<V>>,
}

/// A bounded process-global memo cache with two-generation eviction.
///
/// Unbounded `RwLock<HashMap>` memoization was fine while a process
/// priced one sweep grid, but a long-lived planner (ROADMAP's
/// "planning as a service") accumulates every distinct plan it ever
/// saw. This cache keeps at most two generations of `cap` entries:
/// when the current generation fills, it *becomes* the previous one
/// (whose entries survive and are promoted back on their next hit)
/// and the old previous generation is dropped wholesale — O(1)
/// amortized eviction, no per-entry LRU bookkeeping, and anything
/// referenced within the last two generations stays resident. Hits
/// return the shared `Arc`, and a racing build is resolved
/// first-insert-wins so every caller still shares one value.
pub(crate) struct BoundedCache<K, V> {
    gens: RwLock<Generations<K, V>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V> BoundedCache<K, V> {
    pub(crate) fn new(cap: usize) -> Self {
        BoundedCache {
            gens: RwLock::new(Generations { current: HashMap::new(), previous: HashMap::new() }),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look `key` up; a hit in the previous generation promotes the
    /// entry back into the current one.
    pub(crate) fn get(&self, key: &K) -> Option<Arc<V>> {
        {
            let g = self.gens.read().expect("memo cache poisoned");
            if let Some(v) = g.current.get(key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(Arc::clone(v));
            }
            if !g.previous.contains_key(key) {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        }
        // promotion takes the write lock; re-check both generations
        // under it (a racing promote or rotation may have moved the
        // entry either way in between)
        let mut g = self.gens.write().expect("memo cache poisoned");
        if let Some(v) = g.current.get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(Arc::clone(v));
        }
        match g.previous.remove_entry(key) {
            Some((k, v)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                let out = Arc::clone(&v);
                Self::rotate_if_full(&mut g, self.cap);
                g.current.insert(k, v);
                Some(out)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert `value` unless a racing worker got there first — the
    /// first insert wins, and the winning `Arc` is returned either way.
    pub(crate) fn insert(&self, key: K, value: Arc<V>) -> Arc<V> {
        let mut g = self.gens.write().expect("memo cache poisoned");
        if let Some(v) = g.current.get(&key) {
            return Arc::clone(v);
        }
        if let Some((k, v)) = g.previous.remove_entry(&key) {
            let out = Arc::clone(&v);
            Self::rotate_if_full(&mut g, self.cap);
            g.current.insert(k, v);
            return out;
        }
        Self::rotate_if_full(&mut g, self.cap);
        g.current.insert(key, Arc::clone(&value));
        value
    }

    fn rotate_if_full(g: &mut Generations<K, V>, cap: usize) {
        if g.current.len() >= cap {
            g.previous = std::mem::take(&mut g.current);
        }
    }

    pub(crate) fn len(&self) -> usize {
        let g = self.gens.read().expect("memo cache poisoned");
        g.current.len() + g.previous.len()
    }

    /// Drop every entry (the bench cold legs); the hit/miss counters
    /// keep counting across clears.
    pub(crate) fn clear(&self) {
        let mut g = self.gens.write().expect("memo cache poisoned");
        g.current.clear();
        g.previous.clear();
    }

    /// Snapshot the counters, pricing each resident value through
    /// `bytes_of` — an O(entries) walk, so stats surfaces only.
    pub(crate) fn stats(&self, bytes_of: impl Fn(&V) -> usize) -> CacheStats {
        let g = self.gens.read().expect("memo cache poisoned");
        let approx: usize =
            g.current.values().chain(g.previous.values()).map(|v| bytes_of(v)).sum();
        CacheStats {
            entries: g.current.len() + g.previous.len(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            approx_bytes: approx as u64,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum BlockType {
    Encoder,
    Embedding,
    MlmHead,
    ClsHead,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct BlockKey {
    block: BlockType,
    hidden: usize,
    heads: usize,
    seq_len: usize,
    intermediate: usize,
    vocab: usize,
    lowering: Lowering,
    opts: OptimizationSet,
}

/// Distinct blocks a process realistically prices at once: preset ×
/// sweep grids land in the low hundreds, so two generations of this
/// never rotate mid-search.
const BLOCK_CACHE_CAP: usize = 2048;

fn cache() -> &'static BoundedCache<BlockKey, BlockSummary> {
    static CACHE: OnceLock<BoundedCache<BlockKey, BlockSummary>> = OnceLock::new();
    CACHE.get_or_init(|| BoundedCache::new(BLOCK_CACHE_CAP))
}

fn key_for(block: BlockType, cfg: &ModelConfig, lowering: Lowering, opts: OptimizationSet) -> BlockKey {
    BlockKey {
        block,
        hidden: cfg.hidden,
        heads: cfg.heads,
        seq_len: cfg.seq_len,
        intermediate: cfg.intermediate,
        vocab: cfg.vocab_size,
        lowering,
        opts,
    }
}

fn summary(block: BlockType, cfg: &ModelConfig, lowering: Lowering, opts: OptimizationSet) -> Arc<BlockSummary> {
    let key = key_for(block, cfg, lowering, opts);
    if let Some(hit) = cache().get(&key) {
        return hit;
    }
    let graph = match block {
        BlockType::Encoder => encoder_block_with(cfg, lowering),
        BlockType::Embedding => embedding_block(cfg),
        BlockType::MlmHead => mlm_head_block(cfg),
        BlockType::ClsHead => cls_head_block(cfg),
    };
    cache().insert(key, Arc::new(graph.summarize(opts)))
}

/// Memoized encoder-block summary under the model's default lowering.
pub fn encoder_summary(cfg: &ModelConfig, opts: OptimizationSet) -> Arc<BlockSummary> {
    summary(BlockType::Encoder, cfg, Lowering::for_model(cfg), opts)
}

/// Memoized encoder-block summary under explicit lowering rules.
pub fn encoder_summary_with(
    cfg: &ModelConfig,
    lowering: Lowering,
    opts: OptimizationSet,
) -> Arc<BlockSummary> {
    summary(BlockType::Encoder, cfg, lowering, opts)
}

/// Memoized embedding-block summary.
pub fn embedding_summary(cfg: &ModelConfig, opts: OptimizationSet) -> Arc<BlockSummary> {
    summary(BlockType::Embedding, cfg, Lowering::for_model(cfg), opts)
}

/// Memoized head summary: MLM (pre-training) or classification
/// (fine-tuning) head.
pub fn head_summary(cfg: &ModelConfig, opts: OptimizationSet, mlm: bool) -> Arc<BlockSummary> {
    let block = if mlm { BlockType::MlmHead } else { BlockType::ClsHead };
    summary(block, cfg, Lowering::for_model(cfg), opts)
}

/// Segment-level checkpoint rewrite of the (unoptimized) encoder block.
pub fn checkpoint_summary(cfg: &ModelConfig) -> SegmentCheckpoint {
    SegmentCheckpoint::of(&encoder_summary(cfg, OptimizationSet::none()))
}

/// Number of distinct lowered blocks currently cached (bench/test
/// introspection).
pub fn cache_len() -> usize {
    cache().len()
}

/// Counters of the block-summary memo cache (`tempo placement
/// --stats`; a [`BlockSummary`] is plain data, so its footprint is its
/// struct size).
pub fn block_cache_stats() -> CacheStats {
    cache().stats(|_| std::mem::size_of::<BlockSummary>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn second_lookup_shares_the_same_arc() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let a = encoder_summary(&cfg, OptimizationSet::full());
        let b = encoder_summary(&cfg, OptimizationSet::full());
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn distinct_opts_and_lowerings_get_distinct_entries() {
        let cfg = ModelConfig::bert_base();
        let none = encoder_summary(&cfg, OptimizationSet::none());
        let full = encoder_summary(&cfg, OptimizationSet::full());
        assert!(!Arc::ptr_eq(&none, &full));
        assert!(none.map_elems > full.map_elems);
        let native = encoder_summary_with(&cfg, Lowering::gpt2_native(), OptimizationSet::none());
        assert!(native.map_elems != 0);
        assert!(!Arc::ptr_eq(&none, &native));
    }

    #[test]
    fn memoized_summary_equals_fresh_lowering() {
        let cfg = ModelConfig::bert_mini();
        for opts in OptimizationSet::all_subsets() {
            let cached = encoder_summary(&cfg, opts);
            let fresh = super::super::lower::encoder_block(&cfg).summarize(opts);
            assert_eq!(*cached, fresh, "{opts:?}");
        }
    }

    #[test]
    fn bounded_cache_rotates_generations_and_counts() {
        let cache: BoundedCache<usize, usize> = BoundedCache::new(2);
        for k in 0..2 {
            assert!(cache.get(&k).is_none());
            cache.insert(k, Arc::new(k));
        }
        // current is full: the next fresh insert rotates it out
        assert!(cache.get(&5).is_none());
        cache.insert(5, Arc::new(5));
        assert_eq!(cache.len(), 3, "rotated generation stays resident");
        // a hit in the previous generation promotes the entry...
        assert_eq!(*cache.get(&0).unwrap(), 0);
        // ...so the next rotation drops only what never came back
        cache.insert(6, Arc::new(6));
        cache.insert(7, Arc::new(7));
        assert!(cache.get(&1).is_none(), "two generations without a hit evicts");
        assert!(cache.get(&0).is_some(), "promoted entry survives the rotation");
        let stats = cache.stats(|_| 8);
        assert_eq!(stats.approx_bytes, 8 * stats.entries as u64);
        assert!(stats.hits >= 2 && stats.misses >= 4, "{stats:?}");
        cache.clear();
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn since_scopes_the_monotone_counters_only() {
        let base = CacheStats { entries: 3, hits: 10, misses: 4, approx_bytes: 96 };
        let now = CacheStats { entries: 5, hits: 25, misses: 7, approx_bytes: 160 };
        let scoped = now.since(&base);
        assert_eq!(scoped.hits, 15);
        assert_eq!(scoped.misses, 3);
        assert_eq!(scoped.entries, 5, "entries stay absolute");
        assert_eq!(scoped.approx_bytes, 160, "bytes stay absolute");
        // a stale (future) baseline saturates instead of wrapping
        let stale = base.since(&now);
        assert_eq!((stale.hits, stale.misses), (0, 0));
    }

    #[test]
    fn first_insert_wins_the_racing_build() {
        let cache: BoundedCache<u32, u32> = BoundedCache::new(8);
        let first = cache.insert(1, Arc::new(10));
        let second = cache.insert(1, Arc::new(99));
        assert!(Arc::ptr_eq(&first, &second));
        assert_eq!(*cache.get(&1).unwrap(), 10);
    }

    #[test]
    fn concurrent_lookups_agree() {
        let cfg = ModelConfig::bert_tiny();
        let summaries: Vec<_> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|_| scope.spawn(|| encoder_summary(&cfg, OptimizationSet::full())))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for s in &summaries[1..] {
            assert_eq!(**s, *summaries[0]);
        }
    }
}
