//! Execution schedule: the graph IR lowered to a fwd+bwd op timeline.
//!
//! The paper's capacity argument (Fig 9/12, Table 2) is a statement
//! about the *peak of a liveness timeline* — which tensors are
//! simultaneously alive at the worst instant of a training step. This
//! module makes that timeline explicit: [`lower_step`] chains the
//! lowered blocks (embedding → N encoder blocks → head) into a
//! time-ordered [`StepSchedule`] of forward and backward op events,
//! each event carrying `alloc`/`free` edges for the tensors it retains
//! or releases. Peak memory, the step work census and Auto-Tempo's
//! max-batch search are all folds over this one schedule
//! (`liveness.rs` holds the folds).
//!
//! Rewrites are **schedule transforms**, not byte arithmetic:
//!
//! * An in-place rewrite (GELU/LN/softmax/dropout §3.1–3.4) moves a
//!   tensor's free *into the op itself*: the tensor still appears on
//!   the event (the forward really materializes it) but is released
//!   before the next op runs ([`ScheduleEvent::inplace`]), and the
//!   replacement tensor (sign mask, rstd) plus the rewrite's backward
//!   census are spliced into the matching events.
//! * [`SegmentCheckpoint`](super::SegmentCheckpoint) semantics move
//!   every free of a block's inventory up to the block's forward exit
//!   (only the stored input survives) and splice a re-forward segment
//!   ([`EventKind::Recompute`], priced at the 1.25× recompute-
//!   inefficiency knob) into the backward, right before the block's
//!   backward events.
//!
//! **Peak-equivalence guarantee.** Under the default semantics the
//! timeline's peak is *bit-identical* to the legacy static sum
//! (`params + grads + optimizer + activations + transient`) for every
//! preset × batch × rewrite subset × technique — pinned by
//! `tests/schedule_equivalence.rs`:
//!
//! * Non-checkpoint: the backward workspace (double-buffered
//!   activation-gradient rows of the widest encoder map, the old
//!   `2 × widest` transient) is allocated at the fwd→bwd turnaround,
//!   while every activation is still retained — that instant *is* the
//!   static sum.
//! * Checkpoint: the first segment's re-forward is prefetched under
//!   the head backward (L2L-style overlap, hiding recompute latency),
//!   so the head activations and one recomputed inventory genuinely
//!   coexist — exactly the `full inventory + float volume` transient
//!   the old closed form charged on top of the head.
//!
//! The one *intentional divergence* is opt-in: [`CkptStyle::Serial`]
//! (via [`SchedulePlan::serial`]) models PyTorch-style serial
//! checkpointing (no prefetch), whose true peak is **lower** than the
//! static sum by exactly `min(head bytes, block inventory)` — the
//! static model double-charged the head activations and the recompute
//! live set, which a serial schedule never holds at once. The
//! equivalence test enumerates and justifies this divergence; the
//! calibrated defaults (Table 2, §4.2 pins) keep the overlapped
//! semantics.
//!
//! **Per-layer placement.** Where a layer's inventory lives is a
//! per-layer arm, not a whole-model switch: every encoder layer
//! independently carries a [`Residency`] (`Resident` |
//! `Checkpoint(Overlapped | Serial)` | `Offload`) next to its rewrite
//! subset, so one plan can checkpoint the bottom blocks, offload the
//! middle and leave rewrites on the rest — the joint search space
//! Auto-Tempo's placement pass explores (`autotempo::placement`,
//! DESIGN.md §Placement). An `Overlapped` layer's re-forward is hoisted
//! above the *preceding* segment's backward (the L2L-style prefetch)
//! unless that segment is itself checkpointed — the model keeps a
//! single re-forward buffer, never a pipeline of them — while a
//! `Serial` layer recomputes strictly in place. Uniform plans reproduce
//! the legacy `checkpoint: bool` semantics bit-identically.
//!
//! **Offload (L2L host streaming).** An [`Residency::Offload`] layer
//! forwards exactly like a resident one — its rewrite subset still
//! applies, shrinking the bytes it ships — then emits one
//! [`EventKind::Store`] on [`Lane::HostLink`] whose `frees` release the
//! layer's entire retained inventory: *frees at store completion*, the
//! Pudipeddi et al. constant-memory discipline. In the backward, one
//! [`EventKind::Load`] re-allocates a fresh inventory of the same
//! shapes immediately before the layer's own backward. The tape
//! position of a host-link event is the transfer's **completion
//! deadline**, not its start: the DMA runs concurrently with the
//! compute ahead of it (the store against the remaining forward, the
//! load against the covering backward window), which is where the
//! latency fold (`perfmodel::plan_lane_times`) credits the overlap and
//! charges only the unhidden tail. Liveness stays lane-blind, so
//! placing the load at its deadline — rather than hoisting it like an
//! `Overlapped` recompute — means converting a layer to `Offload`
//! shrinks the live set at every instant of the step.
//!
//! **Lanes (DESIGN.md §Lanes).** The timeline is no longer one stream:
//! every event carries a [`Lane`] tag. [`Lane::Compute`] is the serial
//! stream (today's timeline, unchanged); [`Lane::Prefetch`] marks the
//! hoisted `Overlapped` re-forwards that run concurrently under the
//! preceding segment's backward. The comm lane is *data*, not events:
//! [`StepSchedule::grad_buckets`] lists the bucketed gradient
//! all-reduce in readiness order (head first, encoder top-down,
//! embedding last — the tied-vocab bucket is both the largest and the
//! last ready). Collective events hold no device memory beyond the
//! resident `grads` tensor, so the liveness fold never sees them; the
//! roofline's exposure fold (`perfmodel::plan_lane_times`) prices them
//! against the concurrent backward. Data-parallel replicas execute the
//! same SPMD timeline, so "one timeline per device" is this schedule ×
//! `GpuSpec::devices`, and every peak is a per-device peak. A
//! single-device/no-collective configuration has an empty comm lane
//! and lowers to the bit-identical pre-lane timeline (same events,
//! peak and census).

use std::sync::{Arc, OnceLock};

use crate::config::{ModelConfig, OptimizationSet, Technique};

use super::liveness::{CommBucket, HostTransfer, ScheduleSummary};
use super::memo::{BoundedCache, CacheStats};
use super::lower::{
    cls_head_block, embedding_block, encoder_block_with, mlm_head_block, BlockGraph, Lowering,
};
use super::op::Census;

/// Memory class of a scheduled allocation — the rows of
/// `memmodel::Breakdown`, now derived from the timeline's high-water
/// instant instead of hand-written.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemClass {
    /// fp32 parameters.
    Params,
    /// fp32 gradients.
    Grads,
    /// Adam `m`+`v` state.
    OptimizerState,
    /// Encoder-layer retained activations (checkpoint: the stored
    /// block inputs).
    EncoderAct,
    /// Embedding + head activations.
    OtherAct,
    /// Backward working set: activation-gradient workspace, in-flight
    /// recompute inventories, forward transients.
    Workspace,
}

/// Number of [`MemClass`] variants (array-indexed folds).
pub const MEM_CLASS_COUNT: usize = 6;

impl MemClass {
    /// Stable array index for fold accumulators.
    pub fn index(self) -> usize {
        match self {
            MemClass::Params => 0,
            MemClass::Grads => 1,
            MemClass::OptimizerState => 2,
            MemClass::EncoderAct => 3,
            MemClass::OtherAct => 4,
            MemClass::Workspace => 5,
        }
    }

    /// Breakdown-row label.
    pub fn name(self) -> &'static str {
        match self {
            MemClass::Params => "params",
            MemClass::Grads => "grads",
            MemClass::OptimizerState => "optimizer",
            MemClass::EncoderAct => "encoder activations",
            MemClass::OtherAct => "other activations",
            MemClass::Workspace => "working set",
        }
    }
}

/// Which model segment a schedule event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Segment {
    /// Model states (params/grads/optimizer), step-lifetime.
    Setup,
    /// The embedding block.
    Embedding,
    /// Encoder layer `l`.
    Encoder(usize),
    /// The MLM/classification head.
    Head,
    /// Step-level events: turnaround, optimizer step.
    Step,
}

impl Segment {
    /// Compact segment label (`emb`, `enc3`, `head`, …).
    pub fn label(self) -> String {
        match self {
            Segment::Setup => "model".into(),
            Segment::Embedding => "emb".into(),
            Segment::Encoder(l) => format!("enc{l}"),
            Segment::Head => "head".into(),
            Segment::Step => "step".into(),
        }
    }
}

/// Which concurrent lane a schedule event occupies.
///
/// The schedule models a step as concurrent streams, not one serial
/// tape: the compute lane is the classic timeline, prefetched
/// checkpoint re-forwards ([`CkptStyle::Overlapped`]) issue on a second
/// stream under the preceding segment's backward, and offloaded
/// layers' store/load DMAs ride the host link. Liveness folds are
/// lane-blind (a tensor's bytes are live whichever lane allocated
/// them); only the latency fold (`perfmodel::plan_lane_times`) treats
/// lanes as concurrent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// The serial compute stream (forward, backward, in-place
    /// recompute, optimizer).
    Compute,
    /// The overlap stream: an `Overlapped` layer's re-forward hoisted
    /// under the preceding segment's backward, which (partially) hides
    /// its latency.
    Prefetch,
    /// The host-link (PCIe/NVLink-host) DMA stream: an `Offload`
    /// layer's inventory store after its forward and load before its
    /// backward ([`GpuSpec::host_link_bw`](crate::config::GpuSpec)).
    HostLink,
    /// The tensor-parallel scale-up interconnect: in-block
    /// [`EventKind::AllGather`]/[`EventKind::ReduceScatter`] collectives
    /// of a [`Residency::Shard`] layer (and the vocab-parallel head),
    /// whose readiness couples to the producing/consuming *op* events
    /// inside the block tape — not to a segment's backward exit like
    /// the gradient buckets
    /// ([`GpuSpec::tp_bw`](crate::config::GpuSpec)).
    TpLink,
}

impl Lane {
    /// Stable lane tag for tables and JSON output (`compute` /
    /// `prefetch` / `host` / `tp`).
    pub fn label(self) -> &'static str {
        match self {
            Lane::Compute => "compute",
            Lane::Prefetch => "prefetch",
            Lane::HostLink => "host",
            Lane::TpLink => "tp",
        }
    }
}

/// What a schedule event does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Model-state residency (start of step).
    Setup,
    /// Forward op.
    Forward,
    /// The fwd→bwd turnaround: the backward workspace is allocated
    /// here, while every retained activation is still alive — the
    /// high-water instant of a non-checkpointed step.
    Turnaround,
    /// Spliced checkpoint re-forward (priced at the 1.25× recompute-
    /// inefficiency knob).
    Recompute,
    /// Backward op (≈ 2× forward work, plus any rewrite overhead).
    Backward,
    /// Optimizer step; releases the backward workspace.
    Optimizer,
    /// Offload store DMA on [`Lane::HostLink`]: ships an `Offload`
    /// layer's inventory to host memory; its `frees` release that
    /// inventory (frees at store completion).
    Store,
    /// Offload load DMA on [`Lane::HostLink`]: re-materializes an
    /// `Offload` layer's inventory right before the layer's backward;
    /// the tape position is the transfer's completion deadline.
    Load,
    /// Tensor-parallel all-gather on [`Lane::TpLink`]: re-materializes
    /// the full activation from its shards at a region entry (QKV
    /// matmul in). Holds no device memory of its own
    /// ([`ScheduleEvent::comm_item_bytes`] is the wire payload); the
    /// tape position is the consuming op's issue point.
    AllGather,
    /// Tensor-parallel reduce-scatter on [`Lane::TpLink`]: reduces the
    /// partial outputs back to shards at a region exit (attention-out,
    /// MLP-out). Same zero-liveness payload discipline as
    /// [`EventKind::AllGather`].
    ReduceScatter,
}

impl EventKind {
    /// Compact event label for the schedule table.
    pub fn label(self) -> &'static str {
        match self {
            EventKind::Setup => "setup",
            EventKind::Forward => "fwd",
            EventKind::Turnaround => "turn",
            EventKind::Recompute => "rfwd",
            EventKind::Backward => "bwd",
            EventKind::Optimizer => "opt",
            EventKind::Store => "store",
            EventKind::Load => "load",
            EventKind::AllGather => "ag",
            EventKind::ReduceScatter => "rs",
        }
    }
}

/// One tensor allocation tracked by the schedule. Activations scale
/// linearly in batch (`item_bytes`); model states do not
/// (`fixed_bytes`). Exactly one of the two is nonzero.
#[derive(Debug, Clone)]
pub struct SchedTensor {
    /// Tensor name (matches the IR's retained-tensor names).
    pub name: &'static str,
    /// Batch-independent bytes (model states).
    pub fixed_bytes: u64,
    /// Bytes per batch item (activations, masks, workspaces).
    pub item_bytes: u64,
    /// Memory class this allocation folds into.
    pub class: MemClass,
}

impl SchedTensor {
    /// Bytes at a concrete batch (`fixed + item·B`, exact).
    pub fn bytes_at(&self, batch: u64) -> u64 {
        self.fixed_bytes + self.item_bytes * batch
    }
}

/// One op event on the timeline.
#[derive(Debug, Clone)]
pub struct ScheduleEvent {
    /// What the event does (fwd/bwd/recompute/…).
    pub kind: EventKind,
    /// Which model segment it belongs to.
    pub segment: Segment,
    /// Op name (matches the IR's op names).
    pub name: &'static str,
    /// Tensors allocated by this event that stay live afterwards.
    pub allocs: Vec<u32>,
    /// Tensors materialized *and released within this event* — a
    /// rewrite moved the free into the op itself (in-place GELU/LN,
    /// output-only softmax, dropout recompute). They count toward this
    /// event's instantaneous live bytes only.
    pub inplace: Vec<u32>,
    /// Tensors released when this event completes (sampled *after*
    /// the event's own liveness, so a backward op still holds what it
    /// is about to free).
    pub frees: Vec<u32>,
    /// Work census per batch item, with the backward 2× / recompute
    /// 1.25× factors already applied (every term stays a multiple of
    /// ¼ far below 2⁵³, so folds remain exact in any order).
    pub census: Census,
    /// Which concurrent lane the event issues on ([`Lane::Compute`]
    /// unless it is a hoisted `Overlapped` re-forward).
    pub lane: Lane,
    /// Wire payload per batch item (bytes) of a [`Lane::TpLink`]
    /// collective — the *full* tensor bytes; the ring factor
    /// `(tp−1)/tp` is applied by the exposure fold. Zero on every
    /// other event: collectives hold no device memory (the
    /// grad-bucket discipline), so liveness never reads this field.
    pub comm_item_bytes: u64,
}

/// The lowered step: a time-ordered event list over a tensor table,
/// plus the comm lane's gradient buckets.
#[derive(Debug, Clone)]
pub struct StepSchedule {
    /// Every allocation the step makes, indexed by the events' ids.
    pub tensors: Vec<SchedTensor>,
    /// The time-ordered event list.
    pub events: Vec<ScheduleEvent>,
    /// The comm lane: bucketed gradient all-reduce in readiness order
    /// (head, encoder top-down, embedding last), each with its
    /// interconnect payload in bytes (fp32 gradients). Bucket bytes sum
    /// exactly to `4·param_count`; the buckets hold no device memory of
    /// their own (the resident `grads` tensor is the payload), so the
    /// liveness fold ignores them and only the exposure fold
    /// (`perfmodel::plan_lane_times`) prices them.
    pub grad_buckets: Vec<(Segment, u64)>,
}

/// Checkpoint scheduling style: where a checkpointed layer's
/// re-forward runs relative to the surrounding backward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CkptStyle {
    /// L2L-style checkpointing: the re-forward is prefetched under the
    /// preceding segment's backward (hides recompute latency; one
    /// recomputed inventory coexists with that segment's live set).
    Overlapped,
    /// PyTorch-style checkpointing: the re-forward runs strictly before
    /// the layer's own backward. Lower peak than `Overlapped` (the
    /// enumerated divergence in `tests/schedule_equivalence.rs`), same
    /// work census.
    Serial,
}

/// Per-layer residency arm: where one encoder layer's retained
/// inventory lives between its forward and its backward. The general
/// axis `placement_search` explores jointly with the rewrite subsets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Residency {
    /// On-device — the layer retains its (possibly rewritten)
    /// inventory until its backward.
    Resident,
    /// Discarded and recomputed: the `SegmentCheckpoint` transform,
    /// with the given re-forward scheduling style.
    Checkpoint(CkptStyle),
    /// Streamed to host memory over [`Lane::HostLink`] after the
    /// layer's forward ([`EventKind::Store`], frees at store
    /// completion) and re-materialized before its backward
    /// ([`EventKind::Load`]). The rewrite subset still applies — it
    /// shrinks the bytes shipped each way.
    Offload,
    /// Tensor-parallel sharded (Megatron-style, sequence-parallel
    /// regions outside the matmul blocks): the layer's retained
    /// inventory and compute census shrink by the plan's resolved
    /// shard degree, and the lowering emits in-block
    /// [`EventKind::AllGather`]/[`EventKind::ReduceScatter`] events on
    /// [`Lane::TpLink`] (QKV matmul in, attention-out and MLP-out
    /// collectives out, mirrored in the backward). Resolves to
    /// [`Residency::Resident`] when the plan's effective `tp` is 1.
    Shard,
}

impl Residency {
    /// Whether this arm applies the segment-checkpoint transform.
    pub fn is_checkpoint(self) -> bool {
        matches!(self, Residency::Checkpoint(_))
    }

    /// Whether this arm streams the inventory over the host link.
    pub fn is_offload(self) -> bool {
        self == Residency::Offload
    }

    /// Whether this arm shards the layer across the TP domain.
    pub fn is_shard(self) -> bool {
        self == Residency::Shard
    }

    /// Short arm label for plan tables
    /// (`-` / `overlap` / `serial` / `offload` / `shard`).
    pub fn label(self) -> &'static str {
        match self {
            Residency::Resident => "-",
            Residency::Checkpoint(CkptStyle::Overlapped) => "overlap",
            Residency::Checkpoint(CkptStyle::Serial) => "serial",
            Residency::Offload => "offload",
            Residency::Shard => "shard",
        }
    }
}

/// What to lower: which rewrites each encoder layer applies, which
/// residency arm each layer takes, and what the embedding/head blocks
/// apply.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulePlan {
    /// Per-encoder-layer rewrite sets (Auto-Tempo's search space).
    /// Shorter-than-model vectors pad the missing layers with
    /// `OptimizationSet::none()`.
    pub per_layer: Vec<OptimizationSet>,
    /// Per-encoder-layer residency arm. A checkpointed layer ignores
    /// its rewrite set (the recompute replays the *unoptimized* block,
    /// like the legacy whole-model checkpoint); an offloaded layer
    /// keeps it (rewrites shrink the shipped bytes). Shorter-than-model
    /// vectors pad the missing layers with [`Residency::Resident`].
    pub residency: Vec<Residency>,
    /// Rewrites applied to the embedding and head blocks.
    pub other: OptimizationSet,
    /// MLM head (pre-training, B·S·V logits) vs classification head.
    pub mlm_head: bool,
    /// Tensor-parallel shard degree (`1`, `2`, `4` or `8`). A degree
    /// the model's dimensions do not permit
    /// ([`ModelConfig::tp_permitted`]) resolves to 1, and at resolved
    /// degree 1 every [`Residency::Shard`] arm resolves to
    /// [`Residency::Resident`] — the lowering is then bit-identical to
    /// the pre-TP timeline. At resolved degree > 1 the head is always
    /// vocab-parallel sharded (its logits dominate capacity), while
    /// encoder layers shard only where their arm says `Shard`.
    pub tp: usize,
}

impl SchedulePlan {
    /// The plan a top-level technique induces (what
    /// `memmodel::ModelFootprint::new` prices). `Technique::Checkpoint`
    /// is the uniform [`CkptStyle::Overlapped`] placement — the legacy
    /// semantics the Table 2 / §4.2 calibration pins price.
    pub fn for_technique(cfg: &ModelConfig, technique: Technique, mlm_head: bool) -> SchedulePlan {
        let opts = match technique {
            Technique::Tempo => OptimizationSet::full(),
            _ => OptimizationSet::none(),
        };
        let residency = if technique == Technique::Checkpoint {
            vec![Residency::Checkpoint(CkptStyle::Overlapped); cfg.layers]
        } else {
            Vec::new()
        };
        SchedulePlan { per_layer: vec![opts; cfg.layers], residency, other: opts, mlm_head, tp: 1 }
    }

    /// Uniform rewrite subset on every block (Fig 12 ablations,
    /// `ModelFootprint::with_opts`).
    pub fn uniform(cfg: &ModelConfig, opts: OptimizationSet, mlm_head: bool) -> SchedulePlan {
        SchedulePlan {
            per_layer: vec![opts; cfg.layers],
            residency: Vec::new(),
            other: opts,
            mlm_head,
            tp: 1,
        }
    }

    /// Auto-Tempo's mixed per-layer rewrite plan (embedding/head stay
    /// at the baseline inventory, like `LayerPlan` pricing always has).
    pub fn from_per_layer(per_layer: Vec<OptimizationSet>, mlm_head: bool) -> SchedulePlan {
        Self::from_placement(per_layer, Vec::new(), mlm_head)
    }

    /// A full joint placement: per-layer rewrite sets plus per-layer
    /// residency arms (embedding/head stay at the baseline inventory).
    pub fn from_placement(
        per_layer: Vec<OptimizationSet>,
        residency: Vec<Residency>,
        mlm_head: bool,
    ) -> SchedulePlan {
        SchedulePlan { per_layer, residency, other: OptimizationSet::none(), mlm_head, tp: 1 }
    }

    /// Builder: set the tensor-parallel shard degree (1/2/4/8;
    /// impermissible degrees resolve to 1 at lowering time).
    pub fn with_tp(mut self, tp: usize) -> SchedulePlan {
        self.tp = tp;
        self
    }

    /// The shard degree the lowering actually uses: `tp` when the
    /// model's dimensions permit it, else 1 (see
    /// [`ModelConfig::tp_permitted`]).
    pub fn resolved_tp(&self, cfg: &ModelConfig) -> usize {
        if self.tp > 1 && cfg.tp_permitted(self.tp) {
            self.tp
        } else {
            1
        }
    }

    /// Builder: switch every overlapped layer to serial (no-prefetch)
    /// checkpoint semantics. A no-op on checkpoint-free plans.
    pub fn serial(mut self) -> SchedulePlan {
        for m in &mut self.residency {
            if *m == Residency::Checkpoint(CkptStyle::Overlapped) {
                *m = Residency::Checkpoint(CkptStyle::Serial);
            }
        }
        self
    }

    /// The residency arm layer `l` takes (missing entries pad to
    /// [`Residency::Resident`]).
    pub fn residency(&self, l: usize) -> Residency {
        self.residency.get(l).copied().unwrap_or(Residency::Resident)
    }

    /// Whether any layer applies the segment-checkpoint transform.
    pub fn any_checkpoint(&self) -> bool {
        self.residency.iter().any(|m| m.is_checkpoint())
    }

    /// Whether any layer streams its inventory over the host link.
    pub fn any_offload(&self) -> bool {
        self.residency.iter().any(|m| m.is_offload())
    }

    /// Number of checkpointed layers.
    pub fn checkpointed_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_checkpoint()).count()
    }

    /// Number of offloaded layers.
    pub fn offloaded_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_offload()).count()
    }

    /// Number of layers carrying the [`Residency::Shard`] arm (before
    /// resolution — at resolved `tp == 1` they lower as resident).
    pub fn sharded_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_shard()).count()
    }

    /// `Some(opts)` when every layer applies the same subset (the
    /// common case; keeps the cache key small).
    fn uniform_opts(&self) -> Option<OptimizationSet> {
        let first = self.per_layer.first().copied().unwrap_or_else(OptimizationSet::none);
        if self.per_layer.iter().all(|o| *o == first) {
            Some(first)
        } else {
            None
        }
    }

    /// Human-readable plan label for reports.
    pub fn label(&self) -> String {
        if self.tp > 1 {
            let base = SchedulePlan { tp: 1, ..self.clone() }.label();
            return format!("{base}, tp={}", self.tp);
        }
        let head = if self.mlm_head { "mlm" } else { "cls" };
        let layers = self.per_layer.len().max(self.residency.len());
        let n_ckpt = self.checkpointed_layers();
        let n_off = self.offloaded_layers();
        if n_off > 0 && n_off == layers {
            return format!("offload, {head} head");
        }
        if n_ckpt > 0 && n_ckpt == layers {
            let mode = if self.residency.iter().all(|m| *m == Residency::Checkpoint(CkptStyle::Serial)) {
                "serial"
            } else {
                "overlapped"
            };
            return format!("checkpoint({mode}), {head} head");
        }
        if n_ckpt > 0 || n_off > 0 {
            let offload_note =
                if n_off > 0 { format!(", {n_off} offloaded") } else { String::new() };
            return format!(
                "mixed placement ({}/{layers} layers optimized, {n_ckpt} checkpointed{offload_note}), {head} head",
                self.per_layer
                    .iter()
                    .zip((0..layers).map(|l| self.residency(l)))
                    .filter(|(o, m)| o.count() > 0 && !m.is_checkpoint())
                    .count(),
            );
        }
        match self.uniform_opts() {
            Some(o) => format!("{}, {head} head", o.label()),
            None => format!(
                "mixed plan ({}/{} layers optimized), {head} head",
                self.per_layer.iter().filter(|o| o.count() > 0).count(),
                self.per_layer.len()
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Builder {
    tensors: Vec<SchedTensor>,
    events: Vec<ScheduleEvent>,
}

impl Builder {
    fn tensor(&mut self, name: &'static str, fixed: u64, item: u64, class: MemClass) -> u32 {
        let id = self.tensors.len() as u32;
        self.tensors.push(SchedTensor { name, fixed_bytes: fixed, item_bytes: item, class });
        id
    }

    #[allow(clippy::too_many_arguments)]
    fn event(
        &mut self,
        kind: EventKind,
        segment: Segment,
        name: &'static str,
        allocs: Vec<u32>,
        inplace: Vec<u32>,
        frees: Vec<u32>,
        census: Census,
    ) {
        self.events.push(ScheduleEvent {
            kind,
            segment,
            name,
            allocs,
            inplace,
            frees,
            census,
            lane: Lane::Compute,
            comm_item_bytes: 0,
        });
    }

    /// One TP collective on [`Lane::TpLink`]: zero device memory, zero
    /// compute census — only the wire payload (full-tensor bytes per
    /// item; the exposure fold applies the ring factor). The tape
    /// position is the producing/consuming op's issue point.
    fn tp_collective(
        &mut self,
        kind: EventKind,
        segment: Segment,
        name: &'static str,
        item_bytes: u64,
    ) {
        self.events.push(ScheduleEvent {
            kind,
            segment,
            name,
            allocs: Vec::new(),
            inplace: Vec::new(),
            frees: Vec::new(),
            census: Census::ZERO,
            lane: Lane::TpLink,
            comm_item_bytes: item_bytes,
        });
    }

    /// Forward pass of one block: each op allocates its retained
    /// tensors; tensors a rewrite deletes become in-place (freed within
    /// the op — the "free moved earlier" transform). Returns the
    /// per-op persistent allocation ids for the backward to release.
    fn forward_block(
        &mut self,
        g: &BlockGraph,
        segment: Segment,
        opts: OptimizationSet,
        class: MemClass,
    ) -> Vec<Vec<u32>> {
        let mut per_op = Vec::with_capacity(g.ops.len());
        for op in &g.ops {
            let mut allocs = Vec::new();
            let mut inplace = Vec::new();
            for t in &op.retained {
                if t.live(&opts) {
                    allocs.push(self.tensor(t.name, 0, t.bytes_per_item(), class));
                } else if t.removed_by.is_some() {
                    // materialized by the forward, released in-op by the
                    // enabled rewrite (rewrite-added tensors whose
                    // rewrite is off never exist at all)
                    inplace.push(self.tensor(t.name, 0, t.bytes_per_item(), MemClass::Workspace));
                }
            }
            self.event(EventKind::Forward, segment, op.name, allocs.clone(), inplace, Vec::new(), op.fwd);
            per_op.push(allocs);
        }
        per_op
    }

    /// Backward pass of one block: reverse op order, ≈ 2× forward work
    /// plus any enabled rewrite's recompute overhead; each op releases
    /// the tensors its forward retained.
    fn backward_block(
        &mut self,
        g: &BlockGraph,
        segment: Segment,
        opts: OptimizationSet,
        per_op: Vec<Vec<u32>>,
    ) {
        for (op, ids) in g.ops.iter().zip(per_op).rev() {
            let mut census = op.fwd.scale(2.0);
            if let Some((rw, c)) = op.overhead {
                if rw.enabled(&opts) {
                    census.add(c);
                }
            }
            self.event(EventKind::Backward, segment, op.name, Vec::new(), Vec::new(), ids, census);
        }
    }

    /// Checkpointed forward of one block: the transform stores the
    /// block input up front, lets the full (unoptimized) inventory
    /// accumulate through the ops, then moves every inventory free up
    /// to the block exit. Returns the stored-input tensor id.
    fn forward_block_checkpoint(&mut self, g: &BlockGraph, segment: Segment) -> u32 {
        let none = OptimizationSet::none();
        let stored = self.tensor("ckpt.stored_input", 0, g.input_elems * 4, MemClass::EncoderAct);
        self.event(EventKind::Forward, segment, "ckpt.store", vec![stored], Vec::new(), Vec::new(), Census::ZERO);
        let mut inventory = Vec::new();
        for op in &g.ops {
            let mut allocs = Vec::new();
            for t in &op.retained {
                if t.live(&none) {
                    allocs.push(self.tensor(t.name, 0, t.bytes_per_item(), MemClass::Workspace));
                }
            }
            inventory.extend(allocs.iter().copied());
            self.event(EventKind::Forward, segment, op.name, allocs, Vec::new(), Vec::new(), op.fwd);
        }
        // frees moved earlier: the whole inventory dies at block exit
        self.event(EventKind::Forward, segment, "ckpt.discard", Vec::new(), Vec::new(), inventory, Census::ZERO);
        stored
    }

    /// Spliced re-forward of a checkpointed block (1.25× the forward
    /// census: RNG restore, cold kernels, extra copies — the recompute-
    /// inefficiency knob the roofline always charged). `lane` is
    /// [`Lane::Prefetch`] for hoisted (overlapped) re-forwards and
    /// [`Lane::Compute`] for in-place (serial) ones. Returns per-op
    /// allocation ids for the block backward to release.
    fn recompute_block(&mut self, g: &BlockGraph, segment: Segment, lane: Lane) -> Vec<Vec<u32>> {
        let none = OptimizationSet::none();
        let mut per_op = Vec::with_capacity(g.ops.len());
        for op in &g.ops {
            let mut allocs = Vec::new();
            for t in &op.retained {
                if t.live(&none) {
                    allocs.push(self.tensor(t.name, 0, t.bytes_per_item(), MemClass::Workspace));
                }
            }
            self.events.push(ScheduleEvent {
                kind: EventKind::Recompute,
                segment,
                name: op.name,
                allocs: allocs.clone(),
                inplace: Vec::new(),
                frees: Vec::new(),
                census: op.fwd.scale(1.25),
                lane,
                comm_item_bytes: 0,
            });
            per_op.push(allocs);
        }
        per_op
    }

    /// Offload store: one DMA on the host link that ships the layer's
    /// whole retained inventory to host memory; its `frees` release
    /// every persistent id the forward allocated (frees at store
    /// completion). The tape position is the transfer's completion
    /// deadline — the DMA itself overlaps the remaining forward.
    fn offload_store(&mut self, segment: Segment, per_op: &[Vec<u32>]) {
        let frees: Vec<u32> = per_op.iter().flatten().copied().collect();
        self.events.push(ScheduleEvent {
            kind: EventKind::Store,
            segment,
            name: "offload.store",
            allocs: Vec::new(),
            inplace: Vec::new(),
            frees,
            census: Census::ZERO,
            lane: Lane::HostLink,
            comm_item_bytes: 0,
        });
    }

    /// Offload load: re-materialize the layer's inventory from host
    /// memory right before its backward. Fresh ids mirror the shipped
    /// tensors' shapes (the in-flight copy is backward working set, so
    /// it folds into [`MemClass::Workspace`]); the per-op structure is
    /// returned so the plain backward releases them op by op.
    fn offload_load(&mut self, segment: Segment, specs: &[Vec<(&'static str, u64)>]) -> Vec<Vec<u32>> {
        let per_op: Vec<Vec<u32>> = specs
            .iter()
            .map(|ops| {
                ops.iter().map(|&(name, item)| self.tensor(name, 0, item, MemClass::Workspace)).collect()
            })
            .collect();
        let allocs: Vec<u32> = per_op.iter().flatten().copied().collect();
        self.events.push(ScheduleEvent {
            kind: EventKind::Load,
            segment,
            name: "offload.load",
            allocs,
            inplace: Vec::new(),
            frees: Vec::new(),
            census: Census::ZERO,
            lane: Lane::HostLink,
            comm_item_bytes: 0,
        });
        per_op
    }

    /// Forward pass of one tensor-parallel sharded block: like
    /// [`Builder::forward_block`] with every retained/in-place tensor
    /// ceil-divided by the shard degree and every op census scaled by
    /// `1/tp` (exact: `tp` is a power of two, so census terms stay
    /// multiples of 1/32 below 2⁵³). With `collectives`, the
    /// sequence-parallel region boundaries emit TpLink events at the
    /// ops that produce/consume the full tensor: an all-gather feeding
    /// the QKV matmul, reduce-scatters draining the attention-out and
    /// MLP-out projections (the head's allreduce pair lives in its
    /// backward instead).
    fn forward_block_shard(
        &mut self,
        g: &BlockGraph,
        segment: Segment,
        opts: OptimizationSet,
        class: MemClass,
        tp: u64,
        collectives: bool,
    ) -> Vec<Vec<u32>> {
        let inv = 1.0 / tp as f64;
        let payload = g.input_elems * 4;
        let mut per_op = Vec::with_capacity(g.ops.len());
        for op in &g.ops {
            if collectives && op.name == "attn.qkv" {
                self.tp_collective(EventKind::AllGather, segment, "tp.allgather", payload);
            }
            let mut allocs = Vec::new();
            let mut inplace = Vec::new();
            for t in &op.retained {
                let item = (t.bytes_per_item() + tp - 1) / tp;
                if t.live(&opts) {
                    allocs.push(self.tensor(t.name, 0, item, class));
                } else if t.removed_by.is_some() {
                    inplace.push(self.tensor(t.name, 0, item, MemClass::Workspace));
                }
            }
            self.event(
                EventKind::Forward,
                segment,
                op.name,
                allocs.clone(),
                inplace,
                Vec::new(),
                op.fwd.scale(inv),
            );
            per_op.push(allocs);
            if collectives && (op.name == "attn.proj_dropout" || op.name == "ffn.fc2_dropout") {
                self.tp_collective(EventKind::ReduceScatter, segment, "tp.reducescatter", payload);
            }
        }
        per_op
    }

    /// Backward pass of one sharded block: reverse op order at `2/tp ×`
    /// forward work (rewrite overheads shard too). With `collectives`
    /// the forward's region boundaries are mirrored (conjugate
    /// collective, reverse order): all-gathers feeding the MLP-out and
    /// attention-out backward, a reduce-scatter draining the QKV
    /// backward. Without (the vocab-parallel head), the input-gradient
    /// allreduce is emitted as a reduce-scatter + all-gather pair after
    /// the block's last backward op.
    #[allow(clippy::too_many_arguments)]
    fn backward_block_shard(
        &mut self,
        g: &BlockGraph,
        segment: Segment,
        opts: OptimizationSet,
        per_op: Vec<Vec<u32>>,
        tp: u64,
        collectives: bool,
    ) {
        let inv = 1.0 / tp as f64;
        let payload = g.input_elems * 4;
        for (op, ids) in g.ops.iter().zip(per_op).rev() {
            if collectives && (op.name == "ffn.fc2_dropout" || op.name == "attn.proj_dropout") {
                self.tp_collective(EventKind::AllGather, segment, "tp.allgather", payload);
            }
            let mut census = op.fwd.scale(2.0 * inv);
            if let Some((rw, c)) = op.overhead {
                if rw.enabled(&opts) {
                    census.add(c.scale(inv));
                }
            }
            self.event(EventKind::Backward, segment, op.name, Vec::new(), Vec::new(), ids, census);
            if collectives && op.name == "attn.qkv" {
                self.tp_collective(EventKind::ReduceScatter, segment, "tp.reducescatter", payload);
            }
        }
        if !collectives {
            // vocab-parallel head: each shard holds a partial input
            // gradient; the ring allreduce is a reduce-scatter followed
            // by an all-gather of the block input
            self.tp_collective(EventKind::ReduceScatter, segment, "tp.reducescatter", payload);
            self.tp_collective(EventKind::AllGather, segment, "tp.allgather", payload);
        }
    }

    /// Backward of a checkpointed block over its recomputed inventory;
    /// the stored input is released with the block's last backward op.
    fn backward_block_checkpoint(
        &mut self,
        g: &BlockGraph,
        segment: Segment,
        per_op: Vec<Vec<u32>>,
        stored: u32,
    ) {
        for (i, (op, mut ids)) in g.ops.iter().zip(per_op).enumerate().rev() {
            if i == 0 {
                ids.push(stored);
            }
            self.event(EventKind::Backward, segment, op.name, Vec::new(), Vec::new(), ids, op.fwd.scale(2.0));
        }
    }
}

/// Lower one full training step of `cfg` under `plan` into a
/// [`StepSchedule`]: embedding → encoder layers → head forward, the
/// turnaround workspace, then the mirrored backward (with checkpoint
/// re-forward segments and offload store/load DMAs spliced in where
/// the plan's per-layer [`Residency`] arms ask for them).
pub fn lower_step(cfg: &ModelConfig, plan: &SchedulePlan, lowering: Lowering) -> StepSchedule {
    /// Forward bookkeeping for one encoder layer: the per-op
    /// retained-tensor ids of a plain layer, the stored-input id of a
    /// checkpointed one, or the shipped tensor shapes (per-op
    /// `(name, item_bytes)`) of an offloaded one.
    enum LayerFwd {
        Plain(Vec<Vec<u32>>),
        Ckpt(u32),
        Offload(Vec<Vec<(&'static str, u64)>>),
        Shard(Vec<Vec<u32>>),
    }

    let mut b = Builder::default();
    let tp = plan.resolved_tp(cfg) as u64;
    let layer_opts =
        |l: usize| plan.per_layer.get(l).copied().unwrap_or_else(OptimizationSet::none);
    // at resolved tp == 1 a Shard arm lowers as Resident — the
    // bit-identity contract tests/tp_equivalence.rs pins
    let mode = |l: usize| match plan.residency(l) {
        Residency::Shard if tp == 1 => Residency::Resident,
        m => m,
    };

    // model states: resident for the whole step
    let p_bytes = cfg.param_count() as u64 * 4;
    let params = b.tensor("params", p_bytes, 0, MemClass::Params);
    let grads = b.tensor("grads", p_bytes, 0, MemClass::Grads);
    let opt = b.tensor("adam.m+v", 2 * p_bytes, 0, MemClass::OptimizerState);
    b.event(
        EventKind::Setup,
        Segment::Setup,
        "step.setup",
        vec![params, grads, opt],
        Vec::new(),
        Vec::new(),
        Census::ZERO,
    );

    // forward
    let emb = embedding_block(cfg);
    let emb_ids = b.forward_block(&emb, Segment::Embedding, plan.other, MemClass::OtherAct);

    let enc = encoder_block_with(cfg, lowering);
    let mut fwd_ids: Vec<LayerFwd> = Vec::with_capacity(cfg.layers);
    for l in 0..cfg.layers {
        match mode(l) {
            Residency::Checkpoint(_) => {
                fwd_ids.push(LayerFwd::Ckpt(b.forward_block_checkpoint(&enc, Segment::Encoder(l))));
            }
            Residency::Offload => {
                // forwards exactly like a resident layer (the rewrite
                // subset applies, shrinking the shipped bytes), then one
                // store DMA frees the whole retained inventory
                let per_op =
                    b.forward_block(&enc, Segment::Encoder(l), layer_opts(l), MemClass::EncoderAct);
                let specs: Vec<Vec<(&'static str, u64)>> = per_op
                    .iter()
                    .map(|ids| {
                        ids.iter()
                            .map(|&id| {
                                let t = &b.tensors[id as usize];
                                (t.name, t.item_bytes)
                            })
                            .collect()
                    })
                    .collect();
                b.offload_store(Segment::Encoder(l), &per_op);
                fwd_ids.push(LayerFwd::Offload(specs));
            }
            Residency::Shard => {
                fwd_ids.push(LayerFwd::Shard(b.forward_block_shard(
                    &enc,
                    Segment::Encoder(l),
                    layer_opts(l),
                    MemClass::EncoderAct,
                    tp,
                    true,
                )));
            }
            Residency::Resident => {
                fwd_ids.push(LayerFwd::Plain(b.forward_block(
                    &enc,
                    Segment::Encoder(l),
                    layer_opts(l),
                    MemClass::EncoderAct,
                )));
            }
        }
    }

    // at resolved tp > 1 the head is always vocab-parallel sharded —
    // its B·S·V logits dominate capacity, so an unsharded head would
    // cap every TP plan at the tp=1 frontier
    let head = if plan.mlm_head { mlm_head_block(cfg) } else { cls_head_block(cfg) };
    let head_ids = if tp > 1 {
        b.forward_block_shard(&head, Segment::Head, plan.other, MemClass::OtherAct, tp, false)
    } else {
        b.forward_block(&head, Segment::Head, plan.other, MemClass::OtherAct)
    };

    // turnaround: the backward workspace appears while everything is
    // still retained — the high-water instant of a plain step
    let full = enc.summarize(OptimizationSet::none());
    // scan the *resolved* layers only (0..cfg.layers): entries of an
    // over-long ckpt vector must not leak into the lowering, or the
    // schedule would diverge from its resolved-semantics cache key
    let any_ckpt = (0..cfg.layers).any(|l| mode(l).is_checkpoint());
    let (ws_name, ws_item) = if any_ckpt {
        // activation gradients flowing through one recomputed block
        // (≈ its float volume again — Table 2's doubled transient).
        // The float volume always covers the plain layers' 2×-widest
        // double buffer (the block retains at least two maps of the
        // widest width), so one shared workspace serves a mixed
        // placement; `max` keeps that explicit.
        ("ckpt.grad_workspace", full.float_bytes(1).max(2 * full.widest_map_elems * 4))
    } else {
        // double-buffered activation-gradient rows of the widest map
        ("bwd.workspace", 2 * full.widest_map_elems * 4)
    };
    let ws = b.tensor(ws_name, 0, ws_item, MemClass::Workspace);
    b.event(EventKind::Turnaround, Segment::Step, "bwd.turnaround", vec![ws], Vec::new(), Vec::new(), Census::ZERO);

    // An `Overlapped` layer's re-forward is hoisted above the preceding
    // segment's backward (head, or the plain layer above it) — the
    // L2L-style prefetch that hides recompute latency and is what the
    // legacy static sum priced all along. A checkpointed layer never
    // prefetches the layer below it: the model keeps a single
    // re-forward buffer, never a pipeline of recomputed inventories.
    let mut pending: Option<(usize, Vec<Vec<u32>>)> = None;
    if cfg.layers > 0 && mode(cfg.layers - 1) == Residency::Checkpoint(CkptStyle::Overlapped) {
        let top = cfg.layers - 1;
        pending = Some((top, b.recompute_block(&enc, Segment::Encoder(top), Lane::Prefetch)));
    }

    // backward
    if tp > 1 {
        b.backward_block_shard(&head, Segment::Head, plan.other, head_ids, tp, false);
    } else {
        b.backward_block(&head, Segment::Head, plan.other, head_ids);
    }
    for l in (0..cfg.layers).rev() {
        match fwd_ids.pop().expect("per-layer forward ids") {
            LayerFwd::Plain(ids) => {
                if l > 0
                    && mode(l - 1) == Residency::Checkpoint(CkptStyle::Overlapped)
                    && pending.is_none()
                {
                    // prefetch the overlapped layer below under this
                    // plain layer's backward
                    pending =
                        Some((l - 1, b.recompute_block(&enc, Segment::Encoder(l - 1), Lane::Prefetch)));
                }
                b.backward_block(&enc, Segment::Encoder(l), layer_opts(l), ids);
            }
            LayerFwd::Shard(ids) => {
                // a sharded layer's backward is an ordinary compute-lane
                // run, so it hosts an Overlapped prefetch below exactly
                // like a plain layer
                if l > 0
                    && mode(l - 1) == Residency::Checkpoint(CkptStyle::Overlapped)
                    && pending.is_none()
                {
                    pending =
                        Some((l - 1, b.recompute_block(&enc, Segment::Encoder(l - 1), Lane::Prefetch)));
                }
                b.backward_block_shard(&enc, Segment::Encoder(l), layer_opts(l), ids, tp, true);
            }
            LayerFwd::Offload(specs) => {
                // the load's tape position is its completion deadline:
                // the DMA overlapped the backward above; the inventory
                // only becomes device-resident here, right before the
                // layer's own backward (liveness never sees a deeper
                // co-residency than the resident twin held)
                let ids = b.offload_load(Segment::Encoder(l), &specs);
                b.backward_block(&enc, Segment::Encoder(l), layer_opts(l), ids);
            }
            LayerFwd::Ckpt(stored) => {
                let ids = match pending.take() {
                    // a pending prefetch is always one segment deep, so
                    // it can only belong to this layer; a violation
                    // would splice the recomputed inventory into the
                    // wrong layer's backward and silently mis-order the
                    // timeline, so this holds in release builds too
                    Some((pl, ids)) => {
                        assert_eq!(
                            pl, l,
                            "prefetch invariant violated: pending re-forward for layer {pl} \
                             consumed by layer {l} (prefetch must be one segment deep)"
                        );
                        ids
                    }
                    // not prefetched (serial arm, or the segment above
                    // was itself checkpointed): recompute in place,
                    // right before this layer's backward
                    None => b.recompute_block(&enc, Segment::Encoder(l), Lane::Compute),
                };
                b.backward_block_checkpoint(&enc, Segment::Encoder(l), ids, stored);
            }
        }
    }
    b.backward_block(&emb, Segment::Embedding, plan.other, emb_ids);

    b.event(EventKind::Optimizer, Segment::Step, "optimizer.step", Vec::new(), Vec::new(), vec![ws], Census::ZERO);

    // the comm lane: gradient buckets in readiness order — a bucket
    // becomes ready when its segment's last backward op completes, so
    // the head fires first, the encoder drains top-down, and the
    // embedding bucket (the tied vocabulary matrix, the largest) is
    // ready only at the very end of backward
    let (emb_params, layer_params, head_params) = cfg.param_count_split();
    let mut grad_buckets = Vec::with_capacity(cfg.layers + 2);
    grad_buckets.push((Segment::Head, head_params as u64 * 4));
    for l in (0..cfg.layers).rev() {
        grad_buckets.push((Segment::Encoder(l), layer_params as u64 * 4));
    }
    grad_buckets.push((Segment::Embedding, emb_params as u64 * 4));

    StepSchedule { tensors: b.tensors, events: b.events, grad_buckets }
}

// ---------------------------------------------------------------------------
// Memoization: sweeps price thousands of (plan, batch) cells; one
// summary per distinct (dims, lowering, plan) prices any batch (all
// activations scale linearly in B, states are batch-free, and the
// argmax instant is batch-independent because the batch-free part of
// the curve is constant over the step).
// ---------------------------------------------------------------------------

/// The plan's *resolved* per-layer semantics — exactly what
/// `lower_step` sees after padding short vectors: one
/// `(rewrite set, residency arm)` pair per model layer. Keying on the
/// resolution (not the representation) lets every spelling of the same
/// placement share one cache entry, and collapses the common uniform
/// case to a single pair.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum PlanKey {
    Uniform(OptimizationSet, Residency),
    PerLayer(Vec<(OptimizationSet, Residency)>),
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ScheduleKey {
    hidden: usize,
    heads: usize,
    seq_len: usize,
    intermediate: usize,
    vocab: usize,
    max_position: usize,
    type_vocab: usize,
    layers: usize,
    lowering: Lowering,
    plan: PlanKey,
    other: OptimizationSet,
    mlm_head: bool,
    /// Resolved shard degree (1 unless the plan's `tp` is permitted),
    /// so every spelling that lowers identically shares one entry.
    tp: usize,
}

/// Generation-bounded summary cache: placement sweeps touch thousands
/// of arms, but two retained generations of this size keep every arm
/// of the active search warm (a BERT-LARGE joint family is ~1.5k).
const SCHEDULE_CACHE_CAP: usize = 8192;

fn schedule_cache() -> &'static BoundedCache<ScheduleKey, ScheduleSummary> {
    static CACHE: OnceLock<BoundedCache<ScheduleKey, ScheduleSummary>> = OnceLock::new();
    CACHE.get_or_init(|| BoundedCache::new(SCHEDULE_CACHE_CAP))
}

/// Memoized step-schedule summary under the model's default lowering.
pub fn schedule_summary(cfg: &ModelConfig, plan: &SchedulePlan) -> Arc<ScheduleSummary> {
    schedule_summary_with(cfg, plan, Lowering::for_model(cfg))
}

/// Memoized step-schedule summary under explicit lowering rules.
pub fn schedule_summary_with(
    cfg: &ModelConfig,
    plan: &SchedulePlan,
    lowering: Lowering,
) -> Arc<ScheduleSummary> {
    let tp = plan.resolved_tp(cfg);
    let resolved: Vec<(OptimizationSet, Residency)> = (0..cfg.layers)
        .map(|l| {
            let m = match plan.residency(l) {
                Residency::Shard if tp == 1 => Residency::Resident,
                m => m,
            };
            (plan.per_layer.get(l).copied().unwrap_or_else(OptimizationSet::none), m)
        })
        .collect();
    let plan_key = match resolved.first().copied() {
        None => PlanKey::Uniform(OptimizationSet::none(), Residency::Resident),
        Some(first) if resolved.iter().all(|p| *p == first) => PlanKey::Uniform(first.0, first.1),
        _ => PlanKey::PerLayer(resolved.clone()),
    };
    let key = ScheduleKey {
        hidden: cfg.hidden,
        heads: cfg.heads,
        seq_len: cfg.seq_len,
        intermediate: cfg.intermediate,
        vocab: cfg.vocab_size,
        max_position: cfg.max_position,
        type_vocab: cfg.type_vocab,
        layers: cfg.layers,
        lowering,
        plan: plan_key,
        other: plan.other,
        mlm_head: plan.mlm_head,
        tp,
    };
    if let Some(hit) = schedule_cache().get(&key) {
        return hit;
    }
    // compose the summary from cached per-chunk summaries — the
    // donor-sliced fold in `graph::segment`, bit-identical to
    // `lower_step(cfg, plan, lowering).summarize_step()` (the oracle
    // `tests/incremental_pricing.rs` pins) at a fraction of the cost
    let built = Arc::new(super::segment::composed_summary(
        cfg,
        &resolved,
        plan.other,
        plan.mlm_head,
        tp,
        lowering,
    ));
    // first insert wins so racing workers share one Arc
    schedule_cache().insert(key, built)
}

/// Number of distinct lowered schedules currently cached (bench/test
/// introspection).
pub fn schedule_cache_len() -> usize {
    schedule_cache().len()
}

/// Hit/miss/size counters of the schedule-summary cache
/// (`tempo placement --stats`, bench annotations).
pub fn schedule_cache_stats() -> CacheStats {
    schedule_cache().stats(|s| {
        std::mem::size_of::<ScheduleSummary>()
            + s.lanes.buckets.len() * std::mem::size_of::<CommBucket>()
            + (s.lanes.stores.len() + s.lanes.loads.len() + s.lanes.tp_links.len())
                * std::mem::size_of::<HostTransfer>()
    })
}

/// Drop every cached schedule summary (cold-start benchmarking; the
/// per-chunk cache is cleared separately via
/// [`clear_plan_caches`](super::clear_plan_caches)).
pub fn clear_schedule_cache() {
    schedule_cache().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny() -> ModelConfig {
        ModelConfig::bert_tiny()
    }

    #[test]
    fn schedule_is_time_ordered_fwd_then_bwd() {
        let cfg = tiny();
        let plan = SchedulePlan::for_technique(&cfg, Technique::Tempo, true);
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let turn = s
            .events
            .iter()
            .position(|e| e.kind == EventKind::Turnaround)
            .expect("one turnaround");
        assert!(s.events[..turn]
            .iter()
            .all(|e| matches!(e.kind, EventKind::Setup | EventKind::Forward)));
        assert!(s.events[turn + 1..]
            .iter()
            .all(|e| matches!(e.kind, EventKind::Backward | EventKind::Recompute | EventKind::Optimizer)));
        assert_eq!(s.events.last().unwrap().kind, EventKind::Optimizer);
    }

    #[test]
    fn every_alloc_is_freed_exactly_once() {
        for technique in Technique::all() {
            let cfg = tiny();
            let plan = SchedulePlan::for_technique(&cfg, technique, true);
            let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
            let mut allocated = vec![0u32; s.tensors.len()];
            let mut freed = vec![0u32; s.tensors.len()];
            let mut inplace = vec![0u32; s.tensors.len()];
            for e in &s.events {
                for &id in &e.allocs {
                    allocated[id as usize] += 1;
                }
                for &id in &e.frees {
                    freed[id as usize] += 1;
                }
                for &id in &e.inplace {
                    inplace[id as usize] += 1;
                }
            }
            for (id, t) in s.tensors.iter().enumerate() {
                if inplace[id] > 0 {
                    // rewritten-away tensors live only inside their op
                    assert_eq!((allocated[id], freed[id], inplace[id]), (0, 0, 1), "{}", t.name);
                } else if matches!(t.class, MemClass::Params | MemClass::Grads | MemClass::OptimizerState) {
                    assert_eq!((allocated[id], freed[id]), (1, 0), "{} persists", t.name);
                } else {
                    assert_eq!((allocated[id], freed[id]), (1, 1), "{technique:?} {}", t.name);
                }
            }
        }
    }

    #[test]
    fn rewrites_move_frees_into_the_op() {
        let cfg = tiny();
        let full = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
        let s = lower_step(&cfg, &full, Lowering::for_model(&cfg));
        let gelu_fwd = s
            .events
            .iter()
            .find(|e| e.kind == EventKind::Forward && e.name == "ffn.gelu" && e.segment == Segment::Encoder(0))
            .expect("gelu fwd event");
        // the removed fp32 input is in-op; the added mask persists
        let inplace_names: Vec<&str> =
            gelu_fwd.inplace.iter().map(|&id| s.tensors[id as usize].name).collect();
        let alloc_names: Vec<&str> =
            gelu_fwd.allocs.iter().map(|&id| s.tensors[id as usize].name).collect();
        assert!(inplace_names.contains(&"ffn.gelu_input"));
        assert!(alloc_names.contains(&"ffn.gelu_mask"));
        assert!(alloc_names.contains(&"ffn.gelu_output"));
        // baseline: no in-op frees anywhere
        let base = SchedulePlan::uniform(&cfg, OptimizationSet::none(), true);
        let s0 = lower_step(&cfg, &base, Lowering::for_model(&cfg));
        assert!(s0.events.iter().all(|e| e.inplace.is_empty()));
    }

    #[test]
    fn checkpoint_splices_recompute_and_discards_at_exit() {
        let cfg = tiny();
        let plan = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let n_recompute = s.events.iter().filter(|e| e.kind == EventKind::Recompute).count();
        let ops_per_block = encoder_block_with(&cfg, Lowering::for_model(&cfg)).ops.len();
        assert_eq!(n_recompute, cfg.layers * ops_per_block);
        // the prefetched (overlapped) re-forward of the top layer runs
        // before the head backward
        let first_rfwd = s.events.iter().position(|e| e.kind == EventKind::Recompute).unwrap();
        let first_bwd = s.events.iter().position(|e| e.kind == EventKind::Backward).unwrap();
        assert!(first_rfwd < first_bwd, "overlapped prefetch precedes head bwd");
        assert_eq!(s.events[first_rfwd].segment, Segment::Encoder(cfg.layers - 1));
        // serial semantics: head backward comes first
        let serial = lower_step(&cfg, &plan.clone().serial(), Lowering::for_model(&cfg));
        let first_rfwd = serial.events.iter().position(|e| e.kind == EventKind::Recompute).unwrap();
        let first_bwd = serial.events.iter().position(|e| e.kind == EventKind::Backward).unwrap();
        assert!(first_bwd < first_rfwd, "serial checkpoint recomputes after head bwd");
        // every block forward ends with the inventory discard
        let discards = s.events.iter().filter(|e| e.name == "ckpt.discard").count();
        assert_eq!(discards, cfg.layers);
    }

    #[test]
    fn memoized_summary_shares_one_arc_and_matches_fresh() {
        let cfg = ModelConfig::bert_mini();
        let plan = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
        let a = schedule_summary(&cfg, &plan);
        let b = schedule_summary(&cfg, &plan);
        assert!(Arc::ptr_eq(&a, &b));
        let fresh = lower_step(&cfg, &plan, Lowering::for_model(&cfg)).summarize_step();
        assert_eq!(a.peak_bytes(4), fresh.peak_bytes(4));
        assert_eq!(a.peak_event, fresh.peak_event);
    }

    #[test]
    fn short_uniform_plan_is_not_cached_as_the_full_uniform_plan() {
        // an all-equal per_layer vector shorter than the model pads the
        // missing layers with `none`; it must get its own cache entry
        // (the key holds the plan's *resolved* per-layer semantics, and
        // the padded resolution is not uniform)
        let cfg = ModelConfig::bert_mini(); // 4 layers
        let full = SchedulePlan::uniform(&cfg, OptimizationSet::full(), true);
        let short = SchedulePlan {
            per_layer: vec![OptimizationSet::full(); 2],
            ..full.clone()
        };
        let a = schedule_summary(&cfg, &short);
        let b = schedule_summary(&cfg, &full);
        assert!(!Arc::ptr_eq(&a, &b));
        // padded layers retain the baseline inventory, so the short
        // plan's peak is strictly higher
        assert!(a.peak_bytes(4) > b.peak_bytes(4));
        let fresh = lower_step(&cfg, &short, Lowering::for_model(&cfg)).summarize_step();
        assert_eq!(a.peak_bytes(4), fresh.peak_bytes(4));
    }

    #[test]
    fn over_long_ckpt_vector_does_not_leak_into_the_lowering() {
        // a ckpt vector sized for a bigger model: entries beyond the
        // model's layers are ignored by the lowering, so the plan
        // lowers (and caches) exactly like the checkpoint-free plan
        // its resolved semantics name
        let cfg = tiny(); // 2 layers
        let long = SchedulePlan {
            residency: vec![
                Residency::Resident,
                Residency::Resident,
                Residency::Checkpoint(CkptStyle::Overlapped),
            ],
            ..SchedulePlan::uniform(&cfg, OptimizationSet::none(), true)
        };
        let plain = SchedulePlan::uniform(&cfg, OptimizationSet::none(), true);
        let a = schedule_summary(&cfg, &long);
        let b = schedule_summary(&cfg, &plain);
        assert!(Arc::ptr_eq(&a, &b), "same resolved semantics share one cache entry");
        let fresh = lower_step(&cfg, &long, Lowering::for_model(&cfg)).summarize_step();
        assert_eq!(a.peak_bytes(4), fresh.peak_bytes(4));
        assert_eq!(a.events, fresh.events);
        assert_eq!(fresh.high_water, "bwd working set");
    }

    #[test]
    fn plan_labels_read_well() {
        let cfg = tiny();
        assert!(SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true)
            .label()
            .contains("overlapped"));
        assert!(SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true)
            .serial()
            .label()
            .contains("serial"));
        let mut per_layer = vec![OptimizationSet::none(); cfg.layers];
        per_layer[0] = OptimizationSet::full();
        assert!(SchedulePlan::from_per_layer(per_layer, false).label().contains("mixed"));
        // a joint placement names both counts
        let mut residency = vec![Residency::Resident; cfg.layers];
        residency[0] = Residency::Checkpoint(CkptStyle::Serial);
        let mut per_layer = vec![OptimizationSet::full(); cfg.layers];
        per_layer[0] = OptimizationSet::none();
        let label = SchedulePlan::from_placement(per_layer, residency, true).label();
        assert!(label.contains("mixed placement"), "{label}");
        assert!(label.contains("1 checkpointed"), "{label}");
        // offload arms name themselves too
        let label = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            vec![Residency::Offload; cfg.layers],
            true,
        )
        .label();
        assert!(label.contains("offload"), "{label}");
        let mut residency = vec![Residency::Resident; cfg.layers];
        residency[0] = Residency::Offload;
        let label = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            residency,
            true,
        )
        .label();
        assert!(label.contains("1 offloaded"), "{label}");
    }

    #[test]
    fn mixed_placement_lowers_each_layer_under_its_own_arm() {
        // bottom layer checkpointed, top layer plain: the forward holds
        // one ckpt.store + one plain inventory, and the backward splices
        // exactly one recompute segment
        let cfg = tiny(); // 2 layers
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            vec![Residency::Checkpoint(CkptStyle::Serial), Residency::Resident],
            true,
        );
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let stores = s.events.iter().filter(|e| e.name == "ckpt.store").count();
        assert_eq!(stores, 1);
        let ops_per_block = encoder_block_with(&cfg, Lowering::for_model(&cfg)).ops.len();
        let n_recompute = s.events.iter().filter(|e| e.kind == EventKind::Recompute).count();
        assert_eq!(n_recompute, ops_per_block);
        // the plain layer's rewrites still apply (in-op frees exist in
        // its segment; none in the checkpointed layer's forward)
        assert!(s
            .events
            .iter()
            .any(|e| e.segment == Segment::Encoder(1) && !e.inplace.is_empty()));
        assert!(s
            .events
            .iter()
            .filter(|e| e.segment == Segment::Encoder(0) && e.kind == EventKind::Forward)
            .all(|e| e.inplace.is_empty()));
    }

    #[test]
    fn overlapped_arm_prefetches_under_the_preceding_plain_backward() {
        // layer 0 overlapped, layer 1 plain: the recompute must be
        // emitted after the turnaround but BEFORE layer 1's backward
        let cfg = tiny();
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Checkpoint(CkptStyle::Overlapped), Residency::Resident],
            true,
        );
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let first_rfwd = s.events.iter().position(|e| e.kind == EventKind::Recompute).unwrap();
        let first_enc1_bwd = s
            .events
            .iter()
            .position(|e| e.kind == EventKind::Backward && e.segment == Segment::Encoder(1))
            .unwrap();
        assert!(first_rfwd < first_enc1_bwd, "overlapped prefetch precedes the plain backward");
        // serial arm: the recompute waits until after layer 1's backward
        let serial = plan.serial();
        let s = lower_step(&cfg, &serial, Lowering::for_model(&cfg));
        let first_rfwd = s.events.iter().position(|e| e.kind == EventKind::Recompute).unwrap();
        let last_enc1_bwd = s
            .events
            .iter()
            .rposition(|e| e.kind == EventKind::Backward && e.segment == Segment::Encoder(1))
            .unwrap();
        assert!(first_rfwd > last_enc1_bwd, "serial recompute follows the plain backward");
        // and the serial placement's peak is never above the overlapped one
        let over = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Checkpoint(CkptStyle::Overlapped), Residency::Resident],
            true,
        );
        assert!(
            schedule_summary(&cfg, &serial).peak_bytes(4)
                <= schedule_summary(&cfg, &over).peak_bytes(4)
        );
    }

    #[test]
    fn checkpointed_layers_never_pipeline_recomputes() {
        // two adjacent overlapped layers: only the top one is
        // prefetched (under the head backward); the lower one
        // recomputes after the top layer's backward completes — at most
        // one recomputed inventory is ever in flight
        let cfg = tiny();
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::none(); cfg.layers],
            vec![Residency::Checkpoint(CkptStyle::Overlapped); cfg.layers],
            true,
        );
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let enc0_rfwd = s
            .events
            .iter()
            .position(|e| e.kind == EventKind::Recompute && e.segment == Segment::Encoder(0))
            .unwrap();
        let last_enc1_bwd = s
            .events
            .iter()
            .rposition(|e| e.kind == EventKind::Backward && e.segment == Segment::Encoder(1))
            .unwrap();
        assert!(enc0_rfwd > last_enc1_bwd);
    }

    #[test]
    fn offload_stores_free_at_completion_and_loads_meet_their_deadline() {
        let cfg = tiny();
        let plan = SchedulePlan::from_placement(
            vec![OptimizationSet::full(); cfg.layers],
            vec![Residency::Offload; cfg.layers],
            true,
        );
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let stores: Vec<usize> = (0..s.events.len())
            .filter(|&i| s.events[i].kind == EventKind::Store)
            .collect();
        let loads: Vec<usize> = (0..s.events.len())
            .filter(|&i| s.events[i].kind == EventKind::Load)
            .collect();
        assert_eq!(stores.len(), cfg.layers);
        assert_eq!(loads.len(), cfg.layers);
        let shipped = |seg: Segment, ids: &[u32]| -> u64 {
            assert!(!ids.is_empty(), "{seg:?}: empty transfer");
            ids.iter().map(|&id| s.tensors[id as usize].item_bytes).sum()
        };
        for &i in &stores {
            let e = &s.events[i];
            // a DMA holds no device memory of its own and does no
            // compute-lane work; its frees are the whole inventory the
            // segment's forward retained (frees at store completion)
            assert_eq!(e.lane, Lane::HostLink);
            assert!(e.allocs.is_empty() && e.inplace.is_empty());
            assert_eq!(e.census, Census::ZERO);
            let fwd_persistent: Vec<u32> = s
                .events
                .iter()
                .filter(|x| x.kind == EventKind::Forward && x.segment == e.segment)
                .flat_map(|x| x.allocs.iter().copied())
                .collect();
            assert_eq!(e.frees, fwd_persistent, "{:?}", e.segment);
        }
        for (&i, &j) in loads.iter().zip(&stores) {
            let e = &s.events[i];
            assert_eq!(e.lane, Lane::HostLink);
            assert!(e.frees.is_empty() && e.inplace.is_empty());
            // the load's tape position is its completion deadline:
            // immediately before its own segment's first backward op
            let own_bwd = s
                .events
                .iter()
                .position(|x| x.kind == EventKind::Backward && x.segment == e.segment)
                .unwrap();
            assert_eq!(i + 1, own_bwd, "{:?}", e.segment);
            // round trip: the load re-materializes exactly the bytes
            // the store shipped
            let st = &s.events[j];
            assert_eq!(st.segment, e.segment);
            assert_eq!(shipped(e.segment, &st.frees), shipped(e.segment, &e.allocs));
        }
        // rewrites compose: the full subset ships strictly fewer bytes
        // than the baseline inventory
        let base = lower_step(
            &cfg,
            &SchedulePlan::from_placement(
                vec![OptimizationSet::none(); cfg.layers],
                vec![Residency::Offload; cfg.layers],
                true,
            ),
            Lowering::for_model(&cfg),
        );
        let total_shipped = |sched: &StepSchedule| -> u64 {
            sched
                .events
                .iter()
                .filter(|e| e.kind == EventKind::Store)
                .flat_map(|e| e.frees.iter().map(|&id| sched.tensors[id as usize].item_bytes))
                .sum()
        };
        assert!(total_shipped(&s) < total_shipped(&base));
    }

    #[test]
    fn lanes_tag_hoisted_prefetches_only() {
        let cfg = tiny();
        // overlapped uniform: the top layer's re-forward is hoisted
        // (Prefetch lane); the in-place recomputes below stay Compute
        let plan = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        for e in &s.events {
            if e.lane == Lane::Prefetch {
                assert_eq!(e.kind, EventKind::Recompute, "{}", e.name);
                assert_eq!(e.segment, Segment::Encoder(cfg.layers - 1));
            }
        }
        assert!(s.events.iter().any(|e| e.lane == Lane::Prefetch));
        assert!(s
            .events
            .iter()
            .any(|e| e.kind == EventKind::Recompute && e.lane == Lane::Compute));
        // serial uniform: nothing is hoisted, every event is Compute
        let serial = lower_step(&cfg, &plan.serial(), Lowering::for_model(&cfg));
        assert!(serial.events.iter().all(|e| e.lane == Lane::Compute));
        // a prefetch-lane event always precedes its own segment's
        // backward (it hides under the *preceding* segment's backward)
        let pf = s
            .events
            .iter()
            .position(|e| e.lane == Lane::Prefetch)
            .unwrap();
        let own_bwd = s
            .events
            .iter()
            .position(|e| {
                e.kind == EventKind::Backward && e.segment == Segment::Encoder(cfg.layers - 1)
            })
            .unwrap();
        assert!(pf < own_bwd);
    }

    #[test]
    fn grad_buckets_cover_every_parameter_in_readiness_order() {
        let cfg = ModelConfig::bert_mini();
        let plan = SchedulePlan::uniform(&cfg, OptimizationSet::none(), true);
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        assert_eq!(s.grad_buckets.len(), cfg.layers + 2);
        assert_eq!(s.grad_buckets.first().unwrap().0, Segment::Head);
        assert_eq!(s.grad_buckets.last().unwrap().0, Segment::Embedding);
        // encoder buckets drain top-down between head and embedding
        for (i, l) in (0..cfg.layers).rev().enumerate() {
            assert_eq!(s.grad_buckets[1 + i].0, Segment::Encoder(l));
        }
        let total: u64 = s.grad_buckets.iter().map(|(_, b)| b).sum();
        assert_eq!(total, cfg.param_count() as u64 * 4);
        // readiness order matches the backward's actual segment order:
        // each bucket's last backward event is later than the previous
        // bucket's
        let last_bwd = |seg: Segment| {
            s.events
                .iter()
                .rposition(|e| e.kind == EventKind::Backward && e.segment == seg)
                .unwrap_or_else(|| panic!("no backward for {seg:?}"))
        };
        let mut prev = 0usize;
        for &(seg, _) in &s.grad_buckets {
            let at = last_bwd(seg);
            assert!(at >= prev, "{seg:?} ready out of order");
            prev = at;
        }
    }

    #[test]
    fn prefetch_invariant_holds_across_all_mixed_placements() {
        // ISSUE 6 satellite: the one-segment-deep prefetch check is a
        // real (release-mode) assert now. Exhaustively lower every
        // 4^4 per-layer arm combination on the 4-layer model: each one
        // must lower cleanly, keep at most one recomputed inventory in
        // flight, and place every prefetch-lane event after the
        // turnaround and before its own segment's backward.
        let cfg = ModelConfig::bert_mini();
        let arms = [
            Residency::Resident,
            Residency::Checkpoint(CkptStyle::Overlapped),
            Residency::Checkpoint(CkptStyle::Serial),
            Residency::Offload,
        ];
        for a in arms {
            for bm in arms {
                for c in arms {
                    for d in arms {
                        let plan = SchedulePlan::from_placement(
                            vec![OptimizationSet::full(); cfg.layers],
                            vec![a, bm, c, d],
                            true,
                        );
                        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
                        let turn = s
                            .events
                            .iter()
                            .position(|e| e.kind == EventKind::Turnaround)
                            .unwrap();
                        for (i, e) in s.events.iter().enumerate() {
                            if e.lane == Lane::Prefetch {
                                assert!(i > turn, "prefetch before turnaround");
                                assert_eq!(e.kind, EventKind::Recompute);
                                let own_bwd = s
                                    .events
                                    .iter()
                                    .position(|x| {
                                        x.kind == EventKind::Backward && x.segment == e.segment
                                    })
                                    .unwrap();
                                assert!(
                                    i < own_bwd,
                                    "{:?}: prefetch after its own backward",
                                    (a, bm, c, d)
                                );
                            }
                        }
                        // never two recomputed inventories in flight:
                        // between any two recompute runs of different
                        // segments there is a backward that retires the
                        // first (the single re-forward buffer contract)
                        let rfwd_segs: Vec<Segment> = s
                            .events
                            .iter()
                            .filter(|e| e.kind == EventKind::Recompute)
                            .map(|e| e.segment)
                            .collect();
                        let mut runs: Vec<Segment> = Vec::new();
                        for seg in rfwd_segs {
                            if runs.last() != Some(&seg) {
                                assert!(
                                    !runs.contains(&seg),
                                    "{:?}: recompute runs of {seg:?} interleave",
                                    (a, bm, c, d)
                                );
                                runs.push(seg);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_placement_allocs_are_freed_exactly_once() {
        let cfg = ModelConfig::bert_mini(); // 4 layers
        let plan = SchedulePlan::from_placement(
            vec![
                OptimizationSet::none(),
                OptimizationSet::full(),
                OptimizationSet::none(),
                OptimizationSet::only("gelu").unwrap(),
            ],
            vec![
                Residency::Checkpoint(CkptStyle::Serial),
                Residency::Resident,
                Residency::Checkpoint(CkptStyle::Overlapped),
                Residency::Offload,
            ],
            true,
        );
        let s = lower_step(&cfg, &plan, Lowering::for_model(&cfg));
        let mut allocated = vec![0u32; s.tensors.len()];
        let mut freed = vec![0u32; s.tensors.len()];
        let mut inplace = vec![0u32; s.tensors.len()];
        for e in &s.events {
            for &id in &e.allocs {
                allocated[id as usize] += 1;
            }
            for &id in &e.frees {
                freed[id as usize] += 1;
            }
            for &id in &e.inplace {
                inplace[id as usize] += 1;
            }
        }
        for (id, t) in s.tensors.iter().enumerate() {
            if inplace[id] > 0 {
                assert_eq!((allocated[id], freed[id], inplace[id]), (0, 0, 1), "{}", t.name);
            } else if matches!(t.class, MemClass::Params | MemClass::Grads | MemClass::OptimizerState) {
                assert_eq!((allocated[id], freed[id]), (1, 0), "{} persists", t.name);
            } else {
                assert_eq!((allocated[id], freed[id]), (1, 1), "{}", t.name);
            }
        }
        // and the memoized summary matches a fresh fold at every batch
        let summary = schedule_summary(&cfg, &plan);
        for batch in [1usize, 4, 32] {
            assert_eq!(summary.peak_bytes(batch as u64), s.timeline(batch).peak_bytes);
        }
    }
}
