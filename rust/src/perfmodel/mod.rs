//! GPU roofline throughput simulator.
//!
//! Reproduces the *shape* of the paper's throughput results (who wins,
//! by roughly what factor, where crossovers fall) from first principles:
//!
//! * an op census per encoder layer (matmul FLOPs + vector bytes, fwd
//!   and bwd, per technique — checkpointing pays a full re-forward,
//!   Tempo pays the dropout-recompute multiply + polynomial GELU bwd),
//!   folded from the shared layer-graph IR in [`crate::graph`];
//! * a roofline timing model per GPU (tensor-core peak for matmuls,
//!   HBM bandwidth for elementwise traffic) with a batch-dependent
//!   utilization saturation curve — small batches under-fill the GPU,
//!   which is exactly the effect Tempo's memory savings monetize.
//!
//! Regenerates Fig 2 (throughput vs batch), Fig 5 (throughput at max
//! batch), Fig 7 (hidden-size ablation), Fig 8 (sequence-length
//! ablation) and the §4.3 GPT2/RoBERTa results.

pub mod calib;
mod ops;
mod roofline;
mod throughput;

pub use ops::{plan_census, step_census, OpCensus};
pub use roofline::{
    plan_lane_times, plan_step_time, step_time, utilization, validate_env_knobs, LaneTimes,
    KNOBS, OVERLAP_EFF,
};
pub use throughput::{plan_throughput_at, throughput_at, throughput_at_max_batch, ThroughputPoint};
