//! Roofline timing: census → seconds, with batch-utilization saturation
//! and a lane-aware exposure fold for the comm lane (DESIGN.md §Lanes).
//!
//! The step is priced as concurrent lanes, not one serial tape:
//!
//! * **Compute lane** — the schedule's census (fwd + bwd + recompute +
//!   rewrite overheads) on the classic roofline, *minus* the prefetched
//!   recompute work that hides under its covering backward window
//!   ([`crate::graph::LaneProfile::hidden`], derated by
//!   [`OVERLAP_EFF`]) — overlapped checkpoint arms genuinely buy
//!   latency here, which is what lets `placement_search` prefer them
//!   over serial arms when memory allows.
//! * **Comm lane** — the bucketed DDP gradient all-reduce
//!   (`StepSchedule::grad_buckets`, ring factor `2(n−1)/n` over
//!   [`crate::config::GpuSpec::allreduce_bw`]). Each bucket starts when
//!   its segment's last backward completes; the **exposure fold**
//!   charges only the collective time not hidden under the remaining
//!   backward compute: `exposed = max(0, maxᵢ(Dᵢ − lagᵢ))`, where `Dᵢ`
//!   is the comm work left at bucket `i`'s readiness and `lagᵢ` the
//!   compute seconds still ahead of it. The embedding bucket (tied
//!   vocab matrix — largest, last ready) has zero lag, so a multi-device
//!   step always pays at least its tail; larger batches grow the lags
//!   and amortize the rest — the paper's §4.2 argument for why bigger
//!   batches win on the PCIe rig.
//! * **TP lane** — the in-block all-gather/reduce-scatter collectives a
//!   sharded plan emits ([`crate::graph::LaneProfile::tp_links`]) over
//!   [`crate::config::GpuSpec::tp_bw`]. Unlike gradient buckets, a TP
//!   collective's readiness couples to an individual op inside the
//!   block tape, so each one pipelines under the compute accrued since
//!   the previous collective and pays only its own unhidden tail:
//!   `tp_exposed = Σᵢ max(0, dᵢ − coverᵢ)` with
//!   `dᵢ = ((tp−1)/tp)·bytesᵢ·B / tp_bw` (ring factor on the full
//!   tensor payload). Zero on unsharded plans.
//! * **Host lane** — L2L offload traffic
//!   ([`crate::graph::LaneProfile::stores`]/`loads`) over
//!   [`crate::config::GpuSpec::host_link_bw`]. A store's deadline is
//!   the turnaround (its bytes must be off-device before the backward
//!   needs them gone), so store exposure is a carrying-lag fold over
//!   the forward: `lag ← max(0, lag + dᵢ − coverᵢ)`, paid once at the
//!   turnaround. A load's deadline is its own tape position (right
//!   before the layer's backward), so each load pays its own tail
//!   `max(0, dᵢ − coverᵢ)` — the DMA runs under the covering backward
//!   window and only the unhidden remainder lengthens the step.
//!
//! Setting `TEMPO_AR_EXPOSE` opts back into the legacy scalar-exposure
//! model (a fixed fraction of `2·grad_bytes/bw`, no overlap credit,
//! host lane silent) for calibration A/B sweeps; `TEMPO_HOST_BW`
//! overrides the rig's host-link bandwidth. All knobs live in
//! [`KNOBS`], are parsed once, and malformed values are a hard error
//! (see [`validate_env_knobs`]).

use std::sync::OnceLock;

use crate::config::{GpuSpec, ModelConfig, Technique};
use crate::graph::{schedule_summary, Census, SchedulePlan};

use super::ops::{plan_census, OpCensus};

/// One calibration env knob: its variable name, the accepted-range text
/// every diagnostic quotes, and the predicate a parsed value must
/// satisfy. [`parse_knob`] (the hot-path panic) and
/// [`validate_env_knobs`] (the clean startup error) share the spec, so
/// a knob cannot be accepted by one and rejected by the other — or
/// described differently in their two messages.
#[derive(Clone, Copy)]
struct KnobSpec {
    name: &'static str,
    accepts: &'static str,
    ok: fn(f64) -> bool,
}

/// `TEMPO_UTIL_K`: utilization half-saturation override (tokens).
const UTIL_K_SPEC: KnobSpec = KnobSpec {
    name: "TEMPO_UTIL_K",
    accepts: "a finite token count > 0",
    ok: |x| x.is_finite() && x > 0.0,
};
/// `TEMPO_AR_EXPOSE`: legacy scalar-exposure escape hatch (fraction).
const AR_EXPOSE_SPEC: KnobSpec = KnobSpec {
    name: "TEMPO_AR_EXPOSE",
    accepts: "a finite exposure fraction >= 0",
    ok: |x| x.is_finite() && x >= 0.0,
};
/// `TEMPO_HOST_BW`: host-link bandwidth override (bytes/s).
const HOST_BW_SPEC: KnobSpec = KnobSpec {
    name: "TEMPO_HOST_BW",
    accepts: "a finite bandwidth in bytes/s > 0",
    ok: |x| x.is_finite() && x > 0.0,
};
/// `TEMPO_TP_BW`: tensor-parallel interconnect bandwidth override
/// (bytes/s).
const TP_BW_SPEC: KnobSpec = KnobSpec {
    name: "TEMPO_TP_BW",
    accepts: "a finite bandwidth in bytes/s > 0",
    ok: |x| x.is_finite() && x > 0.0,
};

/// Every knob spec, in one place — [`validate_env_knobs`] iterates this
/// list and the `OnceLock` getters parse through the same entries.
const KNOB_SPECS: [KnobSpec; 4] = [UTIL_K_SPEC, AR_EXPOSE_SPEC, HOST_BW_SPEC, TP_BW_SPEC];

/// The calibration env knobs, in one place: [`validate_env_knobs`] and
/// the `OnceLock` getters iterate/name this same list, so a knob cannot
/// be validated under one name and parsed under another.
pub const KNOBS: [&str; 4] =
    [UTIL_K_SPEC.name, AR_EXPOSE_SPEC.name, HOST_BW_SPEC.name, TP_BW_SPEC.name];

/// Parse an optional f64 env knob once; malformed or out-of-range
/// values are a hard error (panic naming the knob and its accepted
/// range — [`validate_env_knobs`] turns the same condition into a clean
/// startup error in the CLI).
fn parse_knob(spec: &KnobSpec) -> Option<f64> {
    let KnobSpec { name, accepts, ok } = *spec;
    match std::env::var(name) {
        Ok(v) => match v.parse::<f64>() {
            Ok(x) if ok(x) => Some(x),
            _ => panic!("invalid {name}={v:?}: expected {accepts} — fix or unset the variable"),
        },
        Err(_) => None,
    }
}

/// `TEMPO_UTIL_K` (half-saturation override), parsed once per process.
fn util_k_base() -> f64 {
    static K: OnceLock<f64> = OnceLock::new();
    *K.get_or_init(|| parse_knob(&UTIL_K_SPEC).unwrap_or(K_TOKENS_DEFAULT))
}

/// `TEMPO_AR_EXPOSE` (legacy scalar-exposure escape hatch), parsed once
/// per process. `None` = unset = the lane-aware exposure fold.
fn legacy_exposure() -> Option<f64> {
    static E: OnceLock<Option<f64>> = OnceLock::new();
    *E.get_or_init(|| parse_knob(&AR_EXPOSE_SPEC))
}

/// `TEMPO_HOST_BW` (host-link bandwidth override, bytes/s), parsed once
/// per process. `None` = unset = the rig's `host_link_bw`.
fn host_bw_override() -> Option<f64> {
    static H: OnceLock<Option<f64>> = OnceLock::new();
    *H.get_or_init(|| parse_knob(&HOST_BW_SPEC))
}

/// `TEMPO_TP_BW` (TP interconnect bandwidth override, bytes/s), parsed
/// once per process. `None` = unset = the rig's `tp_bw`.
fn tp_bw_override() -> Option<f64> {
    static T: OnceLock<Option<f64>> = OnceLock::new();
    *T.get_or_init(|| parse_knob(&TP_BW_SPEC))
}

/// Validate the calibration env knobs ([`KNOBS`]) without touching the
/// process-wide caches: a malformed or out-of-range value
/// (`TEMPO_UTIL_K=abc`, `TEMPO_HOST_BW=0`) returns `Err` naming the
/// knob **and its accepted range** so `main` can fail at startup with a
/// clean actionable diagnostic instead of a mid-sweep panic. Library
/// callers that skip this check hit the same condition as a panic at
/// first use — never a silent fallback to the default.
pub fn validate_env_knobs() -> crate::Result<()> {
    for spec in &KNOB_SPECS {
        let KnobSpec { name, accepts, ok } = *spec;
        if let Ok(v) = std::env::var(name) {
            if !matches!(v.parse::<f64>(), Ok(x) if ok(x)) {
                return Err(crate::Error::Invalid(format!(
                    "invalid {name}={v:?}: expected {accepts} — fix or unset the variable"
                )));
            }
        }
    }
    Ok(())
}

/// Tensor-core utilization as a function of in-flight tokens.
///
/// Small batches cannot fill the SMs (wave quantization, launch gaps,
/// low occupancy); utilization saturates as tokens grow. The half-
/// saturation constant is the per-GPU calibration knob — larger GPUs
/// need more parallelism to fill (A100 > V100 > 2080 Ti).
pub fn utilization(spec: &GpuSpec, tokens: f64) -> f64 {
    // half-saturation in tokens, scaled by device width (wider GPUs need
    // more parallelism to fill). TEMPO_UTIL_K overrides for calibration
    // sweeps (perfmodel::calib documents the chosen default); the knob
    // is parsed once, not per call — this is the hot pricing path.
    let k = util_k_base() * (spec.peak_matmul_flops / 53.8e12).powf(1.6);
    let u = tokens / (tokens + k);
    // floor: even B=1 keeps some pipelines busy
    0.08 + 0.92 * u
}

/// Default half-saturation (tokens) on the 2080 Ti, calibrated against
/// the paper's Fig 5 speedup annotations (see perfmodel::calib tests).
pub const K_TOKENS_DEFAULT: f64 = 60.0;

/// Stream-packing efficiency of prefetched recompute under its covering
/// backward window. An overlapped re-forward shares SMs and memory
/// bandwidth with the backward it hides under — concurrent streams only
/// slot work into each other's bubbles (memory-bound phases idle the
/// tensor cores and vice versa), so only this fraction of the
/// overlappable census ([`crate::graph::LaneProfile::hidden`], already
/// capped by the covering window) is genuinely bought back. Calibrated
/// jointly with the Fig 5 bands: high enough that `Overlapped` arms
/// beat `Serial` wherever a covering window exists, low enough that
/// uniform checkpointing keeps its Fig 2 recompute penalty.
pub const OVERLAP_EFF: f64 = 0.25;

/// Lane-priced timing of one training step (seconds). The fields are
/// the decomposition `step = compute + comm_exposed + host_exposed`;
/// `hidden_recompute`, `comm_total − comm_exposed` and
/// `host_total − host_exposed` are the concurrency wins the
/// single-lane model could not see.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneTimes {
    /// Compute-lane seconds: census + optimizer state traffic + fixed
    /// overhead, with the prefetch-hidden recompute already credited.
    pub compute: f64,
    /// Seconds of prefetched (overlapped-checkpoint) recompute work
    /// hidden under its covering backward window (the overlappable
    /// census × [`OVERLAP_EFF`]) — subtracted from `compute` relative
    /// to a serial single-lane fold.
    pub hidden_recompute: f64,
    /// Total collective seconds on the comm lane (every gradient
    /// bucket, ring all-reduce). Zero when `allreduce_bw` is `None` or
    /// `devices == 1`.
    pub comm_total: f64,
    /// Collective seconds *not* hidden under concurrent backward
    /// compute — what the step actually waits on. In
    /// `[0, comm_total]`, monotone in `allreduce_bw`⁻¹.
    pub comm_exposed: f64,
    /// Total host-link DMA seconds (every offload store and load over
    /// `host_link_bw`). Zero on offload-free plans.
    pub host_total: f64,
    /// Host-link seconds *not* hidden under the covering compute
    /// windows — the carrying store lag at the turnaround plus each
    /// load's unhidden tail. In `[0, host_total]`; exactly zero as
    /// `host_link_bw → ∞`.
    pub host_exposed: f64,
    /// Total TP-lane collective seconds (every in-block all-gather /
    /// reduce-scatter at the ring rate over `tp_bw`). Zero on unsharded
    /// plans.
    pub tp_total: f64,
    /// TP-lane seconds *not* hidden under the compute since the
    /// previous collective — the per-collective unhidden tails. In
    /// `[0, tp_total]`; monotone non-increasing in `tp_bw`.
    pub tp_exposed: f64,
    /// End-to-end step seconds (`compute + comm_exposed +
    /// host_exposed + tp_exposed`).
    pub step: f64,
}

/// Compute-lane seconds of a batch-scaled census (no state/fixed/comm
/// terms) — the affine core every lane shares.
fn census_seconds(c: Census, spec: &GpuSpec, util: f64) -> f64 {
    c.matmul_flops / (spec.peak_matmul_flops * util)
        + c.vector_flops / (spec.peak_vector_flops * 0.6)
        + c.vector_bytes / (spec.bandwidth * 0.75)
}

/// Roofline seconds of a full step census (matmul + vector + state
/// streams; the legacy single-lane compute fold).
fn opcensus_seconds(census: &OpCensus, spec: &GpuSpec, util: f64) -> f64 {
    let t_matmul = census.matmul_flops / (spec.peak_matmul_flops * util);
    let t_vector = census.vector_flops / (spec.peak_vector_flops * 0.6)
        + census.vector_bytes / (spec.bandwidth * 0.75);
    let t_state = census.state_bytes / (spec.bandwidth * 0.75);
    t_matmul + t_vector + t_state
}

/// Price one training step of `cfg` under `plan` on `spec` at batch B,
/// lane by lane — the exposure fold behind [`plan_step_time`].
///
/// The single-device / no-collective configuration (`devices == 1` or
/// `allreduce_bw: None`) has `comm_total == comm_exposed == 0`; a plan
/// without overlapped checkpoint arms additionally has
/// `hidden_recompute == 0`, which makes `step` the plain single-lane
/// census fold.
pub fn plan_lane_times(
    cfg: &ModelConfig,
    plan: &SchedulePlan,
    spec: &GpuSpec,
    batch: usize,
) -> LaneTimes {
    let b = batch as f64;
    let tokens = b * cfg.seq_len as f64;
    let util = utilization(spec, tokens);
    let total = plan_census(cfg, plan, batch);
    let total_s = opcensus_seconds(&total, spec, util);
    // fixed per-step overhead: launches, host loop
    let t_fixed = 0.7e-3 + cfg.layers as f64 * 60.0e-6;

    if let Some(expose) = legacy_exposure() {
        // legacy scalar model: no overlap credit, a fixed fraction of
        // the ring all-reduce exposed regardless of the backward shape
        // (and regardless of `devices` — the pre-lane model had no
        // device count, so the escape hatch must not consult it)
        let comm_total = match spec.allreduce_bw {
            Some(bw) => 2.0 * (cfg.param_count() as f64 * 4.0) / bw,
            None => 0.0,
        };
        let comm_exposed = expose * comm_total;
        let compute = total_s + t_fixed;
        return LaneTimes {
            compute,
            hidden_recompute: 0.0,
            comm_total,
            comm_exposed,
            host_total: 0.0,
            host_exposed: 0.0,
            tp_total: 0.0,
            tp_exposed: 0.0,
            step: compute + comm_exposed,
        };
    }

    let summary = schedule_summary(cfg, plan);
    let hidden_s = OVERLAP_EFF * census_seconds(summary.lanes.hidden.scale(b), spec, util);
    let compute = total_s - hidden_s + t_fixed;

    let (comm_total, comm_exposed) = match spec.allreduce_bw {
        Some(bw) if spec.devices > 1 => {
            // ring all-reduce: each device moves 2(n−1)/n of the bucket
            let ring = 2.0 * (spec.devices as f64 - 1.0) / spec.devices as f64;
            let durs: Vec<f64> =
                summary.lanes.buckets.iter().map(|bk| ring * bk.bytes as f64 / bw).collect();
            let total_comm: f64 = durs.iter().sum();
            // exposed = max(0, maxᵢ(Dᵢ − lagᵢ)): Dᵢ is the serialized
            // comm work remaining when bucket i becomes ready, lagᵢ the
            // compute seconds still ahead of the step at that instant
            let mut exposed = 0.0f64;
            let mut remaining = total_comm;
            for (bk, d) in summary.lanes.buckets.iter().zip(&durs) {
                let lag = census_seconds(bk.tail.scale(b), spec, util);
                exposed = exposed.max(remaining - lag);
                remaining -= d;
            }
            (total_comm, exposed.max(0.0))
        }
        _ => (0.0, 0.0),
    };

    // host lane: offload stores/loads over the (per-device) host link.
    // Stores share one deadline — the turnaround — so their exposure is
    // a carrying lag the covering forward windows drain; each load's
    // deadline is its own tape position, so its unhidden tail is paid
    // per window. Offload-free plans have empty transfer lists and land
    // on exactly (0.0, 0.0).
    let host_bw = host_bw_override().unwrap_or(spec.host_link_bw);
    let mut host_total = 0.0f64;
    let mut store_lag = 0.0f64;
    for t in &summary.lanes.stores {
        let d = t.bytes as f64 * b / host_bw;
        let c = census_seconds(t.cover.scale(b), spec, util);
        host_total += d;
        store_lag = (store_lag + d - c).max(0.0);
    }
    let mut load_exposed = 0.0f64;
    for t in &summary.lanes.loads {
        let d = t.bytes as f64 * b / host_bw;
        let c = census_seconds(t.cover.scale(b), spec, util);
        host_total += d;
        load_exposed += (d - c).max(0.0);
    }
    let host_exposed = store_lag + load_exposed;

    // TP lane: each in-block collective pipelines under the compute
    // accrued since the previous one (op-coupled readiness, so there is
    // no cross-collective serialization like the gradient ring's) and
    // pays only its own unhidden tail. The wire payload is the full
    // tensor; the ring factor (tp−1)/tp is what one shard actually
    // moves. Unsharded plans have an empty tp_links list → (0.0, 0.0).
    let tp = plan.resolved_tp(cfg);
    let tp_bw = tp_bw_override().unwrap_or(spec.tp_bw);
    let ring_tp = (tp.saturating_sub(1)) as f64 / tp.max(1) as f64;
    let mut tp_total = 0.0f64;
    let mut tp_exposed = 0.0f64;
    for t in &summary.lanes.tp_links {
        let d = ring_tp * t.bytes as f64 * b / tp_bw;
        let c = census_seconds(t.cover.scale(b), spec, util);
        tp_total += d;
        tp_exposed += (d - c).max(0.0);
    }

    LaneTimes {
        compute,
        hidden_recompute: hidden_s,
        comm_total,
        comm_exposed,
        host_total,
        host_exposed,
        tp_total,
        tp_exposed,
        step: compute + comm_exposed + host_exposed + tp_exposed,
    }
}

/// Seconds for one training step of `cfg` under `technique` at batch B.
pub fn step_time(cfg: &ModelConfig, technique: Technique, spec: &GpuSpec, batch: usize) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    plan_lane_times(cfg, &SchedulePlan::for_technique(cfg, technique, true), spec, batch).step
}

/// Seconds for one training step under an arbitrary execution-schedule
/// plan at batch B — the exposure fold over the schedule's lanes, so
/// mixed placements (per-layer rewrites + checkpoint arms) price their
/// recompute, overlap hiding and collective exposure exactly where the
/// timeline puts them. Bit-identical to [`step_time`] on
/// technique-induced plans (one pricing path).
pub fn plan_step_time(cfg: &ModelConfig, plan: &SchedulePlan, spec: &GpuSpec, batch: usize) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    plan_lane_times(cfg, plan, spec, batch).step
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Gpu, ModelConfig};
    use crate::graph::{CkptStyle, Residency};

    #[test]
    fn utilization_monotone_saturating() {
        let spec = Gpu::V100.spec();
        let mut prev = 0.0;
        for tokens in [64.0, 128.0, 512.0, 2048.0, 8192.0, 65536.0] {
            let u = utilization(&spec, tokens);
            assert!(u > prev);
            assert!(u <= 1.0);
            prev = u;
        }
        assert!(utilization(&spec, 1e9) > 0.97);
    }

    #[test]
    fn bigger_gpu_needs_more_tokens() {
        let t = utilization(&Gpu::Rtx2080Ti.spec(), 1024.0);
        let a = utilization(&Gpu::A100.spec(), 1024.0);
        assert!(a < t);
    }

    #[test]
    fn step_time_decreases_per_sequence_as_batch_grows() {
        // throughput (seqs/s) must improve with batch — Fig 2's premise
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let spec = Gpu::Rtx2080Ti.spec();
        let mut prev = f64::INFINITY;
        for b in [1usize, 2, 4, 8, 16] {
            let per_seq = step_time(&cfg, Technique::Baseline, &spec, b) / b as f64;
            assert!(per_seq < prev, "B={b}");
            prev = per_seq;
        }
    }

    #[test]
    fn checkpoint_slower_than_baseline_at_equal_batch() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let spec = Gpu::V100.spec();
        let base = step_time(&cfg, Technique::Baseline, &spec, 4);
        let chk = step_time(&cfg, Technique::Checkpoint, &spec, 4);
        assert!(chk > 1.15 * base, "chk={chk} base={base}");
    }

    #[test]
    fn tempo_overhead_within_a_few_percent_at_equal_batch() {
        // §1: "very low throughput degradation (as low as 1%)"
        for s in [128usize, 512] {
            let cfg = ModelConfig::bert_large().with_seq_len(s);
            let spec = Gpu::V100.spec();
            let base = step_time(&cfg, Technique::Baseline, &spec, 4);
            let tempo = step_time(&cfg, Technique::Tempo, &spec, 4);
            let overhead = tempo / base - 1.0;
            assert!((0.0..0.08).contains(&overhead), "S={s}: {overhead:.4}");
        }
    }

    #[test]
    fn step_time_magnitude_plausible() {
        // BERT-LARGE on V100 at B=8 S=128: ~0.1–1.0 s/step territory
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let t = step_time(&cfg, Technique::Baseline, &Gpu::V100.spec(), 8);
        assert!((0.02..2.0).contains(&t), "t={t}");
    }

    #[test]
    fn zero_batch_is_infinite() {
        let cfg = ModelConfig::bert_large();
        assert!(step_time(&cfg, Technique::Baseline, &Gpu::V100.spec(), 0).is_infinite());
    }

    #[test]
    fn lane_times_decompose_the_step() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
        for gpu in Gpu::all() {
            let lt = plan_lane_times(&cfg, &plan, &gpu.spec(), 4);
            assert_eq!(
                lt.step,
                lt.compute + lt.comm_exposed + lt.host_exposed + lt.tp_exposed,
                "{}",
                gpu.name()
            );
            assert!(lt.comm_exposed >= 0.0 && lt.comm_exposed <= lt.comm_total, "{}", gpu.name());
            assert_eq!(lt.hidden_recompute, 0.0, "no prefetches in a plain plan");
            assert_eq!(lt.host_total, 0.0, "no offload arms in a plain plan");
            assert_eq!(lt.host_exposed, 0.0, "no offload arms in a plain plan");
            assert_eq!(lt.tp_total, 0.0, "no collectives in an unsharded plan");
            assert_eq!(lt.tp_exposed, 0.0, "no collectives in an unsharded plan");
        }
        // the single-GPU box has an empty comm lane
        let solo = plan_lane_times(&cfg, &plan, &Gpu::A100.spec(), 4);
        assert_eq!(solo.comm_total, 0.0);
        assert_eq!(solo.comm_exposed, 0.0);
        assert_eq!(solo.step, solo.compute);
        // and so does any rig demoted to one device
        let demoted = plan_lane_times(&cfg, &plan, &Gpu::Rtx2080Ti.spec().with_devices(1), 4);
        assert_eq!(demoted.comm_total, 0.0);
        assert_eq!(demoted.comm_exposed, 0.0);
    }

    #[test]
    fn exposure_shrinks_as_batch_grows() {
        // bigger batches stretch the backward, hiding more of the
        // (batch-independent) collective — the amortization the paper
        // leans on for the PCIe rig
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let plan = SchedulePlan::for_technique(&cfg, Technique::Baseline, true);
        let spec = Gpu::Rtx2080Ti.spec();
        let mut prev = f64::INFINITY;
        for b in [1usize, 2, 4, 8] {
            let e = plan_lane_times(&cfg, &plan, &spec, b).comm_exposed;
            assert!(e <= prev, "B={b}: exposure rose");
            assert!(e > 0.0, "B={b}: the embedding tail bucket is never fully hidden");
            prev = e;
        }
    }

    #[test]
    fn overlapped_checkpoint_prices_below_serial_at_equal_batch() {
        // the tentpole divergence: equal census, but the overlapped
        // arm's prefetched re-forward hides under the head backward
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let over = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        let serial = over.clone().serial();
        for gpu in Gpu::all() {
            let spec = gpu.spec();
            let t_over = plan_lane_times(&cfg, &over, &spec, 4);
            let t_serial = plan_lane_times(&cfg, &serial, &spec, 4);
            assert!(t_over.hidden_recompute > 0.0, "{}", gpu.name());
            assert_eq!(t_serial.hidden_recompute, 0.0, "{}", gpu.name());
            assert!(t_over.step < t_serial.step, "{}", gpu.name());
        }
        // bottom-c mixed placements diverge the same way
        let mut residency = vec![Residency::Resident; cfg.layers];
        residency[0] = Residency::Checkpoint(CkptStyle::Overlapped);
        let over = SchedulePlan::from_placement(
            vec![crate::config::OptimizationSet::full(); cfg.layers],
            residency,
            true,
        );
        let serial = over.clone().serial();
        let spec = Gpu::Rtx2080Ti.spec();
        assert!(
            plan_step_time(&cfg, &over, &spec, 4) < plan_step_time(&cfg, &serial, &spec, 4)
        );
    }

    #[test]
    fn tp_exposure_is_bounded_and_the_collective_total_is_physical() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let plan = SchedulePlan::from_placement(
            vec![crate::config::OptimizationSet::none(); cfg.layers],
            vec![Residency::Shard; cfg.layers],
            true,
        )
        .with_tp(8);
        let spec = Gpu::A100.spec();
        let lt = plan_lane_times(&cfg, &plan, &spec, 4);
        assert!(lt.tp_total > 0.0);
        assert!(lt.tp_exposed >= 0.0 && lt.tp_exposed <= lt.tp_total);
        assert_eq!(lt.step, lt.compute + lt.comm_exposed + lt.host_exposed + lt.tp_exposed);
        // the total is the ring share of the full-tensor payloads over
        // the TP link, at batch 4
        let summary = schedule_summary(&cfg, &plan);
        assert!(!summary.lanes.tp_links.is_empty());
        let shipped: u64 = summary.lanes.tp_links.iter().map(|t| t.bytes).sum();
        let expect = (7.0 / 8.0) * shipped as f64 * 4.0 / spec.tp_bw;
        assert!((lt.tp_total - expect).abs() < 1e-12 * expect.max(1.0));
        // a faster scale-up link never raises exposure
        let mut fast = spec;
        fast.tp_bw *= 10.0;
        let lt_fast = plan_lane_times(&cfg, &plan, &fast, 4);
        assert!(lt_fast.tp_exposed <= lt.tp_exposed);
    }

    #[test]
    fn offload_exposure_is_bounded_and_the_transfer_total_is_physical() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let n = cfg.layers;
        let plan = SchedulePlan::from_placement(
            vec![crate::config::OptimizationSet::none(); n],
            vec![Residency::Offload; n],
            true,
        );
        let spec = Gpu::Rtx2080Ti.spec();
        let lt = plan_lane_times(&cfg, &plan, &spec, 4);
        assert!(lt.host_total > 0.0);
        assert!(lt.host_exposed >= 0.0 && lt.host_exposed <= lt.host_total);
        assert_eq!(lt.step, lt.compute + lt.comm_exposed + lt.host_exposed);
        // the total is the shipped bytes over the link, out and back
        let summary = schedule_summary(&cfg, &plan);
        let shipped: u64 = summary.lanes.stores.iter().map(|t| t.bytes).sum();
        let expect = 2.0 * shipped as f64 * 4.0 / spec.host_link_bw;
        assert!((lt.host_total - expect).abs() < 1e-12 * expect.max(1.0));
    }
}
