//! Roofline timing: census → seconds, with batch-utilization saturation.

use crate::config::{GpuSpec, ModelConfig, Technique};
use crate::graph::SchedulePlan;

use super::ops::{plan_census, step_census, OpCensus};

/// Tensor-core utilization as a function of in-flight tokens.
///
/// Small batches cannot fill the SMs (wave quantization, launch gaps,
/// low occupancy); utilization saturates as tokens grow. The half-
/// saturation constant is the per-GPU calibration knob — larger GPUs
/// need more parallelism to fill (A100 > V100 > 2080 Ti).
pub fn utilization(spec: &GpuSpec, tokens: f64) -> f64 {
    // half-saturation in tokens, scaled by device width (wider GPUs need
    // more parallelism to fill). TEMPO_UTIL_K overrides for calibration
    // sweeps (perfmodel::calib documents the chosen default).
    let k_base = std::env::var("TEMPO_UTIL_K")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(K_TOKENS_DEFAULT);
    let k = k_base * (spec.peak_matmul_flops / 53.8e12).powf(1.6);
    let u = tokens / (tokens + k);
    // floor: even B=1 keeps some pipelines busy
    0.08 + 0.92 * u
}

/// Default half-saturation (tokens) on the 2080 Ti, calibrated against
/// the paper's Fig 5 speedup annotations (see perfmodel::calib tests).
pub const K_TOKENS_DEFAULT: f64 = 60.0;

/// Fraction of the ring all-reduce NOT hidden by backward overlap.
fn allreduce_exposure() -> f64 {
    std::env::var("TEMPO_AR_EXPOSE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(AR_EXPOSE_DEFAULT)
}

/// Calibrated default all-reduce exposure.
pub const AR_EXPOSE_DEFAULT: f64 = 0.05;

/// Roofline pricing of a step census: the shared core of
/// [`step_time`] and [`plan_step_time`] (affine in the census, so the
/// technique path and the plan path price identical censuses to
/// identical seconds).
fn census_time(cfg: &ModelConfig, census: &OpCensus, spec: &GpuSpec, batch: usize) -> f64 {
    let tokens = (batch * cfg.seq_len) as f64;
    let util = utilization(spec, tokens);

    let t_matmul = census.matmul_flops / (spec.peak_matmul_flops * util);
    let t_vector = census.vector_flops / (spec.peak_vector_flops * 0.6)
        + census.vector_bytes / (spec.bandwidth * 0.75);
    let t_state = census.state_bytes / (spec.bandwidth * 0.75);
    // fixed per-step overhead: launches, host loop
    let t_fixed = 0.7e-3 + cfg.layers as f64 * 60.0e-6;
    // DDP gradient all-reduce: a batch-independent per-step cost that
    // larger batches amortize (ring all-reduce moves ~2× the gradient
    // bytes; DDP bucketing overlaps roughly half of it with backward).
    let t_allreduce = match spec.allreduce_bw {
        Some(bw) => allreduce_exposure() * 2.0 * (cfg.param_count() as f64 * 4.0) / bw,
        None => 0.0,
    };

    // matmul and vector work overlap poorly in practice; sum them
    t_matmul + t_vector + t_state + t_fixed + t_allreduce
}

/// Seconds for one training step of `cfg` under `technique` at batch B.
pub fn step_time(cfg: &ModelConfig, technique: Technique, spec: &GpuSpec, batch: usize) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    census_time(cfg, &step_census(cfg, technique, batch), spec, batch)
}

/// Seconds for one training step under an arbitrary execution-schedule
/// plan at batch B — the roofline over [`plan_census`]'s schedule fold,
/// so mixed placements (per-layer rewrites + checkpoint arms) price
/// their recompute and rewrite overheads exactly where the timeline
/// splices them. Bit-identical to [`step_time`] on technique-induced
/// plans.
pub fn plan_step_time(cfg: &ModelConfig, plan: &SchedulePlan, spec: &GpuSpec, batch: usize) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    census_time(cfg, &plan_census(cfg, plan, batch), spec, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Gpu, ModelConfig};

    #[test]
    fn utilization_monotone_saturating() {
        let spec = Gpu::V100.spec();
        let mut prev = 0.0;
        for tokens in [64.0, 128.0, 512.0, 2048.0, 8192.0, 65536.0] {
            let u = utilization(&spec, tokens);
            assert!(u > prev);
            assert!(u <= 1.0);
            prev = u;
        }
        assert!(utilization(&spec, 1e9) > 0.97);
    }

    #[test]
    fn bigger_gpu_needs_more_tokens() {
        let t = utilization(&Gpu::Rtx2080Ti.spec(), 1024.0);
        let a = utilization(&Gpu::A100.spec(), 1024.0);
        assert!(a < t);
    }

    #[test]
    fn step_time_decreases_per_sequence_as_batch_grows() {
        // throughput (seqs/s) must improve with batch — Fig 2's premise
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let spec = Gpu::Rtx2080Ti.spec();
        let mut prev = f64::INFINITY;
        for b in [1usize, 2, 4, 8, 16] {
            let per_seq = step_time(&cfg, Technique::Baseline, &spec, b) / b as f64;
            assert!(per_seq < prev, "B={b}");
            prev = per_seq;
        }
    }

    #[test]
    fn checkpoint_slower_than_baseline_at_equal_batch() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let spec = Gpu::V100.spec();
        let base = step_time(&cfg, Technique::Baseline, &spec, 4);
        let chk = step_time(&cfg, Technique::Checkpoint, &spec, 4);
        assert!(chk > 1.15 * base, "chk={chk} base={base}");
    }

    #[test]
    fn tempo_overhead_within_a_few_percent_at_equal_batch() {
        // §1: "very low throughput degradation (as low as 1%)"
        for s in [128usize, 512] {
            let cfg = ModelConfig::bert_large().with_seq_len(s);
            let spec = Gpu::V100.spec();
            let base = step_time(&cfg, Technique::Baseline, &spec, 4);
            let tempo = step_time(&cfg, Technique::Tempo, &spec, 4);
            let overhead = tempo / base - 1.0;
            assert!((0.0..0.08).contains(&overhead), "S={s}: {overhead:.4}");
        }
    }

    #[test]
    fn step_time_magnitude_plausible() {
        // BERT-LARGE on V100 at B=8 S=128: ~0.1–1.0 s/step territory
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let t = step_time(&cfg, Technique::Baseline, &Gpu::V100.spec(), 8);
        assert!((0.02..2.0).contains(&t), "t={t}");
    }

    #[test]
    fn zero_batch_is_infinite() {
        let cfg = ModelConfig::bert_large();
        assert!(step_time(&cfg, Technique::Baseline, &Gpu::V100.spec(), 0).is_infinite());
    }
}
