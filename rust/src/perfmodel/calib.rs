//! Calibration against the paper's published speedups.
//!
//! `paper_speedup_checks()` evaluates every headline throughput claim
//! and returns (claim, paper value, model value) rows; tests assert the
//! model lands in a sensible band around each.

use crate::config::{Gpu, ModelConfig, Technique};

use super::throughput::throughput_at_max_batch;

/// One model-vs-measured calibration row from the measured probe
/// (`tempo autotempo --probe measured`): what the analytic models
/// predicted for a quantity versus what the kernel backend measured.
///
/// Step-time rows carry *normalized* columns (each divided by its
/// fastest candidate) since the roofline prices a GPU while the
/// kernels run on host cores; peak-bytes rows compare raw bytes.
#[derive(Debug, Clone)]
pub struct DriftRow {
    /// Candidate plan label the row belongs to.
    pub plan: String,
    /// Which quantity is compared (`"step time (relative)"`,
    /// `"peak bytes"`).
    pub quantity: &'static str,
    /// The analytic model's value.
    pub modeled: f64,
    /// The value the kernel backend measured.
    pub measured: f64,
}

impl DriftRow {
    /// `measured / modeled` — 1.0 means perfectly calibrated.
    pub fn ratio(&self) -> f64 {
        if self.modeled == 0.0 {
            f64::INFINITY
        } else {
            self.measured / self.modeled
        }
    }

    /// Signed drift percentage (positive = measurement above model).
    pub fn drift_pct(&self) -> f64 {
        100.0 * (self.ratio() - 1.0)
    }
}

/// One speedup claim from the paper.
#[derive(Debug, Clone)]
pub struct SpeedupCheck {
    /// Which headline claim this row checks.
    pub claim: &'static str,
    /// The paper's reported speedup factor.
    pub paper: f64,
    /// The roofline model's speedup factor.
    pub model: f64,
}

fn speedup(cfg: &ModelConfig, gpu: Gpu, over: Technique) -> f64 {
    let tempo = throughput_at_max_batch(cfg, Technique::Tempo, gpu).seqs_per_s;
    let other = throughput_at_max_batch(cfg, over, gpu).seqs_per_s;
    tempo / other
}

/// Evaluate the §4.2 headline speedups (Fig 5 annotations).
pub fn paper_speedup_checks() -> Vec<SpeedupCheck> {
    let l128 = ModelConfig::bert_large().with_seq_len(128);
    let l512 = ModelConfig::bert_large().with_seq_len(512);
    vec![
        SpeedupCheck {
            claim: "2080Ti S=512: Tempo vs Baseline (+16%)",
            paper: 1.16,
            model: speedup(&l512, Gpu::Rtx2080Ti, Technique::Baseline),
        },
        SpeedupCheck {
            claim: "2080Ti S=512: Tempo vs Checkpoint (+8%)",
            paper: 1.08,
            model: speedup(&l512, Gpu::Rtx2080Ti, Technique::Checkpoint),
        },
        SpeedupCheck {
            claim: "V100 S=512: Tempo vs Baseline (+5%)",
            paper: 1.05,
            model: speedup(&l512, Gpu::V100, Technique::Baseline),
        },
        SpeedupCheck {
            claim: "V100 S=512: Tempo vs Checkpoint (+27%)",
            paper: 1.27,
            model: speedup(&l512, Gpu::V100, Technique::Checkpoint),
        },
        SpeedupCheck {
            claim: "2080Ti S=128: Tempo vs Baseline",
            paper: 1.10, // Fig 5 shows a moderate win at S=128
            model: speedup(&l128, Gpu::Rtx2080Ti, Technique::Baseline),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_row_math() {
        let r = DriftRow { plan: "tempo".into(), quantity: "peak bytes", modeled: 100.0, measured: 110.0 };
        assert!((r.ratio() - 1.1).abs() < 1e-12);
        assert!((r.drift_pct() - 10.0).abs() < 1e-9);
        let z = DriftRow { plan: "x".into(), quantity: "peak bytes", modeled: 0.0, measured: 1.0 };
        assert!(z.ratio().is_infinite());
    }

    #[test]
    fn all_headline_speedups_have_the_right_sign() {
        for c in paper_speedup_checks() {
            assert!(c.model > 1.0, "{}: model {:.3} not a speedup", c.claim, c.model);
        }
    }

    #[test]
    fn headline_speedups_in_band() {
        // Shape reproduction: within ±12 percentage points of the paper
        // (our substrate is a simulator, not the authors' testbed).
        for c in paper_speedup_checks() {
            let diff = (c.model - c.paper).abs();
            assert!(
                diff < 0.12 + 0.05 * c.paper,
                "{}: paper {:.2} vs model {:.2}",
                c.claim, c.paper, c.model
            );
        }
    }

    #[test]
    fn fig7_hidden_size_ablation_tempo_wins() {
        // Fig 7 (A100): Tempo tracks or beats Baseline on every widened
        // config, with a clear (≥8%) win somewhere in the grid — the
        // gains grow with memory pressure (larger H), as in the paper.
        let mut best = 0.0f64;
        for (base, h) in [
            (ModelConfig::bert_large(), 1024),
            (ModelConfig::bert_base(), 2048),
            (ModelConfig::bert_large(), 2048),
            (ModelConfig::bert_base(), 3072),
        ] {
            for s in [128usize, 512] {
                let cfg = base.with_hidden(h).unwrap().with_seq_len(s);
                let t = throughput_at_max_batch(&cfg, Technique::Tempo, Gpu::A100).seqs_per_s;
                let b = throughput_at_max_batch(&cfg, Technique::Baseline, Gpu::A100).seqs_per_s;
                assert!(t > 0.97 * b, "H={h} S={s}: {t:.2} vs {b:.2}");
                best = best.max(t / b);
            }
        }
        assert!(best > 1.08, "no clear Fig 7 win (best {best:.3})");
    }

    #[test]
    fn fig8_long_sequences_tempo_wins_and_baseline_ooms() {
        // Fig 8: BERT-LARGE-12L on A100, S up to 3072; Baseline cannot
        // run the longest sequence.
        let cfg12 = ModelConfig::bert_large().with_layers(12);
        for s in [512usize, 1024, 2048, 3072] {
            let cfg = cfg12.with_seq_len(s);
            let t = throughput_at_max_batch(&cfg, Technique::Tempo, Gpu::A100);
            let b = throughput_at_max_batch(&cfg, Technique::Baseline, Gpu::A100);
            // near-parity at short S (plenty of memory), clear wins as
            // S² pressure grows
            if s <= 1024 {
                assert!(t.seqs_per_s > 0.97 * b.seqs_per_s, "S={s}");
            } else {
                assert!(t.seqs_per_s > b.seqs_per_s, "S={s}");
            }
        }
        // the paper's OOM cell: Baseline at S=3072 fits at most a
        // couple of sequences (the figure reports none at batch > 0)
        let b3072 = crate::memmodel::max_batch(
            &cfg12.with_seq_len(3072),
            Technique::Baseline,
            Gpu::A100,
        );
        assert!(b3072.max_batch <= 2, "baseline S=3072 batch {}", b3072.max_batch);
    }

    #[test]
    fn other_models_gpt2_roberta_speedups() {
        // §4.3: GPT2 +19%, RoBERTa +26% over Baseline on the 2080 Ti;
        // +5% / +4% on V100. Assert sign everywhere and magnitude band
        // on the 2080 Ti.
        let gpt2 = ModelConfig::gpt2();
        let roberta = ModelConfig::roberta_large();
        for cfg in [&gpt2, &roberta] {
            for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
                let s = speedup(cfg, gpu, Technique::Baseline);
                assert!(s > 1.0, "{} {gpu:?}: {s:.3}", cfg.name);
            }
            let s_t = speedup(cfg, Gpu::Rtx2080Ti, Technique::Baseline);
            assert!((1.02..1.55).contains(&s_t), "{}: {s_t:.3}", cfg.name);
        }
    }
}
