//! Throughput (sequences/s) sweeps — the paper's primary metric.

use crate::config::{Gpu, ModelConfig, Technique};
use crate::memmodel::max_batch;

use super::roofline::step_time;

/// One throughput measurement (one bar in Fig 5/7/8, one point in Fig 2).
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    /// Technique being measured.
    pub technique: Technique,
    /// GPU platform.
    pub gpu: Gpu,
    /// Sequence length.
    pub seq_len: usize,
    /// Per-GPU batch size.
    pub batch: usize,
    /// sequences per second (per GPU).
    pub seqs_per_s: f64,
}

/// Throughput at an explicit batch size.
pub fn throughput_at(cfg: &ModelConfig, technique: Technique, gpu: Gpu, batch: usize) -> ThroughputPoint {
    let t = step_time(cfg, technique, &gpu.spec(), batch);
    ThroughputPoint {
        technique,
        gpu,
        seq_len: cfg.seq_len,
        batch,
        seqs_per_s: if batch == 0 { 0.0 } else { batch as f64 / t },
    }
}

/// Throughput at the memory-model max batch (the Fig 5/7/8 protocol:
/// every technique runs as large as it fits).
pub fn throughput_at_max_batch(cfg: &ModelConfig, technique: Technique, gpu: Gpu) -> ThroughputPoint {
    let b = max_batch(cfg, technique, gpu).max_batch;
    throughput_at(cfg, technique, gpu, b)
}

/// Throughput (sequences/s) of an arbitrary execution-schedule plan at
/// an explicit batch — the lane-aware roofline over the plan's own
/// schedule summary: compute lane (census minus the hidden-prefetch
/// credit) plus the exposed collective time on multi-device rigs
/// (Auto-Tempo's placement search prices every candidate plan through
/// this).
pub fn plan_throughput_at(
    cfg: &ModelConfig,
    plan: &crate::graph::SchedulePlan,
    gpu: Gpu,
    batch: usize,
) -> f64 {
    if batch == 0 {
        return 0.0;
    }
    batch as f64 / super::roofline::plan_step_time(cfg, plan, &gpu.spec(), batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large(s: usize) -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(s)
    }

    #[test]
    fn fig2_shape_rising_throughput_with_batch() {
        let cfg = large(128);
        let mut prev = 0.0;
        for b in [1usize, 2, 4, 8, 15] {
            let p = throughput_at(&cfg, Technique::Baseline, Gpu::Rtx2080Ti, b);
            assert!(p.seqs_per_s > prev, "B={b}");
            prev = p.seqs_per_s;
        }
    }

    #[test]
    fn fig5_tempo_wins_at_max_batch_everywhere() {
        // The headline: Tempo outperforms both baselines across both
        // sequence lengths and both GPUs.
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            for s in [128usize, 512] {
                let cfg = large(s);
                let t = throughput_at_max_batch(&cfg, Technique::Tempo, gpu).seqs_per_s;
                let b = throughput_at_max_batch(&cfg, Technique::Baseline, gpu).seqs_per_s;
                let c = throughput_at_max_batch(&cfg, Technique::Checkpoint, gpu).seqs_per_s;
                assert!(t > b, "{gpu:?} S={s}: tempo {t:.2} !> baseline {b:.2}");
                assert!(t > c, "{gpu:?} S={s}: tempo {t:.2} !> checkpoint {c:.2}");
            }
        }
    }

    #[test]
    fn unrunnable_config_reports_zero() {
        // Fig 8's S=3072 Baseline bar is missing (OOM) — batch 0 → 0 seq/s
        let p = throughput_at(&large(128), Technique::Baseline, Gpu::Rtx2080Ti, 0);
        assert_eq!(p.seqs_per_s, 0.0);
    }
}
