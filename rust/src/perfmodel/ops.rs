//! Op census: FLOPs and memory traffic for one training step.
//!
//! The census is a fold over the **execution schedule**
//! ([`crate::graph::StepSchedule`]) — the same fwd+bwd event timeline
//! the capacity model folds for liveness. Each forward event carries
//! its op's census, each backward event ≈ 2× forward plus any enabled
//! rewrite's recompute overhead, and checkpointing's spliced re-forward
//! events carry the 1.25× recompute-inefficiency factor (RNG-state
//! restore, cold kernels, extra copies). Every term is a multiple of ¼
//! far below 2⁵³, so the fold is exact in any order — pinned
//! bit-identical to the pre-refactor closed form by
//! `tests/graph_equivalence.rs`. Only the optimizer/gradient state
//! traffic is added here (it is step-level, not an op event).

use crate::config::{ModelConfig, Technique};
use crate::graph::{self, SchedulePlan};

/// Aggregate work of one training step at batch B.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCensus {
    /// Tensor-core matmul FLOPs (fwd + bwd + any recompute).
    pub matmul_flops: f64,
    /// CUDA-core elementwise FLOPs (softmax, GELU poly, LN, dropout…).
    pub vector_flops: f64,
    /// HBM bytes moved by bandwidth-bound ops (activations r/w).
    pub vector_bytes: f64,
    /// Optimizer + gradient traffic (params-sized streams).
    pub state_bytes: f64,
}

impl From<graph::Census> for OpCensus {
    fn from(c: graph::Census) -> OpCensus {
        OpCensus {
            matmul_flops: c.matmul_flops,
            vector_flops: c.vector_flops,
            vector_bytes: c.vector_bytes,
            state_bytes: 0.0,
        }
    }
}

/// Census of one full training step under an arbitrary
/// execution-schedule plan: the schedule's per-item event fold scaled
/// to batch B, plus optimizer traffic. Checkpointed layers carry their
/// spliced 1.25×-priced re-forward events; rewritten layers carry
/// their backward recompute overheads — recompute pricing is the
/// schedule fold itself, not a side formula.
pub fn plan_census(cfg: &ModelConfig, plan: &SchedulePlan, batch: usize) -> OpCensus {
    let summary = graph::schedule_summary(cfg, plan);
    let mut total: OpCensus = summary.census.scale(batch as f64).into();
    // optimizer: read params+grads+m+v, write params+m+v (fp32), plus
    // DDP all-reduce traffic ≈ 2× grads through HBM
    let p = cfg.param_count() as f64;
    total.state_bytes += 4.0 * p * 9.0;
    total
}

/// Census of one full training step under `technique` — [`plan_census`]
/// over the technique-induced uniform plan.
pub fn step_census(cfg: &ModelConfig, technique: Technique, batch: usize) -> OpCensus {
    plan_census(cfg, &SchedulePlan::for_technique(cfg, technique, true), batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large(s: usize) -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(s)
    }

    #[test]
    fn checkpoint_pays_a_third_more_matmul() {
        let cfg = large(128);
        let base = step_census(&cfg, Technique::Baseline, 8);
        let chk = step_census(&cfg, Technique::Checkpoint, 8);
        let ratio = chk.matmul_flops / base.matmul_flops;
        // re-forward of the encoder ≈ +1/3 of encoder matmul work,
        // plus the 25% recompute-inefficiency factor
        assert!((1.25..1.45).contains(&ratio), "ratio={ratio:.3}");
    }

    #[test]
    fn tempo_overhead_is_small() {
        // §1: "as low as 1%" throughput degradation — the extra vector
        // work must be a tiny fraction of the step's total traffic.
        for s in [128, 512] {
            let cfg = large(s);
            let base = step_census(&cfg, Technique::Baseline, 8);
            let tempo = step_census(&cfg, Technique::Tempo, 8);
            let extra_bytes = tempo.vector_bytes - base.vector_bytes;
            assert!(extra_bytes > 0.0);
            assert!(
                extra_bytes / base.vector_bytes < 0.25,
                "S={s}: byte overhead {:.3}",
                extra_bytes / base.vector_bytes
            );
            assert_eq!(tempo.matmul_flops, base.matmul_flops);
        }
    }

    #[test]
    fn census_scales_linearly_in_batch() {
        let cfg = large(128);
        let one = step_census(&cfg, Technique::Baseline, 1);
        let four = step_census(&cfg, Technique::Baseline, 4);
        let lin = |a: f64, b: f64| ((b - 4.0 * a) / (4.0 * a)).abs();
        assert!(lin(one.matmul_flops, four.matmul_flops) < 1e-9);
        // state traffic is batch-independent
        assert_eq!(one.state_bytes, four.state_bytes);
    }

    #[test]
    fn attention_flops_grow_quadratically_in_s() {
        let c1 = step_census(&large(512), Technique::Baseline, 1);
        let c2 = step_census(&large(1024), Technique::Baseline, 1);
        // doubling S more than doubles FLOPs (S² attention term)
        assert!(c2.matmul_flops > 2.1 * c1.matmul_flops);
    }

    #[test]
    fn flops_magnitude_sanity() {
        // BERT-LARGE fwd+bwd ≈ 6·params FLOPs per token (transformer rule
        // of thumb), excluding attention and head.
        let cfg = large(128);
        let census = step_census(&cfg, Technique::Baseline, 1);
        let tokens = 128.0;
        let rule = 6.0 * cfg.param_count() as f64 * tokens;
        let ratio = census.matmul_flops / rule;
        assert!((0.6..1.6).contains(&ratio), "ratio={ratio:.2}");
    }
}
