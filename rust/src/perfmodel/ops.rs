//! Op census: FLOPs and memory traffic for one training step.
//!
//! Per-layer and head work is a fold over [`crate::graph`] lowered
//! blocks (the same lowering `memmodel` folds for bytes): forward op
//! censuses sum per block, Tempo's rewrite overheads come from the
//! rewrites themselves, and checkpointing's re-forward reprices the
//! lowered block. Only step-level assembly (fwd+bwd factors, optimizer
//! traffic, the recompute-inefficiency knob) lives here. The fold is
//! pinned bit-identical to the pre-refactor closed form by
//! `tests/graph_equivalence.rs`.

use crate::config::{ModelConfig, OptimizationSet, Technique};
use crate::graph;

/// Aggregate work of one training step at batch B.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCensus {
    /// Tensor-core matmul FLOPs (fwd + bwd + any recompute).
    pub matmul_flops: f64,
    /// CUDA-core elementwise FLOPs (softmax, GELU poly, LN, dropout…).
    pub vector_flops: f64,
    /// HBM bytes moved by bandwidth-bound ops (activations r/w).
    pub vector_bytes: f64,
    /// Optimizer + gradient traffic (params-sized streams).
    pub state_bytes: f64,
}

impl OpCensus {
    fn zero() -> Self {
        OpCensus { matmul_flops: 0.0, vector_flops: 0.0, vector_bytes: 0.0, state_bytes: 0.0 }
    }

    fn add(&mut self, o: OpCensus) {
        self.matmul_flops += o.matmul_flops;
        self.vector_flops += o.vector_flops;
        self.vector_bytes += o.vector_bytes;
        self.state_bytes += o.state_bytes;
    }

    fn scale(mut self, f: f64) -> Self {
        self.matmul_flops *= f;
        self.vector_flops *= f;
        self.vector_bytes *= f;
        self.state_bytes *= f;
        self
    }
}

impl From<graph::Census> for OpCensus {
    fn from(c: graph::Census) -> OpCensus {
        OpCensus {
            matmul_flops: c.matmul_flops,
            vector_flops: c.vector_flops,
            vector_bytes: c.vector_bytes,
            state_bytes: 0.0,
        }
    }
}

/// Forward-pass census of ONE encoder layer: fold over the lowered
/// block's per-op censuses (QKV/scores/PV/proj/FC matmuls, softmax ≈ 3
/// passes over B·A·S², dropout 2 maps, residuals+LN ≈ 6 passes over
/// B·S·H, GELU ≈ 3 passes over B·S·I).
fn layer_forward(cfg: &ModelConfig, batch: usize) -> OpCensus {
    graph::encoder_summary(cfg, OptimizationSet::none()).fwd_at(batch).into()
}

/// Extra vector work Tempo's backward adds (the "low overhead" of §3):
/// the sum of the enabled rewrites' overhead censuses — the
/// dropout-recompute multiply over the B·A·S² probs and the polynomial
/// (deg ≤ 13) GELU backward over B·S·I; the in-place LN/softmax
/// rewrites are traffic-neutral (x̂ re-derived from already-resident
/// outputs).
fn tempo_overhead(cfg: &ModelConfig, batch: usize) -> OpCensus {
    graph::encoder_summary(cfg, OptimizationSet::full()).overhead_at(batch).into()
}

/// Embedding + MLM-head census (fwd; bwd ≈ 2×, folded by caller): fold
/// over the lowered head block (transform 2BSH² + decoder 2BSHV, the
/// B·S·V loss passes, embedding traffic lumped into the transform row).
fn head_forward(cfg: &ModelConfig, batch: usize) -> OpCensus {
    graph::head_summary(cfg, OptimizationSet::none(), true).fwd_at(batch).into()
}

/// Census of one full training step under `technique`.
pub fn step_census(cfg: &ModelConfig, technique: Technique, batch: usize) -> OpCensus {
    let layers = cfg.layers as f64;
    let fwd = layer_forward(cfg, batch);
    let mut total = OpCensus::zero();
    // forward + backward (bwd ≈ 2× fwd work for matmuls and traffic)
    total.add(fwd.scale(3.0 * layers));
    total.add(head_forward(cfg, batch).scale(3.0));

    match technique {
        Technique::Checkpoint => {
            // full re-forward of every layer during backward; recompute
            // runs ~25% less efficiently than the autotuned first
            // forward (RNG-state restore, cold kernels, extra copies)
            total.add(layer_forward(cfg, batch).scale(1.25 * layers));
        }
        Technique::Tempo => {
            total.add(tempo_overhead(cfg, batch).scale(layers));
        }
        Technique::Baseline => {}
    }

    // optimizer: read params+grads+m+v, write params+m+v (fp32), plus
    // DDP all-reduce traffic ≈ 2× grads through HBM
    let p = cfg.param_count() as f64;
    total.state_bytes += 4.0 * p * 9.0;
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large(s: usize) -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(s)
    }

    #[test]
    fn checkpoint_pays_a_third_more_matmul() {
        let cfg = large(128);
        let base = step_census(&cfg, Technique::Baseline, 8);
        let chk = step_census(&cfg, Technique::Checkpoint, 8);
        let ratio = chk.matmul_flops / base.matmul_flops;
        // re-forward of the encoder ≈ +1/3 of encoder matmul work,
        // plus the 25% recompute-inefficiency factor
        assert!((1.25..1.45).contains(&ratio), "ratio={ratio:.3}");
    }

    #[test]
    fn tempo_overhead_is_small() {
        // §1: "as low as 1%" throughput degradation — the extra vector
        // work must be a tiny fraction of the step's total traffic.
        for s in [128, 512] {
            let cfg = large(s);
            let base = step_census(&cfg, Technique::Baseline, 8);
            let tempo = step_census(&cfg, Technique::Tempo, 8);
            let extra_bytes = tempo.vector_bytes - base.vector_bytes;
            assert!(extra_bytes > 0.0);
            assert!(
                extra_bytes / base.vector_bytes < 0.25,
                "S={s}: byte overhead {:.3}",
                extra_bytes / base.vector_bytes
            );
            assert_eq!(tempo.matmul_flops, base.matmul_flops);
        }
    }

    #[test]
    fn census_scales_linearly_in_batch() {
        let cfg = large(128);
        let one = step_census(&cfg, Technique::Baseline, 1);
        let four = step_census(&cfg, Technique::Baseline, 4);
        let lin = |a: f64, b: f64| ((b - 4.0 * a) / (4.0 * a)).abs();
        assert!(lin(one.matmul_flops, four.matmul_flops) < 1e-9);
        // state traffic is batch-independent
        assert_eq!(one.state_bytes, four.state_bytes);
    }

    #[test]
    fn attention_flops_grow_quadratically_in_s() {
        let c1 = step_census(&large(512), Technique::Baseline, 1);
        let c2 = step_census(&large(1024), Technique::Baseline, 1);
        // doubling S more than doubles FLOPs (S² attention term)
        assert!(c2.matmul_flops > 2.1 * c1.matmul_flops);
    }

    #[test]
    fn flops_magnitude_sanity() {
        // BERT-LARGE fwd+bwd ≈ 6·params FLOPs per token (transformer rule
        // of thumb), excluding attention and head.
        let cfg = large(128);
        let census = step_census(&cfg, Technique::Baseline, 1);
        let tokens = 128.0;
        let rule = 6.0 * cfg.param_count() as f64 * tokens;
        let ratio = census.matmul_flops / rule;
        assert!((0.6..1.6).contains(&ratio), "ratio={ratio:.2}");
    }
}
