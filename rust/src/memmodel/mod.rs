//! GPU memory-capacity simulator.
//!
//! Reproduces the paper's memory results analytically from the Fig 1
//! tensor inventory: which feature maps each technique retains for the
//! backward pass, at what width (fp32 activations + 1-byte masks,
//! matching the paper's accounting in §3 and footnote 3). The inventory
//! itself is the shared layer-graph IR in [`crate::graph`]; this module
//! folds lowered blocks into byte totals.
//!
//! Outputs:
//! * Table 2 — max batch per (GPU, seq len, technique)
//! * §4.2 text — total GB at a fixed batch
//! * Fig 9 — memory breakdown (weights / grads / optimizer / activations)
//! * Fig 12 — per-optimization footprint-reduction ablation vs S
//!
//! The substitution (real HBM → analytical bytes) is sound because max
//! batch is a pure arithmetic consequence of the inventory; the
//! `calib` tests pin the model against the paper's published numbers.

pub mod calib;
mod fit;
mod layer;
mod model;
mod report;

pub use calib::{gb_at_b15, table2, Table2Row, PAPER_GB_AT_B15, PAPER_TABLE2};
pub use fit::{max_batch, max_batch_for_plan, FitResult};
pub use layer::{layer_activation_bytes, LayerBytes};
pub use model::{plan_breakdown, Breakdown, ModelFootprint};
pub use report::{ablation_fig12, breakdown_fig9, AblationRow, BreakdownRow};

/// Bytes per fp32 element (the paper's activation accounting).
pub const F32: u64 = 4;
/// Bytes per 1-byte mask element (footnote 3's int8 masks).
pub const MASK: u64 = 1;
