//! Calibration against the paper's published memory numbers.
//!
//! `table2()` regenerates Table 2 (max batch for BERT-LARGE on 2080 Ti
//! and V100 at S ∈ {128, 512} for Baseline/Checkpoint/Tempo) and the
//! §4.2 fixed-batch GB figures, next to the paper's values.
//!
//! Calibration status (asserted by the tests below):
//! * Baseline and Tempo max-batch: within max(2, 25%) of the paper on
//!   every entry; the headline "Tempo fits ~2× the Baseline batch at
//!   S=512" reproduces exactly.
//! * Checkpoint: correct ordering (Baseline < Tempo < Checkpoint) with
//!   the right magnitude at S=128; at S=512 the analytical model is
//!   optimistic (the paper's 4-GPU PyTorch runs hit allocator
//!   fragmentation + DDP staging the byte model does not capture) —
//!   bounded here at ≤ 4× and documented in EXPERIMENTS.md.

use crate::config::{Gpu, ModelConfig, Technique};

use super::fit::max_batch;

/// One Table 2 cell: model prediction next to the paper's measurement.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// GPU platform of this cell.
    pub gpu: Gpu,
    /// Technique of this cell.
    pub technique: Technique,
    /// Sequence length of this cell.
    pub seq_len: usize,
    /// The analytical model's max batch.
    pub model_batch: usize,
    /// The paper's measured max batch.
    pub paper_batch: usize,
}

/// The paper's Table 2 (BERT-LARGE).
pub const PAPER_TABLE2: [(Technique, usize, usize, usize); 6] = [
    // (technique, seq, 2080Ti batch, V100 batch)
    (Technique::Baseline, 128, 15, 28),
    (Technique::Baseline, 512, 1, 4),
    (Technique::Checkpoint, 128, 50, 96),
    (Technique::Checkpoint, 512, 4, 18),
    (Technique::Tempo, 128, 24, 41),
    (Technique::Tempo, 512, 2, 7),
];

/// Regenerate Table 2 from the analytical model.
pub fn table2() -> Vec<Table2Row> {
    let mut rows = Vec::new();
    for &(tech, s, paper_t, paper_v) in &PAPER_TABLE2 {
        let cfg = ModelConfig::bert_large().with_seq_len(s);
        for (gpu, paper) in [(Gpu::Rtx2080Ti, paper_t), (Gpu::V100, paper_v)] {
            rows.push(Table2Row {
                gpu,
                technique: tech,
                seq_len: s,
                model_batch: max_batch(&cfg, tech, gpu).max_batch,
                paper_batch: paper,
            });
        }
    }
    rows
}

/// §4.2 fixed-batch memory (BERT-LARGE, B=15, S=128): paper GB values.
pub const PAPER_GB_AT_B15: [(Technique, f64); 3] = [
    (Technique::Baseline, 11.3),
    (Technique::Checkpoint, 8.3),
    (Technique::Tempo, 9.2),
];

/// Model GB at B=15 S=128 per technique.
pub fn gb_at_b15(technique: Technique) -> f64 {
    let cfg = ModelConfig::bert_large().with_seq_len(128);
    super::model::ModelFootprint::new(cfg, technique).total_bytes(15) as f64 / 1e9
}

// The calibration pins themselves (per-cell tolerances against
// PAPER_TABLE2 / PAPER_GB_AT_B15, checkpoint ratio band, headline 2×
// ratio, §4.2 ordering) live in ONE place:
// `rust/tests/calibration_paper.rs`, with failure messages naming the
// exact (GPU, seq-len, technique) cell that drifted. Only a structural
// smoke test stays in-module.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_regenerates_every_cell() {
        let rows = table2();
        assert_eq!(rows.len(), PAPER_TABLE2.len() * 2); // × 2 GPUs
        assert!(rows.iter().all(|r| r.paper_batch > 0));
    }

    #[test]
    fn gb_at_b15_is_positive_for_all_techniques() {
        for tech in Technique::all() {
            assert!(gb_at_b15(tech) > 0.0, "{tech:?}");
        }
    }
}
