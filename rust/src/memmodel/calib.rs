//! Calibration against the paper's published memory numbers.
//!
//! `table2()` regenerates Table 2 (max batch for BERT-LARGE on 2080 Ti
//! and V100 at S ∈ {128, 512} for Baseline/Checkpoint/Tempo) and the
//! §4.2 fixed-batch GB figures, next to the paper's values.
//!
//! Calibration status (asserted by the tests below):
//! * Baseline and Tempo max-batch: within max(2, 25%) of the paper on
//!   every entry; the headline "Tempo fits ~2× the Baseline batch at
//!   S=512" reproduces exactly.
//! * Checkpoint: correct ordering (Baseline < Tempo < Checkpoint) with
//!   the right magnitude at S=128; at S=512 the analytical model is
//!   optimistic (the paper's 4-GPU PyTorch runs hit allocator
//!   fragmentation + DDP staging the byte model does not capture) —
//!   bounded here at ≤ 4× and documented in EXPERIMENTS.md.

use crate::config::{Gpu, ModelConfig, Technique};

use super::fit::max_batch;

/// One Table 2 cell: model prediction next to the paper's measurement.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub gpu: Gpu,
    pub technique: Technique,
    pub seq_len: usize,
    pub model_batch: usize,
    pub paper_batch: usize,
}

/// The paper's Table 2 (BERT-LARGE).
pub const PAPER_TABLE2: [(Technique, usize, usize, usize); 6] = [
    // (technique, seq, 2080Ti batch, V100 batch)
    (Technique::Baseline, 128, 15, 28),
    (Technique::Baseline, 512, 1, 4),
    (Technique::Checkpoint, 128, 50, 96),
    (Technique::Checkpoint, 512, 4, 18),
    (Technique::Tempo, 128, 24, 41),
    (Technique::Tempo, 512, 2, 7),
];

/// Regenerate Table 2 from the analytical model.
pub fn table2() -> Vec<Table2Row> {
    let mut rows = Vec::new();
    for &(tech, s, paper_t, paper_v) in &PAPER_TABLE2 {
        let cfg = ModelConfig::bert_large().with_seq_len(s);
        for (gpu, paper) in [(Gpu::Rtx2080Ti, paper_t), (Gpu::V100, paper_v)] {
            rows.push(Table2Row {
                gpu,
                technique: tech,
                seq_len: s,
                model_batch: max_batch(&cfg, tech, gpu).max_batch,
                paper_batch: paper,
            });
        }
    }
    rows
}

/// §4.2 fixed-batch memory (BERT-LARGE, B=15, S=128): paper GB values.
pub const PAPER_GB_AT_B15: [(Technique, f64); 3] = [
    (Technique::Baseline, 11.3),
    (Technique::Checkpoint, 8.3),
    (Technique::Tempo, 9.2),
];

/// Model GB at B=15 S=128 per technique.
pub fn gb_at_b15(technique: Technique) -> f64 {
    let cfg = ModelConfig::bert_large().with_seq_len(128);
    super::model::ModelFootprint::new(cfg, technique).total_bytes(15) as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_baseline_and_tempo_calibrated() {
        for row in table2() {
            if row.technique == Technique::Checkpoint {
                continue;
            }
            let tol = (row.paper_batch as f64 * 0.25).max(2.0);
            let diff = (row.model_batch as f64 - row.paper_batch as f64).abs();
            assert!(
                diff <= tol,
                "{:?} {:?} S={}: model {} vs paper {}",
                row.gpu, row.technique, row.seq_len, row.model_batch, row.paper_batch
            );
        }
    }

    #[test]
    fn table2_checkpoint_bounded() {
        for row in table2() {
            if row.technique != Technique::Checkpoint {
                continue;
            }
            let ratio = row.model_batch as f64 / row.paper_batch as f64;
            assert!(
                (1.0..=4.0).contains(&ratio),
                "{:?} S={}: model {} vs paper {} (ratio {ratio:.2})",
                row.gpu, row.seq_len, row.model_batch, row.paper_batch
            );
        }
    }

    #[test]
    fn headline_tempo_doubles_baseline_batch_at_s512() {
        // Abstract: "up to 2× higher batch sizes".
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            let cfg = ModelConfig::bert_large().with_seq_len(512);
            let base = max_batch(&cfg, Technique::Baseline, gpu).max_batch.max(1);
            let tempo = max_batch(&cfg, Technique::Tempo, gpu).max_batch;
            let ratio = tempo as f64 / base as f64;
            assert!((1.5..=2.6).contains(&ratio), "{gpu:?}: ratio {ratio:.2}");
        }
    }

    #[test]
    fn fixed_batch_gb_within_25pct() {
        for (tech, paper) in PAPER_GB_AT_B15 {
            let got = gb_at_b15(tech);
            let rel = (got - paper).abs() / paper;
            assert!(rel < 0.25, "{tech:?}: model {got:.2} GB vs paper {paper} GB");
        }
    }

    #[test]
    fn fixed_batch_gb_ordering_matches_paper() {
        // checkpoint < tempo < baseline at equal batch (§4.2)
        assert!(gb_at_b15(Technique::Checkpoint) < gb_at_b15(Technique::Tempo));
        assert!(gb_at_b15(Technique::Tempo) < gb_at_b15(Technique::Baseline));
    }
}
