//! Per-encoder-layer retained-activation inventory (paper Fig 1).
//!
//! Every tensor the backward pass needs, per technique, for the
//! HuggingFace BERT encoder layer the paper annotates:
//!
//! ```text
//!  x ─→ Q,K,V linears ─→ scores(S²) ─→ softmax(S²) ─→ dropout(S²)
//!    ─→ PV ─→ proj ─→ dropout ─→ +x → LN1 ─→ FC1(4H) ─→ GELU ─→ FC2
//!    ─→ dropout ─→ +LN1 → LN2 ─→ next layer
//! ```
//!
//! The inventory itself lives in [`crate::graph`] — one declarative
//! lowering shared with `perfmodel` and `autotempo`; this module is a
//! fold over the lowered block's retained tensors. The fold is pinned
//! bit-identical to the pre-refactor closed form by
//! `tests/graph_equivalence.rs`.

use crate::config::{ModelConfig, OptimizationSet};
use crate::graph;

/// Byte totals for one encoder layer at batch B.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerBytes {
    /// fp32 feature maps retained for backward.
    pub float_bytes: u64,
    /// 1-byte masks retained (dropout keep-masks, Tempo's GELU mask).
    pub mask_bytes: u64,
    /// Small per-row statistics (LN mean/var or rstd).
    pub stat_bytes: u64,
}

impl LayerBytes {
    /// All retained bytes (maps + masks + stats).
    pub fn total(&self) -> u64 {
        self.float_bytes + self.mask_bytes + self.stat_bytes
    }
}

/// Retained activations of ONE encoder layer under an optimization set.
///
/// `OptimizationSet::none()` is the Baseline column; `::full()` is Tempo.
/// (Checkpointing is handled at the model level — it changes *which
/// layers* retain anything, not the per-layer inventory.)
pub fn layer_activation_bytes(cfg: &ModelConfig, batch: usize, opts: OptimizationSet) -> LayerBytes {
    let s = graph::encoder_summary(cfg, opts);
    let b = batch as u64;
    LayerBytes {
        float_bytes: s.float_bytes(b),
        mask_bytes: s.mask_bytes(b),
        stat_bytes: s.stat_bytes(b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::memmodel::{F32, MASK};

    fn base_at(s: usize) -> ModelConfig {
        ModelConfig::bert_base().with_seq_len(s)
    }

    #[test]
    fn paper_claim_s2_maps_are_56pct_at_s512() {
        // §2.1 ①: the three B·A·S² maps are 56% of encoder-layer
        // activation memory for BERT_BASE at S=512.
        let cfg = base_at(512);
        let all = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let (b, s, a) = (1u64, 512u64, 12u64);
        let s2_bytes = 3 * b * a * s * s * F32;
        let share = s2_bytes as f64 / all.total() as f64;
        assert!((0.50..0.62).contains(&share), "share={share:.3}");
    }

    #[test]
    fn paper_claim_gelu_input_is_17pct_at_s128() {
        // §2.1 ③: GELU's stored input is ~17% of layer activation
        // memory for BERT_BASE at S=128.
        let cfg = base_at(128);
        let all = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let gelu_x = (128u64 * 3072) * F32;
        let share = gelu_x as f64 / all.total() as f64;
        assert!((0.13..0.21).contains(&share), "share={share:.3}");
    }

    #[test]
    fn each_optimization_strictly_reduces() {
        let cfg = base_at(128);
        let baseline = layer_activation_bytes(&cfg, 4, OptimizationSet::none()).total();
        for which in ["gelu", "layernorm", "dropout", "softmax"] {
            let opt = OptimizationSet::only(which).unwrap();
            let reduced = layer_activation_bytes(&cfg, 4, opt).total();
            assert!(reduced < baseline, "{which} did not reduce");
        }
        let full = layer_activation_bytes(&cfg, 4, OptimizationSet::full()).total();
        assert!(full < baseline / 2 + baseline / 4, "full tempo saves >25%");
    }

    #[test]
    fn savings_are_additive() {
        // the four optimizations touch disjoint tensors, so the full-set
        // saving equals the sum of individual savings
        let cfg = base_at(256);
        let base = layer_activation_bytes(&cfg, 2, OptimizationSet::none()).total();
        let full = layer_activation_bytes(&cfg, 2, OptimizationSet::full()).total();
        let individual_sum: u64 = ["gelu", "layernorm", "dropout", "softmax"]
            .iter()
            .map(|w| base - layer_activation_bytes(&cfg, 2, OptimizationSet::only(w).unwrap()).total())
            .sum();
        assert_eq!(base - full, individual_sum);
    }

    #[test]
    fn scaling_is_linear_in_batch() {
        let cfg = base_at(128);
        let one = layer_activation_bytes(&cfg, 1, OptimizationSet::full());
        let eight = layer_activation_bytes(&cfg, 8, OptimizationSet::full());
        assert_eq!(eight.float_bytes, 8 * one.float_bytes);
        assert_eq!(eight.mask_bytes, 8 * one.mask_bytes);
    }

    #[test]
    fn dropout_recompute_saves_s2_map() {
        let cfg = base_at(512);
        let without = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let with = layer_activation_bytes(&cfg, 1, OptimizationSet::only("dropout").unwrap());
        let saved = without.total() - with.total();
        assert_eq!(saved, 12 * 512 * 512 * F32); // one B·A·S² fp32 map
    }

    #[test]
    fn gelu_mask_costs_quarter_of_saved_map() {
        let cfg = base_at(128);
        let without = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let with = layer_activation_bytes(&cfg, 1, OptimizationSet::only("gelu").unwrap());
        let bsi = 128 * 3072;
        assert_eq!(without.total() - with.total(), bsi * F32 - bsi * MASK);
    }
}
