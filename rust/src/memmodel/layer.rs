//! Per-encoder-layer retained-activation inventory (paper Fig 1).
//!
//! Every tensor the backward pass needs, per technique. Derived from the
//! HuggingFace BERT encoder layer the paper annotates:
//!
//! ```text
//!  x ─→ Q,K,V linears ─→ scores(S²) ─→ softmax(S²) ─→ dropout(S²)
//!    ─→ PV ─→ proj ─→ dropout ─→ +x → LN1 ─→ FC1(4H) ─→ GELU ─→ FC2
//!    ─→ dropout ─→ +LN1 → LN2 ─→ next layer
//! ```

use crate::config::{ModelConfig, OptimizationSet};

use super::{F32, MASK};

/// Byte totals for one encoder layer at batch B.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerBytes {
    /// fp32 feature maps retained for backward.
    pub float_bytes: u64,
    /// 1-byte masks retained (dropout keep-masks, Tempo's GELU mask).
    pub mask_bytes: u64,
    /// Small per-row statistics (LN mean/var or rstd).
    pub stat_bytes: u64,
}

impl LayerBytes {
    pub fn total(&self) -> u64 {
        self.float_bytes + self.mask_bytes + self.stat_bytes
    }
}

/// Retained activations of ONE encoder layer under an optimization set.
///
/// `OptimizationSet::none()` is the Baseline column; `::full()` is Tempo.
/// (Checkpointing is handled at the model level — it changes *which
/// layers* retain anything, not the per-layer inventory.)
pub fn layer_activation_bytes(cfg: &ModelConfig, batch: usize, opts: OptimizationSet) -> LayerBytes {
    let b = batch as u64;
    let s = cfg.seq_len as u64;
    let h = cfg.hidden as u64;
    let a = cfg.heads as u64;
    let i = cfg.intermediate as u64;

    let bsh = b * s * h;
    let bsi = b * s * i;
    let bass = b * a * s * s;

    let mut float_elems: u64 = 0;
    let mut mask_bytes: u64 = 0;
    let mut stat_bytes: u64 = 0;

    // ---- attention block ---------------------------------------------------
    // layer input x (consumed by QKV linears and the residual)
    float_elems += bsh;
    // Q, K, V projections (inputs to the attention core)
    float_elems += 3 * bsh;
    // scores = QKᵀ/√d : the softmax *input*. PyTorch softmax retains it;
    // the §3.4 output-only softmax discards it.
    if !opts.softmax_outonly {
        float_elems += bass;
        // HF GPT2's unfused attention additionally materializes (and
        // autograd retains) the causal-masked scores and the fp32
        // upcast copy — absent once the Tempo fused core is in place.
        if cfg.kind == crate::config::ModelKind::Gpt2 {
            float_elems += 2 * bass;
        }
    }
    // softmax output (needed by both softmax bwd and dropout bwd)
    float_elems += bass;
    // attention-prob dropout: mask always retained (1 byte)…
    mask_bytes += bass * MASK;
    // …and the scaled output (input to the PV matmul) — discarded and
    // recomputed under §3.3 sub-layer dropout recomputation.
    if !opts.dropout_recompute {
        float_elems += bass;
    }
    // context = probs·V (input to the output projection)
    float_elems += bsh;
    // hidden dropout after the projection: mask + (output folded into the
    // residual-sum tensor accounted as the LN input below)
    mask_bytes += bsh * MASK;

    // ---- LayerNorm 1 -------------------------------------------------------
    // LN input (residual sum). In-place LN reconstructs from the output.
    if !opts.inplace_layernorm {
        float_elems += bsh;
        stat_bytes += 2 * b * s * F32; // mean + var
    } else {
        stat_bytes += b * s * F32; // rstd only (App. D)
    }
    // LN1 output (input to FC1 — retained by every variant)
    float_elems += bsh;

    // ---- feed-forward ------------------------------------------------------
    // FC1 output X = GELU input. In-place GELU replaces it with a mask.
    if opts.inplace_gelu {
        mask_bytes += bsi * MASK;
    } else {
        float_elems += bsi;
    }
    // GELU output Y (input to FC2 — retained by every variant)
    float_elems += bsi;
    // hidden dropout after FC2
    mask_bytes += bsh * MASK;

    // ---- LayerNorm 2 -------------------------------------------------------
    if !opts.inplace_layernorm {
        float_elems += bsh;
        stat_bytes += 2 * b * s * F32;
    } else {
        stat_bytes += b * s * F32;
    }
    // LN2 output is the next layer's input — counted there (or by the
    // head for the final layer).

    LayerBytes {
        float_bytes: float_elems * F32,
        mask_bytes,
        stat_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn base_at(s: usize) -> ModelConfig {
        ModelConfig::bert_base().with_seq_len(s)
    }

    #[test]
    fn paper_claim_s2_maps_are_56pct_at_s512() {
        // §2.1 ①: the three B·A·S² maps are 56% of encoder-layer
        // activation memory for BERT_BASE at S=512.
        let cfg = base_at(512);
        let all = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let (b, s, a) = (1u64, 512u64, 12u64);
        let s2_bytes = 3 * b * a * s * s * F32;
        let share = s2_bytes as f64 / all.total() as f64;
        assert!((0.50..0.62).contains(&share), "share={share:.3}");
    }

    #[test]
    fn paper_claim_gelu_input_is_17pct_at_s128() {
        // §2.1 ③: GELU's stored input is ~17% of layer activation
        // memory for BERT_BASE at S=128.
        let cfg = base_at(128);
        let all = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let gelu_x = (128u64 * 3072) * F32;
        let share = gelu_x as f64 / all.total() as f64;
        assert!((0.13..0.21).contains(&share), "share={share:.3}");
    }

    #[test]
    fn each_optimization_strictly_reduces() {
        let cfg = base_at(128);
        let baseline = layer_activation_bytes(&cfg, 4, OptimizationSet::none()).total();
        for which in ["gelu", "layernorm", "dropout", "softmax"] {
            let opt = OptimizationSet::only(which).unwrap();
            let reduced = layer_activation_bytes(&cfg, 4, opt).total();
            assert!(reduced < baseline, "{which} did not reduce");
        }
        let full = layer_activation_bytes(&cfg, 4, OptimizationSet::full()).total();
        assert!(full < baseline / 2 + baseline / 4, "full tempo saves >25%");
    }

    #[test]
    fn savings_are_additive() {
        // the four optimizations touch disjoint tensors, so the full-set
        // saving equals the sum of individual savings
        let cfg = base_at(256);
        let base = layer_activation_bytes(&cfg, 2, OptimizationSet::none()).total();
        let full = layer_activation_bytes(&cfg, 2, OptimizationSet::full()).total();
        let individual_sum: u64 = ["gelu", "layernorm", "dropout", "softmax"]
            .iter()
            .map(|w| base - layer_activation_bytes(&cfg, 2, OptimizationSet::only(w).unwrap()).total())
            .sum();
        assert_eq!(base - full, individual_sum);
    }

    #[test]
    fn scaling_is_linear_in_batch() {
        let cfg = base_at(128);
        let one = layer_activation_bytes(&cfg, 1, OptimizationSet::full());
        let eight = layer_activation_bytes(&cfg, 8, OptimizationSet::full());
        assert_eq!(eight.float_bytes, 8 * one.float_bytes);
        assert_eq!(eight.mask_bytes, 8 * one.mask_bytes);
    }

    #[test]
    fn dropout_recompute_saves_s2_map() {
        let cfg = base_at(512);
        let without = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let with = layer_activation_bytes(&cfg, 1, OptimizationSet::only("dropout").unwrap());
        let saved = without.total() - with.total();
        assert_eq!(saved, 12 * 512 * 512 * F32); // one B·A·S² fp32 map
    }

    #[test]
    fn gelu_mask_costs_quarter_of_saved_map() {
        let cfg = base_at(128);
        let without = layer_activation_bytes(&cfg, 1, OptimizationSet::none());
        let with = layer_activation_bytes(&cfg, 1, OptimizationSet::only("gelu").unwrap());
        let bsi = 128 * 3072;
        assert_eq!(without.total() - with.total(), bsi * F32 - bsi * MASK);
    }
}
