//! Whole-model footprint: model states + activations per technique.
//!
//! Every number here is read off the **liveness timeline** of the
//! lowered execution schedule ([`crate::graph::StepSchedule`]): the
//! breakdown rows are the per-class live bytes at the step's
//! high-water instant, and the total *is* the timeline peak. The
//! once hand-written `transient` heuristic is gone — the backward
//! working set (activation-gradient workspace, checkpoint recompute
//! inventory) is an allocation on the schedule like any other, and the
//! row's label comes from what the high-water op is actually doing.
//! `tests/schedule_equivalence.rs` pins the peak bit-identical to the
//! pre-schedule static sum across the full grid.

use crate::config::{ModelConfig, OptimizationSet, Technique};
use crate::graph::{self, MemClass, SchedulePlan};

/// Full memory breakdown at a given batch size (per GPU): the
/// per-class live bytes at the schedule's high-water instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breakdown {
    /// fp32 parameter bytes.
    pub params: u64,
    /// fp32 gradient bytes.
    pub grads: u64,
    /// Adam `m`+`v` state bytes.
    pub optimizer: u64,
    /// Encoder-layer retained activations (Fig 9's dominant slice;
    /// under checkpointing, the stored block inputs).
    pub encoder_activations: u64,
    /// Embedding + MLM-head activations (incl. the B·S·V logits).
    pub other_activations: u64,
    /// Backward working set live at the peak: activation-gradient
    /// workspace, plus the in-flight recompute inventory under
    /// checkpointing. Derived from the timeline, labeled by
    /// [`Breakdown::transient_label`].
    pub transient: u64,
    /// What the high-water op is doing (e.g. "bwd working set",
    /// "ckpt re-forward + grads") — the derived name for the row that
    /// used to be the hand-written, checkpoint-flavored "transient".
    pub transient_label: &'static str,
}

impl Breakdown {
    /// Sum of every row — the exact liveness-timeline peak.
    pub fn total(&self) -> u64 {
        self.params
            + self.grads
            + self.optimizer
            + self.encoder_activations
            + self.other_activations
            + self.transient
    }

    /// Encoder + other activation bytes at the peak.
    pub fn activations(&self) -> u64 {
        self.encoder_activations + self.other_activations
    }
}

/// Footprint calculator for one (model, technique) pair.
#[derive(Debug, Clone)]
pub struct ModelFootprint {
    /// Model being priced.
    pub cfg: ModelConfig,
    /// Technique being priced.
    pub technique: Technique,
    /// Fine-grained toggles (ignored for Baseline/Checkpoint).
    pub opts: OptimizationSet,
    /// Pre-training (MLM head with B·S·V logits) vs fine-tuning
    /// (classification head, negligible memory) — Fig 9 is fine-tuning.
    pub mlm_head: bool,
}

impl ModelFootprint {
    /// Footprint of `cfg` under a top-level technique (MLM head).
    pub fn new(cfg: ModelConfig, technique: Technique) -> Self {
        let opts = match technique {
            Technique::Tempo => OptimizationSet::full(),
            _ => OptimizationSet::none(),
        };
        ModelFootprint { cfg, technique, opts, mlm_head: true }
    }

    /// Custom optimization subset (Fig 12 ablation / Auto-Tempo).
    pub fn with_opts(cfg: ModelConfig, opts: OptimizationSet) -> Self {
        ModelFootprint { cfg, technique: Technique::Tempo, opts, mlm_head: true }
    }

    /// Fine-tuning footprint (classification head instead of MLM).
    pub fn finetune(mut self) -> Self {
        self.mlm_head = false;
        self
    }

    /// The execution-schedule plan this footprint prices.
    pub fn plan(&self) -> SchedulePlan {
        match self.technique {
            Technique::Checkpoint => {
                SchedulePlan::for_technique(&self.cfg, Technique::Checkpoint, self.mlm_head)
            }
            _ => SchedulePlan::uniform(&self.cfg, self.opts, self.mlm_head),
        }
    }

    /// Full breakdown at batch `b`: the per-class live bytes at the
    /// schedule's high-water instant (memoized per plan; pricing any
    /// batch is exact integer scaling).
    pub fn breakdown(&self, batch: usize) -> Breakdown {
        plan_breakdown(&self.cfg, &self.plan(), batch)
    }

    /// Total bytes at batch `b` — the exact timeline peak.
    pub fn total_bytes(&self, batch: usize) -> u64 {
        graph::schedule_summary(&self.cfg, &self.plan()).peak_bytes(batch as u64)
    }
}

/// Breakdown of an arbitrary execution-schedule plan — the per-class
/// live bytes at the plan's high-water instant, labeled by what that
/// op is doing. [`ModelFootprint::breakdown`] is this fold over the
/// technique-induced plan; Auto-Tempo's placement report calls it with
/// mixed per-layer placements.
pub fn plan_breakdown(cfg: &ModelConfig, plan: &SchedulePlan, batch: usize) -> Breakdown {
    let s = graph::schedule_summary(cfg, plan);
    let b = batch as u64;
    Breakdown {
        params: s.class_bytes(MemClass::Params, b),
        grads: s.class_bytes(MemClass::Grads, b),
        optimizer: s.class_bytes(MemClass::OptimizerState, b),
        encoder_activations: s.class_bytes(MemClass::EncoderAct, b),
        other_activations: s.class_bytes(MemClass::OtherAct, b),
        transient: s.class_bytes(MemClass::Workspace, b),
        transient_label: s.high_water,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Gpu;

    #[test]
    fn states_match_param_count() {
        let fp = ModelFootprint::new(ModelConfig::bert_large(), Technique::Baseline);
        let bd = fp.breakdown(1);
        let p = ModelConfig::bert_large().param_count() as u64 * 4;
        assert_eq!(bd.params, p);
        assert_eq!(bd.grads, p);
        assert_eq!(bd.optimizer, 2 * p);
    }

    #[test]
    fn total_is_the_sum_of_rows_and_the_timeline_peak() {
        for tech in Technique::all() {
            let cfg = ModelConfig::bert_base().with_seq_len(256);
            let fp = ModelFootprint::new(cfg, tech);
            let bd = fp.breakdown(8);
            assert_eq!(bd.total(), fp.total_bytes(8), "{tech:?}");
        }
    }

    #[test]
    fn transient_row_is_labeled_by_the_high_water_op() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let base = ModelFootprint::new(cfg.clone(), Technique::Baseline).breakdown(4);
        assert_eq!(base.transient_label, "bwd working set");
        let ck = ModelFootprint::new(cfg, Technique::Checkpoint).breakdown(4);
        assert_eq!(ck.transient_label, "ckpt re-forward + grads");
        assert!(ck.transient > base.transient);
    }

    #[test]
    fn ordering_tempo_between_baseline_and_checkpoint() {
        // Table 2's qualitative structure: checkpoint < tempo < baseline
        // in footprint at equal batch.
        for s in [128, 512] {
            let cfg = ModelConfig::bert_large().with_seq_len(s);
            let base = ModelFootprint::new(cfg.clone(), Technique::Baseline).total_bytes(4);
            let tempo = ModelFootprint::new(cfg.clone(), Technique::Tempo).total_bytes(4);
            let chk = ModelFootprint::new(cfg, Technique::Checkpoint).total_bytes(4);
            assert!(chk < tempo, "S={s}");
            assert!(tempo < base, "S={s}");
        }
    }

    #[test]
    fn paper_total_at_b15_s128_is_about_11gb() {
        // §4.2: Baseline uses 11.3 GB at B=15, S=128 on BERT_LARGE.
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let gb = ModelFootprint::new(cfg, Technique::Baseline).total_bytes(15) as f64 / 1e9;
        assert!((9.5..12.5).contains(&gb), "got {gb:.2} GB");
    }

    #[test]
    fn encoder_dominates_for_bert_base_b32() {
        // Fig 9 / App A: encoder activations ≈ 66% of total for
        // BERT_BASE fine-tuning at B=32, S=128.
        let cfg = ModelConfig::bert_base().with_seq_len(128);
        let bd = ModelFootprint::new(cfg, Technique::Baseline).finetune().breakdown(32);
        let share = bd.encoder_activations as f64 / bd.total() as f64;
        assert!((0.55..0.75).contains(&share), "share={share:.3}");
    }

    #[test]
    fn fits_on_gpu_sanity() {
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let fp = ModelFootprint::new(cfg, Technique::Baseline);
        let usable = Gpu::Rtx2080Ti.spec().usable_bytes();
        assert!(fp.total_bytes(15) <= usable + usable / 6, "B=15 should ~fit");
        assert!(fp.total_bytes(40) > usable, "B=40 must not fit");
    }
}
