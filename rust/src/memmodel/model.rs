//! Whole-model footprint: model states + activations per technique.
//!
//! Every activation number here is a fold over [`crate::graph`] lowered
//! blocks (encoder, embedding, MLM/classification head); whole-segment
//! checkpointing is the graph's segment-level rewrite
//! ([`crate::graph::SegmentCheckpoint`]). No per-technique tensor
//! arithmetic lives in this module.

use crate::config::{ModelConfig, OptimizationSet, Technique};
use crate::graph;

use super::F32;

/// Full memory breakdown at a given batch size (per GPU).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breakdown {
    pub params: u64,
    pub grads: u64,
    pub optimizer: u64,
    /// Encoder-layer retained activations (Fig 9's dominant slice).
    pub encoder_activations: u64,
    /// Embedding + MLM-head activations (incl. the B·S·V logits).
    pub other_activations: u64,
    /// Transient peak during backward of one layer (checkpointing's
    /// recompute live set; small working headroom otherwise).
    pub transient: u64,
}

impl Breakdown {
    pub fn total(&self) -> u64 {
        self.params
            + self.grads
            + self.optimizer
            + self.encoder_activations
            + self.other_activations
            + self.transient
    }

    pub fn activations(&self) -> u64 {
        self.encoder_activations + self.other_activations
    }
}

/// Footprint calculator for one (model, technique) pair.
#[derive(Debug, Clone)]
pub struct ModelFootprint {
    pub cfg: ModelConfig,
    pub technique: Technique,
    /// Fine-grained toggles (ignored for Baseline/Checkpoint).
    pub opts: OptimizationSet,
    /// Pre-training (MLM head with B·S·V logits) vs fine-tuning
    /// (classification head, negligible memory) — Fig 9 is fine-tuning.
    pub mlm_head: bool,
}

impl ModelFootprint {
    pub fn new(cfg: ModelConfig, technique: Technique) -> Self {
        let opts = match technique {
            Technique::Tempo => OptimizationSet::full(),
            _ => OptimizationSet::none(),
        };
        ModelFootprint { cfg, technique, opts, mlm_head: true }
    }

    /// Custom optimization subset (Fig 12 ablation / Auto-Tempo).
    pub fn with_opts(cfg: ModelConfig, opts: OptimizationSet) -> Self {
        ModelFootprint { cfg, technique: Technique::Tempo, opts, mlm_head: true }
    }

    /// Fine-tuning footprint (classification head instead of MLM).
    pub fn finetune(mut self) -> Self {
        self.mlm_head = false;
        self
    }

    /// Model states: fp32 params + fp32 grads + Adam (m, v).
    fn state_bytes(&self) -> (u64, u64, u64) {
        let p = self.cfg.param_count() as u64 * F32;
        (p, p, 2 * p)
    }

    /// Embedding-block activations (gather output, LN, dropout mask):
    /// fold over the lowered embedding block.
    fn embedding_activation_bytes(&self, batch: usize) -> u64 {
        graph::embedding_summary(&self.cfg, self.opts).total_bytes(batch as u64)
    }

    /// Head activations — MLM (transform + GELU + LN + the B·S·V logits
    /// and log-softmax, dominant for real vocabularies) or the tiny
    /// classification head: fold over the lowered head block.
    fn head_activation_bytes(&self, batch: usize) -> u64 {
        graph::head_summary(&self.cfg, self.opts, self.mlm_head).total_bytes(batch as u64)
    }

    /// Full breakdown at batch `b`.
    pub fn breakdown(&self, batch: usize) -> Breakdown {
        let (params, grads, optimizer) = self.state_bytes();
        let b = batch as u64;
        let layers = self.cfg.layers as u64;

        let (encoder, transient) = match self.technique {
            Technique::Checkpoint => {
                // Segment-level rewrite: retain only each block's input,
                // recompute the block during backward. The backward live
                // set holds the recomputed inventory PLUS the activation
                // gradients flowing through it (≈ the same float volume
                // again) — this doubled transient is what caps
                // checkpointing's batch at long S in Table 2.
                let ck = graph::checkpoint_summary(&self.cfg);
                (layers * ck.stored_bytes(b), ck.transient_bytes(b))
            }
            _ => {
                let per_layer = graph::encoder_summary(&self.cfg, self.opts);
                let stored = layers * per_layer.total_bytes(b);
                // backward working headroom: activation grads of the
                // widest rows while one layer's backprop is in flight
                // (rewrite-independent — the gradient rows exist whether
                // or not the forward copy was rewritten away)
                (stored, 2 * per_layer.widest_map_elems * b * F32)
            }
        };

        Breakdown {
            params,
            grads,
            optimizer,
            encoder_activations: encoder,
            other_activations: self.embedding_activation_bytes(batch)
                + self.head_activation_bytes(batch),
            transient,
        }
    }

    /// Total bytes at batch `b`.
    pub fn total_bytes(&self, batch: usize) -> u64 {
        self.breakdown(batch).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Gpu;

    #[test]
    fn states_match_param_count() {
        let fp = ModelFootprint::new(ModelConfig::bert_large(), Technique::Baseline);
        let bd = fp.breakdown(1);
        let p = ModelConfig::bert_large().param_count() as u64 * 4;
        assert_eq!(bd.params, p);
        assert_eq!(bd.grads, p);
        assert_eq!(bd.optimizer, 2 * p);
    }

    #[test]
    fn ordering_tempo_between_baseline_and_checkpoint() {
        // Table 2's qualitative structure: checkpoint < tempo < baseline
        // in footprint at equal batch.
        for s in [128, 512] {
            let cfg = ModelConfig::bert_large().with_seq_len(s);
            let base = ModelFootprint::new(cfg.clone(), Technique::Baseline).total_bytes(4);
            let tempo = ModelFootprint::new(cfg.clone(), Technique::Tempo).total_bytes(4);
            let chk = ModelFootprint::new(cfg, Technique::Checkpoint).total_bytes(4);
            assert!(chk < tempo, "S={s}");
            assert!(tempo < base, "S={s}");
        }
    }

    #[test]
    fn paper_total_at_b15_s128_is_about_11gb() {
        // §4.2: Baseline uses 11.3 GB at B=15, S=128 on BERT_LARGE.
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let gb = ModelFootprint::new(cfg, Technique::Baseline).total_bytes(15) as f64 / 1e9;
        assert!((9.5..12.5).contains(&gb), "got {gb:.2} GB");
    }

    #[test]
    fn encoder_dominates_for_bert_base_b32() {
        // Fig 9 / App A: encoder activations ≈ 66% of total for
        // BERT_BASE fine-tuning at B=32, S=128.
        let cfg = ModelConfig::bert_base().with_seq_len(128);
        let bd = ModelFootprint::new(cfg, Technique::Baseline).finetune().breakdown(32);
        let share = bd.encoder_activations as f64 / bd.total() as f64;
        assert!((0.55..0.75).contains(&share), "share={share:.3}");
    }

    #[test]
    fn fits_on_gpu_sanity() {
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let fp = ModelFootprint::new(cfg, Technique::Baseline);
        let usable = Gpu::Rtx2080Ti.spec().usable_bytes();
        assert!(fp.total_bytes(15) <= usable + usable / 6, "B=15 should ~fit");
        assert!(fp.total_bytes(40) > usable, "B=40 must not fit");
    }
}
