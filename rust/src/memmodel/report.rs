//! Report generators for the memory figures (Fig 9, Fig 12).

use crate::config::{ModelConfig, OptimizationSet, Technique};

use super::layer::layer_activation_bytes;
use super::model::ModelFootprint;

/// One slice of the Fig 9 breakdown pie.
#[derive(Debug, Clone)]
pub struct BreakdownRow {
    /// Row label (weights / gradients / … / high-water working set).
    pub label: &'static str,
    /// Bytes in this slice.
    pub bytes: u64,
    /// Fraction of the total footprint.
    pub share: f64,
}

/// Fig 9 (App. A): GPU memory breakdown for BERT_BASE fine-tuning at
/// B=32, S=128 — weights / gradients / optimizer / encoder activations /
/// other activations, plus the backward working set. The last row used
/// to be labeled "transient" (which read as checkpoint-only); it is now
/// named by the execution schedule's high-water op, so Baseline/Tempo
/// rows report their true backward working-set headroom ("bwd working
/// set") and Checkpoint rows the in-flight recompute inventory
/// ("ckpt re-forward + grads").
pub fn breakdown_fig9(cfg: &ModelConfig, technique: Technique, batch: usize) -> Vec<BreakdownRow> {
    // Fig 9 profiles the MRPC *fine-tuning* task (classification head).
    let bd = ModelFootprint::new(cfg.clone(), technique).finetune().breakdown(batch);
    let total = bd.total() as f64;
    let row = |label, bytes: u64| BreakdownRow { label, bytes, share: bytes as f64 / total };
    vec![
        row("weights", bd.params),
        row("gradients", bd.grads),
        row("optimizer", bd.optimizer),
        row("encoder activations", bd.encoder_activations),
        row("other activations", bd.other_activations),
        row(bd.transient_label, bd.transient),
    ]
}

/// One row of the Fig 12 ablation: per-optimization share of the
/// encoder-layer footprint reduced, at one sequence length.
#[derive(Debug, Clone)]
pub struct AblationRow {
    /// Sequence length of this ablation point.
    pub seq_len: usize,
    /// Which single optimization is toggled on.
    pub optimization: &'static str,
    /// Fraction of the baseline per-layer footprint this optimization
    /// removes (the paper's y-axis).
    pub reduction_share: f64,
}

/// Fig 12 (App. H): per-layer footprint reduction per optimization
/// across sequence lengths, H/A = 64 fixed.
pub fn ablation_fig12(cfg: &ModelConfig, seq_lens: &[usize]) -> Vec<AblationRow> {
    let mut out = Vec::new();
    for &s in seq_lens {
        let c = cfg.with_seq_len(s);
        let base = layer_activation_bytes(&c, 1, OptimizationSet::none()).total() as f64;
        for which in ["gelu", "layernorm", "dropout", "softmax"] {
            let with = layer_activation_bytes(&c, 1, OptimizationSet::only(which).unwrap()).total();
            out.push(AblationRow {
                seq_len: s,
                optimization: match which {
                    "gelu" => "In-place GELU",
                    "layernorm" => "In-place LayerNorm",
                    "dropout" => "Dropout Recompute",
                    _ => "Softmax (out-only)",
                },
                reduction_share: (base - with as f64) / base,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_shares_sum_to_one() {
        let cfg = ModelConfig::bert_base().with_seq_len(128);
        let rows = breakdown_fig9(&cfg, Technique::Baseline, 32);
        let sum: f64 = rows.iter().map(|r| r.share).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert_eq!(rows.len(), 6);
    }

    #[test]
    fn fig9_working_set_row_is_derived_not_checkpoint_flavored() {
        // the old hand-written row was labeled "transient" for every
        // technique; now the schedule's high-water op names it
        let cfg = ModelConfig::bert_base().with_seq_len(128);
        let base = breakdown_fig9(&cfg, Technique::Baseline, 32);
        assert_eq!(base.last().unwrap().label, "bwd working set");
        let ck = breakdown_fig9(&cfg, Technique::Checkpoint, 32);
        assert_eq!(ck.last().unwrap().label, "ckpt re-forward + grads");
    }

    #[test]
    fn fig12_short_seq_dominated_by_gelu_and_ln() {
        // App. H: In-place GELU + LayerNorm provide the bulk of the
        // reduction at short S (their savings go as S·H)…
        let cfg = ModelConfig::bert_base();
        let rows = ablation_fig12(&cfg, &[128]);
        let get = |name: &str| rows.iter().find(|r| r.optimization.contains(name)).unwrap().reduction_share;
        assert!(get("GELU") + get("LayerNorm") > get("Dropout") + get("Softmax"));
    }

    #[test]
    fn fig12_long_seq_dominated_by_s2_optimizations() {
        // …while dropout-recompute + softmax (O(S²)) take over at long S.
        let cfg = ModelConfig::bert_base();
        let rows = ablation_fig12(&cfg, &[2048]);
        let get = |name: &str| rows.iter().find(|r| r.optimization.contains(name)).unwrap().reduction_share;
        assert!(get("Dropout") + get("Softmax") > get("GELU") + get("LayerNorm"));
    }

    #[test]
    fn fig12_crossover_exists() {
        // somewhere between S=128 and S=2048 the O(S²) pair overtakes —
        // the robustness argument of App. H.
        let cfg = ModelConfig::bert_base();
        let mut crossed = false;
        let mut prev_sign = None;
        for s in [128usize, 256, 512, 1024, 2048] {
            let rows = ablation_fig12(&cfg, &[s]);
            let get = |name: &str| {
                rows.iter().find(|r| r.optimization.contains(name)).unwrap().reduction_share
            };
            let diff = (get("GELU") + get("LayerNorm")) - (get("Dropout") + get("Softmax"));
            let sign = diff > 0.0;
            if let Some(p) = prev_sign {
                if p != sign {
                    crossed = true;
                }
            }
            prev_sign = Some(sign);
        }
        assert!(crossed, "no crossover between SH and S² regimes");
    }
}
