//! Capacity fit: largest batch that fits a GPU (Table 2 generator, and
//! the max-batch leg of Auto-Tempo's placement search).

use crate::config::{Gpu, ModelConfig, Technique};
use crate::graph::{self, SchedulePlan};

use super::model::ModelFootprint;

/// Result of a max-batch search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FitResult {
    /// Largest batch whose footprint fits the GPU (0 when even B=1
    /// overflows).
    pub max_batch: usize,
    /// Bytes used at that batch.
    pub bytes_at_max: u64,
    /// Bytes that would be used at max_batch + 1 (the overflow point).
    pub bytes_over: u64,
}

/// Doubling search + binary refine over a monotone byte curve: the
/// shared core of every max-batch query (`total(b)` is the modeled
/// footprint at batch `b`).
fn fit_curve(budget: u64, total: impl Fn(usize) -> u64) -> FitResult {
    let fits = |b: usize| b == 0 || total(b) <= budget;
    if !fits(1) {
        return FitResult { max_batch: 0, bytes_at_max: total(0), bytes_over: total(1) };
    }
    let mut lo = 1usize;
    let mut hi = 2usize;
    while fits(hi) {
        lo = hi;
        hi *= 2;
        if hi > 1 << 20 {
            break; // absurd; avoid pathological loops for tiny models
        }
    }
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    FitResult { max_batch: lo, bytes_at_max: total(lo), bytes_over: total(lo + 1) }
}

/// Largest per-GPU batch size whose footprint fits `gpu`'s usable memory.
///
/// Footprint is monotone in B, so a doubling search + binary refine
/// suffices. Returns batch 0 if even B=1 does not fit (the paper's
/// "BERT at S=512 does not fit a 12 GB GPU at batch 1" observation).
pub fn max_batch(cfg: &ModelConfig, technique: Technique, gpu: Gpu) -> FitResult {
    let fp = ModelFootprint::new(cfg.clone(), technique);
    fit_curve(gpu.spec().usable_bytes(), |b| fp.total_bytes(b))
}

/// Largest per-GPU batch size for an arbitrary execution-schedule plan
/// (the pricing leg of Auto-Tempo's joint placement search): the same
/// doubling + binary refine, binary-searched against the plan's exact
/// liveness-timeline peak (one memoized schedule summary per distinct
/// plan — every probe is an integer multiply).
pub fn max_batch_for_plan(cfg: &ModelConfig, plan: &SchedulePlan, gpu: Gpu) -> FitResult {
    let summary = graph::schedule_summary(cfg, plan);
    fit_curve(gpu.spec().usable_bytes(), |b| summary.peak_bytes(b as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large(s: usize) -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(s)
    }

    #[test]
    fn fit_is_tight() {
        let r = max_batch(&large(128), Technique::Baseline, Gpu::Rtx2080Ti);
        let budget = Gpu::Rtx2080Ti.spec().usable_bytes();
        assert!(r.bytes_at_max <= budget);
        assert!(r.bytes_over > budget);
    }

    #[test]
    fn longer_sequences_fit_fewer() {
        for t in Technique::all() {
            let b128 = max_batch(&large(128), t, Gpu::V100).max_batch;
            let b512 = max_batch(&large(512), t, Gpu::V100).max_batch;
            assert!(b512 < b128, "{t:?}");
        }
    }

    #[test]
    fn bigger_gpu_fits_more() {
        for t in Technique::all() {
            let small = max_batch(&large(512), t, Gpu::Rtx2080Ti).max_batch;
            let big = max_batch(&large(512), t, Gpu::A100).max_batch;
            assert!(big > small, "{t:?}");
        }
    }

    #[test]
    fn plan_fit_agrees_with_technique_fit() {
        // the plan-shaped search binary-searches the same peak the
        // footprint fold reports, so technique plans must agree exactly
        let cfg = large(512);
        for t in Technique::all() {
            let plan = SchedulePlan::for_technique(&cfg, t, true);
            assert_eq!(
                max_batch_for_plan(&cfg, &plan, Gpu::Rtx2080Ti),
                max_batch(&cfg, t, Gpu::Rtx2080Ti),
                "{t:?}"
            );
        }
    }

    #[test]
    fn serial_placement_fits_at_least_as_much_as_overlapped() {
        let cfg = large(512);
        let over = SchedulePlan::for_technique(&cfg, Technique::Checkpoint, true);
        let serial = over.clone().serial();
        let b_over = max_batch_for_plan(&cfg, &over, Gpu::Rtx2080Ti).max_batch;
        let b_serial = max_batch_for_plan(&cfg, &serial, Gpu::Rtx2080Ti).max_batch;
        assert!(b_serial >= b_over, "{b_serial} !>= {b_over}");
    }

    #[test]
    fn technique_ordering_in_max_batch() {
        // Table 2's structure: Baseline < Tempo < Checkpoint everywhere.
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            for s in [128, 512] {
                let base = max_batch(&large(s), Technique::Baseline, gpu).max_batch;
                let tempo = max_batch(&large(s), Technique::Tempo, gpu).max_batch;
                let chk = max_batch(&large(s), Technique::Checkpoint, gpu).max_batch;
                assert!(base < tempo, "{gpu:?} S={s}: {base} !< {tempo}");
                assert!(tempo < chk, "{gpu:?} S={s}: {tempo} !< {chk}");
            }
        }
    }
}
