//! Capacity fit: largest batch that fits a GPU (Table 2 generator).

use crate::config::{Gpu, ModelConfig, Technique};

use super::model::ModelFootprint;

/// Result of a max-batch search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FitResult {
    pub max_batch: usize,
    /// Bytes used at that batch.
    pub bytes_at_max: u64,
    /// Bytes that would be used at max_batch + 1 (the overflow point).
    pub bytes_over: u64,
}

/// Largest per-GPU batch size whose footprint fits `gpu`'s usable memory.
///
/// Footprint is monotone in B, so a doubling search + binary refine
/// suffices. Returns batch 0 if even B=1 does not fit (the paper's
/// "BERT at S=512 does not fit a 12 GB GPU at batch 1" observation).
pub fn max_batch(cfg: &ModelConfig, technique: Technique, gpu: Gpu) -> FitResult {
    let fp = ModelFootprint::new(cfg.clone(), technique);
    let budget = gpu.spec().usable_bytes();
    let fits = |b: usize| b == 0 || fp.total_bytes(b) <= budget;

    if !fits(1) {
        return FitResult { max_batch: 0, bytes_at_max: fp.total_bytes(0), bytes_over: fp.total_bytes(1) };
    }
    let mut lo = 1usize;
    let mut hi = 2usize;
    while fits(hi) {
        lo = hi;
        hi *= 2;
        if hi > 1 << 20 {
            break; // absurd; avoid pathological loops for tiny models
        }
    }
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    FitResult {
        max_batch: lo,
        bytes_at_max: fp.total_bytes(lo),
        bytes_over: fp.total_bytes(lo + 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large(s: usize) -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(s)
    }

    #[test]
    fn fit_is_tight() {
        let r = max_batch(&large(128), Technique::Baseline, Gpu::Rtx2080Ti);
        let budget = Gpu::Rtx2080Ti.spec().usable_bytes();
        assert!(r.bytes_at_max <= budget);
        assert!(r.bytes_over > budget);
    }

    #[test]
    fn longer_sequences_fit_fewer() {
        for t in Technique::all() {
            let b128 = max_batch(&large(128), t, Gpu::V100).max_batch;
            let b512 = max_batch(&large(512), t, Gpu::V100).max_batch;
            assert!(b512 < b128, "{t:?}");
        }
    }

    #[test]
    fn bigger_gpu_fits_more() {
        for t in Technique::all() {
            let small = max_batch(&large(512), t, Gpu::Rtx2080Ti).max_batch;
            let big = max_batch(&large(512), t, Gpu::A100).max_batch;
            assert!(big > small, "{t:?}");
        }
    }

    #[test]
    fn technique_ordering_in_max_batch() {
        // Table 2's structure: Baseline < Tempo < Checkpoint everywhere.
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            for s in [128, 512] {
                let base = max_batch(&large(s), Technique::Baseline, gpu).max_batch;
                let tempo = max_batch(&large(s), Technique::Tempo, gpu).max_batch;
                let chk = max_batch(&large(s), Technique::Checkpoint, gpu).max_batch;
                assert!(base < tempo, "{gpu:?} S={s}: {base} !< {tempo}");
                assert!(tempo < chk, "{gpu:?} S={s}: {tempo} !< {chk}");
            }
        }
    }
}
