//! Crate-wide error type: thin wrapper so public APIs don't leak
//! backend-specific error types (e.g. `xla::Error` under `--features
//! pjrt`).

use std::fmt;

/// Unified error for runtime, IO, config and coordination failures.
#[derive(Debug)]
pub enum Error {
    /// Execution-backend failures (compile, execute, value conversion).
    Backend(String),
    /// Artifact or checkpoint IO.
    Io(std::io::Error),
    /// Manifest / config parse errors.
    Parse(String),
    /// ABI mismatches between manifest and executable.
    Abi(String),
    /// Invalid configuration or arguments.
    Invalid(String),
}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Backend(m) => write!(f, "backend error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Abi(m) => write!(f, "abi mismatch: {m}"),
            Error::Invalid(m) => write!(f, "invalid: {m}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Backend(e.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
