//! Multi-head attention kernels over `[B, S, H]` row-major buffers
//! (head `a` owns columns `[a·D, (a+1)·D)`), plus the fused
//! single-pass forward.
//!
//! The composed kernels mirror the tape's op granularity
//! (`attn.scores` → `attn.softmax` → `attn.dropout` → `attn.pv`) so
//! the interpreter can retain/free exactly what the plan says; the
//! fused forward collapses score+softmax+context into one pass over a
//! single `S`-float scratch row per output row — the shape of the
//! Tempo fused core whose memory the output-only softmax models — and
//! is tolerance-tested against the composed path. Padding positions
//! get an additive `−1e9` score bias before the softmax, matching the
//! BERT additive-mask convention.
//!
//! Everything parallelizes over output rows in fixed bands; the i/j
//! reductions inside dk/dv run serially in index order, so results are
//! bit-identical across `--jobs` counts.

use crate::coordinator::ExperimentEngine;

use super::{axpy, dot, fill_rows};

/// Additive score bias applied at padding positions.
pub const MASK_BIAS: f32 = -1e9;

/// Attention problem sizes.
#[derive(Debug, Clone, Copy)]
pub struct AttnDims {
    /// Batch size B.
    pub batch: usize,
    /// Head count A.
    pub heads: usize,
    /// Sequence length S.
    pub seq: usize,
    /// Per-head width D = H/A.
    pub head_dim: usize,
}

impl AttnDims {
    /// Hidden width H = A·D.
    pub fn hidden(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Score scale 1/√D.
    pub fn scale(&self) -> f32 {
        1.0 / (self.head_dim as f32).sqrt()
    }
}

#[inline]
fn bias(attn_mask: Option<&[i32]>, b: usize, j: usize, seq: usize) -> f32 {
    match attn_mask {
        Some(m) if m[b * seq + j] == 0 => MASK_BIAS,
        _ => 0.0,
    }
}

/// Masked, scaled scores `QKᵀ/√D + bias → [B, A, S, S]`.
pub fn attn_scores(
    engine: &ExperimentEngine,
    q: &[f32],
    k: &[f32],
    attn_mask: Option<&[i32]>,
    d: AttnDims,
) -> Vec<f32> {
    let (s, dd, h) = (d.seq, d.head_dim, d.hidden());
    let scale = d.scale();
    fill_rows(engine, d.batch * d.heads * s, s, |row, out| {
        let i = row % s;
        let a = (row / s) % d.heads;
        let b = row / (s * d.heads);
        let qr = &q[(b * s + i) * h + a * dd..][..dd];
        for (j, o) in out.iter_mut().enumerate() {
            let kr = &k[(b * s + j) * h + a * dd..][..dd];
            *o = dot(qr, kr) * scale + bias(attn_mask, b, j, s);
        }
    })
}

/// Backward of [`attn_scores`]: `(dQ, dK)`, both `[B, S, H]`. The mask
/// bias is additive, so it vanishes from the gradient.
pub fn attn_scores_bwd(
    engine: &ExperimentEngine,
    dscores: &[f32],
    q: &[f32],
    k: &[f32],
    d: AttnDims,
) -> (Vec<f32>, Vec<f32>) {
    let (s, dd, h) = (d.seq, d.head_dim, d.hidden());
    let scale = d.scale();
    let dq = fill_rows(engine, d.batch * s, h, |row, out| {
        let (b, i) = (row / s, row % s);
        for a in 0..d.heads {
            let ds = &dscores[((b * d.heads + a) * s + i) * s..][..s];
            let o = &mut out[a * dd..(a + 1) * dd];
            for (j, &dv) in ds.iter().enumerate() {
                axpy(o, dv * scale, &k[(b * s + j) * h + a * dd..][..dd]);
            }
        }
    });
    let dk = fill_rows(engine, d.batch * s, h, |row, out| {
        let (b, j) = (row / s, row % s);
        for a in 0..d.heads {
            let o = &mut out[a * dd..(a + 1) * dd];
            for i in 0..s {
                let dv = dscores[((b * d.heads + a) * s + i) * s + j];
                axpy(o, dv * scale, &q[(b * s + i) * h + a * dd..][..dd]);
            }
        }
    });
    (dq, dk)
}

/// Context `probs·V`: `[B, A, S, S] × [B, S, H] → [B, S, H]`.
pub fn attn_context(
    engine: &ExperimentEngine,
    probs: &[f32],
    v: &[f32],
    d: AttnDims,
) -> Vec<f32> {
    let (s, dd, h) = (d.seq, d.head_dim, d.hidden());
    fill_rows(engine, d.batch * s, h, |row, out| {
        let (b, i) = (row / s, row % s);
        for a in 0..d.heads {
            let pr = &probs[((b * d.heads + a) * s + i) * s..][..s];
            let o = &mut out[a * dd..(a + 1) * dd];
            for (j, &p) in pr.iter().enumerate() {
                axpy(o, p, &v[(b * s + j) * h + a * dd..][..dd]);
            }
        }
    })
}

/// Backward of [`attn_context`]: `(dprobs [B,A,S,S], dV [B,S,H])`.
pub fn attn_context_bwd(
    engine: &ExperimentEngine,
    dctx: &[f32],
    probs: &[f32],
    v: &[f32],
    d: AttnDims,
) -> (Vec<f32>, Vec<f32>) {
    let (s, dd, h) = (d.seq, d.head_dim, d.hidden());
    let dprobs = fill_rows(engine, d.batch * d.heads * s, s, |row, out| {
        let i = row % s;
        let a = (row / s) % d.heads;
        let b = row / (s * d.heads);
        let dr = &dctx[(b * s + i) * h + a * dd..][..dd];
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(dr, &v[(b * s + j) * h + a * dd..][..dd]);
        }
    });
    let dv = fill_rows(engine, d.batch * s, h, |row, out| {
        let (b, j) = (row / s, row % s);
        for a in 0..d.heads {
            let o = &mut out[a * dd..(a + 1) * dd];
            for i in 0..s {
                let p = probs[((b * d.heads + a) * s + i) * s + j];
                axpy(o, p, &dctx[(b * s + i) * h + a * dd..][..dd]);
            }
        }
    });
    (dprobs, dv)
}

/// Fused attention forward: scores, max-subtracted softmax and context
/// in one pass per output row, never materializing the `[B, A, S, S]`
/// map (dropout disabled — the composed path owns the training
/// semantics; this is the memory shape the §3.4 rewrite prices).
pub fn attention_fwd(
    engine: &ExperimentEngine,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    attn_mask: Option<&[i32]>,
    d: AttnDims,
) -> Vec<f32> {
    let (s, dd, h) = (d.seq, d.head_dim, d.hidden());
    let scale = d.scale();
    fill_rows(engine, d.batch * s, h, |row, out| {
        let (b, i) = (row / s, row % s);
        let mut srow = vec![0f32; s];
        for a in 0..d.heads {
            let qr = &q[(b * s + i) * h + a * dd..][..dd];
            let mut m = f32::NEG_INFINITY;
            for (j, sv) in srow.iter_mut().enumerate() {
                let kr = &k[(b * s + j) * h + a * dd..][..dd];
                *sv = dot(qr, kr) * scale + bias(attn_mask, b, j, s);
                m = m.max(*sv);
            }
            let mut z = 0f64;
            for sv in srow.iter_mut() {
                let e = f64::from(*sv - m).exp();
                *sv = e as f32;
                z += e;
            }
            let inv = (1.0 / z) as f32;
            let o = &mut out[a * dd..(a + 1) * dd];
            for (j, &p) in srow.iter().enumerate() {
                axpy(o, p * inv, &v[(b * s + j) * h + a * dd..][..dd]);
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::norm::softmax_fwd;
    use crate::tensor::Rng;

    fn dims() -> AttnDims {
        AttnDims { batch: 2, heads: 3, seq: 7, head_dim: 4 }
    }

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn fused_forward_matches_composed_path() {
        let d = dims();
        let (n, sc) = (d.batch * d.seq * d.hidden(), d.batch * d.heads * d.seq);
        let mut rng = Rng::new(9);
        let q = randn(&mut rng, n);
        let k = randn(&mut rng, n);
        let v = randn(&mut rng, n);
        let mut mask = vec![1i32; d.batch * d.seq];
        mask[5] = 0; // one padding slot in batch 0
        let e1 = ExperimentEngine::serial();
        let scores = attn_scores(&e1, &q, &k, Some(&mask), d);
        let probs = softmax_fwd(&e1, &scores, sc, d.seq);
        let composed = attn_context(&e1, &probs, &v, d);
        let fused = attention_fwd(&e1, &q, &k, &v, Some(&mask), d);
        for (a, b) in fused.iter().zip(&composed) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(fused, attention_fwd(&ExperimentEngine::new(4), &q, &k, &v, Some(&mask), d));
        // masked position gets ~zero probability everywhere
        for row in 0..sc {
            if row / (d.seq * d.heads) == 0 {
                assert!(probs[row * d.seq + 5] < 1e-9);
            }
        }
    }

    #[test]
    fn context_bwd_matches_finite_differences() {
        let d = dims();
        let (n, sc) = (d.batch * d.seq * d.hidden(), d.batch * d.heads * d.seq);
        let mut rng = Rng::new(10);
        let probs = {
            let x = randn(&mut rng, sc * d.seq);
            softmax_fwd(&ExperimentEngine::serial(), &x, sc, d.seq)
        };
        let v = randn(&mut rng, n);
        let dctx = randn(&mut rng, n);
        let e = ExperimentEngine::serial();
        let (dprobs, dv) = attn_context_bwd(&e, &dctx, &probs, &v, d);
        let loss = |probs: &[f32], v: &[f32]| -> f64 {
            attn_context(&e, probs, v, d)
                .iter()
                .zip(&dctx)
                .map(|(&a, &b)| f64::from(a) * f64::from(b))
                .sum()
        };
        let h = 1e-3f32;
        for &idx in &[0usize, 17, n - 1] {
            let mut vp = v.clone();
            vp[idx] += h;
            let mut vm = v.clone();
            vm[idx] -= h;
            let fd = ((loss(&probs, &vp) - loss(&probs, &vm)) / (2.0 * f64::from(h))) as f32;
            assert!((dv[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()), "dv[{idx}]={} fd={fd}", dv[idx]);
        }
        for &idx in &[3usize, sc * d.seq - 2] {
            let mut pp = probs.clone();
            pp[idx] += h;
            let mut pm = probs.clone();
            pm[idx] -= h;
            let fd = ((loss(&pp, &v) - loss(&pm, &v)) / (2.0 * f64::from(h))) as f32;
            assert!(
                (dprobs[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()),
                "dprobs[{idx}]={} fd={fd}",
                dprobs[idx]
            );
        }
    }

    #[test]
    fn scores_bwd_matches_finite_differences() {
        let d = dims();
        let n = d.batch * d.seq * d.hidden();
        let sc = d.batch * d.heads * d.seq;
        let mut rng = Rng::new(12);
        let q = randn(&mut rng, n);
        let k = randn(&mut rng, n);
        let ds = randn(&mut rng, sc * d.seq);
        let e = ExperimentEngine::serial();
        let (dq, dk) = attn_scores_bwd(&e, &ds, &q, &k, d);
        let loss = |q: &[f32], k: &[f32]| -> f64 {
            attn_scores(&e, q, k, None, d)
                .iter()
                .zip(&ds)
                .map(|(&a, &b)| f64::from(a) * f64::from(b))
                .sum()
        };
        let h = 1e-3f32;
        for &idx in &[0usize, n / 2, n - 1] {
            let mut qp = q.clone();
            qp[idx] += h;
            let mut qm = q.clone();
            qm[idx] -= h;
            let fd = ((loss(&qp, &k) - loss(&qm, &k)) / (2.0 * f64::from(h))) as f32;
            assert!((dq[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()), "dq[{idx}]={} fd={fd}", dq[idx]);
            let mut kp = k.clone();
            kp[idx] += h;
            let mut km = k.clone();
            km[idx] -= h;
            let fd = ((loss(&q, &kp) - loss(&q, &km)) / (2.0 * f64::from(h))) as f32;
            assert!((dk[idx] - fd).abs() < 1e-2 * (1.0 + fd.abs()), "dk[{idx}]={} fd={fd}", dk[idx]);
        }
    }
}
