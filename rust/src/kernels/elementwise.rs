//! Flat elementwise kernels: the GELU map (with the §3.1 in-place
//! backward), seeded dropout, residual adds and scaling.
//!
//! All of these chunk the tensor into fixed [`CHUNK_ELEMS`] spans and
//! fan the chunks out on the engine. Dropout's randomness is keyed
//! `(op_seed, chunk_index, offset)` — a per-chunk SplitMix64 stream
//! forked from the op seed — so a mask depends only on the seed and
//! the element position, never on worker count, tape position or plan
//! shape. That single property carries the backend's determinism and
//! cross-plan parity contracts (DESIGN.md §Kernels).
//!
//! [`CHUNK_ELEMS`]: super::CHUNK_ELEMS

use crate::coordinator::ExperimentEngine;
use crate::tensor::Rng;

use super::{map_elems, math, run_chunks};

/// Fused GELU forward: `(y, mask)` with the paper's one-byte mask
/// recording `x ≥ x*` (footnote 3). The input is then recoverable per
/// branch, which is what lets the in-place rewrite discard it.
pub fn gelu_fwd(engine: &ExperimentEngine, x: &[f32]) -> (Vec<f32>, Vec<u8>) {
    let chunks = run_chunks(engine, x.len(), |_, start, len| {
        let span = &x[start..start + len];
        let mut y = Vec::with_capacity(len);
        let mut m = Vec::with_capacity(len);
        for &v in span {
            y.push(math::gelu(f64::from(v)) as f32);
            m.push(u8::from(f64::from(v) >= math::XSTAR));
        }
        (y, m)
    });
    let mut y = Vec::with_capacity(x.len());
    let mut m = Vec::with_capacity(x.len());
    for (cy, cm) in chunks {
        y.extend_from_slice(&cy);
        m.extend_from_slice(&cm);
    }
    (y, m)
}

/// Stock GELU backward from the retained *input*: `dx = dy·GELU′(x)`.
pub fn gelu_bwd(engine: &ExperimentEngine, dy: &[f32], x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(dy.len(), x.len());
    map_elems(engine, dy, |i, d| (f64::from(d) * math::gelu_grad(f64::from(x[i]))) as f32)
}

/// In-place GELU backward from `(y, mask)` alone (§3.1):
/// `dx = dy · g(y, m)` with `g = GELU′ ∘ GELU⁻¹` evaluated by exact
/// Newton inversion ([`math::gelu_out_grad`]) rather than the paper's
/// lossy polynomial table.
pub fn gelu_bwd_inplace(engine: &ExperimentEngine, dy: &[f32], y: &[f32], mask: &[u8]) -> Vec<f32> {
    debug_assert_eq!(dy.len(), y.len());
    debug_assert_eq!(dy.len(), mask.len());
    map_elems(engine, dy, |i, d| {
        (f64::from(d) * math::gelu_out_grad(f64::from(y[i]), mask[i] != 0)) as f32
    })
}

/// Seeded dropout mask (1 = keep), Bernoulli(1−p) per element.
/// Deterministic in `(op_seed, element index)` only.
pub fn dropout_mask(engine: &ExperimentEngine, len: usize, p: f32, op_seed: u64) -> Vec<u8> {
    let chunks = run_chunks(engine, len, |c, _, n| {
        let mut rng = Rng::new(op_seed).fork(c as u64);
        let mut m = Vec::with_capacity(n);
        for _ in 0..n {
            m.push(u8::from(rng.next_f64() >= f64::from(p)));
        }
        m
    });
    let mut out = Vec::with_capacity(len);
    for c in chunks {
        out.extend_from_slice(&c);
    }
    out
}

/// Apply a dropout mask with inverted-scaling: `y = x·m/(1−p)`. The
/// same map is the dropout backward (applied to `dy`), and the §3.3
/// recompute of a discarded dropped tensor — all three call sites run
/// identical arithmetic, so recomputed values are bit-equal to the
/// originals.
pub fn dropout_apply(engine: &ExperimentEngine, x: &[f32], mask: &[u8], p: f32) -> Vec<f32> {
    debug_assert_eq!(x.len(), mask.len());
    let scale = 1.0 / (1.0 - p);
    map_elems(engine, x, |i, v| if mask[i] != 0 { v * scale } else { 0.0 })
}

/// Elementwise residual add `a + b`.
pub fn add(engine: &ExperimentEngine, a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    map_elems(engine, a, |i, v| v + b[i])
}

/// Elementwise scale `s·x`.
pub fn scale(engine: &ExperimentEngine, x: &[f32], s: f32) -> Vec<f32> {
    map_elems(engine, x, |_, v| v * s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_inplace_backward_matches_input_backward() {
        let e = ExperimentEngine::serial();
        let x: Vec<f32> = (0..4000).map(|i| -6.0 + 12.0 * i as f32 / 3999.0).collect();
        let dy = vec![1.0f32; x.len()];
        let (y, m) = gelu_fwd(&e, &x);
        let from_input = gelu_bwd(&e, &dy, &x);
        let from_output = gelu_bwd_inplace(&e, &dy, &y, &m);
        for (i, (&a, &b)) in from_input.iter().zip(&from_output).enumerate() {
            if f64::from(x[i]) <= math::X_LO_CLAMP {
                assert_eq!(b, 0.0, "clamp region returns exactly 0");
                assert!(a.abs() < 6e-4, "clamped derivative was tiny anyway");
            } else {
                // f32 rounding of y softens the inversion near the
                // minimum; elsewhere the branches agree tightly
                assert!((a - b).abs() < 2e-4, "x={} {a} vs {b}", x[i]);
            }
        }
    }

    #[test]
    fn dropout_mask_is_positional_and_jobs_invariant() {
        let e1 = ExperimentEngine::serial();
        let e4 = ExperimentEngine::new(4);
        let n = super::super::CHUNK_ELEMS * 2 + 100;
        let m1 = dropout_mask(&e1, n, 0.25, 0xDEAD);
        assert_eq!(m1, dropout_mask(&e4, n, 0.25, 0xDEAD));
        assert_ne!(m1, dropout_mask(&e1, n, 0.25, 0xBEEF), "seed matters");
        // a shorter tensor shares its prefix (positional streams)
        let short = dropout_mask(&e1, 100, 0.25, 0xDEAD);
        assert_eq!(&m1[..100], &short[..]);
        let keep = m1.iter().filter(|&&b| b != 0).count() as f64 / n as f64;
        assert!((keep - 0.75).abs() < 0.02, "keep rate {keep}");
    }

    #[test]
    fn dropout_apply_scales_survivors() {
        let e = ExperimentEngine::serial();
        let x = vec![2.0f32; 8];
        let mask = vec![1, 0, 1, 0, 1, 1, 0, 1];
        let y = dropout_apply(&e, &x, &mask, 0.5);
        assert_eq!(y, vec![4.0, 0.0, 4.0, 0.0, 4.0, 4.0, 0.0, 4.0]);
    }
}
