//! LayerNorm and softmax kernels — forward plus the *output-based*
//! backwards the Tempo rewrites rely on.
//!
//! LayerNorm backward always reconstructs `x̂ = (y − β)/γ` from the
//! output (Appendix D): that is exactly the §3.2 in-place rewrite, and
//! using it unconditionally means stock and rewritten plans execute the
//! same instruction stream — gradient parity between them is bit-exact
//! by construction (the stock plan merely *retains more*; see
//! DESIGN.md §Kernels). Softmax backward likewise needs only the
//! output: `dx = (dy − Σ dy·y)·y` (§3.4).
//!
//! Rows are independent, so both kernels band-parallelize over rows;
//! the dγ/dβ cross-row reductions are computed as per-band partials and
//! folded serially in band order (bit-stable across `--jobs`). Row
//! statistics accumulate in f64.

use crate::coordinator::ExperimentEngine;

use super::run_bands;

/// HuggingFace BERT LayerNorm epsilon (`layernorm.py::EPS_DEFAULT`).
pub const LN_EPS: f64 = 1e-12;

/// LayerNorm forward products: the normalized output plus the per-row
/// statistics in both retention flavors (stock keeps `mean`+`var`, the
/// in-place rewrite keeps `rstd` only — the backend stores whichever
/// the plan says and the backward needs only `rstd` either way).
pub struct LayerNormFwd {
    /// `y = (x − μ)·rstd·γ + β`, `rows × cols`.
    pub y: Vec<f32>,
    /// Per-row mean μ.
    pub mean: Vec<f32>,
    /// Per-row (biased) variance.
    pub var: Vec<f32>,
    /// Per-row `1/√(var + eps)`.
    pub rstd: Vec<f32>,
}

/// LayerNorm backward products.
pub struct LayerNormBwd {
    /// Input gradient, `rows × cols`.
    pub dx: Vec<f32>,
    /// Scale gradient, `cols`.
    pub dgamma: Vec<f32>,
    /// Shift gradient, `cols`.
    pub dbeta: Vec<f32>,
}

/// Fused LayerNorm forward over `rows × cols`.
pub fn layernorm_fwd(
    engine: &ExperimentEngine,
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rows: usize,
    cols: usize,
    eps: f64,
) -> LayerNormFwd {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(gamma.len(), cols);
    debug_assert_eq!(beta.len(), cols);
    struct Band {
        y: Vec<f32>,
        mean: Vec<f32>,
        var: Vec<f32>,
        rstd: Vec<f32>,
    }
    let bands = run_bands(engine, rows, |r0, n| {
        let mut band = Band {
            y: vec![0f32; n * cols],
            mean: vec![0f32; n],
            var: vec![0f32; n],
            rstd: vec![0f32; n],
        };
        for j in 0..n {
            let row = &x[(r0 + j) * cols..(r0 + j + 1) * cols];
            let mut s = 0f64;
            for &v in row {
                s += f64::from(v);
            }
            let mu = s / cols as f64;
            let mut vs = 0f64;
            for &v in row {
                let d = f64::from(v) - mu;
                vs += d * d;
            }
            // Round the variance to f32 *first* and derive rstd from
            // that rounding: a stock plan stores `var` and recomputes
            // rstd in backward ([`rstd_from_var`]), an in-place plan
            // stores rstd directly — deriving both from the same f32
            // keeps the two plans' backwards bit-identical.
            let var = (vs / cols as f64) as f32;
            let rstd = 1.0 / (f64::from(var) + eps).sqrt();
            band.mean[j] = mu as f32;
            band.var[j] = var;
            band.rstd[j] = rstd as f32;
            let out = &mut band.y[j * cols..(j + 1) * cols];
            for ((o, &v), (&g, &b)) in out.iter_mut().zip(row).zip(gamma.iter().zip(beta)) {
                *o = ((f64::from(v) - mu) * rstd) as f32 * g + b;
            }
        }
        band
    });
    let mut out = LayerNormFwd {
        y: Vec::with_capacity(rows * cols),
        mean: Vec::with_capacity(rows),
        var: Vec::with_capacity(rows),
        rstd: Vec::with_capacity(rows),
    };
    for b in bands {
        out.y.extend_from_slice(&b.y);
        out.mean.extend_from_slice(&b.mean);
        out.var.extend_from_slice(&b.var);
        out.rstd.extend_from_slice(&b.rstd);
    }
    out
}

/// Recover per-row `rstd` from a stored f32 variance — bit-identical
/// to the `rstd` [`layernorm_fwd`] produced, because the forward also
/// derives it from the f32-rounded variance.
pub fn rstd_from_var(var: &[f32], eps: f64) -> Vec<f32> {
    var.iter().map(|&v| (1.0 / (f64::from(v) + eps).sqrt()) as f32).collect()
}

/// Output-based LayerNorm backward (Appendix D):
/// `x̂ = (y − β)/γ`, `g = dy·γ`,
/// `dx = (g − mean(g·x̂)·x̂ − mean(g))·rstd`,
/// `dγ = Σ_rows dy·x̂`, `dβ = Σ_rows dy`.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_bwd(
    engine: &ExperimentEngine,
    dy: &[f32],
    y: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rstd: &[f32],
    rows: usize,
    cols: usize,
) -> LayerNormBwd {
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(y.len(), rows * cols);
    debug_assert_eq!(rstd.len(), rows);
    let bands = run_bands(engine, rows, |r0, n| {
        let mut dx = vec![0f32; n * cols];
        let mut dgamma = vec![0f32; cols];
        let mut dbeta = vec![0f32; cols];
        let mut xhat = vec![0f32; cols];
        let mut g = vec![0f32; cols];
        for j in 0..n {
            let yr = &y[(r0 + j) * cols..(r0 + j + 1) * cols];
            let dyr = &dy[(r0 + j) * cols..(r0 + j + 1) * cols];
            let r = f64::from(rstd[r0 + j]);
            for (((xh, gv), (&yv, &dyv)), (&gm, &bt)) in xhat
                .iter_mut()
                .zip(g.iter_mut())
                .zip(yr.iter().zip(dyr))
                .zip(gamma.iter().zip(beta))
            {
                *xh = (yv - bt) / gm;
                *gv = dyv * gm;
            }
            let mut sg = 0f64;
            let mut sgx = 0f64;
            for (&gv, &xh) in g.iter().zip(&xhat) {
                sg += f64::from(gv);
                sgx += f64::from(gv) * f64::from(xh);
            }
            let mean_g = sg / cols as f64;
            let mean_gx = sgx / cols as f64;
            let out = &mut dx[j * cols..(j + 1) * cols];
            for ((o, (&gv, &xh)), (dg, (db, &dyv))) in out
                .iter_mut()
                .zip(g.iter().zip(&xhat))
                .zip(dgamma.iter_mut().zip(dbeta.iter_mut().zip(dyr)))
            {
                *o = ((f64::from(gv) - mean_gx * f64::from(xh) - mean_g) * r) as f32;
                *dg += dyv * xh;
                *db += dyv;
            }
        }
        (dx, dgamma, dbeta)
    });
    let mut out = LayerNormBwd {
        dx: Vec::with_capacity(rows * cols),
        dgamma: vec![0f32; cols],
        dbeta: vec![0f32; cols],
    };
    // Fold the per-band partials in band order: the reduction tree is
    // fixed by BAND_ROWS, never by the worker count.
    for (dx, dgamma, dbeta) in bands {
        out.dx.extend_from_slice(&dx);
        for (o, v) in out.dgamma.iter_mut().zip(dgamma) {
            *o += v;
        }
        for (o, v) in out.dbeta.iter_mut().zip(dbeta) {
            *o += v;
        }
    }
    out
}

/// Row-wise max-subtracted softmax over `rows × cols`.
pub fn softmax_fwd(engine: &ExperimentEngine, x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * cols);
    super::fill_rows(engine, rows, cols, |i, out| {
        let row = &x[i * cols..(i + 1) * cols];
        let mut m = f32::NEG_INFINITY;
        for &v in row {
            m = m.max(v);
        }
        let mut s = 0f64;
        for (o, &v) in out.iter_mut().zip(row) {
            let e = f64::from(v - m).exp();
            *o = e as f32;
            s += e;
        }
        let inv = (1.0 / s) as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
    })
}

/// Output-only softmax backward: `dx = (dy − Σ dy·y)·y` per row (§3.4
/// — the input is never needed, so it is never retained).
pub fn softmax_bwd(
    engine: &ExperimentEngine,
    dy: &[f32],
    y: &[f32],
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), rows * cols);
    debug_assert_eq!(y.len(), rows * cols);
    super::fill_rows(engine, rows, cols, |i, out| {
        let yr = &y[i * cols..(i + 1) * cols];
        let dyr = &dy[i * cols..(i + 1) * cols];
        let mut s = 0f64;
        for (&dyv, &yv) in dyr.iter().zip(yr) {
            s += f64::from(dyv) * f64::from(yv);
        }
        let sf = s as f32;
        for ((o, &dyv), &yv) in out.iter_mut().zip(dyr).zip(yr) {
            *o = (dyv - sf) * yv;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn layernorm_normalizes_and_is_jobs_invariant() {
        let (rows, cols) = (70, 33);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 2.0 + 0.5) as f32).collect();
        let gamma: Vec<f32> = (0..cols).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let beta: Vec<f32> = (0..cols).map(|_| 0.1 * rng.normal() as f32).collect();
        let e1 = ExperimentEngine::serial();
        let f = layernorm_fwd(&e1, &x, &gamma, &beta, rows, cols, LN_EPS);
        // each row of (y − β)/γ has ~zero mean and ~unit variance
        for i in 0..rows {
            let mut s = 0f64;
            let mut s2 = 0f64;
            for j in 0..cols {
                let xh = f64::from((f.y[i * cols + j] - beta[j]) / gamma[j]);
                s += xh;
                s2 += xh * xh;
            }
            assert!((s / cols as f64).abs() < 1e-5);
            assert!((s2 / cols as f64 - 1.0).abs() < 1e-4);
        }
        let f4 = layernorm_fwd(&ExperimentEngine::new(4), &x, &gamma, &beta, rows, cols, LN_EPS);
        assert_eq!(f.y, f4.y);
        assert_eq!(f.rstd, f4.rstd);

        let dy: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let b1 = layernorm_bwd(&e1, &dy, &f.y, &gamma, &beta, &f.rstd, rows, cols);
        let b4 =
            layernorm_bwd(&ExperimentEngine::new(4), &dy, &f.y, &gamma, &beta, &f.rstd, rows, cols);
        assert_eq!(b1.dx, b4.dx);
        assert_eq!(b1.dgamma, b4.dgamma);
        assert_eq!(b1.dbeta, b4.dbeta);
        // dβ is the plain column sum
        let mut db = vec![0f32; cols];
        for i in 0..rows {
            for j in 0..cols {
                db[j] += dy[i * cols + j];
            }
        }
        for (a, b) in b1.dbeta.iter().zip(&db) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn rstd_recomputed_from_stored_var_is_bit_identical() {
        let (rows, cols) = (19, 21);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * 3.0) as f32).collect();
        let gamma = vec![1.0f32; cols];
        let beta = vec![0.0f32; cols];
        let f = layernorm_fwd(&ExperimentEngine::serial(), &x, &gamma, &beta, rows, cols, LN_EPS);
        assert_eq!(rstd_from_var(&f.var, LN_EPS), f.rstd);
    }

    #[test]
    fn layernorm_bwd_matches_finite_differences() {
        let (rows, cols) = (4, 9);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let gamma = vec![1.0f32; cols];
        let beta = vec![0.0f32; cols];
        let dy: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let e = ExperimentEngine::serial();
        let f = layernorm_fwd(&e, &x, &gamma, &beta, rows, cols, LN_EPS);
        let b = layernorm_bwd(&e, &dy, &f.y, &gamma, &beta, &f.rstd, rows, cols);
        // loss = Σ dy·y; check ∂loss/∂x by central differences
        let h = 1e-3f32;
        for &idx in &[0usize, 5, 17, rows * cols - 1] {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let yp = layernorm_fwd(&e, &xp, &gamma, &beta, rows, cols, LN_EPS).y;
            let ym = layernorm_fwd(&e, &xm, &gamma, &beta, rows, cols, LN_EPS).y;
            let lp: f64 = yp.iter().zip(&dy).map(|(&a, &b)| f64::from(a) * f64::from(b)).sum();
            let lm: f64 = ym.iter().zip(&dy).map(|(&a, &b)| f64::from(a) * f64::from(b)).sum();
            let fd = ((lp - lm) / (2.0 * f64::from(h))) as f32;
            assert!(
                (b.dx[idx] - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                "dx[{idx}] = {} vs fd {fd}",
                b.dx[idx]
            );
        }
    }

    #[test]
    fn softmax_rows_sum_to_one_and_bwd_is_orthogonal_to_ones() {
        let (rows, cols) = (65, 17);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..rows * cols).map(|_| (3.0 * rng.normal()) as f32).collect();
        let e1 = ExperimentEngine::serial();
        let y = softmax_fwd(&e1, &x, rows, cols);
        assert_eq!(y, softmax_fwd(&ExperimentEngine::new(4), &x, rows, cols));
        for i in 0..rows {
            let s: f64 = y[i * cols..(i + 1) * cols].iter().map(|&v| f64::from(v)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        let dy: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let dx = softmax_bwd(&e1, &dy, &y, rows, cols);
        assert_eq!(dx, softmax_bwd(&ExperimentEngine::new(4), &dy, &y, rows, cols));
        // softmax Jacobian rows are orthogonal to 1: Σ_j dx_j ≈ 0
        for i in 0..rows {
            let s: f64 = dx[i * cols..(i + 1) * cols].iter().map(|&v| f64::from(v)).sum();
            assert!(s.abs() < 1e-4, "row {i} sums to {s}");
        }
    }
}
