//! Real CPU kernels for the graph IR — the numeric layer under
//! [`crate::runtime::KernelBackend`].
//!
//! Every kernel here follows the same execution contract
//! (DESIGN.md §Kernels):
//!
//! * **f32 storage, wide accumulation.** Activations and parameters
//!   live in `f32` slices; dot products accumulate in 8 parallel f32
//!   lanes (folded once at the end) and row statistics / transcendental
//!   math run in `f64`, so the single rounding step happens at the
//!   final store.
//! * **Portable chunked SIMD.** Inner loops are written as chunked
//!   8-wide slice iterations (`chunks_exact(8)` / `zip` over contiguous
//!   slices) that LLVM autovectorizes on any target — no intrinsics,
//!   no feature gates.
//! * **Fixed-grain parallelism.** Work splits into *fixed-size* bands
//!   ([`BAND_ROWS`] output rows, or [`CHUNK_ELEMS`] elements for flat
//!   elementwise maps) fanned out on the
//!   [`ExperimentEngine`](crate::coordinator::ExperimentEngine)
//!   scoped-thread pool. The grain never depends on the worker count
//!   and cross-band reductions are folded serially in band order, so
//!   every kernel is **bit-identical across `--jobs` settings** — the
//!   same contract the sweep engine gives the coordinator
//!   (DESIGN.md §Concurrency).
//!
//! Module map: [`math`] (scalar `erf`/GELU family and the output-side
//! GELU inversion the §3.1 in-place rewrite needs), [`matmul`] (dense
//! GEMM in the three orientations training needs), [`norm`]
//! (LayerNorm and softmax, forward and output-based backward per
//! §3.2/§3.4), [`elementwise`] (GELU maps, seeded dropout, residual
//! adds), [`attention`] (the per-head score/context kernels and the
//! fused single-pass forward).

pub mod attention;
pub mod elementwise;
pub mod math;
pub mod matmul;
pub mod norm;

pub use attention::{
    attention_fwd, attn_context, attn_context_bwd, attn_scores, attn_scores_bwd, AttnDims,
};
pub use elementwise::{
    add, dropout_apply, dropout_mask, gelu_bwd, gelu_bwd_inplace, gelu_fwd, scale,
};
pub use matmul::{bias_grad, matmul, matmul_at, matmul_bias, matmul_bt};
pub use norm::{
    layernorm_bwd, layernorm_fwd, rstd_from_var, softmax_bwd, softmax_fwd, LayerNormBwd,
    LayerNormFwd, LN_EPS,
};

use crate::coordinator::ExperimentEngine;

/// Fixed row band: the parallel grain for row-parallel kernels.
/// Deliberately independent of the worker count so banded reductions
/// stay bit-stable across `--jobs` settings.
pub const BAND_ROWS: usize = 64;

/// Fixed element chunk for flat elementwise kernels (and the grain of
/// their per-chunk dropout RNG streams).
pub const CHUNK_ELEMS: usize = 4096;

/// Split `rows` into [`BAND_ROWS`]-sized bands and run
/// `f(first_row, band_rows)` across the engine's pool; slot `i` of the
/// result is band `i`'s output regardless of completion order.
pub fn run_bands<T: Send>(
    engine: &ExperimentEngine,
    rows: usize,
    f: impl Fn(usize, usize) -> T + Sync,
) -> Vec<T> {
    if rows == 0 {
        return Vec::new();
    }
    let bands = rows.div_ceil(BAND_ROWS);
    engine
        .run_cells(bands, |b| {
            let r0 = b * BAND_ROWS;
            Ok(f(r0, (rows - r0).min(BAND_ROWS)))
        })
        .into_iter()
        .map(|r| r.expect("kernel bands are infallible"))
        .collect()
}

/// Split a flat length into [`CHUNK_ELEMS`]-sized chunks and run
/// `f(chunk_index, start, len)` across the pool (slot-stable).
pub fn run_chunks<T: Send>(
    engine: &ExperimentEngine,
    len: usize,
    f: impl Fn(usize, usize, usize) -> T + Sync,
) -> Vec<T> {
    if len == 0 {
        return Vec::new();
    }
    let chunks = len.div_ceil(CHUNK_ELEMS);
    engine
        .run_cells(chunks, |c| {
            let start = c * CHUNK_ELEMS;
            Ok(f(c, start, (len - start).min(CHUNK_ELEMS)))
        })
        .into_iter()
        .map(|r| r.expect("kernel chunks are infallible"))
        .collect()
}

/// Allocate a zeroed `rows × cols` matrix and fill it band-parallel;
/// `f(row, out_row)` writes one output row.
pub fn fill_rows(
    engine: &ExperimentEngine,
    rows: usize,
    cols: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) -> Vec<f32> {
    let bands = run_bands(engine, rows, |r0, n| {
        let mut chunk = vec![0f32; n * cols];
        for (j, row) in chunk.chunks_exact_mut(cols).enumerate() {
            f(r0 + j, row);
        }
        chunk
    });
    let mut out = Vec::with_capacity(rows * cols);
    for band in bands {
        out.extend_from_slice(&band);
    }
    out
}

/// Map a flat f32 slice chunk-parallel through `f(index, value)`.
pub fn map_elems(
    engine: &ExperimentEngine,
    x: &[f32],
    f: impl Fn(usize, f32) -> f32 + Sync,
) -> Vec<f32> {
    let chunks = run_chunks(engine, x.len(), |_, start, len| {
        x[start..start + len]
            .iter()
            .enumerate()
            .map(|(j, &v)| f(start + j, v))
            .collect::<Vec<f32>>()
    });
    let mut out = Vec::with_capacity(x.len());
    for chunk in chunks {
        out.extend_from_slice(&chunk);
    }
    out
}

/// Chunked 8-lane dot product: deterministic (fixed association,
/// independent of thread count) and autovectorizable.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for j in 0..8 {
            acc[j] += xa[j] * xb[j];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
    for (&xa, &xb) in ca.remainder().iter().zip(cb.remainder()) {
        s += xa * xb;
    }
    s
}

/// `out[i] += s * x[i]` over contiguous slices (axpy; autovectorizes).
#[inline]
pub fn axpy(out: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o += s * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_cover_rows_exactly_once() {
        let engine = ExperimentEngine::new(3);
        let spans = run_bands(&engine, 2 * BAND_ROWS + 7, |r0, n| (r0, n));
        assert_eq!(spans, vec![(0, BAND_ROWS), (BAND_ROWS, BAND_ROWS), (2 * BAND_ROWS, 7)]);
        assert!(run_bands(&engine, 0, |r0, n| (r0, n)).is_empty());
    }

    #[test]
    fn fill_rows_matches_serial_for_any_jobs() {
        let rows = BAND_ROWS + 9;
        let cols = 5;
        let f = |i: usize, out: &mut [f32]| {
            for (j, o) in out.iter_mut().enumerate() {
                *o = (i * cols + j) as f32;
            }
        };
        let serial = fill_rows(&ExperimentEngine::serial(), rows, cols, f);
        let par = fill_rows(&ExperimentEngine::new(4), rows, cols, f);
        assert_eq!(serial, par);
        assert_eq!(serial.len(), rows * cols);
        assert_eq!(serial[rows * cols - 1], (rows * cols - 1) as f32);
    }

    #[test]
    fn dot_handles_remainders() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b = vec![2.0f32; 19];
        let expect: f32 = 2.0 * (0..19).sum::<i32>() as f32;
        assert_eq!(dot(&a, &b), expect);
    }
}
