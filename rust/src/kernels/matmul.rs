//! Dense f32 GEMM in the three orientations a training step needs:
//! `A·B` (forward), `A·Bᵀ` (activation gradients against a stored
//! weight, and QKᵀ scores), and `Aᵀ·B` (weight gradients). All three
//! parallelize over *output* rows in fixed [`BAND_ROWS`] bands, so the
//! result is bit-identical for every `--jobs` setting; inner loops are
//! contiguous-slice axpy/dot forms that LLVM autovectorizes 8-wide.
//!
//! [`BAND_ROWS`]: super::BAND_ROWS

use crate::coordinator::ExperimentEngine;

use super::{axpy, dot, fill_rows};

/// `A[m,k] · B[k,n] → [m,n]`.
pub fn matmul(engine: &ExperimentEngine, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_bias(engine, a, b, None, m, k, n)
}

/// `A[m,k] · B[k,n] (+ bias[n]) → [m,n]` — the fused forward form.
///
/// Row-parallel: each output row walks A's row once and accumulates
/// axpy over B's rows (the `ikj` order — unit-stride streaming through
/// both operands).
pub fn matmul_bias(
    engine: &ExperimentEngine,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    fill_rows(engine, m, n, |i, out| {
        if let Some(bs) = bias {
            out.copy_from_slice(bs);
        }
        let ar = &a[i * k..(i + 1) * k];
        for (l, &av) in ar.iter().enumerate() {
            axpy(out, av, &b[l * n..(l + 1) * n]);
        }
    })
}

/// `A[m,k] · B[n,k]ᵀ → [m,n]` — rows-times-rows dot products.
///
/// The backward's dX = dY·Wᵀ uses this against the stored row-major
/// weight; attention's QKᵀ uses it per head.
pub fn matmul_bt(
    engine: &ExperimentEngine,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    fill_rows(engine, m, n, |i, out| {
        let ar = &a[i * k..(i + 1) * k];
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(ar, &b[j * k..(j + 1) * k]);
        }
    })
}

/// `A[m,k]ᵀ · B[m,n] → [k,n]` — the weight-gradient form dW = Xᵀ·dY.
///
/// Parallel over the k output rows; the m-sum inside each row runs
/// serially in index order, so the reduction is deterministic across
/// worker counts.
pub fn matmul_at(
    engine: &ExperimentEngine,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    fill_rows(engine, k, n, |i, out| {
        for l in 0..m {
            axpy(out, a[l * k + i], &b[l * n..(l + 1) * n]);
        }
    })
}

/// Bias gradient: column sums of `dY[m,n] → [n]`. Serial in row order
/// (the whole reduction is one pass; parallel bands would buy nothing
/// on a vector this small and the order must stay fixed anyway).
pub fn bias_grad(dy: &[f32], m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), m * n);
    let mut out = vec![0f32; n];
    for l in 0..m {
        for (o, &v) in out.iter_mut().zip(&dy[l * n..(l + 1) * n]) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f32;
                for l in 0..k {
                    s += a[i * k + l] * b[l * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn all_orientations_match_naive_and_jobs() {
        let (m, k, n) = (67, 33, 29);
        let mut rng = crate::tensor::Rng::new(7);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let e1 = ExperimentEngine::serial();
        let e4 = ExperimentEngine::new(4);

        let ab = matmul(&e1, &a, &b, m, k, n);
        close(&ab, &naive(&a, &b, m, k, n));
        assert_eq!(ab, matmul(&e4, &a, &b, m, k, n), "jobs-invariant");

        // A·Bᵀ against the transposed operand
        let bt: Vec<f32> = {
            let mut t = vec![0f32; n * k];
            for l in 0..k {
                for j in 0..n {
                    t[j * k + l] = b[l * n + j];
                }
            }
            t
        };
        let ab2 = matmul_bt(&e1, &a, &bt, m, k, n);
        close(&ab2, &naive(&a, &b, m, k, n));
        assert_eq!(ab2, matmul_bt(&e4, &a, &bt, m, k, n));

        // Aᵀ·B: compare against naive on the transposed A
        let at: Vec<f32> = {
            let mut t = vec![0f32; k * m];
            for i in 0..m {
                for l in 0..k {
                    t[l * m + i] = a[i * k + l];
                }
            }
            t
        };
        let c: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let atc = matmul_at(&e1, &a, &c, m, k, n);
        close(&atc, &naive(&at, &c, k, m, n));
        assert_eq!(atc, matmul_at(&e4, &a, &c, m, k, n));
    }

    #[test]
    fn bias_rides_on_the_forward() {
        let (m, k, n) = (3, 4, 5);
        let a = vec![1f32; m * k];
        let b = vec![2f32; k * n];
        let bias: Vec<f32> = (0..n).map(|j| j as f32).collect();
        let out = matmul_bias(&ExperimentEngine::serial(), &a, &b, Some(&bias), m, k, n);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(out[i * n + j], 8.0 + j as f32);
            }
        }
        assert_eq!(bias_grad(&out, m, n), vec![24.0, 27.0, 30.0, 33.0, 36.0]);
    }
}
