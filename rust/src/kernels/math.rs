//! Scalar f64 math for the GELU family: `erf`, GELU, its derivative,
//! and the *output-side* inversion that the §3.1 in-place rewrite
//! needs to run its backward from `(y, mask)` alone.
//!
//! `std` ships no `erf`, and the crate takes no dependencies, so the
//! error function is implemented here via the positive-term Kummer
//! series — every term has the same sign, so there is no cancellation
//! and the result is accurate to a few ulps across the whole useful
//! range (|x| < 6; beyond that `erf` is ±1 to ~2e-17).
//!
//! Where the paper (Appendix F.1) approximates the backward factor
//! `g(y, m) = GELU′(GELU⁻¹(y, m))` with lossy degree-≤13 polynomials,
//! this CPU implementation inverts GELU *exactly* with a safeguarded
//! Newton iteration (bisection fallback, bracketed per mask branch) —
//! a handful of f64 transcendental evaluations per element, which is
//! cheap on a CPU and removes the approximation-error axis from the
//! gradient-parity tests. The paper's clamp semantics are kept: on the
//! drop branch, inputs left of [`X_LO_CLAMP`] have |GELU′| < 6e-4 and
//! the backward factor is 0.

/// GELU minimum abscissa x\* — the root of GELU′, solved by bisection
/// in f64 (matches `python/compile/kernels/gelu.py::XSTAR`). The
/// forward mask records `x ≥ XSTAR`; GELU is one-to-one on each side.
pub const XSTAR: f64 = -0.751_791_524_693_564_47;

/// GELU(x\*) — the minimum value y\* (`gelu.py::YSTAR`).
pub const YSTAR: f64 = -0.169_971_207_479_903_69;

/// Drop-branch clamp: for `x ≤ −4` the derivative magnitude is below
/// 6e-4 and the in-place backward returns 0 (paper Appendix F.1).
pub const X_LO_CLAMP: f64 = -4.0;

/// GELU([`X_LO_CLAMP`]): drop-branch outputs above this value came
/// from the clamp region, so their backward factor is 0.
pub const GELU_AT_X_LO: f64 = -1.266_849_673_324_799_1e-4;

/// Error function.
///
/// Kummer-series form `erf(x) = 2/√π · e^(−x²) · Σₙ x^(2n+1)·2ⁿ/(2n+1)!!`
/// — all terms positive, so no cancellation at any `x`.
pub fn erf(x: f64) -> f64 {
    let ax = x.abs();
    if ax == 0.0 {
        return x;
    }
    if ax >= 6.0 {
        // |erfc| < 3e-17: saturated at f64 precision.
        return 1.0f64.copysign(x);
    }
    let x2 = ax * ax;
    let mut term = ax;
    let mut sum = ax;
    let mut n = 0u32;
    while term > sum * 1e-18 && n < 400 {
        n += 1;
        term *= 2.0 * x2 / (2.0 * f64::from(n) + 1.0);
        sum += term;
    }
    let r = 2.0 / std::f64::consts::PI.sqrt() * (-x2).exp() * sum;
    r.copysign(x)
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x * std::f64::consts::FRAC_1_SQRT_2))
}

/// Standard normal PDF φ(x).
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Exact (erf-based) GELU: `x · Φ(x)`.
pub fn gelu(x: f64) -> f64 {
    x * norm_cdf(x)
}

/// GELU derivative: `Φ(x) + x · φ(x)`.
pub fn gelu_grad(x: f64) -> f64 {
    norm_cdf(x) + x * norm_pdf(x)
}

/// Invert `y = GELU(x)` on the branch selected by `keep` (the forward
/// mask `x ≥ x*`). Safeguarded Newton: the bracket shrinks every
/// iteration (bisection step whenever Newton leaves it), so the loop
/// always converges; Newton makes it quadratic near the root.
pub fn gelu_invert(y: f64, keep: bool) -> f64 {
    if y <= YSTAR {
        // At (or, after f32 rounding, fractionally below) the minimum.
        return XSTAR;
    }
    if keep {
        // Increasing branch [x*, ∞). gelu(x) ≥ x + y* gives the bracket.
        let hi = if y > 1.0 { y - YSTAR } else { 1.2 };
        solve(y, XSTAR, hi, true)
    } else {
        // Decreasing branch (−∞, x*]; the clamp region never reaches
        // the solver (callers check GELU_AT_X_LO first), but keep the
        // bracket defensive.
        if y >= GELU_AT_X_LO {
            return X_LO_CLAMP;
        }
        solve(y, X_LO_CLAMP, XSTAR, false)
    }
}

/// The in-place GELU backward factor `g(y, m) = GELU′(GELU⁻¹(y, m))`,
/// with the paper's drop-branch clamp (`x ≤ −4 → 0`).
pub fn gelu_out_grad(y: f64, keep: bool) -> f64 {
    if y <= YSTAR {
        return 0.0; // the minimum itself: GELU′(x*) = 0
    }
    if keep {
        gelu_grad(gelu_invert(y, true))
    } else if y >= GELU_AT_X_LO {
        0.0 // clamp region (Appendix F.1)
    } else {
        gelu_grad(gelu_invert(y, false))
    }
}

fn solve(y: f64, mut lo: f64, mut hi: f64, increasing: bool) -> f64 {
    let mut x = 0.5 * (lo + hi);
    for _ in 0..80 {
        let f = gelu(x) - y;
        if f == 0.0 {
            return x;
        }
        if (f > 0.0) == increasing {
            hi = x;
        } else {
            lo = x;
        }
        let d = gelu_grad(x);
        let newton = x - f / d;
        x = if newton.is_finite() && newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
        if hi - lo <= f64::EPSILON * (1.0 + x.abs()) {
            break;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_matches_known_values() {
        // Reference values from the f64 math library (15+ digits).
        let cases = [
            (0.5, 0.520_499_877_813_046_5),
            (1.0, 0.842_700_792_949_714_9),
            (2.0, 0.995_322_265_018_952_7),
            (-1.5, -0.966_105_146_475_310_7),
            (4.0, 0.999_999_984_582_742_1),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 1e-14, "erf({x}) = {} want {want}", erf(x));
        }
        assert_eq!(erf(7.0), 1.0);
        assert_eq!(erf(-7.0), -1.0);
        assert_eq!(erf(0.0), 0.0);
    }

    #[test]
    fn gelu_minimum_constants_are_consistent() {
        // x* is the root of GELU′ and y* its value.
        assert!(gelu_grad(XSTAR).abs() < 1e-12);
        assert!((gelu(XSTAR) - YSTAR).abs() < 1e-15);
        assert!((gelu(X_LO_CLAMP) - GELU_AT_X_LO).abs() < 1e-18);
    }

    #[test]
    fn invert_round_trips_both_branches() {
        for i in 0..200 {
            // keep branch: x ∈ [x*, 8]
            let x = XSTAR + (8.0 - XSTAR) * f64::from(i) / 199.0;
            let xi = gelu_invert(gelu(x), true);
            assert!((xi - x).abs() < 1e-9 * (1.0 + x.abs()), "keep x={x} xi={xi}");
            // drop branch: x ∈ [−4, x*]
            let x = X_LO_CLAMP + (XSTAR - X_LO_CLAMP) * f64::from(i) / 199.0;
            let xi = gelu_invert(gelu(x), false);
            assert!((xi - x).abs() < 1e-6 * (1.0 + x.abs()), "drop x={x} xi={xi}");
        }
    }

    #[test]
    fn out_grad_matches_direct_derivative() {
        for i in 0..400 {
            let x = -3.9 + 10.0 * f64::from(i) / 399.0;
            let keep = x >= XSTAR;
            let g = gelu_out_grad(gelu(x), keep);
            assert!(
                (g - gelu_grad(x)).abs() < 1e-7,
                "x={x} g={g} direct={}",
                gelu_grad(x)
            );
        }
        // clamp region: factor pinned to zero
        assert_eq!(gelu_out_grad(gelu(-5.0), false), 0.0);
    }
}
