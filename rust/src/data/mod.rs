//! Synthetic data substrate.
//!
//! The paper trains on English Wikipedia / WikiText / MRPC; none are
//! shippable here, so this module synthesizes corpora with the same
//! *statistical* properties the experiments depend on (Zipfian unigram
//! distribution + local structure a language model can actually learn,
//! so loss curves fall) and an MRPC-like paraphrase-pair task whose
//! labels are learnable from token overlap. The paper's claims are
//! variant-vs-variant comparisons, which are dataset-agnostic —
//! DESIGN.md §2 documents the substitution.

mod corpus;
mod mlm;
mod pairs;

pub use corpus::{Corpus, CorpusConfig};
pub use mlm::{MlmBatch, MlmBatcher, MlmConfig};
pub use pairs::{PairBatch, PairTask};
