//! Synthetic corpus: Zipfian unigrams + order-1 Markov bigram structure.
//!
//! Token frequencies follow a Zipf law (like natural text), and each
//! token deterministically prefers a small successor set (seeded hash),
//! giving the model real mutual information to learn — a masked-LM
//! trained on this corpus shows a falling loss curve like Fig 6a.

use crate::tensor::Rng;

/// Reserved padding token id (BERT conventions).
pub const PAD: i32 = 0;
/// `[CLS]` token id.
pub const CLS: i32 = 1;
/// `[SEP]` token id.
pub const SEP: i32 = 2;
/// `[MASK]` token id.
pub const MASK: i32 = 3;
/// `[UNK]` token id (reserved; the synthetic corpus never emits it).
#[allow(dead_code)]
pub const UNK: i32 = 4;
/// First ordinary vocabulary id.
pub const FIRST_WORD: i32 = 5;

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    /// Vocabulary size (including the reserved special ids).
    pub vocab_size: usize,
    /// Zipf exponent (≈1 for natural language).
    pub zipf_s: f64,
    /// Probability of following the Markov link vs drawing fresh.
    pub coherence: f64,
    /// Successor-set size per token.
    pub branching: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { vocab_size: 4096, zipf_s: 1.05, coherence: 0.65, branching: 4 }
    }
}

/// A seeded synthetic corpus; generates token streams on demand.
#[derive(Debug, Clone)]
pub struct Corpus {
    cfg: CorpusConfig,
    /// Cumulative Zipf distribution over word ids.
    cumw: Vec<f64>,
    seed: u64,
}

impl Corpus {
    /// Seeded corpus with a precomputed cumulative Zipf table.
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        let n_words = cfg.vocab_size - FIRST_WORD as usize;
        let mut cumw = Vec::with_capacity(n_words);
        let mut acc = 0.0;
        for r in 1..=n_words {
            acc += 1.0 / (r as f64).powf(cfg.zipf_s);
            cumw.push(acc);
        }
        Corpus { cfg, cumw, seed }
    }

    /// The configured vocabulary size.
    pub fn vocab_size(&self) -> usize {
        self.cfg.vocab_size
    }

    /// Draw one token from the Zipf marginal.
    fn draw_zipf(&self, rng: &mut Rng) -> i32 {
        let total = *self.cumw.last().unwrap();
        let t = rng.next_f64() * total;
        // binary search the cumulative table
        let idx = self.cumw.partition_point(|&c| c < t);
        FIRST_WORD + idx.min(self.cumw.len() - 1) as i32
    }

    /// Deterministic successor of `tok` (k-th branch) — the Markov link.
    fn successor(&self, tok: i32, k: usize) -> i32 {
        let n_words = (self.cfg.vocab_size - FIRST_WORD as usize) as u64;
        let mut h = (tok as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(self.seed)
            .wrapping_add((k as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        h ^= h >> 29;
        h = h.wrapping_mul(0x94D049BB133111EB);
        h ^= h >> 32;
        // Skew successors toward the frequent head (u² mapping) so the
        // Markov-linked tokens keep the corpus marginal Zipf-like.
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        FIRST_WORD + ((u * u * n_words as f64) as u64).min(n_words - 1) as i32
    }

    /// Generate a sentence of `len` tokens (no special tokens).
    pub fn sentence(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut prev = self.draw_zipf(rng);
        out.push(prev);
        while out.len() < len {
            let tok = if rng.coin(self.cfg.coherence) {
                self.successor(prev, rng.below(self.cfg.branching))
            } else {
                self.draw_zipf(rng)
            };
            out.push(tok);
            prev = tok;
        }
        out
    }

    /// A full `[CLS] sent [SEP]`-framed sequence padded to `seq_len`.
    /// Returns (ids, attention_mask).
    pub fn sequence(&self, rng: &mut Rng, seq_len: usize) -> (Vec<i32>, Vec<i32>) {
        // vary real length to exercise padding (paper uses packed 128/512)
        let body = seq_len - 2;
        let real = rng.range(body / 2, body + 1);
        let sent = self.sentence(rng, real);
        let mut ids = Vec::with_capacity(seq_len);
        ids.push(CLS);
        ids.extend(&sent);
        ids.push(SEP);
        let mut mask = vec![1i32; ids.len()];
        while ids.len() < seq_len {
            ids.push(PAD);
            mask.push(0);
        }
        (ids, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::default(), 7)
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = corpus();
        let mut rng = Rng::new(1);
        for tok in c.sentence(&mut rng, 1000) {
            assert!((FIRST_WORD..c.vocab_size() as i32).contains(&tok));
        }
    }

    #[test]
    fn zipf_head_is_heavy() {
        let c = corpus();
        let mut rng = Rng::new(2);
        let toks = c.sentence(&mut rng, 50_000);
        let head = toks.iter().filter(|&&t| t < FIRST_WORD + 100).count();
        // top-100 words should carry a large share under Zipf(1.05)
        assert!(head as f64 / toks.len() as f64 > 0.3);
    }

    #[test]
    fn markov_structure_exists() {
        // successors of a token should repeat far above chance
        let c = corpus();
        let mut rng = Rng::new(3);
        let toks = c.sentence(&mut rng, 200_000);
        let probe = toks[0];
        let mut followers = std::collections::HashMap::new();
        for w in toks.windows(2) {
            if w[0] == probe {
                *followers.entry(w[1]).or_insert(0usize) += 1;
            }
        }
        let total: usize = followers.values().sum();
        if total >= 50 {
            let max = *followers.values().max().unwrap();
            assert!(
                max as f64 / total as f64 > 0.05,
                "no dominant successor ({max}/{total})"
            );
        }
    }

    #[test]
    fn sequence_is_framed_and_padded() {
        let c = corpus();
        let mut rng = Rng::new(4);
        let (ids, mask) = c.sequence(&mut rng, 64);
        assert_eq!(ids.len(), 64);
        assert_eq!(mask.len(), 64);
        assert_eq!(ids[0], CLS);
        let n_real = mask.iter().filter(|&&m| m == 1).count();
        assert_eq!(ids[n_real - 1], SEP);
        assert!(ids[n_real..].iter().all(|&t| t == PAD));
        assert!(mask[..n_real].iter().all(|&m| m == 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = {
            let mut rng = Rng::new(9);
            corpus().sentence(&mut rng, 64)
        };
        let b = {
            let mut rng = Rng::new(9);
            corpus().sentence(&mut rng, 64)
        };
        assert_eq!(a, b);
    }
}
