//! MRPC-analogue paraphrase-pair task (for the Fig 6b fine-tuning
//! experiment): sentence pairs `[CLS] a [SEP] b [SEP]` labelled 1 when
//! `b` is a light corruption of `a` (prefix-preserving token
//! dropout/swap), 0 when `b` is an independent sentence drawn from a
//! *shifted register* (its tokens mapped into a rotated vocabulary
//! range).
//!
//! Design note: the paper fine-tunes a *pre-trained* BERT, for which
//! genuine paraphrase overlap is learnable. Our Fig 6b analogue starts
//! from random init (no pre-trained checkpoint exists for the synthetic
//! vocabulary), so the negative class carries an additional absolute
//! distributional signal — keeping the experiment's actual claim
//! (baseline and Tempo accuracy bands overlap) testable within a few
//! hundred CPU steps.

use crate::data::corpus::{Corpus, CLS, PAD, SEP};
use crate::tensor::{HostTensor, Rng};
use crate::Result;

/// One classification batch (labels packed in column 0, ABI with cls task).
#[derive(Debug, Clone)]
pub struct PairBatch {
    /// `B×S` token ids (`[CLS] a [SEP] b [SEP]`).
    pub input_ids: HostTensor,
    /// `B×S` segment ids (0 for sentence a, 1 for sentence b).
    pub token_type_ids: HostTensor,
    /// `B×S` attention mask (1 = real token, 0 = padding).
    pub attention_mask: HostTensor,
    /// Labels packed in column 0 of a `B×S` tensor (the cls ABI).
    pub labels: HostTensor,
    /// Plain copy of the per-row labels for host-side accuracy checks.
    pub label_vec: Vec<i32>,
}

impl PairBatch {
    /// The four tensors in manifest `batch_inputs` order.
    pub fn tensors(&self) -> [&HostTensor; 4] {
        [&self.input_ids, &self.token_type_ids, &self.attention_mask, &self.labels]
    }
}

/// Paraphrase-pair generator.
pub struct PairTask {
    corpus: Corpus,
    batch_size: usize,
    seq_len: usize,
    rng: Rng,
    /// Corruption strength for positive pairs (fraction of tokens edited).
    pub noise: f64,
}

impl PairTask {
    /// Seeded pair generator with the ABI's batch/sequence shape.
    pub fn new(corpus: Corpus, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        PairTask { corpus, batch_size, seq_len, rng: Rng::new(seed), noise: 0.2 }
    }

    /// Tokens at the head of a positive pair's second sentence that are
    /// kept verbatim — the position-aligned overlap a small from-scratch
    /// encoder can exploit (a pre-trained model, as in the paper's MRPC
    /// runs, would not need this crutch).
    const KEEP_PREFIX: usize = 10;

    /// Map a sentence into the rotated half of the vocabulary (the
    /// negative-class "register"; see module docs).
    fn shift_register(&self, sent: &[i32]) -> Vec<i32> {
        let first = crate::data::corpus::FIRST_WORD;
        let n = (self.corpus.vocab_size() as i32 - first) as i64;
        sent.iter()
            .map(|&t| {
                let idx = (t - first) as i64;
                first + ((idx + n / 2) % n) as i32
            })
            .collect()
    }

    fn corrupt(&mut self, sent: &[i32]) -> Vec<i32> {
        let mut out = sent.to_vec();
        for i in Self::KEEP_PREFIX..out.len() {
            if self.rng.coin(self.noise) {
                match self.rng.below(3) {
                    0 if i + 1 < out.len() => out.swap(i, i + 1),
                    1 => {
                        // substitute with a Markov-plausible token
                        let mut r2 = self.rng.fork(i as u64);
                        out[i] = self.corpus.sentence(&mut r2, 1)[0];
                    }
                    _ => {} // keep
                }
            }
        }
        out
    }

    /// Next batch of pairs (balanced labels in expectation).
    pub fn next_batch(&mut self) -> Result<PairBatch> {
        let (b, s) = (self.batch_size, self.seq_len);
        let body = (s - 3) / 2; // room for [CLS] a [SEP] b [SEP]
        let mut ids = Vec::with_capacity(b * s);
        let mut attn = Vec::with_capacity(b * s);
        let mut types = Vec::with_capacity(b * s);
        let mut labels = vec![0i32; b * s];
        let mut label_vec = Vec::with_capacity(b);
        for row in 0..b {
            let len_a = self.rng.range(body / 2, body + 1);
            let mut rng_a = self.rng.fork(row as u64);
            let a = self.corpus.sentence(&mut rng_a, len_a);
            let positive = self.rng.coin(0.5);
            let b_sent = if positive {
                self.corrupt(&a)
            } else {
                let len_b = self.rng.range(body / 2, body + 1);
                let mut rng_b = self.rng.fork(row as u64 + 1_000_003);
                let raw = self.corpus.sentence(&mut rng_b, len_b);
                self.shift_register(&raw)
            };
            let mut row_ids = vec![CLS];
            let mut row_types = vec![0i32];
            row_ids.extend(&a);
            row_types.extend(std::iter::repeat(0).take(a.len()));
            row_ids.push(SEP);
            row_types.push(0);
            let b_trunc: Vec<i32> = b_sent.into_iter().take(body).collect();
            row_ids.extend(&b_trunc);
            row_types.extend(std::iter::repeat(1).take(b_trunc.len()));
            row_ids.push(SEP);
            row_types.push(1);
            row_ids.truncate(s);
            row_types.truncate(s);
            let real = row_ids.len();
            let mut row_attn = vec![1i32; real];
            while row_ids.len() < s {
                row_ids.push(PAD);
                row_types.push(0);
                row_attn.push(0);
            }
            ids.extend(row_ids);
            types.extend(row_types);
            attn.extend(row_attn);
            labels[row * s] = positive as i32;
            label_vec.push(positive as i32);
        }
        Ok(PairBatch {
            input_ids: HostTensor::i32(vec![b, s], ids)?,
            token_type_ids: HostTensor::i32(vec![b, s], types)?,
            attention_mask: HostTensor::i32(vec![b, s], attn)?,
            labels: HostTensor::i32(vec![b, s], labels)?,
            label_vec,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn task(seed: u64) -> PairTask {
        PairTask::new(Corpus::new(CorpusConfig::default(), 5), 16, 64, seed)
    }

    #[test]
    fn batch_layout() {
        let b = task(1).next_batch().unwrap();
        assert_eq!(b.input_ids.shape(), &[16, 64]);
        assert_eq!(b.label_vec.len(), 16);
        let _ = b.tensors();
    }

    #[test]
    fn labels_balanced_in_expectation() {
        let mut t = task(2);
        let mut pos = 0usize;
        let mut total = 0usize;
        for _ in 0..20 {
            let b = t.next_batch().unwrap();
            pos += b.label_vec.iter().filter(|&&l| l == 1).count();
            total += b.label_vec.len();
        }
        let rate = pos as f64 / total as f64;
        assert!((0.4..0.6).contains(&rate), "rate={rate}");
    }

    #[test]
    fn positives_overlap_more_than_negatives() {
        let mut t = task(3);
        let mut pos_overlap = Vec::new();
        let mut neg_overlap = Vec::new();
        for _ in 0..10 {
            let batch = t.next_batch().unwrap();
            let ids = batch.input_ids.as_i32().unwrap();
            let types = batch.token_type_ids.as_i32().unwrap();
            let attn = batch.attention_mask.as_i32().unwrap();
            for row in 0..16 {
                let s = 64;
                let row_ids = &ids[row * s..(row + 1) * s];
                let row_ty = &types[row * s..(row + 1) * s];
                let row_at = &attn[row * s..(row + 1) * s];
                let seg_a: std::collections::HashSet<i32> = row_ids
                    .iter()
                    .zip(row_ty)
                    .zip(row_at)
                    .filter(|((&t_, &ty), &at)| at == 1 && ty == 0 && t_ > 4)
                    .map(|((&t_, _), _)| t_)
                    .collect();
                let seg_b: Vec<i32> = row_ids
                    .iter()
                    .zip(row_ty)
                    .zip(row_at)
                    .filter(|((&t_, &ty), &at)| at == 1 && ty == 1 && t_ > 4)
                    .map(|((&t_, _), _)| t_)
                    .collect();
                if seg_b.is_empty() {
                    continue;
                }
                let overlap = seg_b.iter().filter(|t_| seg_a.contains(t_)).count() as f64
                    / seg_b.len() as f64;
                if batch.label_vec[row] == 1 {
                    pos_overlap.push(overlap);
                } else {
                    neg_overlap.push(overlap);
                }
            }
        }
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(
            m(&pos_overlap) > m(&neg_overlap) + 0.3,
            "pos={} neg={}",
            m(&pos_overlap),
            m(&neg_overlap)
        );
    }

    #[test]
    fn segment_ids_mark_second_sentence() {
        let b = task(4).next_batch().unwrap();
        let types = b.token_type_ids.as_i32().unwrap();
        assert!(types.iter().any(|&t| t == 1));
        assert!(types.iter().all(|&t| t == 0 || t == 1));
    }
}
