//! BERT masked-LM batch construction (80/10/10 masking, label = -100 on
//! unmasked positions — HuggingFace conventions, matching the L2 loss).

use crate::data::corpus::{Corpus, CLS, FIRST_WORD, MASK, PAD, SEP};
use crate::tensor::{HostTensor, Rng};
use crate::Result;

/// Masking hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct MlmConfig {
    /// Fraction of (non-special) tokens selected for prediction.
    pub mask_prob: f64,
    /// Of the selected: replaced by [MASK] (0.8), random (0.1), kept (0.1).
    pub replace_mask: f64,
    /// Of the selected: replaced by a random token.
    pub replace_random: f64,
}

impl Default for MlmConfig {
    fn default() -> Self {
        MlmConfig { mask_prob: 0.15, replace_mask: 0.8, replace_random: 0.1 }
    }
}

/// One MLM training batch in the artifact ABI layout.
#[derive(Debug, Clone)]
pub struct MlmBatch {
    /// `B×S` token ids (with `[MASK]`/random substitutions applied).
    pub input_ids: HostTensor,
    /// `B×S` segment ids (all zero for single-sentence MLM).
    pub token_type_ids: HostTensor,
    /// `B×S` attention mask (1 = real token, 0 = padding).
    pub attention_mask: HostTensor,
    /// `B×S` MLM labels (-100 on unmasked positions).
    pub labels: HostTensor,
}

impl MlmBatch {
    /// The four tensors in manifest `batch_inputs` order.
    pub fn tensors(&self) -> [&HostTensor; 4] {
        [&self.input_ids, &self.token_type_ids, &self.attention_mask, &self.labels]
    }
}

/// Streaming batch generator over a synthetic corpus.
pub struct MlmBatcher {
    corpus: Corpus,
    cfg: MlmConfig,
    batch_size: usize,
    seq_len: usize,
    rng: Rng,
}

impl MlmBatcher {
    /// Seeded batcher over `corpus` with the ABI's batch/sequence shape.
    pub fn new(corpus: Corpus, cfg: MlmConfig, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        MlmBatcher { corpus, cfg, batch_size, seq_len, rng: Rng::new(seed) }
    }

    /// Produce the next batch.
    pub fn next_batch(&mut self) -> Result<MlmBatch> {
        let (b, s) = (self.batch_size, self.seq_len);
        let mut ids = Vec::with_capacity(b * s);
        let mut attn = Vec::with_capacity(b * s);
        let mut labels = vec![-100i32; b * s];
        for row in 0..b {
            let (seq, mask) = self.corpus.sequence(&mut self.rng, s);
            for (col, (&tok, &m)) in seq.iter().zip(mask.iter()).enumerate() {
                let idx = row * s + col;
                let special = matches!(tok, PAD | CLS | SEP | MASK);
                let mut out_tok = tok;
                if m == 1 && !special && self.rng.coin(self.cfg.mask_prob) {
                    labels[idx] = tok;
                    let r = self.rng.next_f64();
                    if r < self.cfg.replace_mask {
                        out_tok = MASK;
                    } else if r < self.cfg.replace_mask + self.cfg.replace_random {
                        out_tok = FIRST_WORD
                            + self.rng.below(self.corpus.vocab_size() - FIRST_WORD as usize) as i32;
                    } // else keep original
                }
                ids.push(out_tok);
                attn.push(m);
            }
        }
        Ok(MlmBatch {
            input_ids: HostTensor::i32(vec![b, s], ids)?,
            token_type_ids: HostTensor::zeros(crate::tensor::Dtype::I32, vec![b, s]),
            attention_mask: HostTensor::i32(vec![b, s], attn)?,
            labels: HostTensor::i32(vec![b, s], labels)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn batcher(seed: u64) -> MlmBatcher {
        let corpus = Corpus::new(CorpusConfig::default(), 5);
        MlmBatcher::new(corpus, MlmConfig::default(), 4, 64, seed)
    }

    #[test]
    fn shapes_and_dtypes() {
        let b = batcher(1).next_batch().unwrap();
        assert_eq!(b.input_ids.shape(), &[4, 64]);
        assert_eq!(b.labels.shape(), &[4, 64]);
        assert_eq!(b.tensors().len(), 4);
    }

    #[test]
    fn mask_rate_near_15_percent() {
        let mut gen = batcher(2);
        let mut masked = 0usize;
        let mut real = 0usize;
        for _ in 0..20 {
            let b = gen.next_batch().unwrap();
            let labels = b.labels.as_i32().unwrap();
            let attn = b.attention_mask.as_i32().unwrap();
            masked += labels.iter().filter(|&&l| l >= 0).count();
            real += attn.iter().filter(|&&m| m == 1).count();
        }
        let rate = masked as f64 / real as f64;
        assert!((0.10..0.20).contains(&rate), "rate={rate}");
    }

    #[test]
    fn labels_only_on_real_tokens() {
        let b = batcher(3).next_batch().unwrap();
        let labels = b.labels.as_i32().unwrap();
        let attn = b.attention_mask.as_i32().unwrap();
        for (l, m) in labels.iter().zip(attn) {
            if *m == 0 {
                assert_eq!(*l, -100);
            }
        }
    }

    #[test]
    fn masked_positions_mostly_mask_token() {
        let mut gen = batcher(4);
        let mut mask_tok = 0usize;
        let mut total = 0usize;
        for _ in 0..10 {
            let b = gen.next_batch().unwrap();
            let ids = b.input_ids.as_i32().unwrap();
            let labels = b.labels.as_i32().unwrap();
            for (i, l) in labels.iter().enumerate() {
                if *l >= 0 {
                    total += 1;
                    if ids[i] == MASK {
                        mask_tok += 1;
                    }
                }
            }
        }
        let frac = mask_tok as f64 / total as f64;
        assert!((0.7..0.9).contains(&frac), "frac={frac}");
    }

    #[test]
    fn deterministic_stream() {
        let a = batcher(9).next_batch().unwrap();
        let b = batcher(9).next_batch().unwrap();
        assert_eq!(a.input_ids, b.input_ids);
        assert_eq!(a.labels, b.labels);
    }
}
