//! Joint (rewrite ∪ checkpoint ∪ offload) placement search over the
//! execution schedule.
//!
//! The paper's headline "up to 2× batch" numbers come from combining
//! the drop-in rewrites *with* checkpointing; where you checkpoint
//! matters as much as whether (Pudipeddi et al.'s layer-to-layer
//! execution is the limiting case of "checkpoint everything, stream
//! the rest" — and its host-streaming arm is now literal:
//! [`Residency::Offload`]). [`placement_search`] therefore searches
//! over per-layer `(rewrite subset, Residency)` assignments — 16 × 4
//! arms per layer — instead of `fine_search`'s rewrite subsets alone.
//!
//! ## Candidate family
//!
//! The raw space (64ⁿ assignments) is intractable and almost entirely
//! redundant: encoder layers are interchangeable blocks, so a plan's
//! price depends on the *multiset* of arms (plus which checkpointed
//! layer sits topmost, which the canonical layouts below fix). The
//! search enumerates the canonical two-knob family
//!
//! * **prefix rewrite plans** — subset `s` on the first `j` layers,
//!   baseline on the rest (the shape `fine_search` walks),
//! * **joint checkpoint plans** — checkpoint arm
//!   `m ∈ {Overlapped, Serial}` on the *bottom* `c` layers, subset `s`
//!   on the remaining top layers. Bottom placement is canonical
//!   because a bottom block's re-forward runs after the layers above
//!   have already freed their inventories, so it never pays the
//!   prefetch co-residency the top placement does, and
//! * **joint offload plans** — [`Residency::Offload`] on the bottom
//!   `c` layers with subset `s` on *every* layer: rewrites run on
//!   offloaded layers too and shrink the bytes they ship, so the two
//!   axes compose rather than exclude. Bottom placement is canonical
//!   here as well — bottom stores get the longest forward windows to
//!   drain under, and the first load inherits the deepest backward
//!   cover.
//!
//! Every uniform plan (all 16 subsets, both uniform checkpoint modes,
//! all 16 uniform-offload plans) is a member, so the joint search can
//! never return a plan worse than the best uniform one
//! (`tests/placement_search.rs` pins this).
//!
//! ## Tensor-parallel degrees
//!
//! Under a [`TpPolicy`] the family is replicated per permitted shard
//! degree `d ∈ {1, 2, 4, 8}` (see
//! [`ModelConfig::tp_permitted`](crate::config::ModelConfig::tp_permitted)).
//! At `d > 1` the per-layer arms gain [`Residency::Shard`]: **bottom-c
//! shard plans** (Shard on the bottom `c` layers, Resident above,
//! subset `s` on every layer — rewrites run inside sharded blocks and
//! compose), a **shard ∘ offload composition** (Shard bottom, Offload
//! top), and the uniform checkpoint/offload arms repriced at degree
//! `d` — the vocab-parallel head shards at *any* resolved degree > 1,
//! so even shard-free residency layouts change peak and census and
//! must re-enumerate. `c == n` recovers the uniform-shard plans and
//! `c == 0` the pure-rewrite plans at degree `d`, keeping
//! joint ⊇ uniform per degree.
//!
//! ## Dominance pruning
//!
//! Candidates are first summarized (one memoized
//! [`ScheduleSummary`](crate::graph::ScheduleSummary) per distinct
//! plan — the §Schedule memoization contract is what makes enumerating
//! ~1k plans cheap), then **pruned before pricing**. The lane-aware
//! roofline prices a plan as `t(effective census · B) + constants +
//! exposed(B)`, where the *effective census* is the schedule census
//! minus the prefetch-hidden credit (`total − OVERLAP_EFF · hidden`)
//! and the exposed collective time is a fold over the gradient
//! buckets' compute tails. Plan Q is therefore dominated when some
//! plan P has, componentwise:
//!
//! * per-item peak ≤ Q's (P's max batch is ≥ Q's), and
//! * effective census ≤ Q's (P's compute lane is faster at every
//!   batch — the roofline is a positive-weighted sum), and
//! * for every gradient bucket, *pre-readiness* effective census
//!   (`eff − tail`) ≤ Q's — by linearity this bounds P's exposure by
//!   Q's exposure plus exactly the compute P already saved, so P's
//!   *step* is ≤ Q's at every batch even where the collective is
//!   exposed, and
//! * for every host-link transfer (stores then loads, in tape order):
//!   payload bytes ≤ Q's *and* covering-window census ≥ Q's. Transfer
//!   durations are linear in bytes and window drains linear in the
//!   cover, so each of P's per-window unhidden tails — and the
//!   carrying store lag, a monotone fold over exactly those pairs —
//!   is ≤ Q's at every batch and every host bandwidth. Plans with
//!   *different* host-transfer shapes (different counts) are
//!   incomparable and both survive, so the prune stays lossless
//!   without modeling cross-shape exposure, and
//! * equal resolved shard degree, and per TP-lane collective (tape
//!   order) the same `(bytes ≤, cover ≥)` argument as the host lane:
//!   at equal degree the ring factor cancels, so smaller payloads
//!   under larger covering windows expose less collective time at
//!   every batch and every `tp_bw`. Plans at *different* resolved
//!   degrees are incomparable by construction (their per-device
//!   shards, ring factors, and collective shapes all differ), so the
//!   prune never reasons across degrees and stays lossless.
//!
//! Q can then never win any selection objective and pruning it is
//! lossless (pinned against exhaustive pricing in
//! `tests/placement_search.rs`). Strictness is counted on the first
//! two conditions only — the bucket and host conditions are
//! qualifiers, so exposure-equal exact ties are all kept for the
//! tie-breaks. Only survivors pay the max-batch binary search and
//! throughput pricing; [`PruneStats`] reports the funnel.
//!
//! Throughput ties break toward the **lower peak** first (a
//! zero-overhead rewrite like output-only softmax or in-place
//! LayerNorm is a free win and is always taken), then toward **fewer
//! checkpointed layers**, then **fewer offloaded layers**, then the
//! smaller rewrite surface: equal peak and equal effective census mean
//! the extra checkpoints buy nothing, host traffic that buys nothing
//! is pure PCIe risk, and recompute surface (like the lossy GELU
//! surface) is pure risk.
//!
//! Under the pre-lane latency-blind fold, `Serial` checkpointing
//! strictly dominated `Overlapped` (equal census, lower peak) and
//! overlap never survived the prune. That is no longer true: an
//! `Overlapped` arm's hidden prefetch gives it a strictly *smaller
//! effective census* than its `Serial` twin, while `Serial` keeps the
//! strictly lower peak — the two are incomparable, both survive, and
//! the exposure fold decides at pricing time. Where memory allows the
//! overlapped arm's batch, its hidden recompute genuinely buys
//! throughput and the search now selects it
//! (`tests/lane_exposure.rs` pins the divergence). Offload arms play
//! the same game one level up: an offloaded layer keeps the serial
//! arm's step-shaped census (no recompute at all) at a near-resident
//! peak, so capacity queries that used to land on all-`Serial` now
//! land on offload placements — at the priced cost of the unhidden
//! host-transfer tail.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{Gpu, ModelConfig, OptimizationSet};
use crate::coordinator::ExperimentEngine;
use crate::graph::{self, Census, CkptStyle, Residency, ScheduleSummary};
use crate::memmodel::max_batch_for_plan;
use crate::perfmodel::{plan_throughput_at, OVERLAP_EFF};

use super::search::LayerPlan;

/// Which candidate family `placement_search` explores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// Uniform plans only: one rewrite subset (or one checkpoint mode)
    /// on every layer — the pre-placement search space.
    Uniform,
    /// The joint per-layer family: checkpoint or offload arms on the
    /// bottom layers, rewrite subsets on the rest (plus every prefix
    /// rewrite plan).
    Joint,
}

impl PlacementMode {
    /// Parse a `--placement` CLI value.
    pub fn parse(name: &str) -> Option<PlacementMode> {
        match name {
            "uniform" => Some(PlacementMode::Uniform),
            "joint" => Some(PlacementMode::Joint),
            _ => None,
        }
    }

    /// CLI-facing name.
    pub fn name(self) -> &'static str {
        match self {
            PlacementMode::Uniform => "uniform",
            PlacementMode::Joint => "joint",
        }
    }
}

/// The shard degrees a search may explore (`tempo placement --tp`).
pub const TP_DEGREES: [usize; 4] = [1, 2, 4, 8];

/// Which tensor-parallel shard degrees `placement_search_jobs`
/// explores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TpPolicy {
    /// One fixed degree. `Fixed(1)` is the shard-free legacy search;
    /// an impermissible degree normalizes to 1 (the lowering would
    /// resolve it there anyway — see
    /// [`SchedulePlan::resolved_tp`](crate::graph::SchedulePlan::resolved_tp)).
    Fixed(usize),
    /// Every degree in [`TP_DEGREES`] the model's dimensions permit.
    Auto,
}

impl TpPolicy {
    /// Parse a `--tp` CLI value: `auto` or a degree from
    /// [`TP_DEGREES`].
    pub fn parse(name: &str) -> Option<TpPolicy> {
        if name == "auto" {
            return Some(TpPolicy::Auto);
        }
        name.parse::<usize>().ok().filter(|k| TP_DEGREES.contains(k)).map(TpPolicy::Fixed)
    }

    /// The concrete degrees this policy explores on `cfg`, ascending.
    /// Never empty: degree 1 is always permitted.
    pub fn degrees(self, cfg: &ModelConfig) -> Vec<usize> {
        match self {
            TpPolicy::Fixed(k) => vec![if cfg.tp_permitted(k) { k } else { 1 }],
            TpPolicy::Auto => {
                TP_DEGREES.iter().copied().filter(|&d| cfg.tp_permitted(d)).collect()
            }
        }
    }

    /// CLI-facing name (`auto` or the degree).
    pub fn label(self) -> String {
        match self {
            TpPolicy::Fixed(k) => k.to_string(),
            TpPolicy::Auto => "auto".into(),
        }
    }
}

/// The search funnel: how many candidate plans were enumerated, how
/// many the dominance prune removed before pricing, and how many were
/// actually priced (max-batch binary search + throughput).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Canonical candidate plans enumerated.
    pub enumerated: usize,
    /// Candidates removed as dominated (≥ peak and ≥ census of some
    /// other candidate) before pricing.
    pub pruned: usize,
    /// Survivors that paid the max-batch search and throughput eval.
    pub priced: usize,
}

/// Outcome of a placement search.
#[derive(Debug, Clone)]
pub struct PlacementDecision {
    /// The chosen per-layer placement.
    pub plan: LayerPlan,
    /// The chosen plan's *resolved* shard degree (1 on shard-free
    /// searches).
    pub tp: usize,
    /// Modeled max batch of the chosen plan on the target GPU.
    pub max_batch: usize,
    /// Modeled throughput (seqs/s) at [`PlacementDecision::eval_batch`].
    pub throughput: f64,
    /// The batch the throughput was modeled at: the clamped target
    /// when one was given, else the plan's own max batch.
    pub eval_batch: usize,
    /// Human-readable rationale (selection objective + funnel).
    pub rationale: String,
    /// The enumerate → prune → price funnel.
    pub stats: PruneStats,
}

/// One candidate with its schedule summary (pre-pricing state).
struct Summarized {
    plan: LayerPlan,
    /// Resolved shard degree (`plan.tp` gated by divisibility).
    tp: usize,
    summary: Arc<ScheduleSummary>,
}

/// One priced survivor.
struct Scored {
    plan: LayerPlan,
    tp: usize,
    peak_item: u64,
    max_batch: usize,
    eval_batch: usize,
    throughput: f64,
    ckpt_layers: usize,
    offload_layers: usize,
    shard_layers: usize,
    rewrite_surface: usize,
}

/// The canonical candidate family (see module docs): the degree-1
/// families plus the shard families at every other degree the policy
/// explores. Deduplicated within each degree, and distinct across
/// degrees (`LayerPlan::tp` participates in equality).
fn candidates(cfg: &ModelConfig, mode: PlacementMode, tp: TpPolicy) -> Vec<LayerPlan> {
    let mut out = Vec::new();
    for d in tp.degrees(cfg) {
        if d == 1 {
            base_candidates(cfg, mode, &mut out);
        } else {
            shard_candidates(cfg, mode, d, &mut out);
        }
    }
    out
}

/// The shard-free (degree 1) families. Deduplicated:
/// the all-baseline plan appears once, and `c == layers` joint
/// checkpoint plans (no plain layers left) once per checkpoint style.
fn base_candidates(cfg: &ModelConfig, mode: PlacementMode, out: &mut Vec<LayerPlan>) {
    let n = cfg.layers;
    let subsets = OptimizationSet::all_subsets();
    let none = OptimizationSet::none();
    match mode {
        PlacementMode::Uniform => {
            for &s in &subsets {
                out.push(LayerPlan::uniform(n, s));
            }
            for style in [CkptStyle::Overlapped, CkptStyle::Serial] {
                out.push(LayerPlan::uniform_checkpoint(n, style));
            }
            for &s in &subsets {
                out.push(LayerPlan::uniform_offload(n, s));
            }
        }
        PlacementMode::Joint => {
            // prefix rewrite plans: s on the first j layers
            out.push(LayerPlan::uniform(n, none));
            for &s in &subsets {
                if s == none {
                    continue;
                }
                for j in 1..=n {
                    let mut per_layer = vec![none; n];
                    for set in per_layer.iter_mut().take(j) {
                        *set = s;
                    }
                    out.push(LayerPlan::rewrites_only(per_layer));
                }
            }
            // joint checkpoint plans: style on the bottom c layers, s
            // above (rewrites are moot on checkpointed layers)
            for style in [CkptStyle::Overlapped, CkptStyle::Serial] {
                for c in 1..=n {
                    let mut residency = vec![Residency::Resident; n];
                    for arm in residency.iter_mut().take(c) {
                        *arm = Residency::Checkpoint(style);
                    }
                    for &s in &subsets {
                        if c == n && s != none {
                            continue; // no plain layers left; s is moot
                        }
                        let mut per_layer = vec![none; n];
                        for set in per_layer.iter_mut().skip(c) {
                            *set = s;
                        }
                        out.push(LayerPlan { per_layer, residency: residency.clone(), tp: 1 });
                    }
                }
            }
            // joint offload plans: stream the bottom c layers, subset s
            // on every layer — rewrites shrink what offloaded layers
            // ship, so the axes compose (c == n are the uniform-offload
            // plans, keeping joint ⊇ uniform)
            for c in 1..=n {
                let mut residency = vec![Residency::Resident; n];
                for arm in residency.iter_mut().take(c) {
                    *arm = Residency::Offload;
                }
                for &s in &subsets {
                    out.push(LayerPlan {
                        per_layer: vec![s; n],
                        residency: residency.clone(),
                        tp: 1,
                    });
                }
            }
        }
    }
}

/// The shard families at degree `d > 1` (see module docs §Tensor-
/// parallel degrees). Every plan here carries `tp: d`; the lowering
/// shards the vocab-parallel head regardless of the residency layout,
/// so the shard-free arms genuinely reprice at this degree.
fn shard_candidates(cfg: &ModelConfig, mode: PlacementMode, d: usize, out: &mut Vec<LayerPlan>) {
    let n = cfg.layers;
    let subsets = OptimizationSet::all_subsets();
    match mode {
        PlacementMode::Uniform => {
            for &s in &subsets {
                out.push(LayerPlan::uniform(n, s).with_tp(d));
                out.push(LayerPlan {
                    per_layer: vec![s; n],
                    residency: vec![Residency::Shard; n],
                    tp: d,
                });
            }
            for style in [CkptStyle::Overlapped, CkptStyle::Serial] {
                out.push(LayerPlan::uniform_checkpoint(n, style).with_tp(d));
            }
            for &s in &subsets {
                out.push(LayerPlan::uniform_offload(n, s).with_tp(d));
            }
        }
        PlacementMode::Joint => {
            // bottom-c shard plans: Shard on the bottom c layers,
            // Resident above, subset s on every layer (rewrites run
            // inside sharded blocks and compose). c == 0 are the
            // pure-rewrite plans at degree d, c == n the uniform-shard
            // plans — keeping joint ⊇ uniform per degree.
            for c in 0..=n {
                let mut residency = vec![Residency::Resident; n];
                for arm in residency.iter_mut().take(c) {
                    *arm = Residency::Shard;
                }
                for &s in &subsets {
                    out.push(LayerPlan {
                        per_layer: vec![s; n],
                        residency: residency.clone(),
                        tp: d,
                    });
                }
            }
            // shard ∘ offload composition: Shard on the bottom c,
            // Offload above — the sharded bottom keeps its backward
            // math local while the top streams to the host. c == n
            // (nothing left to offload) is already a bottom-c plan.
            for c in 1..n {
                let mut residency = vec![Residency::Offload; n];
                for arm in residency.iter_mut().take(c) {
                    *arm = Residency::Shard;
                }
                for &s in &subsets {
                    out.push(LayerPlan {
                        per_layer: vec![s; n],
                        residency: residency.clone(),
                        tp: d,
                    });
                }
            }
            // uniform checkpoint / offload layouts repriced at degree
            // d (the sharded head shifts both their peak and census)
            for style in [CkptStyle::Overlapped, CkptStyle::Serial] {
                out.push(LayerPlan::uniform_checkpoint(n, style).with_tp(d));
            }
            for &s in &subsets {
                out.push(LayerPlan::uniform_offload(n, s).with_tp(d));
            }
        }
    }
}

/// Pre-computed dominance key of one candidate (see module docs):
/// per-item peak, the *effective* census the compute lane prices
/// (`total − OVERLAP_EFF · hidden`), per gradient bucket the
/// pre-readiness effective census `eff − tail` (which by the
/// roofline's linearity bounds how much more collective time this plan
/// can leave exposed than a plan with smaller pre-readiness census),
/// and per host-link transfer its `(bytes, cover)` pair (stores then
/// loads, in tape order) — smaller payloads under larger covering
/// windows expose less host time at every batch and bandwidth. TP
/// plans add the resolved shard degree (an equality gate: degrees
/// never cross-compare) and the TP lane's `(bytes, cover)` pairs
/// under the same payload/window argument.
/// Keys hold *interned* slices: many candidates share identical
/// readiness vectors and host-transfer shapes (every offload-free plan
/// has the empty host slice; same-census twins share buckets), so the
/// per-search [`Interner`] hands out one shared allocation per
/// distinct vector instead of cloning a fresh `Vec` into every key.
/// `dominates` then short-circuits shared slices by pointer before
/// reading a single element.
struct DomKey {
    /// Resolved shard degree — keys at different degrees never
    /// compare (different per-device shards and ring factors).
    tp: usize,
    peak_item: u64,
    eff: Census,
    pre_readiness: Arc<[Census]>,
    host: Arc<[(u64, Census)]>,
    /// Per TP-lane collective `(bytes, cover)` in tape order — the
    /// same shape (and the same interner map) as the host lane.
    tp_links: Arc<[(u64, Census)]>,
}

/// Per-search deduplication of dominance-key vectors. [`Census`] holds
/// `f64`s (no `Eq`/`Hash`), so vectors are keyed by their exact bit
/// patterns — the folds that produce them are bit-deterministic, which
/// makes bit-equality the right identity here.
#[derive(Default)]
struct Interner {
    readiness: HashMap<Vec<u64>, Arc<[Census]>>,
    host: HashMap<Vec<u64>, Arc<[(u64, Census)]>>,
}

fn census_bits(c: &Census, out: &mut Vec<u64>) {
    out.push(c.matmul_flops.to_bits());
    out.push(c.vector_flops.to_bits());
    out.push(c.vector_bytes.to_bits());
}

impl Interner {
    fn readiness(&mut self, v: Vec<Census>) -> Arc<[Census]> {
        let mut bits = Vec::with_capacity(3 * v.len());
        for c in &v {
            census_bits(c, &mut bits);
        }
        Arc::clone(self.readiness.entry(bits).or_insert_with(|| v.into()))
    }

    fn host(&mut self, v: Vec<(u64, Census)>) -> Arc<[(u64, Census)]> {
        let mut bits = Vec::with_capacity(4 * v.len());
        for (b, c) in &v {
            bits.push(*b);
            census_bits(c, &mut bits);
        }
        Arc::clone(self.host.entry(bits).or_insert_with(|| v.into()))
    }
}

/// Componentwise census difference. Exact in f64: every component is
/// an integer below 2⁵³ and `OVERLAP_EFF` is a power of two, so the
/// keys (and hence the prune) are deterministic.
fn census_sub(a: Census, b: Census) -> Census {
    Census {
        matmul_flops: a.matmul_flops - b.matmul_flops,
        vector_flops: a.vector_flops - b.vector_flops,
        vector_bytes: a.vector_bytes - b.vector_bytes,
    }
}

/// Componentwise `a ≤ b`.
fn census_le(a: &Census, b: &Census) -> bool {
    a.matmul_flops <= b.matmul_flops
        && a.vector_flops <= b.vector_flops
        && a.vector_bytes <= b.vector_bytes
}

fn dom_key(s: &ScheduleSummary, tp: usize, interner: &mut Interner) -> DomKey {
    let eff = census_sub(s.census, s.lanes.hidden.scale(OVERLAP_EFF));
    let pre_readiness =
        s.lanes.buckets.iter().map(|bk| census_sub(eff, bk.tail)).collect();
    let host = s
        .lanes
        .stores
        .iter()
        .chain(s.lanes.loads.iter())
        .map(|t| (t.bytes, t.cover))
        .collect();
    let tp_links = s.lanes.tp_links.iter().map(|t| (t.bytes, t.cover)).collect();
    DomKey {
        tp,
        peak_item: s.peak_item_bytes,
        eff,
        pre_readiness: interner.readiness(pre_readiness),
        host: interner.host(host),
        tp_links: interner.host(tp_links),
    }
}

/// `true` when `a` dominates `b`: equal resolved shard degree, peak ≤,
/// effective census ≤ componentwise, per-bucket pre-readiness census ≤
/// componentwise, and per host transfer and per TP collective:
/// payload ≤ with covering window ≥ componentwise.
/// Together these make `a`'s priced step ≤ `b`'s at every batch on
/// every rig (see module docs for the exposure-bound argument; both
/// plans share the same batch-free state bytes and the same bucket
/// bytes, so peak and collective durations need no further terms — and
/// at equal degree the TP ring factor cancels out of the comparison).
/// Plans with differently-shaped host or TP lanes (different transfer
/// counts) or different degrees are incomparable by construction.
fn dominates(a: &DomKey, b: &DomKey) -> bool {
    // interned slices: pointer equality means element equality, and an
    // equal vector always satisfies its own componentwise conditions
    a.tp == b.tp
        && a.peak_item <= b.peak_item
        && census_le(&a.eff, &b.eff)
        && a.pre_readiness.len() == b.pre_readiness.len()
        && (Arc::ptr_eq(&a.pre_readiness, &b.pre_readiness)
            || a.pre_readiness.iter().zip(b.pre_readiness.iter()).all(|(x, y)| census_le(x, y)))
        && a.host.len() == b.host.len()
        && (Arc::ptr_eq(&a.host, &b.host)
            || a.host
                .iter()
                .zip(b.host.iter())
                .all(|((ab, ac), (bb, bc))| ab <= bb && census_le(bc, ac)))
        && a.tp_links.len() == b.tp_links.len()
        && (Arc::ptr_eq(&a.tp_links, &b.tp_links)
            || a.tp_links
                .iter()
                .zip(b.tp_links.iter())
                .all(|((ab, ac), (bb, bc))| ab <= bb && census_le(bc, ac)))
}

/// Strict version: dominates with at least one strict inequality on
/// peak or effective census. The bucket and host conditions stay
/// non-strict qualifiers — two plans equal on peak and effective
/// census are both kept regardless of their exposure, so the selection
/// tie-breaks see every exact tie.
fn strictly_dominates(a: &DomKey, b: &DomKey) -> bool {
    dominates(a, b) && (a.peak_item < b.peak_item || a.eff != b.eff)
}

/// Drop every candidate strictly dominated by another (O(n²) over ~1k
/// keys — each comparison is a handful of scalar reads). Exact-tie
/// plans are all kept: the selection tie-breaks (fewer checkpoints,
/// smaller rewrite surface, enumeration order) must see them.
fn prune_dominated(cands: Vec<Summarized>) -> Vec<Summarized> {
    let mut interner = Interner::default();
    let keys: Vec<DomKey> =
        cands.iter().map(|c| dom_key(&c.summary, c.tp, &mut interner)).collect();
    let keep: Vec<bool> = keys
        .iter()
        .map(|q| !keys.iter().any(|p| strictly_dominates(p, q)))
        .collect();
    cands
        .into_iter()
        .zip(keep)
        .filter_map(|(c, k)| if k { Some(c) } else { None })
        .collect()
}

/// Lexicographic "is `a` better than `b`" under the selection
/// objective. With a target: reach it, then throughput at the target;
/// without: max batch, then throughput at max. Ties then break toward
/// lower peak, fewer checkpointed layers, smaller rewrite surface, and
/// finally enumeration order (the caller keeps the incumbent).
fn better(a: &Scored, b: &Scored, target: Option<usize>) -> bool {
    if let Some(t) = target {
        let (ra, rb) = (a.max_batch >= t, b.max_batch >= t);
        if ra != rb {
            return ra;
        }
        if ra {
            if a.throughput != b.throughput {
                return a.throughput > b.throughput;
            }
            return tie_break(a, b);
        }
        // neither reaches the target: fall through to capacity order
    }
    if a.max_batch != b.max_batch {
        return a.max_batch > b.max_batch;
    }
    if a.throughput != b.throughput {
        return a.throughput > b.throughput;
    }
    tie_break(a, b)
}

fn tie_break(a: &Scored, b: &Scored) -> bool {
    if a.peak_item != b.peak_item {
        return a.peak_item < b.peak_item;
    }
    if a.ckpt_layers != b.ckpt_layers {
        return a.ckpt_layers < b.ckpt_layers;
    }
    if a.offload_layers != b.offload_layers {
        return a.offload_layers < b.offload_layers;
    }
    // collective traffic that buys nothing is pure interconnect risk:
    // prefer fewer sharded layers, then the smaller shard degree
    // (fewer GPUs burned in the scale-up domain)
    if a.shard_layers != b.shard_layers {
        return a.shard_layers < b.shard_layers;
    }
    if a.tp != b.tp {
        return a.tp < b.tp;
    }
    a.rewrite_surface < b.rewrite_surface
}

/// Joint placement search: pick the per-layer `(rewrites, Residency)`
/// placement that maximizes the modeled max batch (or, given
/// `target_batch`, reaches it at the highest modeled throughput).
/// Shard-free (`tp = 1`); [`placement_search_tp`] takes a degree
/// policy. Dominance pruning is enabled; [`placement_search_with`]
/// exposes the switch for the losslessness tests and benches.
pub fn placement_search(
    cfg: &ModelConfig,
    gpu: Gpu,
    mode: PlacementMode,
    target_batch: Option<usize>,
) -> PlacementDecision {
    placement_search_with(cfg, gpu, mode, target_batch, true)
}

/// [`placement_search`] under a tensor-parallel degree policy
/// (`tempo placement --tp K|auto`).
pub fn placement_search_tp(
    cfg: &ModelConfig,
    gpu: Gpu,
    mode: PlacementMode,
    tp: TpPolicy,
    target_batch: Option<usize>,
) -> PlacementDecision {
    placement_search_jobs(cfg, gpu, mode, tp, target_batch, true, &ExperimentEngine::serial())
}

/// [`placement_search`] with the dominance prune switchable. Pruning
/// is lossless — `prune: false` prices every candidate and must reach
/// the same decision (`tests/placement_search.rs` pins this on a
/// 4-layer model) — so the flag exists only to *prove* that, and to
/// measure the funnel in `benches/placement.rs`.
pub fn placement_search_with(
    cfg: &ModelConfig,
    gpu: Gpu,
    mode: PlacementMode,
    target_batch: Option<usize>,
    prune: bool,
) -> PlacementDecision {
    placement_search_jobs(
        cfg,
        gpu,
        mode,
        TpPolicy::Fixed(1),
        target_batch,
        prune,
        &ExperimentEngine::serial(),
    )
}

/// [`placement_search_with`] across an [`ExperimentEngine`] worker
/// pool (`tempo placement --jobs N|auto`). Candidate summarization and
/// survivor pricing fan out as grid cells with slot-stable collection
/// (the PR 2 pattern); the dominance prune and the selection fold stay
/// serial in enumeration order. The winner is **bit-identical** to the
/// serial search at any job count: every cell is a pure function of
/// its candidate, the shared summary caches are first-insert-wins (so
/// worker interleaving never changes a value), and the reduction reads
/// the slots in enumeration order (`tests/incremental_pricing.rs` pins
/// jobs-4 ≡ jobs-1).
pub fn placement_search_jobs(
    cfg: &ModelConfig,
    gpu: Gpu,
    mode: PlacementMode,
    tp: TpPolicy,
    target_batch: Option<usize>,
    prune: bool,
    engine: &ExperimentEngine,
) -> PlacementDecision {
    let cands = candidates(cfg, mode, tp);
    let enumerated = cands.len();

    let summaries = engine
        .run_cells(cands.len(), |i| Ok(graph::schedule_summary(cfg, &cands[i].schedule_plan())));
    let summarized: Vec<Summarized> = cands
        .into_iter()
        .zip(summaries)
        .map(|(plan, summary)| {
            let resolved = plan.schedule_plan().resolved_tp(cfg);
            Summarized {
                plan,
                tp: resolved,
                summary: summary.expect("placement summarize cell"),
            }
        })
        .collect();

    let survivors = if prune { prune_dominated(summarized) } else { summarized };
    let stats = PruneStats {
        enumerated,
        pruned: enumerated - survivors.len(),
        priced: survivors.len(),
    };

    // price the survivors as cells too: the max-batch search and the
    // throughput pricing both hit the summary each plan already holds
    // (memoized), so every cell is cache lookups + arithmetic
    let priced = engine.run_cells(survivors.len(), |i| {
        let splan = survivors[i].plan.schedule_plan();
        let fit = max_batch_for_plan(cfg, &splan, gpu);
        let eval_batch = match target_batch {
            Some(t) => t.min(fit.max_batch),
            None => fit.max_batch,
        };
        Ok((fit.max_batch, eval_batch, plan_throughput_at(cfg, &splan, gpu, eval_batch)))
    });

    let mut best: Option<Scored> = None;
    for (Summarized { plan, tp, summary }, cell) in survivors.into_iter().zip(priced) {
        let (max_batch, eval_batch, throughput) = cell.expect("placement pricing cell");
        let scored = Scored {
            tp,
            peak_item: summary.peak_item_bytes,
            max_batch,
            eval_batch,
            throughput,
            ckpt_layers: plan.checkpointed_layers(),
            offload_layers: plan.offloaded_layers(),
            shard_layers: plan.sharded_layers(),
            rewrite_surface: plan.rewrite_surface(),
            plan,
        };
        let replace = match &best {
            None => true,
            Some(incumbent) => better(&scored, incumbent, target_batch),
        };
        if replace {
            best = Some(scored);
        }
    }

    let best = best.expect("placement search over a non-empty candidate family");
    let funnel = format!(
        "{} candidates, {} pruned as dominated, {} priced",
        stats.enumerated, stats.pruned, stats.priced
    );
    let rationale = match target_batch {
        Some(t) if best.max_batch >= t => format!(
            "{} search: batch {} reachable at {:.2} seq/s at tp {} with {} checkpointed + {} \
             offloaded + {} sharded layer(s) + rewrites on {} ({funnel})",
            mode.name(),
            t,
            best.throughput,
            best.tp,
            best.ckpt_layers,
            best.offload_layers,
            best.shard_layers,
            best.plan.applied_layers(),
        ),
        Some(t) => format!(
            "{} search: target batch {t} unreachable (best max batch {} at tp {}); returning \
             the highest-capacity plan ({funnel})",
            mode.name(),
            best.max_batch,
            best.tp,
        ),
        None => format!(
            "{} search: max batch {} at tp {} with {} checkpointed + {} offloaded + {} \
             sharded layer(s) + rewrites on {} ({funnel})",
            mode.name(),
            best.max_batch,
            best.tp,
            best.ckpt_layers,
            best.offload_layers,
            best.shard_layers,
            best.plan.applied_layers(),
        ),
    };
    PlacementDecision {
        plan: best.plan,
        tp: best.tp,
        max_batch: best.max_batch,
        throughput: best.throughput,
        eval_batch: best.eval_batch,
        rationale,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Technique;
    use crate::memmodel::max_batch;

    #[test]
    fn uniform_candidates_cover_all_subsets_and_every_residency_arm() {
        let cfg = ModelConfig::bert_mini();
        let c = candidates(&cfg, PlacementMode::Uniform, TpPolicy::Fixed(1));
        // 16 rewrite subsets + 2 uniform checkpoint styles + 16
        // uniform-offload plans (offloaded layers keep their rewrites)
        assert_eq!(c.len(), 34);
        assert!(c.iter().any(|p| p.checkpointed_layers() == cfg.layers
            && p.residency.iter().all(|m| *m == Residency::Checkpoint(CkptStyle::Serial))));
        assert_eq!(
            c.iter().filter(|p| p.offloaded_layers() == cfg.layers).count(),
            16,
            "one uniform-offload plan per rewrite subset"
        );
    }

    #[test]
    fn joint_candidates_contain_every_uniform_plan() {
        let cfg = ModelConfig::bert_mini();
        for tp in [TpPolicy::Fixed(1), TpPolicy::Auto] {
            let joint = candidates(&cfg, PlacementMode::Joint, tp);
            for u in candidates(&cfg, PlacementMode::Uniform, tp) {
                assert!(joint.contains(&u), "missing uniform plan {u:?} under {tp:?}");
            }
            // no duplicate canonical candidates
            for (i, a) in joint.iter().enumerate() {
                assert!(!joint[i + 1..].contains(a), "duplicate candidate {a:?} under {tp:?}");
            }
        }
    }

    #[test]
    fn tp_policies_resolve_to_the_permitted_degrees() {
        // bert-mini: 4 heads — degree 8 does not divide and drops out
        let mini = ModelConfig::bert_mini();
        assert_eq!(TpPolicy::Auto.degrees(&mini), vec![1, 2, 4]);
        assert_eq!(TpPolicy::Fixed(4).degrees(&mini), vec![4]);
        // impermissible fixed degrees normalize to the shard-free search
        assert_eq!(TpPolicy::Fixed(8).degrees(&mini), vec![1]);
        let large = ModelConfig::bert_large();
        assert_eq!(TpPolicy::Auto.degrees(&large), vec![1, 2, 4, 8]);
        // parsing: auto, the permitted degrees, nothing else
        assert_eq!(TpPolicy::parse("auto"), Some(TpPolicy::Auto));
        assert_eq!(TpPolicy::parse("2"), Some(TpPolicy::Fixed(2)));
        assert_eq!(TpPolicy::parse("3"), None);
        assert_eq!(TpPolicy::parse("0"), None);
        assert_eq!(TpPolicy::parse("fast"), None);
    }

    #[test]
    fn shard_degrees_never_cross_compare_in_the_prune() {
        // a tp=4 uniform-shard plan holds a far lower per-device peak
        // than its tp=2 twin, but the two lower different collective
        // schedules (different ring factors, different per-item
        // payloads): the key's degree gate keeps them incomparable and
        // the priced exposure decides
        let cfg = ModelConfig::bert_mini();
        let n = cfg.layers;
        let mut interner = Interner::default();
        let mut key = |p: &LayerPlan| {
            let sp = p.schedule_plan();
            dom_key(&graph::schedule_summary(&cfg, &sp), sp.resolved_tp(&cfg), &mut interner)
        };
        let shard = |d: usize| LayerPlan {
            per_layer: vec![OptimizationSet::none(); n],
            residency: vec![Residency::Shard; n],
            tp: d,
        };
        let (k2, k4) = (key(&shard(2)), key(&shard(4)));
        assert!(k4.peak_item < k2.peak_item, "tp=4 must shard the peak further");
        assert!(!k2.tp_links.is_empty(), "sharded plans must expose TP collectives");
        assert!(!strictly_dominates(&k4, &k2), "degrees must never cross-compare");
        assert!(!strictly_dominates(&k2, &k4));
        // same gate against the shard-free baseline
        let k1 = key(&LayerPlan::uniform(n, OptimizationSet::none()));
        assert!(k1.tp_links.is_empty());
        assert!(!strictly_dominates(&k4, &k1));
        assert!(!strictly_dominates(&k1, &k4));
    }

    #[test]
    fn both_checkpoint_modes_survive_the_lane_aware_prune() {
        // pre-lane pricing pruned every Overlapped arm here (equal
        // census, strictly higher peak than its Serial twin); with the
        // hidden-prefetch credit the two arms are incomparable — Serial
        // keeps the lower peak, Overlapped the smaller effective
        // census — and both must reach pricing
        let cfg = ModelConfig::bert_mini();
        let n = cfg.layers;
        let over = LayerPlan::uniform_checkpoint(n, CkptStyle::Overlapped);
        let serial = LayerPlan::uniform_checkpoint(n, CkptStyle::Serial);
        let mut interner = Interner::default();
        let mut key = |p: &LayerPlan| {
            let sp = p.schedule_plan();
            dom_key(&graph::schedule_summary(&cfg, &sp), sp.resolved_tp(&cfg), &mut interner)
        };
        let (ko, ks) = (key(&over), key(&serial));
        assert!(ks.peak_item < ko.peak_item, "serial must hold the lower peak");
        assert!(
            census_le(&ko.eff, &ks.eff) && ko.eff != ks.eff,
            "overlap must hold the smaller effective census"
        );
        assert!(!strictly_dominates(&ks, &ko), "serial no longer dominates overlap");
        assert!(!strictly_dominates(&ko, &ks), "overlap must not dominate serial either");

        let summarized = candidates(&cfg, PlacementMode::Uniform, TpPolicy::Fixed(1))
            .into_iter()
            .map(|plan| {
                let summary = graph::schedule_summary(&cfg, &plan.schedule_plan());
                Summarized { plan, tp: 1, summary }
            })
            .collect();
        let survivors = prune_dominated(summarized);
        for want in [&over, &serial] {
            assert!(
                survivors.iter().any(|s| s.plan == *want),
                "{want:?} was pruned from the uniform family"
            );
        }
    }

    #[test]
    fn offload_plans_are_incomparable_across_host_lane_shapes() {
        // an offload plan has a non-empty host lane; any plan with a
        // differently-shaped host lane (including every offload-free
        // plan) must be incomparable to it, so both reach pricing and
        // the bandwidth-dependent exposure decides
        let cfg = ModelConfig::bert_mini();
        let n = cfg.layers;
        let mut interner = Interner::default();
        let mut key = |p: &LayerPlan| {
            let sp = p.schedule_plan();
            dom_key(&graph::schedule_summary(&cfg, &sp), sp.resolved_tp(&cfg), &mut interner)
        };
        let off = key(&LayerPlan::uniform_offload(n, OptimizationSet::none()));
        let serial = key(&LayerPlan::uniform_checkpoint(n, CkptStyle::Serial));
        assert_eq!(off.host.len(), 2 * n, "one store + one load per offloaded layer");
        assert!(serial.host.is_empty());
        assert!(!strictly_dominates(&off, &serial));
        assert!(!strictly_dominates(&serial, &off));
        // fewer offloaded layers → different host shape → incomparable
        let mut residency = vec![Residency::Offload; n];
        residency[n - 1] = Residency::Resident;
        let partial =
            key(&LayerPlan { per_layer: vec![OptimizationSet::none(); n], residency, tp: 1 });
        assert!(!strictly_dominates(&partial, &off));
        assert!(!strictly_dominates(&off, &partial));
    }

    #[test]
    fn rewrites_shrink_what_an_offloaded_layer_ships() {
        // the compose-don't-exclude claim: the full rewrite set on an
        // all-offload plan strictly reduces every store's payload
        let cfg = ModelConfig::bert_mini();
        let n = cfg.layers;
        let mut interner = Interner::default();
        let mut key = |p: &LayerPlan| {
            let sp = p.schedule_plan();
            dom_key(&graph::schedule_summary(&cfg, &sp), sp.resolved_tp(&cfg), &mut interner)
        };
        let plain = key(&LayerPlan::uniform_offload(n, OptimizationSet::none()));
        let rewritten = key(&LayerPlan::uniform_offload(n, OptimizationSet::full()));
        for (i, ((pb, _), (rb, _))) in plain.host.iter().zip(rewritten.host.iter()).enumerate() {
            assert!(rb < pb, "transfer {i}: rewritten {rb} !< plain {pb}");
        }
    }

    #[test]
    fn capacity_mode_beats_every_technique() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let d = placement_search(&cfg, Gpu::Rtx2080Ti, PlacementMode::Joint, None);
        for t in Technique::all() {
            let b = max_batch(&cfg, t, Gpu::Rtx2080Ti).max_batch;
            assert!(d.max_batch >= b, "{t:?}: joint {} < {b}", d.max_batch);
        }
        assert!(d.stats.pruned > 0, "expected a non-trivial dominance prune");
        assert_eq!(d.stats.enumerated, d.stats.pruned + d.stats.priced);
    }

    #[test]
    fn reachable_target_takes_only_the_free_rewrites() {
        // a target the baseline already fits needs no checkpointing and
        // no overhead-paying rewrite; the zero-overhead pair (output-only
        // softmax + in-place LayerNorm) still wins the peak tie-break —
        // free memory, identical roofline time
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let base = max_batch(&cfg, Technique::Baseline, Gpu::V100).max_batch;
        let d = placement_search(&cfg, Gpu::V100, PlacementMode::Joint, Some(base.min(2)));
        assert_eq!(d.plan.checkpointed_layers(), 0, "{}", d.rationale);
        let free = OptimizationSet::only("softmax")
            .unwrap()
            .union(OptimizationSet::only("layernorm").unwrap());
        assert!(
            d.plan.per_layer.iter().all(|s| *s == free),
            "expected the free subset everywhere: {}",
            d.rationale
        );
    }
}
