//! Auto-Tempo search policies over the analytical profiles.
//!
//! A [`LayerPlan`] is a per-layer *placement*: which of Tempo's four
//! graph rewrites each encoder layer applies, and which residency arm
//! ([`Residency`]: resident, checkpointed, or host-offloaded) it
//! takes. Pricing a plan lowers it to an execution schedule
//! ([`crate::graph::SchedulePlan`]) and reads the liveness timeline's
//! exact peak (one memoized schedule summary per distinct plan), so
//! max-batch searches binary-search against the true high-water
//! instant rather than a static byte sum — the two coincide
//! bit-identically wherever the old model was correct
//! (`tests/schedule_equivalence.rs`). The joint (rewrites ∪
//! checkpoint ∪ offload) search over this space lives in
//! [`super::placement_search`].

use crate::config::{Gpu, ModelConfig, OptimizationSet, Technique};
use crate::graph::{CkptStyle, Residency, SchedulePlan};
use crate::memmodel::{max_batch, max_batch_for_plan};
use crate::perfmodel::throughput_at;

/// Per-layer placement assignment (index = encoder layer): a rewrite
/// subset plus a residency arm per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    /// Rewrite subset per encoder layer (ignored on checkpointed
    /// layers — the recompute replays the unoptimized block — but
    /// *honored* on offloaded layers, where rewrites shrink the bytes
    /// shipped over the host link).
    pub per_layer: Vec<OptimizationSet>,
    /// Residency arm per encoder layer.
    pub residency: Vec<Residency>,
    /// Tensor-parallel shard degree the plan lowers under (1 = no
    /// sharding; impermissible degrees resolve to 1, see
    /// [`crate::graph::SchedulePlan::resolved_tp`]).
    pub tp: usize,
}

impl LayerPlan {
    /// Uniform rewrite plan: `set` on every layer, everything resident.
    pub fn uniform(layers: usize, set: OptimizationSet) -> Self {
        LayerPlan {
            per_layer: vec![set; layers],
            residency: vec![Residency::Resident; layers],
            tp: 1,
        }
    }

    /// Residency-free plan from per-layer rewrite sets (the legacy
    /// `LayerPlan` shape; `fine_search`'s prefix plans).
    pub fn rewrites_only(per_layer: Vec<OptimizationSet>) -> Self {
        let n = per_layer.len();
        LayerPlan { per_layer, residency: vec![Residency::Resident; n], tp: 1 }
    }

    /// Uniform checkpoint placement: `style` checkpointing on every
    /// layer, rewrites off (the recompute replays the unoptimized
    /// block anyway).
    pub fn uniform_checkpoint(layers: usize, style: CkptStyle) -> Self {
        LayerPlan {
            per_layer: vec![OptimizationSet::none(); layers],
            residency: vec![Residency::Checkpoint(style); layers],
            tp: 1,
        }
    }

    /// Uniform offload placement: every layer streamed to the host,
    /// with `set` rewrites shrinking what each layer ships.
    pub fn uniform_offload(layers: usize, set: OptimizationSet) -> Self {
        LayerPlan {
            per_layer: vec![set; layers],
            residency: vec![Residency::Offload; layers],
            tp: 1,
        }
    }

    /// Builder: set the tensor-parallel shard degree.
    pub fn with_tp(mut self, tp: usize) -> Self {
        self.tp = tp;
        self
    }

    /// Number of sharded ([`Residency::Shard`]) layers.
    pub fn sharded_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_shard()).count()
    }

    /// The residency arm layer `l` takes (missing entries pad to
    /// [`Residency::Resident`]).
    pub fn residency(&self, l: usize) -> Residency {
        self.residency.get(l).copied().unwrap_or(Residency::Resident)
    }

    /// Number of non-checkpointed layers with any rewrite applied
    /// (offloaded layers count: their rewrites run and shrink the
    /// shipped bytes).
    pub fn applied_layers(&self) -> usize {
        self.per_layer
            .iter()
            .enumerate()
            .filter(|(l, s)| s.count() > 0 && !self.residency(*l).is_checkpoint())
            .count()
    }

    /// Number of checkpointed layers.
    pub fn checkpointed_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_checkpoint()).count()
    }

    /// Number of host-offloaded layers.
    pub fn offloaded_layers(&self) -> usize {
        self.residency.iter().filter(|m| m.is_offload()).count()
    }

    /// Total enabled rewrites across non-checkpointed layers (the
    /// "lossy surface" the searches minimize on ties).
    pub fn rewrite_surface(&self) -> usize {
        self.per_layer
            .iter()
            .enumerate()
            .filter(|(l, _)| !self.residency(*l).is_checkpoint())
            .map(|(_, s)| s.count())
            .sum()
    }

    /// The execution-schedule plan this placement lowers to
    /// (embedding/head at the baseline inventory, as always; MLM head).
    pub fn schedule_plan(&self) -> SchedulePlan {
        SchedulePlan::from_placement(self.per_layer.clone(), self.residency.clone(), true)
            .with_tp(self.tp)
    }

    /// Footprint of the plan at batch `b`: the exact peak of the
    /// plan's execution-schedule liveness timeline (each layer lowered
    /// under its own rewrite set and checkpoint arm).
    pub fn total_bytes(&self, cfg: &ModelConfig, batch: usize) -> u64 {
        crate::graph::schedule_summary(cfg, &self.schedule_plan()).peak_bytes(batch as u64)
    }
}

/// Outcome of an Auto-Tempo pass.
#[derive(Debug, Clone)]
pub struct AutoTempoDecision {
    /// The chosen per-layer plan.
    pub plan: LayerPlan,
    /// Max batch under the plan.
    pub max_batch: usize,
    /// Estimated throughput at that batch (seqs/s).
    pub throughput: f64,
    /// Human-readable rationale.
    pub rationale: String,
}

fn plan_max_batch(cfg: &ModelConfig, plan: &LayerPlan, gpu: Gpu) -> usize {
    max_batch_for_plan(cfg, &plan.schedule_plan(), gpu).max_batch
}

/// Coarse policy: all-or-nothing, decided by a quick profile.
pub fn coarse_pass(cfg: &ModelConfig, gpu: Gpu) -> AutoTempoDecision {
    let base = max_batch(cfg, Technique::Baseline, gpu);
    let tempo = max_batch(cfg, Technique::Tempo, gpu);
    let thr_base = throughput_at(cfg, Technique::Baseline, gpu, base.max_batch).seqs_per_s;
    let thr_tempo = throughput_at(cfg, Technique::Tempo, gpu, tempo.max_batch).seqs_per_s;
    if thr_tempo > thr_base {
        AutoTempoDecision {
            plan: LayerPlan::uniform(cfg.layers, OptimizationSet::full()),
            max_batch: tempo.max_batch,
            throughput: thr_tempo,
            rationale: format!(
                "memory-bound: Tempo batch {} > baseline {} → apply everywhere (+{:.1}%)",
                tempo.max_batch,
                base.max_batch,
                100.0 * (thr_tempo / thr_base - 1.0)
            ),
        }
    } else {
        AutoTempoDecision {
            plan: LayerPlan::uniform(cfg.layers, OptimizationSet::none()),
            max_batch: base.max_batch,
            throughput: thr_base,
            rationale: format!(
                "not memory-bound at this scale (baseline {:.1} ≥ tempo {:.1} seq/s) → leave model unchanged",
                thr_base, thr_tempo
            ),
        }
    }
}

/// Throughput (seqs/s) of a prefix plan with `applied` of `cfg.layers`
/// layers tempo-ized, at batch `batch`.
///
/// The roofline's compute lane is affine in the op census, and Tempo's
/// census delta is per-layer linear, so interpolating the two uniform
/// endpoints by the applied fraction reproduces the endpoints
/// bit-for-bit (`applied = 0` ≡ Baseline, `applied = layers` ≡ Tempo)
/// and is exact for prefix plans on single-device rigs. On multi-device
/// rigs the exposed-collective term is a max-fold over the gradient
/// buckets rather than affine in the census, so intermediate prefixes
/// are a tight linear approximation there; the joint
/// [`super::placement_search`] prices candidate plans exactly through
/// [`crate::perfmodel::plan_throughput_at`] instead.
pub fn plan_throughput(cfg: &ModelConfig, gpu: Gpu, applied: usize, batch: usize) -> f64 {
    if batch == 0 {
        return 0.0;
    }
    let spec = gpu.spec();
    let t_base = crate::perfmodel::step_time(cfg, Technique::Baseline, &spec, batch);
    let t_tempo = crate::perfmodel::step_time(cfg, Technique::Tempo, &spec, batch);
    let frac = applied as f64 / cfg.layers.max(1) as f64;
    let t = t_base + frac * (t_tempo - t_base);
    batch as f64 / t
}

/// Fine-grained policy: smallest prefix of tempo-ized layers such that
/// `target_batch` fits (binary search over the prefix length).
///
/// Every branch models throughput with the *plan-aware* estimate
/// ([`plan_throughput`]) at the clamped batch
/// `target_batch.min(max_batch)` — partial plans are no longer priced
/// as uniform Tempo, and an unreachable target is priced at the batch
/// that actually runs.
pub fn fine_search(cfg: &ModelConfig, gpu: Gpu, target_batch: usize) -> AutoTempoDecision {
    let layers = cfg.layers;
    let plan_for = |k: usize| {
        let mut per_layer = vec![OptimizationSet::none(); layers];
        for set in per_layer.iter_mut().take(k) {
            *set = OptimizationSet::full();
        }
        LayerPlan::rewrites_only(per_layer)
    };
    let fits = |k: usize| plan_max_batch(cfg, &plan_for(k), gpu) >= target_batch;
    let decide = |k: usize, rationale: String| {
        let plan = plan_for(k);
        let b = plan_max_batch(cfg, &plan, gpu);
        AutoTempoDecision {
            plan,
            max_batch: b,
            throughput: plan_throughput(cfg, gpu, k, target_batch.min(b)),
            rationale,
        }
    };

    if fits(0) {
        return decide(0, format!("target batch {target_batch} already fits without Tempo"));
    }
    if !fits(layers) {
        let b = plan_max_batch(cfg, &plan_for(layers), gpu);
        return decide(
            layers,
            format!("target batch {target_batch} unreachable even with full Tempo (max {b})"),
        );
    }
    // binary search the smallest sufficient prefix
    let (mut lo, mut hi) = (0usize, layers); // fits(lo)=false, fits(hi)=true
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if fits(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    decide(
        hi,
        format!(
            "smallest sufficient set: Tempo on {hi}/{layers} layers reaches batch {target_batch}"
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn large512() -> ModelConfig {
        ModelConfig::bert_large().with_seq_len(512)
    }

    #[test]
    fn coarse_applies_tempo_when_memory_bound() {
        let d = coarse_pass(&large512(), Gpu::Rtx2080Ti);
        assert_eq!(d.plan.applied_layers(), 24);
        assert!(d.rationale.contains("memory-bound"));
    }

    #[test]
    fn coarse_skips_when_not_memory_bound() {
        // tiny model on an A100: batch is huge either way; overheads make
        // Tempo pointless → pass should leave the model alone
        let cfg = ModelConfig::bert_tiny();
        let d = coarse_pass(&cfg, Gpu::A100);
        assert_eq!(d.plan.applied_layers(), 0, "{}", d.rationale);
    }

    #[test]
    fn fine_search_finds_minimal_prefix() {
        let cfg = large512();
        // target between baseline max (≈2) and tempo max (≈4)
        let base = max_batch(&cfg, Technique::Baseline, Gpu::Rtx2080Ti).max_batch;
        let tempo = max_batch(&cfg, Technique::Tempo, Gpu::Rtx2080Ti).max_batch;
        assert!(tempo > base);
        let target = base + 1;
        let d = fine_search(&cfg, Gpu::Rtx2080Ti, target);
        assert!(d.max_batch >= target);
        assert!(d.plan.applied_layers() > 0);
        assert!(d.plan.applied_layers() <= cfg.layers);
        // minimality: one fewer layer must not reach the target
        let k = d.plan.applied_layers();
        if k > 1 {
            let mut smaller = d.plan.clone();
            smaller.per_layer[k - 1] = OptimizationSet::none();
            let b = super::plan_max_batch(&cfg, &smaller, Gpu::Rtx2080Ti);
            assert!(b < target, "prefix {k}-1 already reaches {target}");
        }
    }

    #[test]
    fn fine_search_zero_when_target_fits() {
        let cfg = ModelConfig::bert_large().with_seq_len(128);
        let d = fine_search(&cfg, Gpu::V100, 2);
        assert_eq!(d.plan.applied_layers(), 0);
    }

    #[test]
    fn fine_search_reports_unreachable() {
        let d = fine_search(&large512(), Gpu::Rtx2080Ti, 1000);
        assert!(d.rationale.contains("unreachable"));
        assert_eq!(d.plan.applied_layers(), 24);
    }

    #[test]
    fn plan_throughput_matches_uniform_endpoints() {
        let cfg = large512();
        for b in [1usize, 2, 4] {
            let p0 = plan_throughput(&cfg, Gpu::Rtx2080Ti, 0, b);
            let base = throughput_at(&cfg, Technique::Baseline, Gpu::Rtx2080Ti, b).seqs_per_s;
            assert!((p0 - base).abs() < 1e-12, "B={b}: plan {p0} vs baseline {base}");
            let pl = plan_throughput(&cfg, Gpu::Rtx2080Ti, cfg.layers, b);
            let tempo = throughput_at(&cfg, Technique::Tempo, Gpu::Rtx2080Ti, b).seqs_per_s;
            assert!((pl - tempo).abs() < 1e-12, "B={b}: plan {pl} vs tempo {tempo}");
        }
    }

    #[test]
    fn plan_throughput_interpolates_monotonically() {
        // Tempo adds per-layer overhead at equal batch, so throughput
        // must fall strictly between the endpoints and decrease as more
        // layers are tempo-ized.
        let cfg = large512();
        let mut prev = f64::INFINITY;
        for k in [0usize, 6, 12, 18, 24] {
            let p = plan_throughput(&cfg, Gpu::Rtx2080Ti, k, 2);
            assert!(p < prev, "k={k}: {p} !< {prev}");
            assert!(p > 0.0);
            prev = p;
        }
    }

    #[test]
    fn plan_throughput_zero_batch_is_zero() {
        assert_eq!(plan_throughput(&large512(), Gpu::Rtx2080Ti, 4, 0), 0.0);
    }

    #[test]
    fn fine_search_unreachable_prices_the_batch_that_runs() {
        // target 1000 is unreachable; throughput must be modeled at the
        // actual max batch, not the fantasy target.
        let cfg = large512();
        let d = fine_search(&cfg, Gpu::Rtx2080Ti, 1000);
        let expect = plan_throughput(&cfg, Gpu::Rtx2080Ti, cfg.layers, d.max_batch);
        assert!((d.throughput - expect).abs() < 1e-12);
    }

    #[test]
    fn fine_search_partial_plan_priced_plan_aware() {
        let cfg = large512();
        let base = max_batch(&cfg, Technique::Baseline, Gpu::Rtx2080Ti).max_batch;
        let d = fine_search(&cfg, Gpu::Rtx2080Ti, base + 1);
        let k = d.plan.applied_layers();
        assert!(k > 0 && k < cfg.layers, "want a partial plan, got {k}");
        let expect = plan_throughput(&cfg, Gpu::Rtx2080Ti, k, (base + 1).min(d.max_batch));
        assert!((d.throughput - expect).abs() < 1e-12);
        // a partial plan must beat uniform-Tempo pricing at the same batch
        let uniform = throughput_at(&cfg, Technique::Tempo, Gpu::Rtx2080Ti, base + 1).seqs_per_s;
        assert!(d.throughput > uniform, "partial {0} !> uniform {uniform}", d.throughput);
    }

    #[test]
    fn plan_bytes_monotone_in_applied_layers() {
        let cfg = large512();
        let mut prev = u64::MAX;
        for k in [0usize, 6, 12, 24] {
            let mut per_layer = vec![OptimizationSet::none(); 24];
            for set in per_layer.iter_mut().take(k) {
                *set = OptimizationSet::full();
            }
            let plan = LayerPlan::rewrites_only(per_layer);
            let bytes = plan.total_bytes(&cfg, 2);
            assert!(bytes < prev, "k={k}");
            prev = bytes;
        }
    }
}
