//! Measured-probe Auto-Tempo: re-rank analytic candidates by *executed*
//! step time and peak bytes on the kernel backend.
//!
//! The analytic policies ([`super::coarse_pass`], [`super::fine_search`],
//! [`super::placement_search`]) trust the roofline and liveness models
//! end to end. The measured probe closes the loop the paper sketches
//! ("the same interface could be backed by measured probes"): rank a
//! family of candidate placements analytically, take the top K, shrink
//! the model to a probe config (same structure, toy dims), run real
//! training steps through [`crate::runtime::step_trace`], and re-rank
//! by wall-clock step time — reporting per-plan calibration drift
//! between the models' predictions and the measurements
//! ([`crate::perfmodel::calib::DriftRow`]).
//!
//! Two kinds of drift are reported per plan:
//!
//! * **Step time** is compared in *relative* terms — each column is
//!   normalized to its fastest measured candidate — because the
//!   roofline prices a GPU while the kernels run on host cores; only
//!   the shape of the ranking is comparable across the two.
//! * **Peak bytes** are compared *directly*: the interpreter meters the
//!   same buffers the liveness timeline prices, so the two columns
//!   share units and should agree closely.

use std::time::Instant;

use crate::config::{Gpu, ModelConfig, OptimizationSet};
use crate::coordinator::ExperimentEngine;
use crate::graph::CkptStyle;
use crate::memmodel::max_batch_for_plan;
use crate::perfmodel::calib::DriftRow;
use crate::perfmodel::{plan_step_time, plan_throughput_at};
use crate::runtime::{init_params, step_trace, Manifest, StepBatch, StepTrace};
use crate::{Error, Result};

use super::search::{AutoTempoDecision, LayerPlan};

/// Per-device batch size every probe run executes.
pub const PROBE_BATCH: usize = 2;

/// Timed steps per candidate (after one untimed warmup step).
pub const PROBE_STEPS: usize = 2;

/// The shrunken stand-in [`measured_probe`] executes: the full config's
/// structure (topology family, dropout rate) at toy dims, with the
/// layer count capped at two — enough depth for the inter-layer
/// effects (checkpoint hoisting, offload turnaround) without paying
/// full-depth wall clock.
pub fn probe_config(cfg: &ModelConfig) -> ModelConfig {
    let mut p = cfg.clone();
    p.name = format!("{}-probe", cfg.name);
    p.hidden = 64;
    p.heads = 2;
    p.seq_len = 16;
    p.intermediate = 128;
    p.vocab_size = 256;
    p.max_position = 32;
    p.type_vocab = p.type_vocab.clamp(1, 2);
    p.layers = cfg.layers.clamp(1, 2);
    p
}

/// The uniform-family candidate placements the probe considers, built
/// at `layers` encoder layers. Labels are stable across layer counts,
/// so the full-config and probe-config instantiations pair up by
/// index.
fn candidates(layers: usize) -> Vec<(&'static str, LayerPlan)> {
    let only = |w: &str| OptimizationSet::only(w).expect("known rewrite name");
    let mut front = vec![OptimizationSet::none(); layers];
    for set in front.iter_mut().take(layers.div_ceil(2)) {
        *set = OptimizationSet::full();
    }
    vec![
        ("baseline", LayerPlan::uniform(layers, OptimizationSet::none())),
        ("tempo", LayerPlan::uniform(layers, OptimizationSet::full())),
        ("gelu", LayerPlan::uniform(layers, only("gelu"))),
        ("layernorm", LayerPlan::uniform(layers, only("layernorm"))),
        ("dropout", LayerPlan::uniform(layers, only("dropout"))),
        ("softmax", LayerPlan::uniform(layers, only("softmax"))),
        ("tempo-front-half", LayerPlan::rewrites_only(front)),
        ("ckpt-overlapped", LayerPlan::uniform_checkpoint(layers, CkptStyle::Overlapped)),
        ("ckpt-serial", LayerPlan::uniform_checkpoint(layers, CkptStyle::Serial)),
        ("offload-tempo", LayerPlan::uniform_offload(layers, OptimizationSet::full())),
    ]
}

/// One measured candidate, with its calibration drift rows.
#[derive(Debug, Clone)]
pub struct ProbeRow {
    /// Candidate label (uniform-family name).
    pub label: &'static str,
    /// The candidate instantiated at the *full* config's layer count.
    pub plan: LayerPlan,
    /// 0-based position in the analytic ranking the probe started from.
    pub analytic_rank: usize,
    /// Mean wall-clock seconds per training step on the kernel backend.
    pub measured_step_s: f64,
    /// Roofline step seconds for the probe config (a GPU prediction —
    /// only comparable to `measured_step_s` in relative terms).
    pub modeled_step_s: f64,
    /// High-water live bytes the interpreter actually held.
    pub measured_peak_bytes: u64,
    /// The liveness timeline's predicted peak for the same plan/batch.
    pub modeled_peak_bytes: u64,
    /// Host-stash high water (offload plans; 0 otherwise).
    pub host_peak_bytes: u64,
    /// Final training loss of the probe run (finite ⇒ numerics sane).
    pub loss: f64,
    /// Relative step-time drift: both columns normalized to their
    /// fastest measured candidate (see the module docs).
    pub time_drift: DriftRow,
    /// Peak-bytes drift (directly comparable units).
    pub peak_drift: DriftRow,
}

/// Outcome of [`measured_probe`].
#[derive(Debug, Clone)]
pub struct ProbeReport {
    /// The shrunken config the measurements ran on.
    pub probe_cfg: ModelConfig,
    /// Number of candidate placements the analytic pass ranked.
    pub candidates: usize,
    /// Measured candidates, fastest wall clock first.
    pub rows: Vec<ProbeRow>,
    /// The measured winner mapped back onto the full config, with max
    /// batch and throughput re-priced analytically at full dims.
    pub decision: AutoTempoDecision,
}

/// Run the measured probe: rank the candidate family analytically at
/// the full config, execute the top `top_k` on the kernel backend at
/// the probe config ([`PROBE_STEPS`] timed steps each, one warmup),
/// and re-rank by measured step time.
pub fn measured_probe(
    cfg: &ModelConfig,
    gpu: Gpu,
    top_k: usize,
    seed: u64,
    engine: &ExperimentEngine,
) -> Result<ProbeReport> {
    if top_k == 0 {
        return Err(Error::Invalid("--top must be at least 1".into()));
    }
    let full = candidates(cfg.layers);

    // Analytic pass: price every candidate at its own max batch — the
    // objective the analytic searches optimize.
    let mut ranked: Vec<(usize, f64)> = full
        .iter()
        .enumerate()
        .map(|(i, (_, plan))| {
            let sp = plan.schedule_plan();
            let b = max_batch_for_plan(cfg, &sp, gpu).max_batch.max(1);
            (i, plan_throughput_at(cfg, &sp, gpu, b))
        })
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    let k = top_k.min(ranked.len());

    // Measured pass at the probe config.
    let pcfg = probe_config(cfg);
    let probe_plans = candidates(pcfg.layers);
    let spec = gpu.spec();
    struct Meas {
        idx: usize,
        analytic_rank: usize,
        measured_s: f64,
        modeled_s: f64,
        trace: StepTrace,
    }
    let mut meas = Vec::with_capacity(k);
    for (analytic_rank, &(idx, _)) in ranked.iter().take(k).enumerate() {
        let label = full[idx].0;
        let plan = probe_plans[idx].1.schedule_plan();
        let manifest = Manifest::synthetic(
            &format!("probe_{label}"),
            "mlm",
            label,
            "kernel",
            PROBE_BATCH,
            &pcfg,
            2,
        );
        let mut params = init_params(&manifest, seed);
        let batch = StepBatch::synthetic(&manifest, seed);
        // warmup step: page in every buffer shape before the clock runs
        let mut trace = step_trace(&manifest, &plan, engine, &mut params, &batch, 0, seed, 1e-3)?;
        let t0 = Instant::now();
        for s in 0..PROBE_STEPS {
            trace =
                step_trace(&manifest, &plan, engine, &mut params, &batch, (s + 1) as i64, seed, 1e-3)?;
        }
        let measured_s = t0.elapsed().as_secs_f64() / PROBE_STEPS as f64;
        let modeled_s = plan_step_time(&pcfg, &plan, &spec, PROBE_BATCH);
        meas.push(Meas { idx, analytic_rank, measured_s, modeled_s, trace });
    }

    // Normalize the time columns to their fastest candidate so the
    // drift compares ranking shape, not GPU-vs-host absolute scale.
    let min_meas = meas.iter().map(|m| m.measured_s).fold(f64::INFINITY, f64::min);
    let min_model = meas.iter().map(|m| m.modeled_s).fold(f64::INFINITY, f64::min);
    let mut rows: Vec<ProbeRow> = meas
        .into_iter()
        .map(|m| {
            let label = full[m.idx].0;
            ProbeRow {
                label,
                plan: full[m.idx].1.clone(),
                analytic_rank: m.analytic_rank,
                measured_step_s: m.measured_s,
                modeled_step_s: m.modeled_s,
                measured_peak_bytes: m.trace.measured_peak_bytes,
                modeled_peak_bytes: m.trace.modeled_peak_bytes,
                host_peak_bytes: m.trace.host_peak_bytes,
                loss: m.trace.loss,
                time_drift: DriftRow {
                    plan: label.to_string(),
                    quantity: "step time (relative)",
                    modeled: m.modeled_s / min_model,
                    measured: m.measured_s / min_meas,
                },
                peak_drift: DriftRow {
                    plan: label.to_string(),
                    quantity: "peak bytes",
                    modeled: m.trace.modeled_peak_bytes as f64,
                    measured: m.trace.measured_peak_bytes as f64,
                },
            }
        })
        .collect();
    rows.sort_by(|a, b| {
        a.measured_step_s.total_cmp(&b.measured_step_s).then(a.analytic_rank.cmp(&b.analytic_rank))
    });

    // Map the measured winner back onto the full config.
    let best = &rows[0];
    let sp = best.plan.schedule_plan();
    let b = max_batch_for_plan(cfg, &sp, gpu).max_batch;
    let decision = AutoTempoDecision {
        plan: best.plan.clone(),
        max_batch: b,
        throughput: plan_throughput_at(cfg, &sp, gpu, b.max(1)),
        rationale: format!(
            "measured probe: '{}' fastest of {k} measured candidates \
             ({:.3} ms/step at {}, analytic rank {}, peak drift {:+.1}%)",
            best.label,
            best.measured_step_s * 1e3,
            pcfg.name,
            best.analytic_rank + 1,
            best.peak_drift.drift_pct(),
        ),
    };
    Ok(ProbeReport { probe_cfg: pcfg, candidates: full.len(), rows, decision })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_config_shrinks_every_axis() {
        let cfg = ModelConfig::bert_large().with_seq_len(512);
        let p = probe_config(&cfg);
        assert_eq!(p.hidden, 64);
        assert_eq!(p.layers, 2);
        assert_eq!(p.seq_len, 16);
        assert_eq!(p.vocab_size, 256);
        assert_eq!(p.hidden % p.heads, 0);
        assert!(p.max_position >= p.seq_len);
        assert!(p.name.ends_with("-probe"));
    }

    #[test]
    fn candidate_family_covers_all_residency_arms() {
        let c = candidates(4);
        assert!(c.iter().any(|(_, p)| p.checkpointed_layers() == 4));
        assert!(c.iter().any(|(_, p)| p.offloaded_layers() == 4));
        assert!(c.iter().any(|(_, p)| p.applied_layers() == 4));
        assert!(c.iter().any(|(_, p)| p.applied_layers() == 0 && p.checkpointed_layers() == 0));
        // labels are unique — they key the drift report
        let mut labels: Vec<_> = c.iter().map(|(l, _)| *l).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), c.len());
    }

    #[test]
    fn measured_probe_ranks_by_wall_clock_and_reports_drift() {
        let cfg = ModelConfig::bert_tiny();
        let engine = ExperimentEngine::serial();
        let r = measured_probe(&cfg, Gpu::Rtx2080Ti, 3, 7, &engine).unwrap();
        assert_eq!(r.candidates, 10);
        assert_eq!(r.rows.len(), 3);
        for w in r.rows.windows(2) {
            assert!(w[0].measured_step_s <= w[1].measured_step_s);
        }
        let mut saw_rel_one = false;
        for row in &r.rows {
            assert!(row.loss.is_finite(), "{}: loss {}", row.label, row.loss);
            assert!(row.measured_step_s > 0.0 && row.modeled_step_s > 0.0);
            assert!(row.measured_peak_bytes > 0 && row.modeled_peak_bytes > 0);
            assert!(row.time_drift.ratio().is_finite());
            // the interpreter meters the same banks and buffers the
            // liveness timeline prices — the columns must stay in the
            // same ballpark at probe dims
            let ratio = row.peak_drift.ratio();
            assert!((0.2..5.0).contains(&ratio), "{}: peak ratio {ratio}", row.label);
            saw_rel_one |= row.time_drift.measured == 1.0;
        }
        // exactly the fastest measured candidate normalizes to 1.0
        assert!(saw_rel_one);
        assert_eq!(r.decision.plan.per_layer.len(), cfg.layers);
        assert!(r.decision.throughput > 0.0);
        assert!(r.decision.rationale.contains("measured probe"));
    }

    #[test]
    fn measured_probe_rejects_zero_top_k() {
        let cfg = ModelConfig::bert_tiny();
        assert!(measured_probe(&cfg, Gpu::V100, 0, 1, &ExperimentEngine::serial()).is_err());
    }
}
