//! Auto-Tempo (§5.2): automatically deciding where to apply Tempo.
//!
//! Two prototype policies, as in the paper:
//!
//! 1. **Coarse** ([`coarse_pass`]) — profile first: if the target batch
//!    does not fit (or utilization is below a knee), switch *all*
//!    applicable layers to Tempo; otherwise leave the model alone.
//! 2. **Fine-grained** ([`fine_search`]) — apply Tempo to a *subset* of
//!    the optimizations/layers, found by a profile-guided search
//!    "analogous to binary search": grow the applied prefix until the
//!    target batch fits, then keep the smallest sufficient set (less
//!    surface for the lossy GELU approximation and overheads).
//!
//! Profiles come from the analytical memmodel/perfmodel — folds over
//! the shared layer-graph IR and its execution schedule
//! ([`crate::graph`]), so a plan is literally a per-layer choice of
//! graph rewrites and max batch is a binary search against the plan's
//! liveness-timeline peak — which is what a compiler pass would
//! precompute; the same interface could be backed by measured probes.
//!
//! A third policy generalizes both: [`placement_search`] runs a
//! **joint** search over per-layer `(rewrite subset, checkpoint arm)`
//! assignments — the paper's rewrites *and* `SegmentCheckpoint`
//! placement in one objective — with dominance pruning over the
//! memoized schedule summaries (`tempo autotempo --placement joint`,
//! `tempo placement`; DESIGN.md §Placement).
//!
//! Finally, [`measured_probe`] backs the interface with *measured*
//! profiles: it re-ranks the analytically best candidates by real
//! wall-clock step time and metered peak bytes on the kernel backend
//! at a shrunken probe config, reporting per-plan model-vs-measured
//! calibration drift (`tempo autotempo --probe measured`).

mod placement;
mod probe;
mod search;

pub use placement::{
    placement_search, placement_search_jobs, placement_search_tp, placement_search_with,
    PlacementDecision, PlacementMode, PruneStats, TpPolicy, TP_DEGREES,
};
pub use probe::{
    measured_probe, probe_config, ProbeReport, ProbeRow, PROBE_BATCH, PROBE_STEPS,
};
pub use search::{coarse_pass, fine_search, plan_throughput, AutoTempoDecision, LayerPlan};
