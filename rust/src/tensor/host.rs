//! Row-major host tensors (f32 / i32) used to stage data across the PJRT
//! boundary and to hold parameter checkpoints.

use crate::{Error, Result};

/// Element type of a [`HostTensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl Dtype {
    /// Bytes per element.
    pub fn size(self) -> usize {
        4
    }

    /// Parse the manifest's dtype string.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" | "f32" => Ok(Dtype::F32),
            "int32" | "i32" => Ok(Dtype::I32),
            other => Err(Error::Parse(format!("unsupported dtype {other}"))),
        }
    }
}

/// A dense row-major tensor on the host.
#[derive(Debug, Clone, PartialEq)]
#[allow(missing_docs)] // variant fields mirror the Dtype variants
pub enum HostTensor {
    /// f32 payload with row-major shape.
    F32 { shape: Vec<usize>, data: Vec<f32> },
    /// i32 payload with row-major shape.
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// New f32 tensor; checks element count against the shape.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Invalid(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(HostTensor::F32 { shape, data })
    }

    /// New i32 tensor; checks element count against the shape.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Invalid(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(HostTensor::I32 { shape, data })
    }

    /// All-zero tensor of the given dtype/shape.
    pub fn zeros(dtype: Dtype, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        match dtype {
            Dtype::F32 => HostTensor::F32 { shape, data: vec![0.0; n] },
            Dtype::I32 => HostTensor::I32 { shape, data: vec![0; n] },
        }
    }

    /// Scalar f32.
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    /// Scalar i32.
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    /// The row-major shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// The element type.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes occupied by the payload.
    pub fn nbytes(&self) -> usize {
        self.len() * self.dtype().size()
    }

    /// Borrow the f32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => Err(Error::Invalid("tensor is not f32".into())),
        }
    }

    /// Borrow the i32 payload.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => Err(Error::Invalid("tensor is not i32".into())),
        }
    }

    /// First element as f64 (handy for scalar outputs like loss).
    pub fn first(&self) -> Result<f64> {
        match self {
            HostTensor::F32 { data, .. } => data
                .first()
                .map(|v| *v as f64)
                .ok_or_else(|| Error::Invalid("empty tensor".into())),
            HostTensor::I32 { data, .. } => data
                .first()
                .map(|v| *v as f64)
                .ok_or_else(|| Error::Invalid("empty tensor".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let s = HostTensor::scalar_f32(2.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.first().unwrap(), 2.5);
        assert_eq!(s.nbytes(), 4);
    }

    #[test]
    fn zeros_len() {
        let z = HostTensor::zeros(Dtype::I32, vec![3, 5]);
        assert_eq!(z.len(), 15);
        assert_eq!(z.dtype(), Dtype::I32);
        assert!(!z.is_empty());
    }
}
