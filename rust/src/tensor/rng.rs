//! SplitMix64 RNG — tiny, fast, reproducible across platforms.
//!
//! Used by the data substrate (corpus synthesis, MLM masking) and the
//! coordinator (shuffling). Deliberately not cryptographic.

/// SplitMix64 finalizer: a bijective avalanche mix of a 64-bit value.
/// Every input bit affects every output bit, so structured seed grids
/// (`base + 1000·trial`) map to well-spread 64-bit values.
pub fn mix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fold a full 64-bit seed into the i32 ABI scalar the artifacts take.
/// A plain `seed as i32` truncation aliases seeds 2³² apart; mixing
/// first and xor-folding the halves keeps all 64 input bits live.
pub fn fold_seed_i32(seed: u64) -> i32 {
    let z = mix64(seed);
    (((z >> 32) as u32) ^ (z as u32)) as i32
}

/// Deterministic 64-bit RNG (SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded constructor; two `Rng`s with the same seed produce the same
    /// stream on every platform.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (e.g. per worker / per epoch).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_separates_aliasing_seeds() {
        // `seed as i32` maps these to the same scalar; the fold must not.
        let a = 42u64;
        let b = 42u64 + (1u64 << 32);
        assert_eq!(a as i32, b as i32, "precondition: plain truncation aliases");
        assert_ne!(fold_seed_i32(a), fold_seed_i32(b));
        // and it stays deterministic
        assert_eq!(fold_seed_i32(a), fold_seed_i32(a));
    }

    #[test]
    fn mix64_spreads_adjacent_seeds() {
        let deltas: Vec<u32> = (0..64u64)
            .map(|i| (mix64(i) ^ mix64(i + 1)).count_ones())
            .collect();
        // avalanche: adjacent inputs flip roughly half the output bits
        let mean = deltas.iter().sum::<u32>() as f64 / deltas.len() as f64;
        assert!((20.0..44.0).contains(&mean), "mean flipped bits {mean}");
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn coin_rate_reasonable() {
        let mut r = Rng::new(3);
        let hits = (0..100_000).filter(|_| r.coin(0.15)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.15).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
