//! Small statistics helpers for metrics and report assertions.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (0 for < 2 samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Welford online mean/variance accumulator (used by throughput metrics).
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for < 2 samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen (∞ when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 4.25, 10.0, 0.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - mean(&xs)).abs() < 1e-12);
        assert!((st.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(st.count(), 6);
        assert_eq!(st.min(), -3.0);
        assert_eq!(st.max(), 10.0);
    }

    #[test]
    fn empty_is_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(OnlineStats::new().variance(), 0.0);
    }
}
