//! Minimal host-side tensor + deterministic RNG substrate.
//!
//! The coordinator only needs CPU-side staging buffers (batches in, loss
//! and checkpoints out) — all heavy math lives inside the XLA
//! executables — so this is deliberately small: row-major buffers of
//! `f32`/`i32` with shape metadata, plus a SplitMix64 RNG for data
//! generation that is reproducible across runs and platforms.

mod host;
mod rng;
pub mod stats;

pub use host::{Dtype, HostTensor};
pub use rng::{fold_seed_i32, mix64, Rng};
pub use stats::{mean, stddev, OnlineStats};
