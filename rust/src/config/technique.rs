//! Technique selection: the paper's three compared methods plus the
//! fine-grained per-optimization toggles used by the ablations (Fig 12)
//! and Auto-Tempo (§5.2).

/// Top-level memory-management technique (§4.2 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Technique {
    /// No footprint reduction (NVIDIA reference model).
    Baseline,
    /// PyTorch-style whole-encoder-layer checkpointing.
    Checkpoint,
    /// Tempo: the optimization set in [`OptimizationSet`].
    Tempo,
}

impl Technique {
    /// Display name (Table 2 / figure row labels).
    pub fn name(self) -> &'static str {
        match self {
            Technique::Baseline => "Baseline",
            Technique::Checkpoint => "Checkpoint",
            Technique::Tempo => "Tempo",
        }
    }

    /// The three compared methods, in the paper's presentation order.
    pub fn all() -> [Technique; 3] {
        [Technique::Baseline, Technique::Checkpoint, Technique::Tempo]
    }
}

/// Fine-grained Tempo optimization toggles (Fig 12 ablation axes;
/// Auto-Tempo searches over subsets of these per layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OptimizationSet {
    /// §3.1 In-place GELU (drop the 4H-wide GELU input, keep int8 mask).
    pub inplace_gelu: bool,
    /// §3.2 In-place LayerNorm (drop LN inputs, keep per-row rstd).
    pub inplace_layernorm: bool,
    /// §3.3 Sub-layer dropout recomputation on the attention probs.
    pub dropout_recompute: bool,
    /// §3.4 output-only softmax (drop the retained softmax input).
    pub softmax_outonly: bool,
}

impl OptimizationSet {
    /// All four optimizations on — the paper's "Tempo" configuration.
    pub fn full() -> Self {
        OptimizationSet {
            inplace_gelu: true,
            inplace_layernorm: true,
            dropout_recompute: true,
            softmax_outonly: true,
        }
    }

    /// Everything off — the baseline inventory.
    pub fn none() -> Self {
        OptimizationSet {
            inplace_gelu: false,
            inplace_layernorm: false,
            dropout_recompute: false,
            softmax_outonly: false,
        }
    }

    /// Exactly one optimization on (ablation rows in Fig 12).
    pub fn only(which: &str) -> Option<Self> {
        let mut s = Self::none();
        match which {
            "gelu" => s.inplace_gelu = true,
            "layernorm" => s.inplace_layernorm = true,
            "dropout" => s.dropout_recompute = true,
            "softmax" => s.softmax_outonly = true,
            _ => return None,
        }
        Some(s)
    }

    /// Set union: every optimization enabled in either operand.
    pub fn union(mut self, other: OptimizationSet) -> OptimizationSet {
        self.inplace_gelu |= other.inplace_gelu;
        self.inplace_layernorm |= other.inplace_layernorm;
        self.dropout_recompute |= other.dropout_recompute;
        self.softmax_outonly |= other.softmax_outonly;
        self
    }

    /// Number of enabled optimizations.
    pub fn count(&self) -> usize {
        [self.inplace_gelu, self.inplace_layernorm, self.dropout_recompute, self.softmax_outonly]
            .iter()
            .filter(|b| **b)
            .count()
    }

    /// Enumerate all 16 subsets (Auto-Tempo's fine-grained search space
    /// per layer).
    pub fn all_subsets() -> Vec<OptimizationSet> {
        (0..16)
            .map(|bits: u32| OptimizationSet {
                inplace_gelu: bits & 1 != 0,
                inplace_layernorm: bits & 2 != 0,
                dropout_recompute: bits & 4 != 0,
                softmax_outonly: bits & 8 != 0,
            })
            .collect()
    }

    /// Compact label for tables (`tempo(all)`, `none`, `gelu+drop`…).
    pub fn label(&self) -> String {
        if *self == Self::full() {
            return "tempo(all)".into();
        }
        if *self == Self::none() {
            return "none".into();
        }
        let mut parts = Vec::new();
        if self.inplace_gelu {
            parts.push("gelu");
        }
        if self.inplace_layernorm {
            parts.push("ln");
        }
        if self.dropout_recompute {
            parts.push("drop");
        }
        if self.softmax_outonly {
            parts.push("sm");
        }
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsets_are_complete_and_unique() {
        let all = OptimizationSet::all_subsets();
        assert_eq!(all.len(), 16);
        let mut labels: Vec<String> = all.iter().map(|s| format!("{s:?}")).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 16);
    }

    #[test]
    fn counts() {
        assert_eq!(OptimizationSet::full().count(), 4);
        assert_eq!(OptimizationSet::none().count(), 0);
        assert_eq!(OptimizationSet::only("gelu").unwrap().count(), 1);
        assert!(OptimizationSet::only("bogus").is_none());
    }

    #[test]
    fn union_is_fieldwise_or() {
        let g = OptimizationSet::only("gelu").unwrap();
        let d = OptimizationSet::only("dropout").unwrap();
        let u = g.union(d);
        assert!(u.inplace_gelu && u.dropout_recompute);
        assert_eq!(u.count(), 2);
        assert_eq!(u.union(u), u);
        assert_eq!(OptimizationSet::none().union(OptimizationSet::full()), OptimizationSet::full());
    }

    #[test]
    fn labels() {
        assert_eq!(OptimizationSet::full().label(), "tempo(all)");
        assert_eq!(OptimizationSet::only("dropout").unwrap().label(), "drop");
    }
}
