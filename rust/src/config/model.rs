//! Transformer model hyperparameters (paper §2.1 notation: H, S, A, L).
//!
//! Presets cover every configuration the paper evaluates: BERT-BASE /
//! BERT-LARGE (Table 2, Fig 2/5/6/9/12), the widened ablation configs
//! (Fig 7: H=2048/3072), the 12-layer BERT-LARGE used for the long-
//! sequence ablation (Fig 8), and the GPT2 / RoBERTa analogues (§4.3
//! "Results on Other Models").

/// Architectural family — affects the per-layer tensor inventory only
/// marginally (all three are post-LN Transformer encoders/decoders with
/// learned positions; GPT2 uses causal attention, same memory shape).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// BERT-style bidirectional encoder (fused-attention lowering).
    Bert,
    /// GPT2-style decoder (HF unfused-attention lowering by default).
    Gpt2,
    /// RoBERTa (BERT-shaped; different vocab/positions).
    Roberta,
}

impl ModelKind {
    /// Short family name (artifact/manifest naming).
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Bert => "bert",
            ModelKind::Gpt2 => "gpt2",
            ModelKind::Roberta => "roberta",
        }
    }
}

/// Model hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Preset name (builders append suffixes, e.g. `bert-large-s512`).
    pub name: String,
    /// Architectural family (drives the default lowering rules).
    pub kind: ModelKind,
    /// Hidden size H.
    pub hidden: usize,
    /// Encoder layers L.
    pub layers: usize,
    /// Attention heads A.
    pub heads: usize,
    /// Sequence length S.
    pub seq_len: usize,
    /// FFN inner size (4H for the standard Transformer).
    pub intermediate: usize,
    /// Vocabulary size V (the B·S·V head terms).
    pub vocab_size: usize,
    /// Learned position-embedding count.
    pub max_position: usize,
    /// Token-type (segment) vocabulary size.
    pub type_vocab: usize,
    /// Dropout probability (data/PRNG side; memory model is p-free).
    pub dropout_p: f64,
}

impl ModelConfig {
    /// Head dimension (H/A; the paper keeps H/A = 64).
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Whether a tensor-parallel shard degree divides this model's
    /// encoder cleanly: Megatron-style sharding splits attention by
    /// head and the FFN by inner column, so `tp` must divide the head
    /// count, the FFN inner size, and the hidden size (row-parallel
    /// inputs). The vocabulary dimension is *not* required to divide —
    /// the vocab-parallel head pads its shard (ceil division), exactly
    /// as Megatron-LM pads the embedding table.
    pub fn tp_permitted(&self, tp: usize) -> bool {
        tp > 0 && self.heads % tp == 0 && self.intermediate % tp == 0 && self.hidden % tp == 0
    }

    /// Total parameter count (embeddings + encoder + MLM head, fp32
    /// element count — multiply by dtype width for bytes).
    pub fn param_count(&self) -> usize {
        let (emb, per_layer, mlm) = self.param_count_split();
        emb + self.layers * per_layer + mlm
    }

    /// Per-segment parameter counts `(embedding, per encoder layer,
    /// MLM head)` — the gradient-bucket granularity of the comm lane.
    ///
    /// The three terms sum exactly to [`param_count`](Self::param_count)
    /// (`emb + layers·per_layer + head`), so the bucketed all-reduce
    /// moves exactly the same interconnect bytes as a monolithic one.
    /// The embedding bucket carries the tied vocabulary matrix, making
    /// it the largest — and it becomes ready only at the very end of
    /// backward, which is what keeps part of the collective exposed.
    pub fn param_count_split(&self) -> (usize, usize, usize) {
        let h = self.hidden;
        let emb = (self.vocab_size + self.max_position + self.type_vocab) * h + 2 * h;
        // per layer: QKV+O (4 h² + 4h), FFN (2·h·i + i + h), 2 LN (4h)
        let per_layer = 4 * h * h + 4 * h + 2 * h * self.intermediate + self.intermediate + h + 4 * h;
        let mlm = h * h + h + 2 * h + self.vocab_size; // transform + LN + tied decoder bias
        (emb, per_layer, mlm)
    }

    /// Builder: override the sequence length (phase 1 vs phase 2).
    pub fn with_seq_len(&self, s: usize) -> ModelConfig {
        ModelConfig { seq_len: s, name: format!("{}-s{}", self.name, s), ..self.clone() }
    }

    /// Builder: override hidden size keeping H/A = 64 (Fig 7 ablation).
    ///
    /// The paper's rule is H/A = 64 exactly, so `h` must be a positive
    /// multiple of 64 — anything else would silently produce a
    /// degenerate config (`heads == 0` for h < 64, or a non-integer
    /// head_dim that truncates) whose capacity/roofline numbers are
    /// meaningless.
    pub fn with_hidden(&self, h: usize) -> crate::Result<ModelConfig> {
        if h == 0 || h % 64 != 0 {
            return Err(crate::Error::Invalid(format!(
                "with_hidden({h}): hidden size must be a positive multiple of 64 \
                 (the paper keeps H/A = 64; {h} would give heads = {} with head_dim {})",
                h / 64,
                if h / 64 > 0 { h / (h / 64) } else { 0 },
            )));
        }
        Ok(ModelConfig {
            hidden: h,
            heads: h / 64,
            intermediate: 4 * h,
            name: format!("{}-h{}", self.name, h),
            ..self.clone()
        })
    }

    /// Builder: override layer count (Fig 8 uses BERT-LARGE with L=12).
    pub fn with_layers(&self, l: usize) -> ModelConfig {
        ModelConfig { layers: l, name: format!("{}-l{}", self.name, l), ..self.clone() }
    }

    // ---- presets -----------------------------------------------------------

    /// BERT-BASE (H=768, L=12; Table 2, Fig 9).
    pub fn bert_base() -> ModelConfig {
        ModelConfig {
            name: "bert-base".into(),
            kind: ModelKind::Bert,
            hidden: 768,
            layers: 12,
            heads: 12,
            seq_len: 128,
            intermediate: 3072,
            vocab_size: 30522,
            max_position: 512,
            type_vocab: 2,
            dropout_p: 0.1,
        }
    }

    /// BERT-LARGE (H=1024, L=24; the paper's flagship).
    pub fn bert_large() -> ModelConfig {
        ModelConfig {
            name: "bert-large".into(),
            kind: ModelKind::Bert,
            hidden: 1024,
            layers: 24,
            heads: 16,
            seq_len: 128,
            intermediate: 4096,
            vocab_size: 30522,
            max_position: 512,
            type_vocab: 2,
            dropout_p: 0.1,
        }
    }

    /// GPT2-124M ("small") — §4.3 other-models ablation.
    pub fn gpt2() -> ModelConfig {
        ModelConfig {
            name: "gpt2".into(),
            kind: ModelKind::Gpt2,
            hidden: 768,
            layers: 12,
            heads: 12,
            seq_len: 512,
            intermediate: 3072,
            vocab_size: 50257,
            max_position: 1024,
            type_vocab: 1,
            dropout_p: 0.1,
        }
    }

    /// RoBERTa-LARGE (fairseq default for the paper's ablation).
    pub fn roberta_large() -> ModelConfig {
        ModelConfig {
            name: "roberta-large".into(),
            kind: ModelKind::Roberta,
            hidden: 1024,
            layers: 24,
            heads: 16,
            seq_len: 512,
            intermediate: 4096,
            vocab_size: 50265,
            max_position: 514,
            type_vocab: 1,
            dropout_p: 0.1,
        }
    }

    /// The scaled-down configs that actually train on the CPU testbed
    /// (mirroring python/compile/model.py CONFIGS).
    pub fn bert_tiny() -> ModelConfig {
        ModelConfig {
            name: "bert-tiny".into(),
            kind: ModelKind::Bert,
            hidden: 128,
            layers: 2,
            heads: 2,
            seq_len: 64,
            intermediate: 512,
            vocab_size: 4096,
            max_position: 512,
            type_vocab: 2,
            dropout_p: 0.1,
        }
    }

    /// 4-layer scaled-down config (CPU testbed; small-model tests).
    pub fn bert_mini() -> ModelConfig {
        ModelConfig {
            name: "bert-mini".into(),
            kind: ModelKind::Bert,
            hidden: 256,
            layers: 4,
            heads: 4,
            seq_len: 128,
            intermediate: 1024,
            vocab_size: 8192,
            max_position: 512,
            type_vocab: 2,
            dropout_p: 0.1,
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        match name {
            "bert-base" => Some(Self::bert_base()),
            "bert-large" => Some(Self::bert_large()),
            "gpt2" => Some(Self::gpt2()),
            "roberta-large" => Some(Self::roberta_large()),
            "bert-tiny" => Some(Self::bert_tiny()),
            "bert-mini" => Some(Self::bert_mini()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_param_count_is_about_110m() {
        let n = ModelConfig::bert_base().param_count();
        assert!((100_000_000..125_000_000).contains(&n), "{n}");
    }

    #[test]
    fn bert_large_param_count_is_about_335m() {
        let n = ModelConfig::bert_large().param_count();
        assert!((320_000_000..350_000_000).contains(&n), "{n}");
    }

    #[test]
    fn param_split_sums_to_param_count() {
        for cfg in [ModelConfig::bert_base(), ModelConfig::bert_large(),
                    ModelConfig::gpt2(), ModelConfig::roberta_large(),
                    ModelConfig::bert_tiny(), ModelConfig::bert_mini()] {
            let (emb, per_layer, head) = cfg.param_count_split();
            assert_eq!(emb + cfg.layers * per_layer + head, cfg.param_count(), "{}", cfg.name);
            // the tied-vocab embedding bucket is the largest single bucket
            assert!(emb > per_layer && emb > head, "{}", cfg.name);
        }
    }

    #[test]
    fn head_ratio_is_64_for_paper_models() {
        for cfg in [ModelConfig::bert_base(), ModelConfig::bert_large(),
                    ModelConfig::gpt2(), ModelConfig::roberta_large()] {
            assert_eq!(cfg.head_dim(), 64, "{}", cfg.name);
        }
    }

    #[test]
    fn with_hidden_keeps_ratio() {
        let cfg = ModelConfig::bert_base().with_hidden(2048).unwrap();
        assert_eq!(cfg.heads, 32);
        assert_eq!(cfg.intermediate, 8192);
        assert_eq!(cfg.head_dim(), 64);
    }

    #[test]
    fn with_hidden_rejects_degenerate_sizes() {
        let base = ModelConfig::bert_base();
        // h < 64 would give heads == 0; non-multiples truncate head_dim
        for bad in [0usize, 32, 100, 96, 1000] {
            let err = base.with_hidden(bad);
            assert!(err.is_err(), "h={bad} must be rejected");
            let msg = format!("{}", err.unwrap_err());
            assert!(msg.contains("multiple of 64"), "h={bad}: {msg}");
        }
        for good in [64usize, 128, 3072] {
            assert!(base.with_hidden(good).is_ok(), "h={good}");
        }
    }

    #[test]
    fn with_seq_len_and_layers() {
        let cfg = ModelConfig::bert_large().with_layers(12).with_seq_len(3072);
        assert_eq!(cfg.layers, 12);
        assert_eq!(cfg.seq_len, 3072);
        assert_eq!(cfg.hidden, 1024);
    }

    #[test]
    fn presets_resolve() {
        for name in ["bert-base", "bert-large", "gpt2", "roberta-large",
                     "bert-tiny", "bert-mini"] {
            assert!(ModelConfig::preset(name).is_some(), "{name}");
        }
        assert!(ModelConfig::preset("nope").is_none());
    }
}
