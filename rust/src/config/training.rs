//! Training-run hyperparameters consumed by the coordinator.

use crate::{Error, Result};

/// Hyperparameters for a coordinator-driven training run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingConfig {
    /// Artifact name (see `artifacts/index.json`).
    pub artifact: String,
    /// Total optimizer steps.
    pub steps: usize,
    /// Linear-warmup steps before `peak_lr` is reached.
    pub warmup_steps: usize,
    /// Peak learning rate (top of the warmup ramp).
    pub peak_lr: f64,
    /// Seed for data generation and the in-graph dropout PRNG.
    pub seed: u64,
    /// Evaluate every N steps (0 = never).
    pub eval_every: usize,
    /// Log every N steps.
    pub log_every: usize,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            artifact: "bert_tiny_tempo".into(),
            steps: 200,
            warmup_steps: 20,
            peak_lr: 1e-3,
            seed: 42,
            eval_every: 50,
            log_every: 10,
        }
    }
}

impl TrainingConfig {
    /// Linear warmup to `peak_lr`, then linear decay to 0 at `steps`
    /// (the BERT pre-training schedule).
    pub fn lr_at(&self, step: usize) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        if step < self.warmup_steps {
            return self.peak_lr * (step as f64 + 1.0) / self.warmup_steps.max(1) as f64;
        }
        let remain = (self.steps - step.min(self.steps)) as f64;
        let denom = (self.steps - self.warmup_steps).max(1) as f64;
        self.peak_lr * (remain / denom).clamp(0.0, 1.0)
    }

    /// Parse from a small `key = value` TOML-subset file (strings,
    /// integers, floats; comments with `#`). Keeps the offline build
    /// free of a TOML dependency while staying human-editable.
    pub fn from_kv_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = TrainingConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Parse(format!("{path}:{}: expected key = value", lineno + 1))
            })?;
            let (k, v) = (k.trim(), v.trim().trim_matches('"'));
            let bad = |what: &str| Error::Parse(format!("{path}:{}: bad {what}", lineno + 1));
            match k {
                "artifact" => cfg.artifact = v.to_string(),
                "steps" => cfg.steps = v.parse().map_err(|_| bad("steps"))?,
                "warmup_steps" => cfg.warmup_steps = v.parse().map_err(|_| bad("warmup_steps"))?,
                "peak_lr" => cfg.peak_lr = v.parse().map_err(|_| bad("peak_lr"))?,
                "seed" => cfg.seed = v.parse().map_err(|_| bad("seed"))?,
                "eval_every" => cfg.eval_every = v.parse().map_err(|_| bad("eval_every"))?,
                "log_every" => cfg.log_every = v.parse().map_err(|_| bad("log_every"))?,
                other => return Err(Error::Parse(format!("{path}: unknown key '{other}'"))),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shape() {
        let cfg = TrainingConfig { steps: 100, warmup_steps: 10, peak_lr: 1.0, ..Default::default() };
        assert!(cfg.lr_at(0) > 0.0);
        assert!(cfg.lr_at(4) < cfg.lr_at(9));
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-9); // peak at end of warmup
        assert!(cfg.lr_at(50) < 1.0);
        assert!(cfg.lr_at(99) > cfg.lr_at(100));
        assert_eq!(cfg.lr_at(100), 0.0);
    }

    #[test]
    fn schedule_monotone_after_warmup() {
        let cfg = TrainingConfig { steps: 60, warmup_steps: 5, peak_lr: 3e-4, ..Default::default() };
        let mut prev = f64::INFINITY;
        for s in 5..=60 {
            let lr = cfg.lr_at(s);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn kv_file_roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.file("run.toml");
        std::fs::write(
            &p,
            "# comment\nartifact = \"bert_mini_tempo\"\nsteps = 300\npeak_lr = 5e-4\nseed = 7\n",
        )
        .unwrap();
        let cfg = TrainingConfig::from_kv_file(p.to_str().unwrap()).unwrap();
        assert_eq!(cfg.artifact, "bert_mini_tempo");
        assert_eq!(cfg.steps, 300);
        assert_eq!(cfg.peak_lr, 5e-4);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.eval_every, 50); // default preserved
    }

    #[test]
    fn kv_file_rejects_unknown_keys() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.file("bad.toml");
        std::fs::write(&p, "nope = 1\n").unwrap();
        assert!(TrainingConfig::from_kv_file(p.to_str().unwrap()).is_err());
    }
}
