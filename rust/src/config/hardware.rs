//! GPU hardware specifications for the capacity and roofline simulators.
//!
//! These describe the paper's three test platforms (Table 4 / §4.1):
//! 4×RTX 2080 Ti (11 GB, PCIe), 4×V100 (16 GB, NVLink), 1×A100 (40 GB).
//! Peak numbers are the published fp16-with-fp32-accumulate tensor
//! throughputs, since the NVIDIA BERT reference trains with AMP.

/// The paper's evaluation GPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gpu {
    /// RTX 2080 Ti (11 GB GDDR6, PCIe ring).
    Rtx2080Ti,
    /// V100 SXM2 (16 GB HBM2, NVLink).
    V100,
    /// A100 (40 GB, single-GPU ablation box).
    A100,
}

impl Gpu {
    /// Display name (`2080Ti`, `V100`, `A100`).
    pub fn name(self) -> &'static str {
        match self {
            Gpu::Rtx2080Ti => "2080Ti",
            Gpu::V100 => "V100",
            Gpu::A100 => "A100",
        }
    }

    /// Static hardware description for the capacity/roofline models.
    pub fn spec(self) -> GpuSpec {
        match self {
            // 2080 Ti: 11 GB GDDR6, 616 GB/s, ~108 TFLOPS fp16 tensor
            // (~57 TFLOPS sustained with fp32 accumulate on TU102).
            Gpu::Rtx2080Ti => GpuSpec {
                gpu: self,
                mem_bytes: 11 * GIB,
                bandwidth: 616.0e9,
                peak_matmul_flops: 53.8e12,
                peak_vector_flops: 13.4e12,
                // fixed CUDA context + framework + cudnn workspace floor,
                // calibrated once against the paper's Table 2 (see
                // memmodel::calib).
                reserved_bytes: (1.05 * GIB as f64) as u64,
                // Effective achieved ring busbw across the 4-GPU PCIe v3
                // node (P2P pairs + bucketed NCCL rings), calibrated so
                // the exposure fold's residual matches the scaling
                // overhead the Fig 5 bands pin (perfmodel::calib) —
                // deliberately above the ~9 GB/s single-link rate.
                allreduce_bw: Some(25.0e9),
                devices: 4,
                // PCIe v3 x16: ~12 GB/s effective h2d/d2h with pinned
                // buffers (the L2L offload lane).
                host_link_bw: 12.0e9,
                // TP collectives ride the same PCIe P2P pairs as the
                // gradient ring, but per-pair rather than bucketed:
                // ~10 GB/s achieved.
                tp_bw: 10.0e9,
            },
            // V100 (SXM2 16 GB): 900 GB/s HBM2, 125 TFLOPS fp16 tensor.
            Gpu::V100 => GpuSpec {
                gpu: self,
                mem_bytes: 16 * GIB,
                bandwidth: 900.0e9,
                peak_matmul_flops: 112.0e12,
                peak_vector_flops: 15.7e12,
                reserved_bytes: (1.10 * GIB as f64) as u64,
                // NVLink (p3.8xlarge): ~55 GB/s effective all-reduce
                allreduce_bw: Some(55.0e9),
                devices: 4,
                // p3-class hosts feed the GPUs over PCIe v3 (NVLink is
                // GPU↔GPU only): ~10 GB/s achieved in the h2d direction.
                host_link_bw: 10.0e9,
                // NVLink GPU↔GPU: ~65 GB/s effective per-collective
                // busbw for the in-block TP all-gather/reduce-scatter.
                tp_bw: 65.0e9,
            },
            // A100 40 GB: 1555 GB/s, 312 TFLOPS bf16 tensor.
            Gpu::A100 => GpuSpec {
                gpu: self,
                mem_bytes: 40 * GIB,
                bandwidth: 1555.0e9,
                peak_matmul_flops: 280.0e12,
                peak_vector_flops: 19.5e12,
                reserved_bytes: (1.20 * GIB as f64) as u64,
                // single-GPU ablation platform: no gradient sync
                allreduce_bw: None,
                devices: 1,
                // PCIe v4 x16 host link on the A100 box: ~25 GB/s
                // effective.
                host_link_bw: 25.0e9,
                // NVLink3 (600 GB/s bidirectional peak): ~250 GB/s
                // effective collective busbw between A100s in a
                // hypothetical scale-up domain. `devices` stays 1 (the
                // ablation box has no DP replica), but the TP axis is a
                // *scale-up* domain orthogonal to DP, so `--tp` can
                // still shard across NVLink3 peers.
                tp_bw: 250.0e9,
            },
        }
    }

    /// The paper's three test platforms, smallest memory first.
    pub fn all() -> [Gpu; 3] {
        [Gpu::Rtx2080Ti, Gpu::V100, Gpu::A100]
    }
}

const GIB: u64 = 1024 * 1024 * 1024;

/// Static hardware description used by memmodel (capacity) and
/// perfmodel (roofline).
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    /// Which GPU this spec describes.
    pub gpu: Gpu,
    /// Total device memory.
    pub mem_bytes: u64,
    /// HBM/GDDR bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Peak tensor-core matmul throughput, FLOP/s (fp16 acc fp32).
    pub peak_matmul_flops: f64,
    /// Peak CUDA-core elementwise throughput, FLOP/s.
    pub peak_vector_flops: f64,
    /// Memory unavailable to tensors (context, cudnn workspace, frags).
    pub reserved_bytes: u64,
    /// Effective all-reduce bandwidth of the node's interconnect
    /// (bytes/s); `None` = single-GPU rig (the A100 ablation box).
    /// This fixed per-step gradient-sync cost is what larger batches
    /// amortize — a key reason bigger batches win on the paper's
    /// PCIe-connected 2080 Ti rig.
    pub allreduce_bw: Option<f64>,
    /// Data-parallel replica count of the rig (the paper trains on
    /// 4×2080Ti and 4×V100 nodes; the A100 ablation box is single-GPU).
    /// Each device holds a full replica, so peak memory is per device;
    /// `devices == 1` means no collective traffic at all.
    pub devices: usize,
    /// Effective host↔device link bandwidth (bytes/s) for the L2L
    /// offload lane — achieved pinned-buffer DMA rate, not the bus
    /// peak. Per device: each replica streams its own activations over
    /// its own link, so offload traffic does not contend across the
    /// rig. `TEMPO_HOST_BW` overrides it at startup.
    pub host_link_bw: f64,
    /// Effective per-collective bandwidth (bytes/s) of the tensor-
    /// parallel scale-up interconnect — what one in-block
    /// all-gather/reduce-scatter achieves between shard peers. TP is a
    /// *scale-up* domain orthogonal to [`devices`](Self::devices) (DP
    /// replica count): sharding divides per-device activations and
    /// compute without changing the DP gradient ring. `TEMPO_TP_BW`
    /// overrides it at startup.
    pub tp_bw: f64,
}

impl GpuSpec {
    /// Bytes usable for model state + activations (per device).
    ///
    /// Saturating: a custom spec with `reserved_bytes >= mem_bytes`
    /// yields 0 usable bytes (nothing fits) instead of a debug panic /
    /// release wrap-around.
    pub fn usable_bytes(&self) -> u64 {
        self.mem_bytes.saturating_sub(self.reserved_bytes)
    }

    /// Builder: the same card in an `n`-way data-parallel rig.
    ///
    /// `n == 1` turns off the comm lane entirely (no gradient buckets,
    /// zero exposed collective time); the memory model is unaffected
    /// because every replica holds the full model state.
    pub fn with_devices(&self, n: usize) -> GpuSpec {
        GpuSpec { devices: n.max(1), ..*self }
    }

    /// Machine balance (FLOP per byte at the matmul roofline knee).
    pub fn balance(&self) -> f64 {
        self.peak_matmul_flops / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_ordering_matches_paper() {
        let caps: Vec<u64> = Gpu::all().iter().map(|g| g.spec().mem_bytes).collect();
        assert!(caps[0] < caps[1] && caps[1] < caps[2]);
        assert_eq!(caps[0], 11 * GIB);
        assert_eq!(caps[1], 16 * GIB);
        assert_eq!(caps[2], 40 * GIB);
    }

    #[test]
    fn usable_is_less_than_total() {
        for g in Gpu::all() {
            let s = g.spec();
            assert!(s.usable_bytes() < s.mem_bytes);
            assert!(s.usable_bytes() > s.mem_bytes / 2);
        }
    }

    #[test]
    fn newer_gpus_are_faster() {
        let [t, v, a] = Gpu::all().map(|g| g.spec().peak_matmul_flops);
        assert!(t < v && v < a);
    }

    #[test]
    fn usable_bytes_saturates_on_overreserved_custom_spec() {
        // regression: this used to be an unchecked u64 subtraction that
        // panicked in debug / wrapped to ~2^64 in release
        let mut s = Gpu::Rtx2080Ti.spec();
        s.reserved_bytes = s.mem_bytes;
        assert_eq!(s.usable_bytes(), 0);
        s.reserved_bytes = s.mem_bytes + GIB;
        assert_eq!(s.usable_bytes(), 0);
    }

    #[test]
    fn paper_rigs_are_four_way_except_the_a100_box() {
        assert_eq!(Gpu::Rtx2080Ti.spec().devices, 4);
        assert_eq!(Gpu::V100.spec().devices, 4);
        assert_eq!(Gpu::A100.spec().devices, 1);
        let solo = Gpu::V100.spec().with_devices(1);
        assert_eq!(solo.devices, 1);
        assert_eq!(solo.mem_bytes, Gpu::V100.spec().mem_bytes);
        // degenerate n=0 clamps to a single device
        assert_eq!(Gpu::V100.spec().with_devices(0).devices, 1);
    }

    #[test]
    fn host_links_are_an_order_slower_than_device_memory() {
        for g in Gpu::all() {
            let s = g.spec();
            assert!(s.host_link_bw > 0.0, "{}", g.name());
            // the L2L premise: PCIe is ~50× slower than HBM/GDDR, so
            // offload only pays when the backward can cover the DMA
            assert!(s.host_link_bw < s.bandwidth / 10.0, "{}", g.name());
        }
    }

    #[test]
    fn balance_is_tens_of_flops_per_byte() {
        for g in Gpu::all() {
            let b = g.spec().balance();
            assert!((50.0..250.0).contains(&b), "{} balance {b}", g.name());
        }
    }
}
