//! Configuration system: model presets (BERT family + GPT2/RoBERTa),
//! GPU hardware specs, technique selection and training hyperparameters.

mod hardware;
mod model;
mod technique;
mod training;

pub use hardware::{Gpu, GpuSpec};
pub use model::{ModelConfig, ModelKind};
pub use technique::{OptimizationSet, Technique};
pub use training::TrainingConfig;
