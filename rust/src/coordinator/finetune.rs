//! Fig 6b analogue: MRPC-like fine-tuning trials.
//!
//! Runs N independent trials of the classification artifact on the
//! synthetic paraphrase-pair task and reports the per-epoch accuracy
//! band (median/min/max across trials), for baseline vs tempo.
//! Backend-generic like [`super::Trainer`].

use crate::data::{Corpus, CorpusConfig, PairTask};
use crate::runtime::{Artifact, Backend, DeviceState, Entry, Program};
use crate::tensor::HostTensor;
use crate::{Error, Result};

/// Accuracy trajectory of one trial.
#[derive(Debug, Clone)]
pub struct TrialCurve {
    pub seed: u64,
    /// accuracy after each eval point
    pub accuracy: Vec<f64>,
}

/// Aggregated fine-tuning result for one artifact.
#[derive(Debug, Clone)]
pub struct FinetuneResult {
    pub artifact: String,
    pub trials: Vec<TrialCurve>,
}

impl FinetuneResult {
    /// (min, median, max) accuracy at the final eval point.
    pub fn final_band(&self) -> (f64, f64, f64) {
        let mut finals: Vec<f64> = self
            .trials
            .iter()
            .filter_map(|t| t.accuracy.last().copied())
            .collect();
        finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = finals.len();
        if n == 0 {
            return (0.0, 0.0, 0.0);
        }
        (finals[0], finals[n / 2], finals[n - 1])
    }
}

/// Run `trials` fine-tuning runs of `steps` steps, evaluating accuracy
/// every `eval_every` steps on held-out pair batches.
#[allow(clippy::too_many_arguments)]
pub fn finetune_trials<B: Backend>(
    backend: &B,
    artifact: &Artifact,
    trials: usize,
    steps: usize,
    eval_every: usize,
    lr: f64,
    base_seed: u64,
    verbose: bool,
) -> Result<FinetuneResult> {
    let m = &artifact.manifest;
    if m.task != "cls" {
        return Err(Error::Invalid(format!("{} is not a cls artifact", m.name)));
    }
    // eval_every = 0 means "final eval only" (and guards the modulo below).
    let eval_every = if eval_every == 0 { steps.max(1) } else { eval_every };
    let init_prog = backend.prepare(artifact, Entry::Init)?;
    let step_prog = backend.prepare(artifact, Entry::Step)?;
    let eval_prog = backend.prepare(artifact, Entry::Eval)?;

    let mut result = FinetuneResult { artifact: m.name.clone(), trials: Vec::new() };
    for trial in 0..trials {
        let seed = base_seed + 1000 * trial as u64;
        let seed_in = backend.upload(&HostTensor::scalar_i32(seed as i32))?;
        let outs = init_prog.run(&[&seed_in])?;
        let mut state = DeviceState::from_init(outs, m)?;
        let corpus = Corpus::new(
            CorpusConfig { vocab_size: m.config.vocab_size, ..Default::default() },
            seed,
        );
        let mut task = PairTask::new(corpus, m.batch_size, m.config.seq_len, seed ^ 0xF00D);
        let mut curve = TrialCurve { seed, accuracy: Vec::new() };

        for s in 0..steps {
            let batch = task.next_batch()?;
            let mut vals = Vec::with_capacity(7);
            for t in batch.tensors() {
                vals.push(backend.upload(t)?);
            }
            vals.push(backend.upload(&HostTensor::scalar_i32(state.step as i32))?);
            vals.push(backend.upload(&HostTensor::scalar_i32(seed as i32))?);
            vals.push(backend.upload(&HostTensor::scalar_f32(lr as f32))?);
            let mut refs: Vec<&B::Value> = Vec::with_capacity(state.leaves.len() + 7);
            refs.extend(state.leaves.iter());
            refs.extend(vals.iter());
            let outs = step_prog.run(&refs)?;
            drop(refs);
            let loss_leaf = state.absorb_step_output(outs)?;
            let train_loss = backend.scalar(&loss_leaf)?;
            if verbose && (s + 1) % eval_every == 0 {
                println!(
                    "[{}] trial {} step {:>4} train loss {:.4}",
                    m.name,
                    trial,
                    s + 1,
                    train_loss
                );
            }

            if (s + 1) % eval_every == 0 || s + 1 == steps {
                // average accuracy over a few held-out batches
                let mut accs = Vec::new();
                for _ in 0..4 {
                    let eval_batch = task.next_batch()?;
                    let mut evals = Vec::with_capacity(5);
                    for t in eval_batch.tensors() {
                        evals.push(backend.upload(t)?);
                    }
                    evals.push(backend.upload(&HostTensor::scalar_i32(0))?);
                    let mut refs: Vec<&B::Value> =
                        Vec::with_capacity(state.n_params + 5);
                    refs.extend(state.params().iter());
                    refs.extend(evals.iter());
                    let outs = eval_prog.run(&refs)?;
                    if outs.len() != 2 {
                        return Err(Error::Abi(format!(
                            "eval returned {} outputs",
                            outs.len()
                        )));
                    }
                    accs.push(backend.scalar(&outs[1])?);
                }
                let acc = accs.iter().sum::<f64>() / accs.len() as f64;
                curve.accuracy.push(acc);
                if verbose {
                    println!(
                        "[{}] trial {} step {:>4}/{} acc {:.3}",
                        m.name,
                        trial,
                        s + 1,
                        steps,
                        acc
                    );
                }
            }
        }
        result.trials.push(curve);
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_band_orders() {
        let r = FinetuneResult {
            artifact: "x".into(),
            trials: vec![
                TrialCurve { seed: 0, accuracy: vec![0.5, 0.8] },
                TrialCurve { seed: 1, accuracy: vec![0.5, 0.6] },
                TrialCurve { seed: 2, accuracy: vec![0.5, 0.9] },
            ],
        };
        let (lo, med, hi) = r.final_band();
        assert_eq!((lo, med, hi), (0.6, 0.8, 0.9));
    }

    #[test]
    fn empty_band_is_zero() {
        let r = FinetuneResult { artifact: "x".into(), trials: vec![] };
        assert_eq!(r.final_band(), (0.0, 0.0, 0.0));
    }
}
