//! Fig 6b analogue: MRPC-like fine-tuning trials.
//!
//! Runs N independent trials of the classification artifact on the
//! synthetic paraphrase-pair task and reports the per-epoch accuracy
//! band (median/min/max across trials), for baseline vs tempo.
//! Backend-generic like [`super::Trainer`].
//!
//! Trials are independent cells on the [`ExperimentEngine`]: the
//! prepared programs are shared (`Arc`), each trial's device state
//! lives and dies on one worker thread, results come back in trial
//! order, and a failing trial is captured in
//! [`FinetuneResult::failures`] instead of aborting the sweep.
//!
//! Evaluation draws from a *held-out* pair stream (seed
//! `trial_seed ^ 0xE7A1`), so the number of eval points never shifts
//! the training data stream — the same split the MLM [`super::Trainer`]
//! applies.

use crate::data::{Corpus, CorpusConfig, PairTask};
use crate::runtime::{Artifact, Backend, DeviceState, Entry, Program};
use crate::tensor::{fold_seed_i32, HostTensor};
use crate::{Error, Result};

use super::engine::{partition_cells, CellFailure, ExperimentEngine};

/// Seed-domain separator for held-out evaluation streams (shared with
/// the MLM trainer's eval batcher).
pub(crate) const EVAL_SEED_SALT: u64 = 0xE7A1;

/// Accuracy trajectory of one trial.
#[derive(Debug, Clone)]
pub struct TrialCurve {
    /// The trial's fully-folded seed.
    pub seed: u64,
    /// accuracy after each eval point
    pub accuracy: Vec<f64>,
}

/// Aggregated fine-tuning result for one artifact.
#[derive(Debug, Clone)]
pub struct FinetuneResult {
    /// Artifact name the trials ran on.
    pub artifact: String,
    /// Successful trials, in trial order.
    pub trials: Vec<TrialCurve>,
    /// Trials whose cell failed (the sweep continued without them).
    pub failures: Vec<CellFailure>,
}

impl FinetuneResult {
    /// (min, median, max) accuracy at the final eval point.
    pub fn final_band(&self) -> (f64, f64, f64) {
        let mut finals: Vec<f64> = self
            .trials
            .iter()
            .filter_map(|t| t.accuracy.last().copied())
            .collect();
        finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = finals.len();
        if n == 0 {
            return (0.0, 0.0, 0.0);
        }
        (finals[0], finals[n / 2], finals[n - 1])
    }
}

/// Run `trials` fine-tuning runs of `steps` steps, evaluating accuracy
/// every `eval_every` steps on held-out pair batches.
///
/// Trial `t` uses seed `base_seed + 1000·t`; the full 64-bit seed is
/// mixed (SplitMix64 fold, [`fold_seed_i32`]) into the i32 ABI scalar,
/// so base seeds ≥ 2³¹ no longer alias across trials.
#[allow(clippy::too_many_arguments)]
pub fn finetune_trials<B: Backend>(
    backend: &B,
    artifact: &Artifact,
    trials: usize,
    steps: usize,
    eval_every: usize,
    lr: f64,
    base_seed: u64,
    engine: &ExperimentEngine,
    verbose: bool,
) -> Result<FinetuneResult> {
    let m = &artifact.manifest;
    if m.task != "cls" {
        return Err(Error::Invalid(format!("{} is not a cls artifact", m.name)));
    }
    // eval_every = 0 means "final eval only" (and guards the modulo below).
    let eval_every = if eval_every == 0 { steps.max(1) } else { eval_every };
    let init_prog = backend.prepare(artifact, Entry::Init)?;
    let step_prog = backend.prepare(artifact, Entry::Step)?;
    let eval_prog = backend.prepare(artifact, Entry::Eval)?;
    let cell_verbose = verbose && engine.jobs() == 1;

    let results = engine.run_cells(trials, |trial| {
        let seed = base_seed + 1000 * trial as u64;
        let abi_seed = fold_seed_i32(seed);
        let seed_in = backend.upload(&HostTensor::scalar_i32(abi_seed))?;
        let outs = init_prog.run(&[&seed_in])?;
        let mut state = DeviceState::from_init(outs, m)?;
        let corpus_cfg = CorpusConfig { vocab_size: m.config.vocab_size, ..Default::default() };
        let corpus = Corpus::new(corpus_cfg.clone(), seed);
        let mut task = PairTask::new(corpus, m.batch_size, m.config.seq_len, seed ^ 0xF00D);
        // Held-out stream: same distribution, disjoint RNG stream, so
        // evaluation never consumes (or shifts) training batches.
        let eval_corpus = Corpus::new(corpus_cfg, seed);
        let mut eval_task =
            PairTask::new(eval_corpus, m.batch_size, m.config.seq_len, seed ^ EVAL_SEED_SALT);
        let mut curve = TrialCurve { seed, accuracy: Vec::new() };

        for s in 0..steps {
            let batch = task.next_batch()?;
            let mut vals = Vec::with_capacity(7);
            for t in batch.tensors() {
                vals.push(backend.upload(t)?);
            }
            vals.push(backend.upload(&HostTensor::scalar_i32(state.step as i32))?);
            vals.push(backend.upload(&HostTensor::scalar_i32(abi_seed))?);
            vals.push(backend.upload(&HostTensor::scalar_f32(lr as f32))?);
            let mut refs: Vec<&B::Value> = Vec::with_capacity(state.leaves.len() + 7);
            refs.extend(state.leaves.iter());
            refs.extend(vals.iter());
            let outs = step_prog.run(&refs)?;
            drop(refs);
            let loss_leaf = state.absorb_step_output(outs)?;
            let train_loss = backend.scalar(&loss_leaf)?;
            if cell_verbose && (s + 1) % eval_every == 0 {
                println!(
                    "[{}] trial {} step {:>4} train loss {:.4}",
                    m.name,
                    trial,
                    s + 1,
                    train_loss
                );
            }

            if (s + 1) % eval_every == 0 || s + 1 == steps {
                // average accuracy over a few held-out batches
                let mut accs = Vec::new();
                for _ in 0..4 {
                    let eval_batch = eval_task.next_batch()?;
                    let mut evals = Vec::with_capacity(5);
                    for t in eval_batch.tensors() {
                        evals.push(backend.upload(t)?);
                    }
                    evals.push(backend.upload(&HostTensor::scalar_i32(0))?);
                    let mut refs: Vec<&B::Value> =
                        Vec::with_capacity(state.n_params + 5);
                    refs.extend(state.params().iter());
                    refs.extend(evals.iter());
                    let outs = eval_prog.run(&refs)?;
                    if outs.len() != 2 {
                        return Err(Error::Abi(format!(
                            "eval returned {} outputs",
                            outs.len()
                        )));
                    }
                    accs.push(backend.scalar(&outs[1])?);
                }
                let acc = accs.iter().sum::<f64>() / accs.len() as f64;
                curve.accuracy.push(acc);
                if cell_verbose {
                    println!(
                        "[{}] trial {} step {:>4}/{} acc {:.3}",
                        m.name,
                        trial,
                        s + 1,
                        steps,
                        acc
                    );
                }
            }
        }
        Ok(curve)
    });
    let (curves, failures) = partition_cells(results, |trial| format!("trial {trial}"));
    Ok(FinetuneResult { artifact: m.name.clone(), trials: curves, failures })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_band_orders() {
        let r = FinetuneResult {
            artifact: "x".into(),
            trials: vec![
                TrialCurve { seed: 0, accuracy: vec![0.5, 0.8] },
                TrialCurve { seed: 1, accuracy: vec![0.5, 0.6] },
                TrialCurve { seed: 2, accuracy: vec![0.5, 0.9] },
            ],
            failures: Vec::new(),
        };
        let (lo, med, hi) = r.final_band();
        assert_eq!((lo, med, hi), (0.6, 0.8, 0.9));
    }

    #[test]
    fn empty_band_is_zero() {
        let r = FinetuneResult { artifact: "x".into(), trials: vec![], failures: vec![] };
        assert_eq!(r.final_band(), (0.0, 0.0, 0.0));
    }
}
