//! The training loop: artifact → PJRT executables → steps over the
//! synthetic corpus, with LR schedule, metrics and checkpointing.

use std::path::PathBuf;
use std::time::Instant;

use crate::config::TrainingConfig;
use crate::data::{Corpus, CorpusConfig, MlmBatch, MlmBatcher, MlmConfig};
use crate::runtime::{tensor_to_literal, Artifact, Executable, LiteralState, Runtime, TrainState};
use crate::tensor::HostTensor;
use crate::{Error, Result};

use super::metrics::{Metrics, StepRecord};

/// Knobs not covered by [`TrainingConfig`].
#[derive(Debug, Clone, Default)]
pub struct TrainerOptions {
    /// Save a checkpoint here at the end of training.
    pub checkpoint_out: Option<PathBuf>,
    /// Resume from this checkpoint instead of running `init`.
    pub resume_from: Option<PathBuf>,
    /// Print progress lines.
    pub verbose: bool,
}

/// Drives one artifact through `cfg.steps` optimizer steps.
pub struct Trainer {
    artifact: Artifact,
    cfg: TrainingConfig,
    opts: TrainerOptions,
    step_exe: std::sync::Arc<Executable>,
    eval_exe: std::sync::Arc<Executable>,
    /// Literal-resident hot state (params, m, v) — see runtime::LiteralState.
    state: LiteralState,
    batcher: MlmBatcher,
    metrics: Metrics,
}

impl Trainer {
    /// Build a trainer: load + compile the artifact's executables, run
    /// `init` (or resume), wire up the data stream.
    pub fn new(rt: &Runtime, artifact: Artifact, cfg: TrainingConfig, opts: TrainerOptions) -> Result<Self> {
        let m = &artifact.manifest;
        if m.task != "mlm" {
            return Err(Error::Invalid(format!(
                "Trainer drives mlm artifacts; {} is {}",
                m.name, m.task
            )));
        }
        let init_exe = rt.load(artifact.init_path())?;
        let step_exe = rt.load(artifact.step_path())?;
        let eval_exe = rt.load(artifact.eval_path())?;

        let state = match &opts.resume_from {
            Some(path) => LiteralState::from_host(&TrainState::load(path)?)?,
            None => {
                // validate the ABI once through the host path, then keep
                // the leaves as literals for the hot loop
                let init_in = tensor_to_literal(&HostTensor::scalar_i32(cfg.seed as i32))?;
                let outs = init_exe.run_literals_raw(&[init_in])?;
                let host: Vec<HostTensor> = outs
                    .iter()
                    .map(crate::runtime::literal_to_tensor)
                    .collect::<Result<_>>()?;
                TrainState::from_init(host, m)?; // shape/arity validation
                LiteralState::from_init(outs, m)?
            }
        };

        let corpus = Corpus::new(
            CorpusConfig { vocab_size: m.config.vocab_size, ..Default::default() },
            cfg.seed,
        );
        let batcher = MlmBatcher::new(
            corpus,
            MlmConfig::default(),
            m.batch_size,
            m.config.seq_len,
            cfg.seed ^ 0xDA7A,
        );
        let metrics = Metrics::new(m.batch_size);
        Ok(Trainer { artifact, cfg, opts, step_exe, eval_exe, state, batcher, metrics })
    }

    /// The artifact being trained.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Host copy of the current state (checkpointing, inspection).
    pub fn state(&self) -> TrainState {
        self.state.to_host().expect("state conversion")
    }

    /// Convert batch tensors + scalars to literals (the only per-step
    /// host→literal conversions on the hot path).
    fn batch_literals(&self, batch: &MlmBatch, lr: f64) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(7);
        for t in batch.tensors() {
            lits.push(tensor_to_literal(t)?);
        }
        lits.push(tensor_to_literal(&HostTensor::scalar_i32(self.state.step as i32))?);
        lits.push(tensor_to_literal(&HostTensor::scalar_i32(self.cfg.seed as i32))?);
        lits.push(tensor_to_literal(&HostTensor::scalar_f32(lr as f32))?);
        Ok(lits)
    }

    /// Run exactly one optimizer step; returns the loss.
    pub fn step(&mut self) -> Result<f64> {
        let lr = self.cfg.lr_at(self.state.step as usize);
        let batch = self.batcher.next_batch()?;
        let batch_lits = self.batch_literals(&batch, lr)?;
        let t0 = Instant::now();
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.state.leaves.len() + 7);
        refs.extend(self.state.leaves.iter());
        refs.extend(batch_lits.iter());
        let outs = self.step_exe.run_refs(&refs)?;
        let loss = self.state.absorb_step_output(outs)?;
        self.metrics.push(StepRecord {
            step: self.state.step - 1,
            loss,
            lr,
            step_time: t0.elapsed(),
        });
        Ok(loss)
    }

    /// Evaluate on one held-out batch; returns (loss, metric).
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let batch = self.batcher.next_batch()?;
        let mut lits = Vec::with_capacity(5);
        for t in batch.tensors() {
            lits.push(tensor_to_literal(t)?);
        }
        lits.push(tensor_to_literal(&HostTensor::scalar_i32(0))?);
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(self.state.n_params + 5);
        refs.extend(self.state.params().iter());
        refs.extend(lits.iter());
        let outs = self.eval_exe.run_refs(&refs)?;
        if outs.len() != 2 {
            return Err(Error::Abi(format!("eval returned {} outputs", outs.len())));
        }
        Ok((outs[0].to_vec::<f32>()?[0] as f64, outs[1].to_vec::<f32>()?[0] as f64))
    }

    /// Run the full configured training loop.
    pub fn run(&mut self) -> Result<()> {
        let total = self.cfg.steps;
        while (self.state.step as usize) < total {
            let loss = self.step()?;
            let s = self.state.step as usize;
            if self.opts.verbose && (s % self.cfg.log_every.max(1) == 0 || s == total) {
                println!(
                    "[{}] step {:>5}/{} loss {:.4} ema {:.4} {:>6.1} seq/s",
                    self.artifact.manifest.name,
                    s,
                    total,
                    loss,
                    self.metrics.ema_loss().unwrap_or(loss),
                    self.metrics.throughput(),
                );
            }
            if self.cfg.eval_every > 0 && s % self.cfg.eval_every == 0 {
                let (eval_loss, _) = self.evaluate()?;
                if self.opts.verbose {
                    println!(
                        "[{}] step {:>5} eval loss {:.4}",
                        self.artifact.manifest.name, s, eval_loss
                    );
                }
            }
        }
        if let Some(path) = &self.opts.checkpoint_out {
            self.state.to_host()?.save(path)?;
            if self.opts.verbose {
                println!("[{}] checkpoint → {}", self.artifact.manifest.name, path.display());
            }
        }
        Ok(())
    }
}
