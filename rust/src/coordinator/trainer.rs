//! The training loop: artifact → backend programs → steps over the
//! synthetic corpus, with LR schedule, metrics and checkpointing.
//!
//! Generic over the execution [`Backend`]: the sim backend drives it
//! with zero artifacts present; the PJRT backend (`--features pjrt`)
//! drives the real AOT-compiled executables. The (params, m, v) state
//! stays device-resident between steps on either backend (the §Perf
//! hot path — see `runtime::DeviceState`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TrainingConfig;
use crate::data::{Corpus, CorpusConfig, MlmBatcher, MlmConfig};
use crate::runtime::{Artifact, Backend, DeviceState, Entry, Program, TrainState};
use crate::tensor::{fold_seed_i32, HostTensor};
use crate::{Error, Result};

use super::metrics::{Metrics, StepRecord};

/// Knobs not covered by [`TrainingConfig`].
#[derive(Debug, Clone, Default)]
pub struct TrainerOptions {
    /// Save a checkpoint here at the end of training.
    pub checkpoint_out: Option<PathBuf>,
    /// Resume from this checkpoint instead of running `init`.
    pub resume_from: Option<PathBuf>,
    /// Print progress lines.
    pub verbose: bool,
}

/// Drives one artifact through `cfg.steps` optimizer steps on a backend.
pub struct Trainer<'b, B: Backend> {
    backend: &'b B,
    artifact: Artifact,
    cfg: TrainingConfig,
    opts: TrainerOptions,
    step_prog: Arc<B::Prog>,
    eval_prog: Arc<B::Prog>,
    /// Device-resident hot state (params, m, v) — see runtime::DeviceState.
    state: DeviceState<B::Value>,
    batcher: MlmBatcher,
    /// Held-out stream for [`Trainer::evaluate`]: a disjoint RNG stream
    /// over the same corpus distribution, so evaluation never consumes
    /// training batches — `eval_every` cannot shift the training trace.
    eval_batcher: MlmBatcher,
    metrics: Metrics,
    /// `Some` when the backend models step latency analytically (sim);
    /// `None` means measure wall clock (pjrt).
    modeled_step_time: Option<Duration>,
}

impl<'b, B: Backend> Trainer<'b, B> {
    /// Build a trainer: prepare the artifact's entry points, run `init`
    /// (or resume), wire up the data stream.
    pub fn new(
        backend: &'b B,
        artifact: Artifact,
        cfg: TrainingConfig,
        opts: TrainerOptions,
    ) -> Result<Self> {
        let m = &artifact.manifest;
        if m.task != "mlm" {
            return Err(Error::Invalid(format!(
                "Trainer drives mlm artifacts; {} is {}",
                m.name, m.task
            )));
        }
        let init_prog = backend.prepare(&artifact, Entry::Init)?;
        let step_prog = backend.prepare(&artifact, Entry::Step)?;
        let eval_prog = backend.prepare(&artifact, Entry::Eval)?;

        let state = match &opts.resume_from {
            Some(path) => {
                let host = TrainState::load(path)?;
                // Validate up front, mirroring the init path below: a
                // checkpoint from a different config must fail with a
                // clear message, not a confusing ABI error many steps in.
                host.validate_manifest(m).map_err(|e| {
                    Error::Abi(format!(
                        "checkpoint {} does not match artifact {}: {e}",
                        path.display(),
                        m.name
                    ))
                })?;
                let leaves = host
                    .leaves
                    .iter()
                    .map(|t| backend.upload(t))
                    .collect::<Result<Vec<_>>>()?;
                DeviceState { leaves, n_params: host.n_params, step: host.step }
            }
            None => {
                // Full 64-bit seed folded into the i32 ABI scalar, so
                // seeds 2³² apart cannot alias (same fix as finetune).
                let seed_in =
                    backend.upload(&HostTensor::scalar_i32(fold_seed_i32(cfg.seed)))?;
                let outs = init_prog.run(&[&seed_in])?;
                let state = DeviceState::from_init(outs, m)?;
                // Validate the ABI once: init's parameter shapes must
                // match the manifest (m and v mirror params exactly).
                for (spec, leaf) in m.params.iter().zip(state.params()) {
                    let host = backend.download(leaf)?;
                    if spec.shape != host.shape() {
                        return Err(Error::Abi(format!(
                            "leaf {}: manifest shape {:?} != init shape {:?}",
                            spec.name,
                            spec.shape,
                            host.shape()
                        )));
                    }
                }
                state
            }
        };

        let corpus_cfg = CorpusConfig { vocab_size: m.config.vocab_size, ..Default::default() };
        let corpus = Corpus::new(corpus_cfg.clone(), cfg.seed);
        let batcher = MlmBatcher::new(
            corpus,
            MlmConfig::default(),
            m.batch_size,
            m.config.seq_len,
            cfg.seed ^ 0xDA7A,
        );
        // Held-out eval stream: same corpus distribution, disjoint RNG
        // stream (salt shared with finetune's eval split).
        let eval_batcher = MlmBatcher::new(
            Corpus::new(corpus_cfg, cfg.seed),
            MlmConfig::default(),
            m.batch_size,
            m.config.seq_len,
            cfg.seed ^ super::finetune::EVAL_SEED_SALT,
        );
        let metrics = Metrics::new(m.batch_size);
        let modeled_step_time = backend.modeled_step_time(&artifact);
        Ok(Trainer {
            backend,
            artifact,
            cfg,
            opts,
            step_prog,
            eval_prog,
            state,
            batcher,
            eval_batcher,
            metrics,
            modeled_step_time,
        })
    }

    /// The artifact being trained.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Rolling metrics of the run so far.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Host copy of the current state (checkpointing, inspection).
    pub fn state(&self) -> Result<TrainState> {
        let leaves = self
            .state
            .leaves
            .iter()
            .map(|v| self.backend.download(v))
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { leaves, n_params: self.state.n_params, step: self.state.step })
    }

    /// Convert batch tensors + scalars to device values (the only
    /// per-step host→device conversions on the hot path).
    fn batch_values(&self, tensors: [&HostTensor; 4], lr: f64) -> Result<Vec<B::Value>> {
        let mut vals = Vec::with_capacity(7);
        for t in tensors {
            vals.push(self.backend.upload(t)?);
        }
        vals.push(self.backend.upload(&HostTensor::scalar_i32(self.state.step as i32))?);
        vals.push(self.backend.upload(&HostTensor::scalar_i32(fold_seed_i32(self.cfg.seed)))?);
        vals.push(self.backend.upload(&HostTensor::scalar_f32(lr as f32))?);
        Ok(vals)
    }

    /// Run exactly one optimizer step; returns the loss.
    pub fn step(&mut self) -> Result<f64> {
        let lr = self.cfg.lr_at(self.state.step as usize);
        let batch = self.batcher.next_batch()?;
        let batch_vals = self.batch_values(batch.tensors(), lr)?;
        let t0 = Instant::now();
        let mut refs: Vec<&B::Value> = Vec::with_capacity(self.state.leaves.len() + 7);
        refs.extend(self.state.leaves.iter());
        refs.extend(batch_vals.iter());
        let outs = self.step_prog.run(&refs)?;
        let loss_leaf = self.state.absorb_step_output(outs)?;
        let loss = self.backend.scalar(&loss_leaf)?;
        self.metrics.push(StepRecord {
            step: self.state.step - 1,
            loss,
            lr,
            step_time: self.modeled_step_time.unwrap_or_else(|| t0.elapsed()),
        });
        Ok(loss)
    }

    /// Evaluate on one held-out batch; returns (loss, metric).
    ///
    /// Draws from the dedicated eval stream, never the training
    /// batcher: the training loss trace is bit-identical whatever
    /// `eval_every` is set to.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let batch = self.eval_batcher.next_batch()?;
        let mut vals = Vec::with_capacity(5);
        for t in batch.tensors() {
            vals.push(self.backend.upload(t)?);
        }
        vals.push(self.backend.upload(&HostTensor::scalar_i32(0))?);
        let mut refs: Vec<&B::Value> = Vec::with_capacity(self.state.n_params + 5);
        refs.extend(self.state.params().iter());
        refs.extend(vals.iter());
        let outs = self.eval_prog.run(&refs)?;
        if outs.len() != 2 {
            return Err(Error::Abi(format!("eval returned {} outputs", outs.len())));
        }
        Ok((self.backend.scalar(&outs[0])?, self.backend.scalar(&outs[1])?))
    }

    /// Run the full configured training loop.
    pub fn run(&mut self) -> Result<()> {
        let total = self.cfg.steps;
        while (self.state.step as usize) < total {
            let loss = self.step()?;
            let s = self.state.step as usize;
            if self.opts.verbose && (s % self.cfg.log_every.max(1) == 0 || s == total) {
                println!(
                    "[{}] step {:>5}/{} loss {:.4} ema {:.4} {:>6.1} seq/s",
                    self.artifact.manifest.name,
                    s,
                    total,
                    loss,
                    self.metrics.ema_loss().unwrap_or(loss),
                    self.metrics.throughput(),
                );
            }
            if self.cfg.eval_every > 0 && s % self.cfg.eval_every == 0 {
                let (eval_loss, _) = self.evaluate()?;
                if self.opts.verbose {
                    println!(
                        "[{}] step {:>5} eval loss {:.4}",
                        self.artifact.manifest.name, s, eval_loss
                    );
                }
            }
        }
        if let Some(path) = &self.opts.checkpoint_out {
            self.state()?.save(path)?;
            if self.opts.verbose {
                println!("[{}] checkpoint → {}", self.artifact.manifest.name, path.display());
            }
        }
        Ok(())
    }
}
