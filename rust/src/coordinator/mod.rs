//! L3 training coordinator: drives train/eval programs over the
//! synthetic data substrate, generic over the execution backend
//! (sim by default, PJRT under `--features pjrt`).
//!
//! * [`Trainer`] — the training loop (schedule, metrics, checkpoints).
//! * [`ExperimentEngine`] — the concurrent experiment engine: sweeps
//!   fan out across a scoped-thread pool with deterministic,
//!   grid-ordered results and per-cell error capture (DESIGN.md
//!   §Concurrency; `run_cells`'s doctest shows the contract).
//! * [`compare_variants`] — baseline-vs-tempo loss-curve runs (Fig 6a
//!   analogue).
//! * [`finetune_trials`] — MRPC-analogue classification trials (Fig 6b).

mod compare;
mod engine;
mod finetune;
mod metrics;
mod trainer;

pub use compare::{compare_variants, CompareResult, LossCurve};
pub use engine::{CellFailure, ExperimentEngine};
pub use finetune::{finetune_trials, FinetuneResult, TrialCurve};
pub use metrics::{Metrics, StepRecord};
pub use trainer::{Trainer, TrainerOptions};
