//! Fig 6a analogue: train baseline vs tempo (same data stream, same
//! dropout seeds) and compare the loss curves point-for-point.
//! Backend-generic: runs on the sim backend with zero artifacts, or on
//! PJRT against the real executables.

use crate::config::TrainingConfig;
use crate::runtime::{ArtifactIndex, Backend};
use crate::Result;

use super::trainer::{Trainer, TrainerOptions};

/// One variant's loss trajectory.
#[derive(Debug, Clone)]
pub struct LossCurve {
    pub artifact: String,
    pub losses: Vec<f64>,
}

impl LossCurve {
    /// Final-window mean (smooths step noise).
    pub fn endpoint(&self, window: usize) -> f64 {
        let n = self.losses.len();
        let w = window.min(n).max(1);
        self.losses[n - w..].iter().sum::<f64>() / w as f64
    }
}

/// Result of a variant comparison run.
#[derive(Debug, Clone)]
pub struct CompareResult {
    pub curves: Vec<LossCurve>,
    /// Max relative endpoint difference vs the first (reference) curve.
    pub max_endpoint_rel_diff: f64,
}

/// Train each artifact with identical config/seeds; collect loss curves.
///
/// The first artifact is the reference (the paper compares Tempo against
/// the NVIDIA baseline and reports ≤0.5% endpoint difference).
pub fn compare_variants<B: Backend>(
    backend: &B,
    index: &ArtifactIndex,
    artifact_names: &[&str],
    cfg: &TrainingConfig,
    verbose: bool,
) -> Result<CompareResult> {
    let mut curves = Vec::new();
    for name in artifact_names {
        let artifact = index.open(name)?;
        let mut trainer = Trainer::new(
            backend,
            artifact,
            cfg.clone(),
            TrainerOptions { verbose, ..Default::default() },
        )?;
        trainer.run()?;
        curves.push(LossCurve {
            artifact: name.to_string(),
            losses: trainer.metrics().records().iter().map(|r| r.loss).collect(),
        });
    }
    let window = (cfg.steps / 10).max(5);
    let reference = curves[0].endpoint(window);
    let max_endpoint_rel_diff = curves
        .iter()
        .skip(1)
        .map(|c| (c.endpoint(window) - reference).abs() / reference)
        .fold(0.0, f64::max);
    Ok(CompareResult { curves, max_endpoint_rel_diff })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_uses_final_window() {
        let c = LossCurve { artifact: "x".into(), losses: vec![10.0, 9.0, 2.0, 2.0] };
        assert!((c.endpoint(2) - 2.0).abs() < 1e-12);
        assert!((c.endpoint(100) - 5.75).abs() < 1e-12); // clamped to len
    }

    #[test]
    fn endpoint_handles_window_one() {
        let c = LossCurve { artifact: "x".into(), losses: vec![3.0, 1.5] };
        assert_eq!(c.endpoint(1), 1.5);
    }
}
