//! Fig 6a analogue: train baseline vs tempo (same data stream, same
//! dropout seeds) and compare the loss curves point-for-point.
//! Backend-generic: runs on the sim backend with zero artifacts, or on
//! PJRT against the real executables.
//!
//! Each variant is one independent cell on the [`ExperimentEngine`]:
//! the sweep scales across cores with `--jobs`, results come back in
//! grid (argument) order, and a failing variant is captured in
//! [`CompareResult::failures`] instead of aborting the others.

use crate::config::TrainingConfig;
use crate::runtime::{ArtifactIndex, Backend};
use crate::{Error, Result};

use super::engine::{partition_cells, CellFailure, ExperimentEngine};
use super::trainer::{Trainer, TrainerOptions};

/// One variant's loss trajectory.
#[derive(Debug, Clone)]
pub struct LossCurve {
    /// Artifact (variant) name.
    pub artifact: String,
    /// Per-step training losses.
    pub losses: Vec<f64>,
}

impl LossCurve {
    /// Final-window mean (smooths step noise).
    pub fn endpoint(&self, window: usize) -> f64 {
        let n = self.losses.len();
        let w = window.min(n).max(1);
        self.losses[n - w..].iter().sum::<f64>() / w as f64
    }
}

/// Result of a variant comparison run.
#[derive(Debug, Clone)]
pub struct CompareResult {
    /// Successful curves, in the order the artifacts were requested.
    pub curves: Vec<LossCurve>,
    /// Max relative endpoint difference vs the first successful
    /// (reference) curve.
    pub max_endpoint_rel_diff: f64,
    /// Variants whose cell failed (the sweep continued without them).
    pub failures: Vec<CellFailure>,
}

/// Train each artifact with identical config/seeds; collect loss curves.
///
/// The first artifact is the reference (the paper compares Tempo against
/// the NVIDIA baseline and reports ≤0.5% endpoint difference). Cells run
/// on `engine`; per-step progress printing is suppressed when the engine
/// is parallel so the output stays deterministic across `--jobs`.
pub fn compare_variants<B: Backend>(
    backend: &B,
    index: &ArtifactIndex,
    artifact_names: &[&str],
    cfg: &TrainingConfig,
    engine: &ExperimentEngine,
    verbose: bool,
) -> Result<CompareResult> {
    if artifact_names.is_empty() {
        return Err(Error::Invalid("compare_variants: no artifacts given".into()));
    }
    let cell_verbose = verbose && engine.jobs() == 1;
    let results = engine.run_cells(artifact_names.len(), |i| {
        let name = artifact_names[i];
        let artifact = index.open(name)?;
        let mut trainer = Trainer::new(
            backend,
            artifact,
            cfg.clone(),
            TrainerOptions { verbose: cell_verbose, ..Default::default() },
        )?;
        trainer.run()?;
        Ok(LossCurve {
            artifact: name.to_string(),
            losses: trainer.metrics().records().iter().map(|r| r.loss).collect(),
        })
    });
    let (curves, failures) = partition_cells(results, |i| artifact_names[i].to_string());
    if curves.is_empty() {
        return Err(Error::Backend(format!(
            "all {} compare cells failed; first: {}",
            artifact_names.len(),
            failures[0]
        )));
    }
    let window = (cfg.steps / 10).max(5);
    let reference = curves[0].endpoint(window);
    let max_endpoint_rel_diff = curves
        .iter()
        .skip(1)
        .map(|c| (c.endpoint(window) - reference).abs() / reference)
        .fold(0.0, f64::max);
    Ok(CompareResult { curves, max_endpoint_rel_diff, failures })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_uses_final_window() {
        let c = LossCurve { artifact: "x".into(), losses: vec![10.0, 9.0, 2.0, 2.0] };
        assert!((c.endpoint(2) - 2.0).abs() < 1e-12);
        assert!((c.endpoint(100) - 5.75).abs() < 1e-12); // clamped to len
    }

    #[test]
    fn endpoint_handles_window_one() {
        let c = LossCurve { artifact: "x".into(), losses: vec![3.0, 1.5] };
        assert_eq!(c.endpoint(1), 1.5);
    }

    #[test]
    fn empty_artifact_list_rejected() {
        let backend = crate::runtime::SimBackend::new();
        let idx = ArtifactIndex::builtin();
        let r = compare_variants(
            &backend,
            &idx,
            &[],
            &TrainingConfig::default(),
            &ExperimentEngine::serial(),
            false,
        );
        assert!(r.is_err());
    }

    #[test]
    fn all_cells_failing_is_an_error() {
        let backend = crate::runtime::SimBackend::new();
        let idx = ArtifactIndex::builtin();
        let cfg = TrainingConfig { steps: 2, ..Default::default() };
        let r = compare_variants(
            &backend,
            &idx,
            &["nope_a", "nope_b"],
            &cfg,
            &ExperimentEngine::serial(),
            false,
        );
        let msg = r.unwrap_err().to_string();
        assert!(msg.contains("nope_a"), "{msg}");
    }
}
