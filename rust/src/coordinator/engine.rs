//! Concurrent experiment engine: fans independent sweep cells out
//! across a scoped-thread worker pool.
//!
//! The paper's headline results (Fig 6a/6b, Table 2) are grids of
//! independent (artifact, variant, trial, batch-size) runs. Each such
//! cell is deterministic on its own — the coordinator's determinism
//! contract (DESIGN.md §Backends) is per run, not per schedule — so the
//! grid can execute in any order on any number of threads as long as
//! results are *collected by grid index*, never by completion order.
//!
//! [`ExperimentEngine::run_cells`] implements exactly that:
//!
//! * workers pull the next cell index from a shared atomic counter
//!   (work stealing without queues);
//! * every result lands in a pre-sized slot vector at its own index,
//!   so the output of `--jobs 4` is bit-identical to `--jobs 1`;
//! * a failing (or panicking) cell yields an `Err` in its slot instead
//!   of aborting the sweep — the remaining cells still run.
//!
//! The pool uses `std::thread::scope`, so cells may borrow the backend
//! and artifact index from the caller's stack; no dependencies, no
//! `'static` bounds. Backends are shared (`Backend: Send + Sync`), but
//! each cell creates and drops its own device values on one worker
//! thread, so `Backend::Value` itself never crosses threads (this is
//! what keeps the non-`Send` PJRT literals legal under the engine).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::{Error, Result};

/// One failed sweep cell, kept alongside the successful results so a
/// partial sweep is still reportable (and reproducible: the index is
/// the cell's grid position, stable across `--jobs` settings).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellFailure {
    /// Grid index of the failed cell.
    pub index: usize,
    /// Human-readable cell label (artifact name, trial id, …).
    pub label: String,
    /// Rendered error.
    pub error: String,
}

impl std::fmt::Display for CellFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cell {} ({}): {}", self.index, self.label, self.error)
    }
}

/// Scoped-thread worker pool over independent experiment cells.
#[derive(Debug, Clone)]
pub struct ExperimentEngine {
    jobs: usize,
}

impl ExperimentEngine {
    /// Pool with exactly `jobs` workers (0 is clamped to 1).
    pub fn new(jobs: usize) -> Self {
        ExperimentEngine { jobs: jobs.max(1) }
    }

    /// Serial engine: cells run in grid order on the calling thread.
    pub fn serial() -> Self {
        Self::new(1)
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    /// Worker count.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `f(0..n)` across the pool; slot `i` of the returned vector
    /// holds cell `i`'s result regardless of completion order. A cell
    /// that returns `Err` (or panics) fills its slot with the error and
    /// the sweep continues.
    ///
    /// The DESIGN.md §Concurrency contract, executable — slot
    /// stability and failing-cell isolation:
    ///
    /// ```
    /// use tempo::coordinator::ExperimentEngine;
    ///
    /// let engine = ExperimentEngine::new(4);
    /// let cells = engine.run_cells(8, |i| {
    ///     if i == 3 {
    ///         Err(tempo::Error::Backend("cell 3 failed".into()))
    ///     } else {
    ///         Ok(i * i)
    ///     }
    /// });
    /// // slot i == cell i, for every --jobs setting
    /// assert_eq!(cells.len(), 8);
    /// assert_eq!(*cells[2].as_ref().unwrap(), 4);
    /// assert_eq!(*cells[7].as_ref().unwrap(), 49);
    /// // the failing cell fills its own slot; the sweep completed
    /// assert!(cells[3].is_err());
    /// ```
    pub fn run_cells<T, F>(&self, n: usize, f: F) -> Vec<Result<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        let run_one = |i: usize| -> Result<T> {
            match catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(r) => r,
                Err(payload) => Err(Error::Backend(format!(
                    "cell {i} panicked: {}",
                    panic_message(&*payload)
                ))),
            }
        };
        if self.jobs == 1 || n <= 1 {
            // Serial fast path: same slots, same order, no threads.
            return (0..n).map(run_one).collect();
        }
        let slots: Vec<Mutex<Option<Result<T>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.jobs.min(n);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let result = run_one(i);
                    *slots[i].lock().unwrap() = Some(result);
                });
            }
        });
        slots
            .into_iter()
            .enumerate()
            .map(|(i, slot)| match slot.into_inner() {
                Ok(Some(r)) => r,
                _ => Err(Error::Backend(format!("cell {i} produced no result"))),
            })
            .collect()
    }
}

impl Default for ExperimentEngine {
    fn default() -> Self {
        Self::auto()
    }
}

/// Split cell results into in-order successes and captured failures.
pub fn partition_cells<T>(
    results: Vec<Result<T>>,
    label: impl Fn(usize) -> String,
) -> (Vec<T>, Vec<CellFailure>) {
    let mut ok = Vec::new();
    let mut failures = Vec::new();
    for (index, r) in results.into_iter().enumerate() {
        match r {
            Ok(v) => ok.push(v),
            Err(e) => failures.push(CellFailure {
                index,
                label: label(index),
                error: e.to_string(),
            }),
        }
    }
    (ok, failures)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_land_in_grid_order() {
        for jobs in [1usize, 4] {
            let engine = ExperimentEngine::new(jobs);
            let out = engine.run_cells(16, |i| Ok(i * i));
            let vals: Vec<usize> = out.into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(vals, (0..16).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn failing_cell_does_not_abort_sweep() {
        let engine = ExperimentEngine::new(4);
        let out = engine.run_cells(5, |i| {
            if i == 2 {
                Err(Error::Invalid("boom".into()))
            } else {
                Ok(i)
            }
        });
        assert!(out[2].is_err());
        for (i, r) in out.iter().enumerate() {
            if i != 2 {
                assert_eq!(*r.as_ref().unwrap(), i);
            }
        }
    }

    #[test]
    fn panicking_cell_is_captured() {
        let engine = ExperimentEngine::new(2);
        let out = engine.run_cells(3, |i| {
            if i == 1 {
                panic!("deliberate test panic");
            }
            Ok(i)
        });
        assert_eq!(*out[0].as_ref().unwrap(), 0);
        assert_eq!(*out[2].as_ref().unwrap(), 2);
        let msg = out[1].as_ref().unwrap_err().to_string();
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("deliberate test panic"), "{msg}");
    }

    #[test]
    fn jobs_are_clamped_and_reported() {
        assert_eq!(ExperimentEngine::new(0).jobs(), 1);
        assert_eq!(ExperimentEngine::serial().jobs(), 1);
        assert!(ExperimentEngine::auto().jobs() >= 1);
    }

    #[test]
    fn partition_keeps_order_and_labels() {
        let results: Vec<Result<usize>> = vec![
            Ok(10),
            Err(Error::Invalid("x".into())),
            Ok(30),
            Err(Error::Backend("y".into())),
        ];
        let (ok, failures) = partition_cells(results, |i| format!("cell-{i}"));
        assert_eq!(ok, vec![10, 30]);
        assert_eq!(failures.len(), 2);
        assert_eq!(failures[0].index, 1);
        assert_eq!(failures[0].label, "cell-1");
        assert!(failures[0].error.contains("x"));
        assert_eq!(failures[1].index, 3);
    }

    #[test]
    fn empty_grid_is_fine() {
        let out = ExperimentEngine::new(4).run_cells(0, |_| Ok(0u8));
        assert!(out.is_empty());
    }

    #[test]
    fn backends_are_engine_shareable() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<crate::runtime::SimBackend>();
        assert_send_sync::<crate::runtime::SimProgram>();
        assert_send_sync::<ExperimentEngine>();
    }
}
