//! Training metrics: per-step records, EMA loss, throughput tracking.

use std::time::Duration;

use crate::tensor::OnlineStats;

/// One logged training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Global step counter.
    pub step: i64,
    /// Training loss at this step.
    pub loss: f64,
    /// Learning rate applied.
    pub lr: f64,
    /// Wall-clock (or modeled) step latency.
    pub step_time: Duration,
}

/// Rolling training metrics.
#[derive(Debug, Clone)]
pub struct Metrics {
    records: Vec<StepRecord>,
    ema_loss: Option<f64>,
    ema_alpha: f64,
    step_stats: OnlineStats,
    batch_size: usize,
}

impl Metrics {
    /// Empty metrics for a run at `batch_size`.
    pub fn new(batch_size: usize) -> Self {
        Metrics {
            records: Vec::new(),
            ema_loss: None,
            ema_alpha: 0.05,
            step_stats: OnlineStats::new(),
            batch_size,
        }
    }

    /// Record one step.
    pub fn push(&mut self, rec: StepRecord) {
        self.ema_loss = Some(match self.ema_loss {
            None => rec.loss,
            Some(prev) => prev + self.ema_alpha * (rec.loss - prev),
        });
        self.step_stats.push(rec.step_time.as_secs_f64());
        self.records.push(rec);
    }

    /// All recorded steps, in order.
    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    /// Exponential-moving-average loss, if any step was recorded.
    pub fn ema_loss(&self) -> Option<f64> {
        self.ema_loss
    }

    /// Loss of the most recent step.
    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean sequences/second across recorded steps.
    pub fn throughput(&self) -> f64 {
        let m = self.step_stats.mean();
        if m > 0.0 {
            self.batch_size as f64 / m
        } else {
            0.0
        }
    }

    /// Mean step latency across recorded steps.
    pub fn mean_step_time(&self) -> Duration {
        Duration::from_secs_f64(self.step_stats.mean())
    }

    /// Dump as CSV text (step,loss,lr,step_time_s).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,lr,step_time_s\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.8},{:.6}\n",
                r.step,
                r.loss,
                r.lr,
                r.step_time.as_secs_f64()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: i64, loss: f64) -> StepRecord {
        StepRecord { step, loss, lr: 1e-3, step_time: Duration::from_millis(10) }
    }

    #[test]
    fn ema_tracks_loss() {
        let mut m = Metrics::new(8);
        for i in 0..100 {
            m.push(rec(i, 10.0 - 0.05 * i as f64));
        }
        let ema = m.ema_loss().unwrap();
        let last = m.last_loss().unwrap();
        assert!(ema > last); // EMA lags a falling curve
        assert!(ema < 10.0);
    }

    #[test]
    fn throughput_from_step_time() {
        let mut m = Metrics::new(4);
        m.push(rec(0, 1.0));
        let thr = m.throughput();
        assert!((thr - 400.0).abs() < 1.0, "{thr}"); // 4 seqs / 10 ms
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = Metrics::new(1);
        m.push(rec(0, 2.5));
        m.push(rec(1, 2.25));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 3);
    }
}
